# Build entry points. The rust crate is self-contained (vendored
# `anyhow` + PJRT shim under rust/vendor/); `artifacts` needs a python
# with jax to AOT-lower the models, and is optional — everything else
# (tests, serve bench with the no-op executor, cache studies) runs
# without it.

.PHONY: build test artifacts data serve-bench clean

build:
	cargo build --release

test:
	cargo test -q

# AOT-lower the JAX models to artifacts/*.hlo.txt + manifest.json
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

# Materialize the synthetic datasets into data/*.bin
data: build
	cargo run --release --bin comm-rand -- gen-data

# Quick online-serving benchmark on the tiny preset
serve-bench: build
	cargo run --release --bin comm-rand -- serve bench tiny

clean:
	rm -rf target
