//! End-to-end driver (EXPERIMENTS.md §E2E): trains GraphSAGE on the
//! reddit stand-in (32k nodes, ~0.7M edges, 41 classes) to
//! convergence with early stopping, logging the full loss curve, for
//! both the uniform baseline and the paper's best COMM-RAND knobs.
//!
//!     cargo run --release --example train_reddit_sim [epochs=N]

use comm_rand::config::{preset, BatchPolicy, TrainConfig};
use comm_rand::sampler::RootPolicy;
use comm_rand::train::{self, Method, RunOptions, Session};
use comm_rand::util::json::{self, Json};

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args()
        .find_map(|a| a.strip_prefix("epochs=").map(|v| v.parse().unwrap()))
        .unwrap_or(40);
    let p = preset("reddit_sim").unwrap();
    let ds = train::dataset::load_or_build(&p, true)?;
    println!(
        "reddit_sim: {} nodes, {} edges, {} train / {} val, {} communities",
        ds.n(),
        ds.csr.num_directed_edges() / 2,
        ds.train_nodes().len(),
        ds.val_nodes().len(),
        ds.num_comms
    );
    let mut session = Session::new()?;
    let cfg = TrainConfig { max_epochs: epochs, ..Default::default() };
    let opts = RunOptions { verbose: true, ..Default::default() };

    let mut results = Vec::new();
    for (name, method) in [
        ("baseline", Method::CommRand(BatchPolicy::baseline())),
        (
            "comm-rand",
            Method::CommRand(BatchPolicy {
                roots: RootPolicy::CommRandMix { pct: 0.125 },
                p_intra: 1.0,
            }),
        ),
    ] {
        println!("=== {name} ===");
        let r = train::train(&mut session, &ds, p.artifact, &method, &cfg, &opts)?;
        println!("{}", r.summary());
        println!("loss curve (train): {:?}",
            r.epochs.iter().map(|e| (e.train_loss * 1e3).round() / 1e3)
                .collect::<Vec<_>>());
        results.push((name, r));
    }

    let (b, c) = (&results[0].1, &results[1].1);
    println!("\n=== headline ===");
    println!(
        "per-epoch modeled speedup : {:.2}x",
        b.mean_epoch_modeled_s() / c.mean_epoch_modeled_s()
    );
    println!(
        "per-epoch wall speedup    : {:.2}x",
        b.mean_epoch_wall_s() / c.mean_epoch_wall_s()
    );
    println!(
        "epochs to converge        : {} -> {}",
        b.converged_epoch, c.converged_epoch
    );
    println!(
        "total modeled speedup     : {:.2}x",
        b.modeled_to_convergence() / c.modeled_to_convergence()
    );
    println!(
        "best val acc              : {:.4} -> {:.4} (Δ {:.2} pts)",
        b.best_val_acc,
        c.best_val_acc,
        (b.best_val_acc - c.best_val_acc) * 100.0
    );

    std::fs::create_dir_all("results")?;
    let out = Json::Arr(results.iter().map(|(_, r)| r.to_json()).collect());
    std::fs::write("results/e2e_reddit_sim.json", out.to_string_pretty())?;
    println!("\nwrote results/e2e_reddit_sim.json");
    let _ = json::num(0.0); // keep util linked in doc example
    Ok(())
}
