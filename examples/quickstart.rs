//! Quickstart: train GraphSAGE on the tiny dataset with COMM-RAND
//! mini-batching and compare against the uniform-random baseline.
//!
//!     make artifacts && cargo run --release --example quickstart

use comm_rand::config::{preset, BatchPolicy, TrainConfig};
use comm_rand::sampler::RootPolicy;
use comm_rand::train::{self, Method, RunOptions, Session};

fn main() -> anyhow::Result<()> {
    // 1. materialize (or load) the dataset: synthetic community graph,
    //    Louvain-detected communities, community-sorted node order
    let p = preset("tiny").unwrap();
    let ds = train::dataset::load_or_build(&p, true)?;
    println!(
        "dataset {}: {} nodes, {} communities",
        ds.name,
        ds.n(),
        ds.num_comms
    );

    // 2. a shared session compiles each artifact once
    let mut session = Session::new()?;
    let cfg = TrainConfig { max_epochs: 15, ..Default::default() };
    let opts = RunOptions::default();

    // 3. uniform-random baseline (RAND-ROOTS, p = 0.5)
    let base = train::train(
        &mut session,
        &ds,
        p.artifact,
        &Method::CommRand(BatchPolicy::baseline()),
        &cfg,
        &opts,
    )?;
    println!("baseline : {}", base.summary());

    // 4. COMM-RAND: community-block shuffling with 12.5% mixing and
    //    full intra-community bias (the paper's best knobs)
    let cr = train::train(
        &mut session,
        &ds,
        p.artifact,
        &Method::CommRand(BatchPolicy {
            roots: RootPolicy::CommRandMix { pct: 0.125 },
            p_intra: 1.0,
        }),
        &cfg,
        &opts,
    )?;
    println!("comm-rand: {}", cr.summary());

    let speedup = base.mean_epoch_modeled_s() / cr.mean_epoch_modeled_s();
    println!(
        "\nper-epoch modeled speedup: {speedup:.2}x  \
         (accuracy {:.4} vs {:.4})",
        cr.best_val_acc, base.best_val_acc
    );
    Ok(())
}
