//! Cache-capacity sensitivity (paper §6.5.2, Fig. 10): per-epoch
//! modeled speedup of COMM-RAND configurations as the simulated L2
//! shrinks from 40MB to 10MB.
//!
//!     cargo run --release --example cache_sensitivity [preset]

use comm_rand::config::{preset, BatchPolicy, TrainConfig};
use comm_rand::sampler::RootPolicy;
use comm_rand::train::{self, Method, RunOptions, Session};

fn main() -> anyhow::Result<()> {
    let name = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "tiny".into());
    let p = preset(&name).expect("unknown preset");
    let ds = train::dataset::load_or_build(&p, true)?;
    let mut session = Session::new()?;
    // epoch-time measurement only: few epochs, no early stop pressure
    let cfg = TrainConfig { max_epochs: 3, ..Default::default() };

    let policies = [
        ("baseline", BatchPolicy::baseline()),
        (
            "MIX-50%+p1.0",
            BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.5 }, p_intra: 1.0 },
        ),
        (
            "MIX-12.5%+p1.0",
            BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.125 }, p_intra: 1.0 },
        ),
        (
            "MIX-0%+p1.0",
            BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.0 }, p_intra: 1.0 },
        ),
    ];

    println!("{:<16} {:>10} {:>10} {:>10}", "policy", "40MB", "20MB", "10MB");
    let mut base: Vec<f64> = Vec::new();
    for (label, pol) in &policies {
        let mut row = Vec::new();
        for (i, scale) in [1.0, 0.5, 0.25].into_iter().enumerate() {
            let opts = RunOptions { l2_scale: scale, ..Default::default() };
            let r = train::train(
                &mut session,
                &ds,
                p.artifact,
                &Method::CommRand(pol.clone()),
                &cfg,
                &opts,
            )?;
            let t = r.mean_epoch_modeled_s();
            if *label == "baseline" {
                base.push(t);
                row.push(1.0);
            } else {
                row.push(base[i] / t);
            }
        }
        println!(
            "{:<16} {:>9.2}x {:>9.2}x {:>9.2}x",
            label, row[0], row[1], row[2]
        );
    }
    Ok(())
}
