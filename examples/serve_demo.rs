//! Online-serving demo: stand up the serving engine on the tiny
//! dataset and replay the same Zipf trace with the community-bias knob
//! at both extremes — pure-FIFO coalescing (p=0) vs pure
//! community-grouped coalescing (p=1) — printing throughput, tail
//! latency and the feature-cache hit rate each way.
//!
//! With `shards=N` the engine partitions communities across N logical
//! device shards (each with its own worker pool and feature cache) and
//! routes every micro-batch to the shard owning its community;
//! `spill=strict|steal|broadcast` picks the cross-shard policy and the
//! demo prints the per-shard breakdown.
//!
//! With `arrival=poisson:RATE` the trace is issued open-loop at a
//! fixed offered rate instead of closed-loop self-pacing — push RATE
//! past what your machine sustains and watch p99 climb; add
//! `admission=reject` (or `degrade`) to see the deadline-aware gate
//! shed (or fanout-degrade) the unmeetable requests instead.
//!
//! With `ckpt=PATH` (a checkpoint file, or a directory whose newest
//! checkpoint wins) the engine installs trained parameters before the
//! first request, so the printed reports carry real top-1 accuracy;
//! add `cache_warm=1` to pre-stage the checkpoint's hot feature rows.
//! Train one first with
//! `comm-rand train tiny backend=host ckpt_dir=ckpts`.
//!
//! Runs with or without AOT artifacts (`make artifacts`): without them
//! the pure-rust host executor still produces real logits, so the
//! whole queue → admit → coalesce → route → cache → assemble → infer
//! path is exercised.
//!
//!     cargo run --release --example serve_demo [preset] [requests=N] \
//!         [shards=N] [spill=strict|steal|broadcast] \
//!         [arrival=closed|poisson:RATE] [admission=none|reject|degrade] \
//!         [ckpt=PATH] [cache_warm=1]

use comm_rand::config::preset;
use comm_rand::serve::{
    engine, AdmissionPolicy, Arrival, LoadConfig, ServeConfig, SpillPolicy,
};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args
        .iter()
        .find(|a| !a.contains('='))
        .cloned()
        .unwrap_or_else(|| "tiny".into());
    let requests: usize = args
        .iter()
        .find_map(|a| a.strip_prefix("requests=").map(|v| v.parse().unwrap()))
        .unwrap_or(200);
    let shards: usize = args
        .iter()
        .find_map(|a| a.strip_prefix("shards=").map(|v| v.parse().unwrap()))
        .unwrap_or(1);
    let spill = args
        .iter()
        .find_map(|a| a.strip_prefix("spill="))
        .map(SpillPolicy::parse)
        .transpose()?
        .unwrap_or(SpillPolicy::Strict);
    let arrival = args
        .iter()
        .find_map(|a| a.strip_prefix("arrival="))
        .map(Arrival::parse)
        .transpose()?
        .unwrap_or(Arrival::Closed);
    let admission = args
        .iter()
        .find_map(|a| a.strip_prefix("admission="))
        .map(AdmissionPolicy::parse)
        .transpose()?
        .unwrap_or(AdmissionPolicy::None);
    let ckpt = args
        .iter()
        .find_map(|a| a.strip_prefix("ckpt="))
        .map(std::path::PathBuf::from);
    let cache_warm = args
        .iter()
        .any(|a| a == "cache_warm=1");

    let p = preset(&name).expect("unknown preset");
    let ds = comm_rand::train::dataset::load_or_build(&p, true)?;
    println!(
        "serving {}: {} nodes, {} communities, feat dim {}, {} shard(s), \
         spill {}, arrival {}, admission {}",
        ds.name,
        ds.n(),
        ds.num_comms,
        ds.feat_dim,
        shards.max(1),
        spill.name(),
        arrival.label(),
        admission.name(),
    );

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.shards = shards.max(1);
    scfg.spill = spill;
    scfg.admission = admission;
    scfg.ckpt = ckpt;
    scfg.cache_warm = cache_warm;
    let lcfg = LoadConfig {
        clients: 8,
        requests_per_client: (requests / 8).max(1),
        zipf_s: 1.1,
        arrival,
        seed: 1,
    };
    let (exec, meta) = engine::build_executor(&p, &ds, &scfg);

    let mut reports = Vec::new();
    for bias in [0.0, 1.0] {
        let cfg = ServeConfig { community_bias: bias, ..scfg.clone() };
        let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &lcfg)?;
        println!("{}", rep.summary());
        if rep.n_shards > 1 {
            for sh in &rep.shards {
                println!(
                    "  shard {}: {} comms / {} nodes owned | {} req \
                     ({} foreign, {} shed, {} degraded) | p99 {:.2} ms | \
                     cache hit {:.1}%",
                    sh.id,
                    sh.owned_comms,
                    sh.owned_nodes,
                    sh.requests,
                    sh.foreign_requests,
                    sh.shed,
                    sh.degraded,
                    sh.lat_p99_ms,
                    sh.cache_hit_rate * 100.0,
                );
            }
        }
        reports.push(rep);
    }

    let (fifo, comm) = (&reports[0], &reports[1]);
    println!(
        "\ncommunity grouping (p=1) vs FIFO (p=0): cache hit rate \
         {:.1}% -> {:.1}%, p99 {:.2}ms -> {:.2}ms",
        fifo.cache_hit_rate * 100.0,
        comm.cache_hit_rate * 100.0,
        fifo.lat_p99_ms,
        comm.lat_p99_ms,
    );
    if comm.evaluated > 0 {
        println!(
            "top-1 accuracy (param version {}): {:.1}% over {} replies",
            comm.param_version,
            comm.accuracy * 100.0,
            comm.evaluated,
        );
    }
    if fifo.shed + comm.shed > 0 {
        println!(
            "shed (admission {} / drop-tail): {:.1}% at p=0, {:.1}% at p=1",
            admission.name(),
            fifo.shed_rate * 100.0,
            comm.shed_rate * 100.0,
        );
    }
    Ok(())
}
