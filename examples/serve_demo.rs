//! Online-serving demo: stand up the serving engine on the tiny
//! dataset and replay the same Zipf closed-loop trace with the
//! community-bias knob at both extremes — pure-FIFO coalescing (p=0)
//! vs pure community-grouped coalescing (p=1) — printing throughput,
//! tail latency and the feature-cache hit rate each way.
//!
//! Runs with or without AOT artifacts (`make artifacts`): without them
//! a no-op executor still exercises queue → coalesce → cache →
//! assemble.
//!
//!     cargo run --release --example serve_demo [preset] [p=F] [requests=N]

use comm_rand::config::preset;
use comm_rand::serve::{engine, LoadConfig, ServeConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args
        .iter()
        .find(|a| !a.contains('='))
        .cloned()
        .unwrap_or_else(|| "tiny".into());
    let requests: usize = args
        .iter()
        .find_map(|a| a.strip_prefix("requests=").map(|v| v.parse().unwrap()))
        .unwrap_or(200);

    let p = preset(&name).expect("unknown preset");
    let ds = comm_rand::train::dataset::load_or_build(&p, true)?;
    println!(
        "serving {}: {} nodes, {} communities, feat dim {}",
        ds.name,
        ds.n(),
        ds.num_comms,
        ds.feat_dim
    );

    let scfg = ServeConfig::for_dataset(&ds);
    let lcfg = LoadConfig {
        clients: 8,
        requests_per_client: (requests / 8).max(1),
        zipf_s: 1.1,
        seed: 1,
    };
    let (exec, meta) = engine::build_executor(&p, &ds, &scfg);

    let mut reports = Vec::new();
    for bias in [0.0, 1.0] {
        let cfg = ServeConfig { community_bias: bias, ..scfg.clone() };
        let rep = engine::run(&ds, &meta, exec.as_ref(), &cfg, &lcfg)?;
        println!("{}", rep.summary());
        reports.push(rep);
    }

    let (fifo, comm) = (&reports[0], &reports[1]);
    println!(
        "\ncommunity grouping (p=1) vs FIFO (p=0): cache hit rate \
         {:.1}% -> {:.1}%, p99 {:.2}ms -> {:.2}ms",
        fifo.cache_hit_rate * 100.0,
        comm.cache_hit_rate * 100.0,
        fifo.lat_p99_ms,
        comm.lat_p99_ms,
    );
    Ok(())
}
