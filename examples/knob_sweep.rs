//! COMM-RAND knob exploration on one dataset: sweeps the root
//! partitioning policies (Table 1) against intra-community sampling
//! probabilities p ∈ {0.5, 0.9, 1.0} and prints the Figure-5-style
//! metric grid (a fast, single-seed version of `comm-rand exp fig5`).
//!
//!     cargo run --release --example knob_sweep [preset] [epochs=N]

use comm_rand::config::{preset, BatchPolicy, TrainConfig};
use comm_rand::sampler::RootPolicy;
use comm_rand::train::{self, Method, RunOptions, Session};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args
        .iter()
        .find(|a| !a.contains('='))
        .cloned()
        .unwrap_or_else(|| "tiny".into());
    let epochs: usize = args
        .iter()
        .find_map(|a| a.strip_prefix("epochs=").map(|v| v.parse().unwrap()))
        .unwrap_or(12);

    let p = preset(&name).expect("unknown preset");
    let ds = train::dataset::load_or_build(&p, true)?;
    let mut session = Session::new()?;
    let cfg = TrainConfig { max_epochs: epochs, ..Default::default() };
    let opts = RunOptions::default();

    let baseline = train::train(
        &mut session,
        &ds,
        p.artifact,
        &Method::CommRand(BatchPolicy::baseline()),
        &cfg,
        &opts,
    )?;
    let base_epoch = baseline.mean_epoch_modeled_s();

    println!(
        "{:<22} {:>6} {:>10} {:>10} {:>8} {:>8}",
        "roots", "p", "epoch-spd", "conv-ep", "val-acc", "net-spd"
    );
    for roots in RootPolicy::figure5_set() {
        for p_intra in [0.5, 0.9, 1.0] {
            let r = if roots == RootPolicy::Rand && p_intra == 0.5 {
                baseline.clone()
            } else {
                train::train(
                    &mut session,
                    &ds,
                    p.artifact,
                    &Method::CommRand(BatchPolicy { roots, p_intra }),
                    &cfg,
                    &opts,
                )?
            };
            println!(
                "{:<22} {:>6.2} {:>9.2}x {:>10} {:>8.4} {:>7.2}x",
                roots.label(),
                p_intra,
                base_epoch / r.mean_epoch_modeled_s(),
                r.converged_epoch,
                r.best_val_acc,
                baseline.modeled_to_convergence() / r.modeled_to_convergence(),
            );
        }
    }
    Ok(())
}
