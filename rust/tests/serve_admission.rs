//! Integration tests for deadline-aware admission control under
//! open-loop (Poisson) load.
//!
//! Like `serve_shard.rs`, these need no AOT artifacts and no real
//! PJRT: the no-op executor exercises the whole pipeline — admission
//! gate → queue → micro-batcher → shard router → worker pools — on the
//! synthetic tiny dataset, so they run everywhere `cargo test` does.
//!
//! The two load points bracket saturation deliberately: the low-rate
//! run offers a few hundred req/s against a deliberately roomy queue
//! and a multi-second deadline, so shedding anything would be a bug;
//! the overload run offers the whole trace effectively at once against
//! a small queue, so *not* shedding would mean the bounded queue (or
//! the feasibility check) failed to protect the server.

use comm_rand::config::preset;
use comm_rand::serve::engine::{self, synthetic_infer_meta};
use comm_rand::serve::{
    AdmissionPolicy, Arrival, LoadConfig, NullExecutor, ServeConfig,
};

fn tiny_dataset() -> comm_rand::graph::Dataset {
    comm_rand::train::dataset::build(&preset("tiny").unwrap(), true)
}

/// Below saturation with `admission=reject`, essentially nothing is
/// shed and every issued request is accounted for.
#[test]
fn low_offered_load_sheds_nothing() {
    let ds = tiny_dataset();
    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = 16;
    scfg.max_delay_us = 1_000;
    // generous deadline: feasibility can only fail under real backlog
    scfg.deadline_us = 2_000_000;
    scfg.workers = 2;
    scfg.queue_cap = 1024;
    scfg.fanouts = vec![5, 5];
    scfg.admission = AdmissionPolicy::Reject;
    scfg.seed = 31;
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let exec = NullExecutor { num_classes: ds.num_classes };
    let issued = 100usize;
    let lcfg = LoadConfig {
        clients: 4,
        requests_per_client: issued / 4,
        zipf_s: 1.1,
        // ~0.25 s of trace at 400 req/s: far below what even one
        // no-op worker sustains
        arrival: Arrival::Poisson { rate_rps: 400.0 },
        seed: 17,
    };
    let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
    assert_eq!(rep.requests + rep.shed, issued, "requests lost");
    assert_eq!(rep.errors, 0);
    assert!(
        rep.shed_rate < 0.05,
        "shed rate {:.3} at trivially low offered load",
        rep.shed_rate
    );
    assert!(rep.requests >= issued * 95 / 100);
}

/// Far past saturation with a small queue and tight deadlines,
/// `admission=reject` sheds a nonzero share of the trace — and still
/// accounts for every issued request (completed + shed = issued).
#[test]
fn overload_sheds_past_saturation() {
    let ds = tiny_dataset();
    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = 8;
    scfg.max_delay_us = 500;
    // tight deadline: once the EWMA warms up, a backlog of a few
    // batches is already infeasible
    scfg.deadline_us = 2_000;
    scfg.workers = 1;
    // small queue: even before the EWMA warms up, drop-tail protects
    // the server, so shed > 0 does not depend on timing
    scfg.queue_cap = 32;
    scfg.fanouts = vec![5, 5];
    scfg.admission = AdmissionPolicy::Reject;
    scfg.seed = 33;
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let exec = NullExecutor { num_classes: ds.num_classes };
    let issued = 800usize;
    let lcfg = LoadConfig {
        clients: 4,
        requests_per_client: issued / 4,
        zipf_s: 1.1,
        // the whole trace arrives in ~1 ms of offered-load time:
        // hundreds of times past saturation by construction
        arrival: Arrival::Poisson { rate_rps: 1_000_000.0 },
        seed: 19,
    };
    let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
    assert_eq!(rep.requests + rep.shed, issued, "requests lost");
    assert_eq!(rep.errors, 0);
    assert!(
        rep.shed > 0,
        "no shedding at 1M offered req/s against a 32-deep queue"
    );
    // the per-shard counters carry the same totals as the rollup
    let shard_shed: usize = rep.shards.iter().map(|sh| sh.shed).sum();
    assert_eq!(shard_shed, rep.shed);
    // completed requests (if any) have sane percentiles
    if rep.requests > 0 {
        assert!(rep.lat_p50_ms <= rep.lat_p99_ms);
        assert!(rep.lat_p99_ms.is_finite());
    }
    // the JSON report carries the admission fields
    let json = rep.to_json().to_string_pretty();
    assert!(json.contains("\"shed\""));
    assert!(json.contains("shed_rate"));
    assert!(json.contains("poisson:1000000"));
    assert!(json.contains("\"admission\""));
}

/// `degrade` under the same overload answers every request it admits
/// with shrunken fanout instead of erroring, and `none` at low load
/// behaves exactly like no admission layer at all.
#[test]
fn degrade_and_none_policies_run_open_loop() {
    let ds = tiny_dataset();
    let meta = synthetic_infer_meta(&ds, 8, &[5, 5]);
    let exec = NullExecutor { num_classes: ds.num_classes };
    for policy in [AdmissionPolicy::Degrade, AdmissionPolicy::None] {
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 8;
        scfg.max_delay_us = 500;
        scfg.deadline_us = 5_000;
        scfg.workers = 1;
        scfg.queue_cap = 256;
        scfg.fanouts = vec![5, 5];
        scfg.admission = policy;
        scfg.seed = 35;
        let issued = 200usize;
        let lcfg = LoadConfig {
            clients: 2,
            requests_per_client: issued / 2,
            zipf_s: 1.1,
            arrival: Arrival::Poisson { rate_rps: 50_000.0 },
            seed: 23,
        };
        let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        assert_eq!(
            rep.requests + rep.shed,
            issued,
            "policy={}: requests lost",
            policy.name()
        );
        assert_eq!(rep.errors, 0, "policy={}", policy.name());
        match policy {
            // degrade never admission-sheds; only queue-full drop-tail
            // can shed, and admitted requests may carry capped fanouts
            AdmissionPolicy::Degrade => {
                assert_eq!(rep.admission, "degrade");
            }
            AdmissionPolicy::None => {
                assert_eq!(rep.admission, "none");
                assert_eq!(rep.degraded, 0, "none must never degrade");
            }
            AdmissionPolicy::Reject => unreachable!(),
        }
    }
}
