//! Integration tests for the temporal health layer: windowed series,
//! SLO burn-rate alerting, the liveness watchdog, and the flight
//! recorder, exercised through the full serving pipeline.
//!
//! Like `serve_admission.rs`, these need no AOT artifacts and no real
//! PJRT: the no-op executor drives the whole engine — admission gate →
//! queue → micro-batcher → shard router → worker pools → telemetry
//! thread — on the synthetic tiny dataset, so they run everywhere
//! `cargo test` does.
//!
//! The two runs bracket the alerting decision deliberately: the
//! overload run offers the trace hundreds of times past saturation
//! against a tight SLO, so an alert *must* fire and the flight
//! recorder *must* publish a postmortem bundle; the low-rate run pairs
//! trivial load with the default SLO, so a single transition would be
//! a false positive.

use comm_rand::config::preset;
use comm_rand::obs::{read_postmortem, SloSpec};
use comm_rand::serve::engine::{self, synthetic_infer_meta};
use comm_rand::serve::{
    AdmissionPolicy, Arrival, LoadConfig, NullExecutor, ServeConfig,
};

fn tiny_dataset() -> comm_rand::graph::Dataset {
    comm_rand::train::dataset::build(&preset("tiny").unwrap(), true)
}

fn scratch(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join(format!("comm_rand_health_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Far past saturation with a tight SLO: the shed-rate burn breaches
/// immediately, the alert fires, the fire transition lands in the
/// Chrome trace, and the flight recorder publishes a postmortem bundle
/// that survives a full re-parse.
#[test]
fn overload_fires_alert_and_dumps_postmortem() {
    let dir = scratch("overload");
    let trace_path = dir.join("trace.json");
    let ds = tiny_dataset();
    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = 8;
    scfg.max_delay_us = 500;
    scfg.deadline_us = 2_000;
    scfg.workers = 1;
    scfg.queue_cap = 32;
    scfg.fanouts = vec![5, 5];
    scfg.admission = AdmissionPolicy::Reject;
    scfg.seed = 41;
    scfg.health_ms = 5;
    // shed budget 2%: the drop-tail queue under 200k offered req/s
    // burns it orders of magnitude faster than `burn=1`
    scfg.slo = Some(
        SloSpec::parse("shed=0.02,fast=1,slow=2,burn=1,clear=2").unwrap(),
    );
    scfg.flight = Some(dir.clone());
    scfg.trace = Some(trace_path.clone());
    scfg.trace_sample = 1000;
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let exec = NullExecutor { num_classes: ds.num_classes };
    let issued = 1200usize;
    let lcfg = LoadConfig {
        clients: 4,
        requests_per_client: issued / 4,
        zipf_s: 1.1,
        arrival: Arrival::Poisson { rate_rps: 200_000.0 },
        seed: 29,
    };
    let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
    assert_eq!(rep.requests + rep.shed, issued, "requests lost");
    assert!(rep.shed > 0, "overload run shed nothing");
    assert!(rep.unjoined_threads.is_empty(), "{:?}", rep.unjoined_threads);

    let health = rep.health.as_ref().expect("health_ms > 0 must report");
    assert!(health.windows_sealed >= 1);
    let shed_alert = health
        .alerts
        .iter()
        .find(|a| a.slo == "shed_rate")
        .expect("shed_rate target present");
    assert!(
        shed_alert.fired > 0,
        "shed alert never fired: burn_fast={} burn_slow={}",
        shed_alert.burn_fast,
        shed_alert.burn_slow
    );
    let breach = shed_alert.first_breach_us.expect("breach timestamp");
    let fire = shed_alert.first_fire_us.expect("fire timestamp");
    assert!(fire >= breach, "fire {fire} before breach {breach}");
    assert!(health.transitions >= 1);

    // flight recorder: a bundle was published and re-parses cleanly
    assert!(
        !health.postmortems.is_empty(),
        "alert fired but no postmortem bundle"
    );
    let bundle = read_postmortem(&health.postmortems[0]).unwrap();
    assert!(bundle.reason.starts_with("slo-") || bundle.reason.starts_with("stall-"));
    assert!(bundle.windows >= 1);
    assert!(bundle.alert_transitions >= 1);

    // the fire transition is visible in the exported Chrome trace
    let trace = std::fs::read_to_string(&trace_path).unwrap();
    assert!(
        trace.contains("slo_fire"),
        "trace has no slo_fire instant"
    );

    // the JSON report carries the health section end to end
    let json = rep.to_json().to_string_pretty();
    assert!(json.contains("\"health\""));
    assert!(json.contains("\"windows_sealed\""));
    assert!(json.contains("\"first_fire_us\""));
    assert!(json.contains("\"postmortems\""));

    let _ = std::fs::remove_dir_all(&dir);
}

/// Trivial load under the default SLO: any transition, stall, or
/// postmortem is a false positive, and shutdown joins every thread.
#[test]
fn low_load_default_slo_stays_quiet() {
    let dir = scratch("quiet");
    let ds = tiny_dataset();
    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = 16;
    scfg.max_delay_us = 1_000;
    scfg.deadline_us = 2_000_000;
    scfg.workers = 2;
    scfg.queue_cap = 1024;
    scfg.fanouts = vec![5, 5];
    scfg.seed = 43;
    scfg.health_ms = 5;
    scfg.slo = Some(SloSpec::parse("default").unwrap());
    scfg.flight = Some(dir.clone());
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let exec = NullExecutor { num_classes: ds.num_classes };
    let lcfg = LoadConfig {
        clients: 4,
        requests_per_client: 50,
        zipf_s: 1.1,
        arrival: Arrival::Closed,
        seed: 31,
    };
    let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
    assert_eq!(rep.requests, 200);
    assert!(rep.unjoined_threads.is_empty(), "{:?}", rep.unjoined_threads);

    let health = rep.health.as_ref().expect("health_ms > 0 must report");
    assert!(health.windows_sealed >= 1);
    assert_eq!(health.transitions, 0, "false positive under default SLO");
    assert!(health.alerts.iter().all(|a| !a.firing && a.fired == 0));
    assert!(health.stalled_threads.is_empty());
    assert!(
        health.postmortems.is_empty(),
        "flight recorder fired on a healthy run"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
