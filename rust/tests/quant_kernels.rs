//! Kernel-equivalence suite for the quantized inference path.
//!
//! The dispatch contract (`runtime::kernels`) is that every backend —
//! scalar, AVX2, and AVX-512 when compiled in — returns **bitwise
//! identical** i32 accumulators, so the `kernel=` knob is purely a
//! throughput choice. These tests pin that contract at three levels:
//!
//! 1. raw kernels over randomized shapes (non-lane-multiple feature
//!    dims, empty neighbor lists, full-range values that exercise the
//!    wrapping paths) against an independent naive reference;
//! 2. the host executor: the same quantized checkpoint installed under
//!    every runnable backend must serve bit-identical logits;
//! 3. a full serve bench: `kernel=scalar` forced vs `kernel=auto`
//!    must agree exactly on accuracy and evaluated count, because no
//!    per-request prediction may depend on the backend.

use comm_rand::batch::{BatchStats, PaddedBatch};
use comm_rand::ckpt::{quantize_checkpoint, Checkpoint, CkptMeta, ParamStore};
use comm_rand::config::{preset, TrainConfig};
use comm_rand::graph::Dataset;
use comm_rand::runtime::host;
use comm_rand::runtime::kernels::{
    accumulate_rows_i8, matvec_i16_i32, pad_to_lanes, KernelBackend, LANES,
};
use comm_rand::serve::engine::{self, synthetic_infer_meta};
use comm_rand::serve::{
    Arrival, HostExecutor, InferExecutor, LoadConfig, ServeConfig,
};
use comm_rand::train::train_host;

fn tiny_dataset() -> Dataset {
    comm_rand::train::dataset::build(&preset("tiny").unwrap(), true)
}

/// Deterministic 64-bit LCG so the randomized shapes need no rand
/// crate and reproduce across runs.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0
    }
    fn i16(&mut self) -> i16 {
        (self.next() >> 16) as i16
    }
    fn i8(&mut self) -> i8 {
        (self.next() >> 24) as i8
    }
    fn below(&mut self, n: usize) -> usize {
        ((self.next() >> 33) as usize) % n
    }
}

/// Raw matvec: every runnable backend reproduces an independently
/// written wrapping reference, bit for bit, across feature dims that
/// are *not* lane multiples (1, 7, 33, 129) and full-range i16 values
/// (so SIMD partial sums genuinely wrap).
#[test]
fn matvec_matches_naive_reference_on_random_shapes() {
    let mut rng = Lcg(0xC0FFEE);
    let backends = KernelBackend::all_available();
    assert!(backends.contains(&KernelBackend::Scalar));
    for feat in [1usize, 7, LANES, 33, 129] {
        for classes in [1usize, 3, 10] {
            let fp = pad_to_lanes(feat);
            // contract: the padded tail is zero (the executor zero-pads)
            let mut wt = vec![0i16; classes * fp];
            let mut x = vec![0i16; fp];
            for c in 0..classes {
                for k in 0..feat {
                    wt[c * fp + k] = rng.i16();
                }
            }
            for k in 0..feat {
                x[k] = rng.i16();
            }
            let bias: Vec<i32> =
                (0..classes).map(|_| rng.next() as i32).collect();
            // independent wrapping reference
            let want: Vec<i32> = (0..classes)
                .map(|c| {
                    let mut acc = bias[c];
                    for k in 0..fp {
                        acc = acc.wrapping_add(
                            (wt[c * fp + k] as i32)
                                .wrapping_mul(x[k] as i32),
                        );
                    }
                    acc
                })
                .collect();
            for &b in &backends {
                let mut out = vec![0i32; classes];
                matvec_i16_i32(b, &wt, &x, &bias, fp, &mut out);
                assert_eq!(
                    out,
                    want,
                    "{} diverges at feat={feat} classes={classes}",
                    b.name()
                );
            }
        }
    }
}

/// Raw row accumulation: empty node lists are a no-op, repeated nodes
/// count twice, and every backend accumulates *into* the seeded output
/// identically to the reference.
#[test]
fn accumulate_matches_naive_reference_on_random_shapes() {
    let mut rng = Lcg(0xB00C);
    let backends = KernelBackend::all_available();
    for feat in [1usize, 7, LANES, 33, 129] {
        let fp = pad_to_lanes(feat);
        let rows = 23usize;
        let mut table = vec![0i8; rows * fp];
        for r in 0..rows {
            for k in 0..feat {
                table[r * fp + k] = rng.i8();
            }
        }
        let seed: Vec<i32> = (0..fp).map(|_| rng.next() as i32).collect();
        let mut lists: Vec<Vec<u32>> = vec![
            vec![],                    // empty neighborhood
            vec![rows as u32 - 1],     // single row
            vec![4, 4, 4],             // multiplicity
        ];
        let long: Vec<u32> =
            (0..300).map(|_| rng.below(rows) as u32).collect();
        lists.push(long);
        for nodes in &lists {
            let mut want = seed.clone();
            for &v in nodes {
                for k in 0..fp {
                    want[k] = want[k]
                        .wrapping_add(table[v as usize * fp + k] as i32);
                }
            }
            for &b in &backends {
                let mut out = seed.clone();
                accumulate_rows_i8(b, &table, fp, nodes, &mut out);
                assert_eq!(
                    out,
                    want,
                    "{} diverges at feat={feat} nodes={:?}",
                    b.name(),
                    &nodes[..nodes.len().min(8)]
                );
            }
        }
    }
}

/// A roots-only batch (all the host executor reads) for driving
/// `InferExecutor::infer` directly.
fn roots_batch(roots: Vec<u32>) -> PaddedBatch {
    PaddedBatch {
        layers: vec![],
        roots,
        labels: vec![],
        lmask: vec![],
        x0: None,
        access_stream: vec![],
        stats: BatchStats::default(),
    }
}

/// Executor level: one quantized checkpoint installed under every
/// runnable backend serves **bit-identical logits** for every node.
#[test]
fn executors_agree_bitwise_across_backends() {
    let ds = tiny_dataset();
    let store = ParamStore::new();
    let shapes = host::param_shapes(ds.feat_dim, ds.num_classes);
    let meta = CkptMeta::for_run(&ds, "host-sgc", "t", 0, shapes);
    let params = host::init_params(ds.feat_dim, ds.num_classes, 99);
    let ck = Checkpoint::new(meta, params).unwrap();
    let qck = quantize_checkpoint(&ck).unwrap();
    let v = store.publish(qck, "mem".into());

    let roots: Vec<u32> = (0..ds.n() as u32).collect();
    let mut reference: Option<Vec<u32>> = None;
    for backend in KernelBackend::all_available() {
        let exec = HostExecutor::with_backend(&ds, 0, backend).unwrap();
        exec.try_install(&v).unwrap();
        let out = exec.infer(&roots_batch(roots.clone())).unwrap();
        assert_eq!(out.dtype, "i16q");
        assert_eq!(out.param_version, 1);
        assert_eq!(out.logits.len(), ds.n() * ds.num_classes);
        let bits: Vec<u32> =
            out.logits.iter().map(|x| x.to_bits()).collect();
        match &reference {
            None => reference = Some(bits),
            Some(want) => assert_eq!(
                &bits,
                want,
                "backend {} served different logits",
                backend.name()
            ),
        }
    }
}

/// Serve-bench level: the same trace served with `kernel=scalar`
/// forced and with `kernel=auto` must agree exactly — accuracy and
/// evaluated count — since logits are a pure function of (root,
/// installed params) and the kernels are bitwise equivalent.
#[test]
fn forced_scalar_serve_bench_matches_auto_exactly() {
    let ds = tiny_dataset();
    let dir = std::env::temp_dir()
        .join(format!("comm_rand_quant_e2e_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    // a trained quantized checkpoint, through the on-disk format
    let mut w = comm_rand::ckpt::CheckpointWriter::new(
        &dir,
        1,
        comm_rand::ckpt::Retention::BestAndLatest,
    )
    .unwrap();
    let tcfg = TrainConfig {
        batch_size: 256,
        lr: 0.5,
        max_epochs: 2,
        seed: 11,
        ..Default::default()
    };
    train_host(&ds, &tcfg, Some(&mut w), false).unwrap();
    let last = w.latest().unwrap().clone();
    let qck = quantize_checkpoint(&Checkpoint::load(&last.path).unwrap())
        .unwrap();
    let qpath = dir.join("ckpt-q.bin");
    qck.write_atomic(&qpath).unwrap();

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = 16;
    scfg.workers = 2;
    scfg.fanouts = vec![5, 5];
    scfg.ckpt = Some(qpath);
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let lcfg = LoadConfig {
        clients: 4,
        requests_per_client: 50,
        zipf_s: 1.1,
        arrival: Arrival::Closed,
        seed: 5,
    };

    let mut run_with = |backend: KernelBackend| {
        let exec = HostExecutor::with_backend(&ds, scfg.seed, backend)
            .unwrap();
        let cfg = ServeConfig {
            kernel: backend.name().to_string(),
            ..scfg.clone()
        };
        let rep = engine::run(&ds, &meta, &exec, &cfg, &lcfg).unwrap();
        assert_eq!(rep.requests, 200);
        assert_eq!(rep.errors, 0);
        assert_eq!(rep.param_version, 1);
        assert!(
            rep.execute.iter().any(|e| e.dtype == "i16q"),
            "quantized run must report i16q execute spans, got {:?}",
            rep.execute.iter().map(|e| e.dtype).collect::<Vec<_>>()
        );
        rep
    };
    let scalar = run_with(KernelBackend::Scalar);
    let auto = run_with(KernelBackend::detect());
    assert_eq!(
        (scalar.accuracy, scalar.evaluated),
        (auto.accuracy, auto.evaluated),
        "forced scalar and auto kernels must serve identical predictions"
    );
    std::fs::remove_dir_all(&dir).ok();
}
