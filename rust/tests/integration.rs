//! Integration tests across the full stack: dataset -> sampler ->
//! padded batch -> PJRT execution -> training dynamics.
//!
//! These need `make artifacts` (the tiny artifacts) and are skipped
//! with a clear message otherwise.

use comm_rand::batch::assemble;
use comm_rand::config::{preset, BatchPolicy, TrainConfig};
use comm_rand::runtime::artifact::{default_dir, Manifest};
use comm_rand::runtime::{Runtime, TrainState};
use comm_rand::sampler::{build_mfg, NeighborPolicy, RootPolicy};
use comm_rand::train::{self, Method, RunOptions, Session};
use comm_rand::util::rng::Rng;

/// These tests need both the tiny AOT artifacts and a real PJRT
/// runtime. They skip (rather than fail) when `make artifacts` hasn't
/// been run, and when the crate was built against the offline xla shim
/// (rust/vendor/xla), which cannot execute HLO.
fn have_artifacts() -> bool {
    if !default_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return false;
    }
    match Runtime::cpu() {
        Ok(rt) if rt.client.platform_name().contains("shim") => {
            eprintln!(
                "skipping: built against the offline xla shim \
                 (rust/vendor/xla); link a real xla-rs to run these"
            );
            false
        }
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping: PJRT cpu client unavailable: {e:#}");
            false
        }
    }
}

fn tiny_dataset() -> comm_rand::graph::Dataset {
    train::dataset::build(&preset("tiny").unwrap(), true)
}

#[test]
fn train_step_executes_and_learns() {
    if !have_artifacts() {
        return;
    }
    let ds = tiny_dataset();
    let manifest = Manifest::load(&default_dir()).unwrap();
    let train_meta = manifest.get("tiny.train").unwrap();
    let infer_meta = manifest.get("tiny.infer").unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut st =
        TrainState::new(&rt, train_meta, Some(infer_meta), Some(&ds), 1e-3, 1)
            .unwrap();
    let mut rng = Rng::new(3);
    let train_nodes = ds.train_nodes();
    let mut losses = Vec::new();
    for i in 0..12 {
        let roots: Vec<u32> = (0..128)
            .map(|_| train_nodes[rng.usize_below(train_nodes.len())])
            .collect();
        let mut roots = roots;
        roots.sort_unstable();
        roots.dedup();
        let mfg = build_mfg(
            &ds.csr,
            &ds.community,
            &roots,
            &train_meta.spec.fanouts,
            NeighborPolicy::Uniform,
            &mut rng,
        );
        let b = assemble(&mfg, &ds, train_meta, true).unwrap();
        let out = st.step(&b).unwrap();
        assert!(out.loss.is_finite(), "step {i} loss not finite");
        losses.push(out.loss);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn infer_is_deterministic_and_state_isolated() {
    if !have_artifacts() {
        return;
    }
    let ds = tiny_dataset();
    let manifest = Manifest::load(&default_dir()).unwrap();
    let train_meta = manifest.get("tiny.train").unwrap();
    let infer_meta = manifest.get("tiny.infer").unwrap();
    let rt = Runtime::cpu().unwrap();
    let st = TrainState::new(&rt, train_meta, Some(infer_meta), Some(&ds), 1e-3, 1)
        .unwrap();
    let mut rng = Rng::new(9);
    let roots: Vec<u32> = ds.val_nodes()[..64].to_vec();
    let mfg = build_mfg(
        &ds.csr,
        &ds.community,
        &roots,
        &infer_meta.spec.fanouts,
        NeighborPolicy::Uniform,
        &mut rng,
    );
    let b = assemble(&mfg, &ds, infer_meta, false).unwrap();
    let l1 = st.infer(&b).unwrap();
    let l2 = st.infer(&b).unwrap();
    assert_eq!(l1, l2, "infer must be pure (resident buffer not donated)");
}

#[test]
fn full_training_run_all_policies() {
    if !have_artifacts() {
        return;
    }
    let ds = tiny_dataset();
    let mut session = Session::new().unwrap();
    let cfg = TrainConfig {
        max_epochs: 3,
        batch_size: 128,
        ..Default::default()
    };
    let opts = RunOptions { l2_base: 0.0016, ..Default::default() };
    let mut accs = Vec::new();
    for pol in [
        BatchPolicy::baseline(),
        BatchPolicy { roots: RootPolicy::NoRand, p_intra: 1.0 },
        BatchPolicy { roots: RootPolicy::CommRandMix { pct: 0.125 }, p_intra: 0.9 },
    ] {
        let r = train::train(
            &mut session,
            &ds,
            "tiny",
            &Method::CommRand(pol),
            &cfg,
            &opts,
        )
        .unwrap();
        assert_eq!(r.epochs.len(), 3);
        assert!(r.best_val_acc > 0.2, "policy failed to learn: {}", r.policy);
        accs.push(r.best_val_acc);
    }
}

#[test]
fn labor_and_clustergcn_methods_run() {
    if !have_artifacts() {
        return;
    }
    let ds = tiny_dataset();
    let mut session = Session::new().unwrap();
    let cfg = TrainConfig {
        max_epochs: 2,
        batch_size: 128,
        ..Default::default()
    };
    let opts = RunOptions::default();
    for m in [Method::Labor, Method::ClusterGcn { q: 1 }] {
        let r = train::train(&mut session, &ds, "tiny", &m, &cfg, &opts).unwrap();
        assert_eq!(r.epochs.len(), 2, "{}", m.label());
        assert!(r.epochs[0].train_loss.is_finite());
    }
}

#[test]
fn gcn_and_gat_artifacts_train() {
    if !have_artifacts() {
        return;
    }
    let ds = tiny_dataset();
    let mut session = Session::new().unwrap();
    let cfg = TrainConfig {
        max_epochs: 2,
        batch_size: 128,
        ..Default::default()
    };
    let opts = RunOptions::default();
    for artifact in ["tiny_gcn", "tiny_gat"] {
        let r = train::train(
            &mut session,
            &ds,
            artifact,
            &Method::CommRand(BatchPolicy::baseline()),
            &cfg,
            &opts,
        )
        .unwrap();
        assert!(
            r.epochs[1].train_loss < r.epochs[0].train_loss + 0.5,
            "{artifact} diverged: {:?}",
            r.epochs.iter().map(|e| e.train_loss).collect::<Vec<_>>()
        );
    }
}

#[test]
fn seeded_runs_are_reproducible() {
    if !have_artifacts() {
        return;
    }
    let ds = tiny_dataset();
    let mut session = Session::new().unwrap();
    let cfg = TrainConfig {
        max_epochs: 2,
        batch_size: 128,
        seed: 42,
        ..Default::default()
    };
    let opts = RunOptions::default();
    let m = Method::CommRand(BatchPolicy::baseline());
    let a = train::train(&mut session, &ds, "tiny", &m, &cfg, &opts).unwrap();
    let b = train::train(&mut session, &ds, "tiny", &m, &cfg, &opts).unwrap();
    assert_eq!(a.epochs[1].train_loss, b.epochs[1].train_loss);
    assert_eq!(a.best_val_acc, b.best_val_acc);
}
