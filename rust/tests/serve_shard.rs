//! Integration tests for multi-shard community-affinity serving.
//!
//! Unlike `integration.rs`, these need no AOT artifacts and no real
//! PJRT: the no-op executor exercises the whole pipeline — queue →
//! micro-batcher → shard router → per-shard worker pools → per-shard
//! feature caches — on the synthetic tiny dataset, so they run
//! everywhere `cargo test` does.

use comm_rand::config::preset;
use comm_rand::serve::engine::{self, synthetic_infer_meta};
use comm_rand::serve::{
    Arrival, LoadConfig, NullExecutor, ServeConfig, ShardPlan, SpillPolicy,
};

fn tiny_dataset() -> comm_rand::graph::Dataset {
    comm_rand::train::dataset::build(&preset("tiny").unwrap(), true)
}

fn base_config(ds: &comm_rand::graph::Dataset) -> ServeConfig {
    let mut scfg = ServeConfig::for_dataset(ds);
    scfg.batch_size = 16;
    scfg.max_delay_us = 1_000;
    scfg.deadline_us = 200_000;
    scfg.community_bias = 0.5;
    scfg.workers = 4;
    scfg.fanouts = vec![5, 5];
    scfg.seed = 21;
    scfg
}

/// Acceptance check: `serve bench --shards {2,4}` end-to-end with the
/// no-op executor, per-shard stats reported, and — under strict spill —
/// every request's seed community processed on the shard that owns it.
#[test]
fn strict_spill_places_every_request_on_its_owning_shard() {
    let ds = tiny_dataset();
    for n_shards in [2usize, 4] {
        let mut scfg = base_config(&ds);
        scfg.shards = n_shards;
        scfg.spill = SpillPolicy::Strict;
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = LoadConfig {
            clients: 4,
            requests_per_client: 40,
            zipf_s: 1.1,
            arrival: Arrival::Closed,
            seed: 5,
        };
        let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();

        // closed loop answered everything, with per-shard stats
        assert_eq!(rep.requests, 160, "shards={n_shards}");
        assert_eq!(rep.errors, 0, "shards={n_shards}");
        assert_eq!(rep.n_shards, n_shards);
        assert_eq!(rep.spill, "strict");
        assert_eq!(rep.shards.len(), n_shards);

        // strict affinity: zero foreign requests on every shard
        for sh in &rep.shards {
            assert_eq!(
                sh.foreign_requests, 0,
                "shards={n_shards}: shard {} served a community it does \
                 not own",
                sh.id
            );
        }

        // shard accounting sums to the run totals
        let req_sum: usize = rep.shards.iter().map(|sh| sh.requests).sum();
        assert_eq!(req_sum, rep.requests);
        let batch_sum: usize = rep.shards.iter().map(|sh| sh.batches).sum();
        assert_eq!(batch_sum, rep.batches);
        let hit_sum: u64 = rep.shards.iter().map(|sh| sh.cache_hits).sum();
        let miss_sum: u64 = rep.shards.iter().map(|sh| sh.cache_misses).sum();
        assert_eq!((hit_sum, miss_sum), (rep.cache_hits, rep.cache_misses));
        assert!(hit_sum + miss_sum > 0, "caches not exercised");

        // per-shard latency percentiles are sane wherever traffic ran
        for sh in rep.shards.iter().filter(|sh| sh.requests > 0) {
            assert!(sh.lat_p50_ms <= sh.lat_p99_ms, "shard {}", sh.id);
            assert!(sh.lat_p99_ms.is_finite(), "shard {}", sh.id);
        }

        // the report's JSON carries the per-shard breakdown
        let json = rep.to_json().to_string_pretty();
        assert!(json.contains("foreign_requests"));
        assert!(json.contains("queue_depth_max"));
    }
}

/// The plan the engine routes with is a pure function of the labels:
/// what the report says each shard owns matches an independently built
/// plan, request placement included.
#[test]
fn shard_plan_is_consistent_with_reported_ownership() {
    let ds = tiny_dataset();
    let plan = ShardPlan::build(&ds.community, ds.num_comms, 2);
    let plan2 = ShardPlan::build(&ds.community, ds.num_comms, 2);
    let mut owned = [0usize; 2];
    for v in 0..ds.n() as u32 {
        let s = plan.shard_of_node(&ds.community, v);
        assert_eq!(s, plan2.shard_of_node(&ds.community, v), "node {v}");
        owned[s] += 1;
    }
    assert_eq!(owned[0] + owned[1], ds.n());
    assert_eq!(owned[0], plan.owned_nodes(0));
    assert_eq!(owned[1], plan.owned_nodes(1));

    let mut scfg = base_config(&ds);
    scfg.shards = 2;
    scfg.spill = SpillPolicy::Strict;
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let exec = NullExecutor { num_classes: ds.num_classes };
    let lcfg = LoadConfig {
        clients: 2,
        requests_per_client: 25,
        zipf_s: 1.1,
        arrival: Arrival::Closed,
        seed: 9,
    };
    let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
    for sh in &rep.shards {
        assert_eq!(sh.owned_nodes, plan.owned_nodes(sh.id));
        assert_eq!(sh.owned_comms, plan.owned_comms(sh.id));
    }
}

// NOTE: steal/broadcast closed-loop coverage lives in the engine's
// unit tests (`spill_policies_run_end_to_end`); this file is the
// strict-affinity acceptance check.
