//! Integration tests for cooperative cross-request sampling: the
//! merged per-batch MFG's dedup accounting must agree *exactly*
//! between the trace exporter and the engine report, and the labor
//! sampler must serve a full closed-loop run end to end with real
//! logits.
//!
//! Acceptance checks from the cooperative-sampling issue:
//! * every Sample span reports `refs >= input_nodes` and an
//!   `overlap_permille` equal to `1000·(refs − unique)/refs`;
//! * summing Sample-span refs/input_nodes over a full-rate trace
//!   reproduces `ServeReport.{frontier_refs, dedup_factor}` exactly;
//! * `sampler=labor` answers every request without error and with
//!   host-executor logits (accuracy in range).

use comm_rand::config::preset;
use comm_rand::serve::engine;
use comm_rand::serve::{Arrival, LoadConfig, SamplerKind, ServeConfig};
use comm_rand::util::json::Json;

fn tiny_dataset() -> comm_rand::graph::Dataset {
    comm_rand::train::dataset::build(&preset("tiny").unwrap(), true)
}

fn base_config(ds: &comm_rand::graph::Dataset) -> ServeConfig {
    let mut scfg = ServeConfig::for_dataset(ds);
    scfg.batch_size = 16;
    scfg.max_delay_us = 2_000;
    scfg.deadline_us = 500_000;
    scfg.workers = 2;
    scfg.fanouts = vec![8, 8];
    scfg.seed = 41;
    scfg
}

fn closed(clients: usize, per: usize, seed: u64) -> LoadConfig {
    LoadConfig {
        clients,
        requests_per_client: per,
        zipf_s: 1.1,
        arrival: Arrival::Closed,
        seed,
    }
}

/// Full-rate trace vs report: per-span invariants hold and the span
/// sums reproduce the report's dedup accounting bit for bit.
#[test]
fn trace_sample_spans_agree_with_report_dedup_factor() {
    let ds = tiny_dataset();
    let trace_path = std::env::temp_dir()
        .join(format!("comm_rand_coop_trace_{}.json", std::process::id()));
    let mut scfg = base_config(&ds);
    scfg.community_bias = 0.9;
    scfg.sampler = SamplerKind::Labor;
    scfg.trace = Some(trace_path.clone());
    scfg.trace_sample = 1000;
    let (exec, meta) =
        engine::build_executor(&preset("tiny").unwrap(), &ds, &scfg).unwrap();
    let lcfg = closed(8, 30, 91);
    let rep = engine::run(&ds, &meta, exec.as_ref(), &scfg, &lcfg).unwrap();
    assert_eq!(rep.requests, 240);
    assert_eq!(rep.errors, 0);
    assert_eq!(rep.sampler, "labor");
    assert!(rep.frontier_refs > 0);
    assert!(rep.dedup_factor >= 1.0);

    let doc = Json::parse_file(&trace_path).unwrap();
    // exact agreement only holds if the ring kept every span
    let dropped = doc
        .get("otherData")
        .unwrap()
        .get("dropped_events")
        .unwrap()
        .as_usize()
        .unwrap();
    assert_eq!(dropped, 0, "ring wrapped; shrink the run");

    let mut sum_refs = 0u64;
    let mut sum_unique = 0u64;
    let mut sample_spans = 0usize;
    for ev in doc.get("traceEvents").unwrap().as_arr().unwrap() {
        if ev.get("ph").unwrap().as_str().unwrap() != "X"
            || ev.get("name").unwrap().as_str().unwrap() != "sample"
        {
            continue;
        }
        sample_spans += 1;
        let args = ev.get("args").unwrap();
        let refs = args.get("refs").unwrap().as_usize().unwrap() as u64;
        let unique =
            args.get("input_nodes").unwrap().as_usize().unwrap() as u64;
        let overlap =
            args.get("overlap_permille").unwrap().as_usize().unwrap() as u64;
        assert!(refs >= unique, "span refs {refs} < unique {unique}");
        let want = if refs == 0 { 0 } else { 1000 * (refs - unique) / refs };
        assert_eq!(
            overlap, want,
            "overlap_permille must be 1000*(refs-unique)/refs"
        );
        sum_refs += refs;
        sum_unique += unique;
    }
    assert!(sample_spans > 0, "full-rate trace must carry sample spans");

    // the trace and the report count the same thing
    assert_eq!(sum_refs, rep.frontier_refs, "span refs sum to the report");
    let from_trace = sum_refs as f64 / sum_unique as f64;
    assert!(
        (from_trace - rep.dedup_factor).abs() < 1e-12,
        "trace dedup {from_trace} != report {}",
        rep.dedup_factor
    );
    assert_eq!(
        rep.gather_bytes,
        sum_unique * ds.feat_dim as u64 * 4,
        "gather bytes = unique inputs x feat row size"
    );
    let _ = std::fs::remove_file(&trace_path);
}

/// The labor sampler end to end under community-grouped batching:
/// every request answered with real host-executor logits, per-shard
/// dedup factors consistent with the rollup, and refs >= unique both
/// per shard and in aggregate.
#[test]
fn labor_sampler_serves_full_run_with_consistent_shard_accounting() {
    let ds = tiny_dataset();
    let mut scfg = base_config(&ds);
    scfg.community_bias = 1.0;
    scfg.sampler = SamplerKind::Labor;
    scfg.shards = 2;
    let (exec, meta) =
        engine::build_executor(&preset("tiny").unwrap(), &ds, &scfg).unwrap();
    let lcfg = closed(6, 40, 3);
    let rep = engine::run(&ds, &meta, exec.as_ref(), &scfg, &lcfg).unwrap();
    assert_eq!(rep.requests, 240);
    assert_eq!(rep.errors, 0);
    assert_eq!(rep.evaluated, 240, "host executor scores every reply");
    assert!((0.0..=1.0).contains(&rep.accuracy));
    assert!(rep.dedup_factor >= 1.0);

    let mut shard_refs = 0u64;
    for sh in &rep.shards {
        assert!(
            sh.dedup_factor >= 1.0,
            "shard {} dedup {} < 1",
            sh.id,
            sh.dedup_factor
        );
        shard_refs += sh.frontier_refs;
    }
    assert_eq!(shard_refs, rep.frontier_refs, "shards sum to the rollup");

    // the JSON artifact carries the new dedup fields
    let j = rep.to_json().to_string_pretty();
    assert!(j.contains("dedup_factor"));
    assert!(j.contains("frontier_refs"));
    assert!(j.contains("gather_bytes"));
    assert!(j.contains("\"sampler\""));
}
