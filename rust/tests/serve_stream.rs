//! Integration tests for the dynamic-graph mutation subsystem: churn
//! running alongside the serving engine, end to end, with no AOT
//! artifacts required (no-op / host executors on the tiny dataset).
//!
//! Acceptance checks from the subsystem issue:
//! * churn at a low rate ⇒ zero errored replies;
//! * feature versions are monotone (strictly increasing per rewrite);
//! * the stale-hit accounting invariant
//!   `hits + misses + stale_hits == lookups` holds per shard and in
//!   aggregate.

use std::sync::atomic::Ordering;

use comm_rand::config::preset;
use comm_rand::serve::engine::{self, synthetic_infer_meta};
use comm_rand::serve::{
    Arrival, HostExecutor, LoadConfig, NullExecutor, ServeConfig,
};
use comm_rand::stream::{
    MaintenanceMode, Mutation, StreamConfig, StreamState,
};

fn tiny_dataset() -> comm_rand::graph::Dataset {
    comm_rand::train::dataset::build(&preset("tiny").unwrap(), true)
}

fn base_config(ds: &comm_rand::graph::Dataset) -> ServeConfig {
    let mut scfg = ServeConfig::for_dataset(ds);
    scfg.batch_size = 16;
    scfg.max_delay_us = 1_000;
    scfg.deadline_us = 500_000;
    scfg.workers = 2;
    scfg.fanouts = vec![5, 5];
    scfg.seed = 33;
    scfg
}

fn closed(clients: usize, per: usize, seed: u64) -> LoadConfig {
    LoadConfig {
        clients,
        requests_per_client: per,
        zipf_s: 1.1,
        arrival: Arrival::Closed,
        seed,
    }
}

/// Low-rate churn: every request answered without error, the engine
/// applies update epochs, feature versions advance monotonically with
/// the rewrite count, and the stale-hit accounting invariant holds.
#[test]
fn low_rate_churn_serves_cleanly_with_exact_accounting() {
    let ds = tiny_dataset();
    let mut scfg = base_config(&ds);
    scfg.shards = 2;
    scfg.mutate_rps = 5_000.0;
    scfg.mutate_epoch = 32;
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let exec = NullExecutor { num_classes: ds.num_classes };
    let lcfg = closed(4, 60, 17);
    let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();

    // zero errored replies at low churn
    assert_eq!(rep.requests, 240, "closed loop must answer every request");
    assert_eq!(rep.errors, 0, "churn must never produce errored replies");

    let st = rep.stream.as_ref().expect("mutate>0 reports a stream section");
    assert!(st.updates_ingested > 0);
    assert!(st.epochs >= 1, "updates must be applied in epochs");
    assert_eq!(
        st.edge_inserts + st.edge_deletes + st.feature_rewrites
            + st.noop_updates,
        st.updates_ingested as usize,
        "every ingested update is applied or counted as a no-op"
    );

    // monotone feature versions: the highest issued version equals the
    // number of rewrites applied (each rewrite bumps by exactly one)
    assert_eq!(
        st.feat_version as usize, st.feature_rewrites,
        "feature versions must advance one per rewrite, monotonically"
    );

    // stale-hit accounting invariant, aggregate and per shard
    assert_eq!(
        rep.cache_lookups,
        rep.cache_hits + rep.cache_misses + rep.stale_hits,
        "aggregate accounting invariant"
    );
    let mut shard_lookups = 0u64;
    for sh in &rep.shards {
        assert_eq!(
            sh.cache_lookups,
            sh.cache_hits + sh.cache_misses + sh.stale_hits,
            "shard {} accounting invariant",
            sh.id
        );
        shard_lookups += sh.cache_lookups;
    }
    assert_eq!(shard_lookups, rep.cache_lookups, "shards sum to the rollup");

    // the JSON artifact carries the streaming section + counters
    let j = rep.to_json().to_string_pretty();
    assert!(j.contains("stale_hits"));
    assert!(j.contains("mutate_ups"));
    assert!(j.contains("relabel_waves"));
}

/// Feature rewrites at a high rate actually produce stale hits — the
/// versioned cache path is exercised, not just plumbed — and replies
/// still carry real logits under the host executor with accuracy in
/// range.
#[test]
fn rewrite_churn_produces_stale_hits_and_real_logits() {
    let ds = tiny_dataset();
    let mut scfg = base_config(&ds);
    // large cache + hot trace so rows stay resident long enough for a
    // rewrite to land between two fetches of the same node; the drift
    // threshold is parked high so no full relabel flushes the cache
    // mid-test
    scfg.cache_rows = ds.n();
    scfg.mutate_rps = 50_000.0;
    scfg.mutate_epoch = 64;
    scfg.drift_threshold = 1e9;
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let exec = HostExecutor::new(&ds, 0).unwrap();
    let lcfg = closed(4, 120, 5);
    let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
    assert_eq!(rep.requests, 480);
    assert_eq!(rep.errors, 0);
    assert_eq!(rep.evaluated, 480, "host executor logits for every reply");
    assert!((0.0..=1.0).contains(&rep.accuracy));
    let st = rep.stream.as_ref().unwrap();
    assert!(st.feature_rewrites > 0, "churn mix must rewrite features");
    assert!(
        rep.stale_hits > 0,
        "high-rate rewrites against a resident cache must go stale \
         (rewrites={}, lookups={})",
        st.feature_rewrites,
        rep.cache_lookups
    );
    assert_eq!(
        rep.cache_lookups,
        rep.cache_hits + rep.cache_misses + rep.stale_hits
    );
}

/// The naive full-relabel baseline completes the same trace with zero
/// errors: every epoch runs a stop-the-world Louvain relabel, rebuilds
/// the plan and flushes the caches, yet no request is lost and the
/// label snapshot version advances.
#[test]
fn naive_full_relabel_mode_loses_no_requests() {
    let ds = tiny_dataset();
    let mut scfg = base_config(&ds);
    scfg.shards = 2;
    scfg.mutate_rps = 3_000.0;
    scfg.mutate_epoch = 48;
    scfg.maintenance = MaintenanceMode::Full;
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let exec = NullExecutor { num_classes: ds.num_classes };
    let lcfg = closed(4, 40, 23);
    let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
    assert_eq!(rep.requests, 160);
    assert_eq!(rep.errors, 0);
    let st = rep.stream.as_ref().unwrap();
    assert!(st.epochs >= 1);
    assert_eq!(
        st.full_relabels, st.epochs,
        "naive mode must fully relabel on every epoch"
    );
    assert!(
        st.label_version >= st.full_relabels as u64,
        "each relabel publishes a label snapshot"
    );
    assert_eq!(
        rep.cache_lookups,
        rep.cache_hits + rep.cache_misses + rep.stale_hits
    );
}

/// Direct StreamState check of the monotone-version contract under
/// concurrent readers: rewrites strictly increase the version while a
/// reader thread observes node versions never going backwards.
#[test]
fn feature_versions_are_monotone_under_concurrent_reads() {
    let ds = tiny_dataset();
    let st = StreamState::new(
        &ds,
        StreamConfig { rate_ups: 1.0, ..StreamConfig::default() },
    );
    let labels = comm_rand::serve::LabelCell::new(
        comm_rand::serve::LabelSnapshot::initial(
            &ds.community,
            ds.num_comms,
            1,
        ),
    );
    let caches: Vec<comm_rand::serve::ShardedFeatureCache> = vec![];
    let stop = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|scope| {
        let st_ref = &st;
        let stop_ref = &stop;
        let reader = scope.spawn(move || {
            let mut last = 0u64;
            let mut observed = 0usize;
            while !stop_ref.load(Ordering::Relaxed) {
                let (ver, _) = st_ref.feat().version_and_row(7);
                assert!(ver >= last, "version went backwards: {ver} < {last}");
                last = ver;
                observed += 1;
            }
            observed
        });
        for i in 0..200u64 {
            st.log().append(
                i,
                Mutation::FeatureRewrite {
                    node: 7,
                    row: vec![i as f32; ds.feat_dim],
                },
            );
            if let Some(ep) = st.log().seal() {
                st.apply_epoch(ep, &labels, &caches);
            }
        }
        stop.store(true, Ordering::Relaxed);
        let observed = reader.join().unwrap();
        assert!(observed > 0, "reader never ran");
    });
    let (ver, row) = st.feat().version_and_row(7);
    assert_eq!(ver, 200, "200 rewrites = version 200");
    assert_eq!(row.unwrap()[0], 199.0, "last write wins");
}
