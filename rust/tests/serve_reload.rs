//! Integration tests for the checkpoint subsystem and zero-downtime
//! hot swap.
//!
//! Like `serve_shard.rs`/`serve_admission.rs`, these need no AOT
//! artifacts and no real PJRT: the host reference executor produces
//! real logits, so the full train → checkpoint → serve → hot-swap
//! path runs everywhere `cargo test` does.
//!
//! Coverage: checkpoint format round-trip (bitwise), truncation and
//! CRC-corruption rejection, community-fingerprint fencing, retention
//! pruning, trained-vs-seed serving accuracy, and the acceptance check
//! for hot swap under load — a checkpoint landing mid-run completes
//! with zero dropped/errored replies and a monotone `param_version`.

use std::path::{Path, PathBuf};

use comm_rand::ckpt::{
    community_fingerprint, quantize_checkpoint, Checkpoint, CheckpointWriter,
    Retention,
};
use comm_rand::config::{preset, TrainConfig};
use comm_rand::graph::Dataset;
use comm_rand::serve::engine::{self, synthetic_infer_meta};
use comm_rand::serve::{Arrival, HostExecutor, LoadConfig, ServeConfig};
use comm_rand::train::train_host;

fn tiny_dataset() -> Dataset {
    comm_rand::train::dataset::build(&preset("tiny").unwrap(), true)
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("comm_rand_reload_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Train briefly and return every per-epoch checkpoint (keep-all).
fn train_with_checkpoints(
    ds: &Dataset,
    dir: &Path,
    epochs: usize,
) -> Vec<comm_rand::ckpt::WrittenCkpt> {
    let mut w = CheckpointWriter::new(dir, 1, Retention::All).unwrap();
    let cfg = TrainConfig {
        batch_size: 256,
        lr: 0.5,
        max_epochs: epochs,
        seed: 11,
        ..Default::default()
    };
    train_host(ds, &cfg, Some(&mut w), false).unwrap();
    let mut entries = w.entries().to_vec();
    entries.sort_by_key(|e| e.epoch);
    entries
}

#[test]
fn checkpoint_roundtrips_bitwise_through_disk() {
    let ds = tiny_dataset();
    let dir = tmpdir("roundtrip");
    let entries = train_with_checkpoints(&ds, &dir, 1);
    let ck = Checkpoint::load(&entries[0].path).unwrap();
    // decode(encode(x)) is the identity on the bytes
    let bytes = std::fs::read(&entries[0].path).unwrap();
    assert_eq!(ck.encode(), bytes, "re-encode must reproduce the file");
    // payload survives bit-for-bit
    let again = Checkpoint::decode(&bytes).unwrap();
    for (a, b) in ck.params.iter().zip(&again.params) {
        let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ab, bb);
    }
    assert_eq!(
        ck.meta.comm_fp,
        community_fingerprint(&ds.community, ds.num_comms),
        "checkpoint must record the dataset's fingerprint"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_and_corrupt_checkpoints_are_refused() {
    let ds = tiny_dataset();
    let dir = tmpdir("corrupt");
    let entries = train_with_checkpoints(&ds, &dir, 1);
    let bytes = std::fs::read(&entries[0].path).unwrap();

    // every truncation point is rejected
    for cut in [0, 10, bytes.len() / 3, bytes.len() - 1] {
        assert!(
            Checkpoint::decode(&bytes[..cut]).is_err(),
            "accepted a checkpoint truncated to {cut} bytes"
        );
    }
    // single-bit payload corruption is caught by the CRC
    let mut bad = bytes.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x01;
    let err = Checkpoint::decode(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("CRC"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn community_fingerprint_mismatch_is_fenced() {
    let ds = tiny_dataset();
    let dir = tmpdir("fence");
    let entries = train_with_checkpoints(&ds, &dir, 1);
    let ck = Checkpoint::load(&entries[0].path).unwrap();
    ck.validate_against(&ds.community, ds.num_comms).unwrap();

    // a permuted labeling must be rejected even though shapes match
    let mut other = ds.community.clone();
    other.swap(0, other.len() - 1);
    let err = ck.validate_against(&other, ds.num_comms).unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");

    // ...and the serving engine refuses to start on it
    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.fanouts = vec![5, 5];
    scfg.ckpt = Some(entries[0].path.clone());
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let exec = HostExecutor::new(&ds, 0).unwrap();
    let lcfg = LoadConfig {
        clients: 1,
        requests_per_client: 4,
        zipf_s: 1.1,
        arrival: Arrival::Closed,
        seed: 1,
    };
    let mut wrong = tiny_dataset();
    // different labeling, same topology: first and last node are in
    // different communities after the community reorder
    let n = wrong.community.len();
    wrong.community.swap(0, n - 1);
    let err = engine::run(&wrong, &meta, &exec, &scfg, &lcfg).unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn retention_keeps_best_and_latest() {
    let ds = tiny_dataset();
    let dir = tmpdir("retention");
    let mut w = CheckpointWriter::new(&dir, 1, Retention::BestAndLatest)
        .unwrap();
    let cfg = TrainConfig {
        batch_size: 256,
        lr: 0.5,
        max_epochs: 5,
        seed: 11,
        ..Default::default()
    };
    train_host(&ds, &cfg, Some(&mut w), false).unwrap();
    let files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    assert!(
        files.len() <= 2,
        "retention must keep at most best + latest, found {files:?}"
    );
    assert!(!files.is_empty());
    let latest = w.latest().unwrap();
    assert_eq!(latest.epoch, 4, "latest epoch must survive pruning");
    let best = w.best().unwrap();
    assert!(files.iter().any(|f| f == &best.path));
    assert!(files.iter().any(|f| f == &latest.path));
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: `serve bench ckpt=<path>` reports real top-1 accuracy
/// from trained parameters, well above the seed-parameter baseline.
#[test]
fn trained_checkpoint_beats_seed_accuracy_at_serve_time() {
    let ds = tiny_dataset();
    let dir = tmpdir("accuracy");
    let entries = train_with_checkpoints(&ds, &dir, 3);
    let last = entries.last().unwrap();

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = 16;
    scfg.workers = 2;
    scfg.fanouts = vec![5, 5];
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let lcfg = LoadConfig {
        clients: 4,
        requests_per_client: 50,
        zipf_s: 1.1,
        arrival: Arrival::Closed,
        seed: 5,
    };

    // seed baseline: fresh executor, no checkpoint
    let exec = HostExecutor::new(&ds, scfg.seed).unwrap();
    let base = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
    assert_eq!(base.requests, 200);
    assert_eq!(base.evaluated, 200, "host executor scores every reply");
    assert_eq!(base.param_version, 0);

    // trained parameters
    let mut cfg = scfg.clone();
    cfg.ckpt = Some(last.path.clone());
    cfg.cache_warm = true; // exercise the hot-node warmup path too
    let trained = engine::run(&ds, &meta, &exec, &cfg, &lcfg).unwrap();
    assert_eq!(trained.requests, 200);
    assert_eq!(trained.errors, 0);
    assert_eq!(trained.param_version, 1, "checkpoint installed as v1");
    assert!(
        trained.accuracy > base.accuracy + 0.1,
        "trained accuracy {:.3} must beat seed {:.3} (train val acc \
         was {:.3})",
        trained.accuracy,
        base.accuracy,
        last.val_acc
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: a checkpoint landing in the watched directory during an
/// active **open-loop** run hot-swaps in with **zero dropped or
/// errored replies**, and the observed `param_version` is monotone
/// (no regressions) with a visible bump in the per-shard reports.
#[test]
fn hot_swap_under_load_drops_nothing_and_is_monotone() {
    let ds = tiny_dataset();
    let stage = tmpdir("swap_stage");
    let entries = train_with_checkpoints(&ds, &stage, 2);
    assert_eq!(entries.len(), 2);

    // the watched dir starts with only the epoch-0 checkpoint
    let watch = tmpdir("swap_watch");
    let v1 = Checkpoint::load(&entries[0].path).unwrap();
    v1.write_atomic(&watch.join("ckpt-e00000.bin")).unwrap();
    let v2 = Checkpoint::load(&entries[1].path).unwrap();

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = 16;
    // 2 workers over 2 shards = one worker per shard: batches are
    // serialized per shard, so `version_regressions == 0` is a hard
    // invariant here (not subject to in-flight overlap at the swap)
    scfg.workers = 2;
    scfg.shards = 2;
    scfg.fanouts = vec![5, 5];
    scfg.max_delay_us = 3_000;
    scfg.deadline_us = 5_000_000;
    scfg.ckpt = Some(watch.clone());
    scfg.ckpt_watch_ms = 5;
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let exec = HostExecutor::new(&ds, 0).unwrap();
    // open loop: 240 requests offered at 2000 req/s (~120 ms of
    // arrivals — far below saturation, so nothing sheds), with the
    // swap checkpoint landing ~50 ms in
    let lcfg = LoadConfig {
        clients: 4,
        requests_per_client: 60,
        zipf_s: 1.1,
        arrival: Arrival::Poisson { rate_rps: 2_000.0 },
        seed: 9,
    };

    let rep = std::thread::scope(|scope| {
        let watch = &watch;
        let v2 = &v2;
        let writer = scope.spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            v2.write_atomic(&watch.join("ckpt-e00001.bin")).unwrap();
        });
        let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        writer.join().unwrap();
        rep
    });

    // zero loss across the swap: every issued request completed (none
    // shed at this offered load), none errored
    assert_eq!(rep.requests, 240, "open loop must answer every request");
    assert_eq!(rep.errors, 0, "hot swap must not produce error replies");
    assert_eq!(rep.evaluated, 240);
    assert_eq!(rep.shed, 0);

    // the swap happened and was visible: startup v1, watcher v2
    assert_eq!(
        rep.param_version, 2,
        "mid-run checkpoint must install as version 2"
    );
    assert!(rep.swaps >= 1, "at least one shard must observe the swap");

    // monotonicity: no shard ever saw the version move backwards
    for sh in &rep.shards {
        assert_eq!(
            sh.version_regressions, 0,
            "shard {} observed a version regression",
            sh.id
        );
        if sh.requests > 0 {
            assert!(
                sh.param_version >= 1,
                "shard {} served with uninstalled params",
                sh.id
            );
        }
    }
    let json = rep.to_json().to_string_pretty();
    assert!(json.contains("param_version"));
    assert!(json.contains("swaps"));
    std::fs::remove_dir_all(&stage).ok();
    std::fs::remove_dir_all(&watch).ok();
}

/// The quantized (`i16q`) on-disk format gets the same integrity
/// battery as f32: an intact file round-trips with its i16 payload,
/// truncations and CRC corruption are refused, an unknown dtype tag is
/// refused even with a valid CRC, and the community fence still trips
/// at engine startup.
#[test]
fn quantized_checkpoint_survives_integrity_and_fence_battery() {
    let ds = tiny_dataset();
    let dir = tmpdir("quant_battery");
    let entries = train_with_checkpoints(&ds, &dir, 1);
    let qck =
        quantize_checkpoint(&Checkpoint::load(&entries[0].path).unwrap())
            .unwrap();
    let qpath = dir.join("ckpt-q.bin");
    qck.write_atomic(&qpath).unwrap();

    // intact: i16 payload and the exact dequantized f32 view survive
    let back = Checkpoint::load(&qpath).unwrap();
    assert_eq!(back.dtype(), "i16q");
    assert_eq!(back.quant, qck.quant, "i16 payload must round-trip");
    for (a, b) in back.params.iter().zip(&qck.params) {
        let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ab, bb, "dequantized view must round-trip bitwise");
    }

    let bytes = std::fs::read(&qpath).unwrap();
    // every truncation point is rejected
    for cut in [0, 10, bytes.len() / 3, bytes.len() - 1] {
        assert!(
            Checkpoint::decode(&bytes[..cut]).is_err(),
            "accepted a quantized checkpoint truncated to {cut} bytes"
        );
    }
    // single-bit payload corruption is caught by the CRC
    let mut bad = bytes.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x01;
    let err = Checkpoint::decode(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("CRC"), "{err:#}");

    // unknown dtype tag: patch "i16q" to same-length garbage and
    // re-CRC, so the *reader's dtype check* (not the CRC) must refuse
    let mut bad = bytes.clone();
    let hlen = u32::from_le_bytes(bad[8..12].try_into().unwrap()) as usize;
    let header = std::str::from_utf8(&bad[12..12 + hlen]).unwrap();
    let at = 12 + header.find("i16q").expect("dtype tag in header");
    bad[at..at + 4].copy_from_slice(b"zz9q");
    let body = bad.len() - 4;
    let crc = comm_rand::ckpt::format::crc32(&bad[..body]).to_le_bytes();
    bad[body..].copy_from_slice(&crc);
    let err = Checkpoint::decode(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("dtype"), "{err:#}");

    // the fingerprint fence holds for quantized checkpoints too: a
    // dataset with a permuted labeling refuses it at engine startup
    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.fanouts = vec![5, 5];
    scfg.ckpt = Some(qpath);
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let exec = HostExecutor::new(&ds, 0).unwrap();
    let lcfg = LoadConfig {
        clients: 1,
        requests_per_client: 4,
        zipf_s: 1.1,
        arrival: Arrival::Closed,
        seed: 1,
    };
    let mut wrong = tiny_dataset();
    let n = wrong.community.len();
    wrong.community.swap(0, n - 1);
    let err = engine::run(&wrong, &meta, &exec, &scfg, &lcfg).unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance for mixed-dtype hot swap: a run that starts on an f32
/// checkpoint and hot-swaps to the **quantized version of the same
/// parameters** mid-run completes with zero errors, a monotone
/// `param_version`, execute spans under *both* dtypes, and accuracy
/// within quantization noise of the pure-f32 run on the same trace.
#[test]
fn quantized_hot_swap_under_load_keeps_accuracy_and_both_dtypes() {
    let ds = tiny_dataset();
    let stage = tmpdir("qswap_stage");
    let entries = train_with_checkpoints(&ds, &stage, 2);
    let last = entries.last().unwrap();
    let v1 = Checkpoint::load(&last.path).unwrap();
    let mut v2 = quantize_checkpoint(&v1).unwrap();
    // same parameters, quantized; bump the epoch so the watcher's
    // fence (keyed on meta.epoch) lets it surface mid-run
    v2.meta.epoch = v1.meta.epoch + 1;

    let watch = tmpdir("qswap_watch");
    v1.write_atomic(&watch.join("ckpt-e00001.bin")).unwrap();

    let mut scfg = ServeConfig::for_dataset(&ds);
    scfg.batch_size = 16;
    scfg.workers = 2;
    scfg.shards = 2;
    scfg.fanouts = vec![5, 5];
    scfg.max_delay_us = 3_000;
    scfg.deadline_us = 5_000_000;
    scfg.ckpt = Some(watch.clone());
    scfg.ckpt_watch_ms = 5;
    let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
    let lcfg = LoadConfig {
        clients: 4,
        requests_per_client: 60,
        zipf_s: 1.1,
        arrival: Arrival::Poisson { rate_rps: 2_000.0 },
        seed: 9,
    };

    // pure-f32 baseline on the identical trace (no watcher)
    let mut base_cfg = scfg.clone();
    base_cfg.ckpt = Some(last.path.clone());
    base_cfg.ckpt_watch_ms = 0;
    let exec = HostExecutor::new(&ds, 0).unwrap();
    let base = engine::run(&ds, &meta, &exec, &base_cfg, &lcfg).unwrap();
    assert_eq!(base.requests, 240);
    assert_eq!(base.errors, 0);

    // mixed run: the quantized checkpoint lands ~50 ms in
    let exec = HostExecutor::new(&ds, 0).unwrap();
    let rep = std::thread::scope(|scope| {
        let watch = &watch;
        let v2 = &v2;
        let writer = scope.spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            v2.write_atomic(&watch.join("ckpt-e00002.bin")).unwrap();
        });
        let rep = engine::run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        writer.join().unwrap();
        rep
    });

    assert_eq!(rep.requests, 240, "open loop must answer every request");
    assert_eq!(rep.errors, 0, "mixed-dtype swap must not error a reply");
    assert_eq!(rep.evaluated, 240);
    assert_eq!(rep.shed, 0);
    assert_eq!(rep.param_version, 2, "quantized checkpoint installs as v2");
    assert!(rep.swaps >= 1, "at least one shard must observe the swap");
    for sh in &rep.shards {
        assert_eq!(
            sh.version_regressions, 0,
            "shard {} observed a version regression",
            sh.id
        );
    }
    let dtypes: Vec<&str> = rep.execute.iter().map(|e| e.dtype).collect();
    assert!(
        dtypes.contains(&"f32") && dtypes.contains(&"i16q"),
        "both dtypes must appear in the execute report, got {dtypes:?}"
    );
    // the swap replaced the parameters with their own quantization, so
    // only quantization noise on post-swap requests can move accuracy;
    // 0.02 allows ~5 argmax flips out of 240 — far above anything the
    // ≤ 2⁻¹⁵-per-weight rounding error can produce, but not flaky
    assert!(
        (rep.accuracy - base.accuracy).abs() <= 0.02,
        "mixed-dtype accuracy {:.4} drifted from pure f32 {:.4}",
        rep.accuracy,
        base.accuracy
    );
    std::fs::remove_dir_all(&stage).ok();
    std::fs::remove_dir_all(&watch).ok();
}
