//! Small statistics helpers used by the evaluation harness
//! (means, stddev, Pearson correlation for Fig. 6/7, percentiles).

/// Arithmetic mean (NaN on empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation, Bessel-corrected (0 for fewer than two
/// observations).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Pearson correlation coefficient (NaN for degenerate inputs).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return f64::NAN;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return f64::NAN;
    }
    sxy / (sxx * syy).sqrt()
}

/// p in [0,100]; linear interpolation between order statistics.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Geometric mean (for speedup aggregation across datasets).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Shannon entropy (bits) of a discrete histogram — label-diversity
/// metric for Fig. 7.
pub fn entropy_bits(counts: &[usize]) -> f64 {
    let total: usize = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &c in counts {
        if c > 0 {
            let p = c as f64 / total as f64;
            h -= p * p.log2();
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate() {
        assert!(pearson(&[1.0, 1.0], &[2.0, 3.0]).is_nan());
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn entropy() {
        assert_eq!(entropy_bits(&[10, 0, 0]), 0.0);
        assert!((entropy_bits(&[5, 5]) - 1.0).abs() < 1e-12);
        assert!((entropy_bits(&[1, 1, 1, 1]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
