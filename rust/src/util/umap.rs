//! Open-addressing u32 -> u32 map for the sampling hot path.
//!
//! The MFG builder's global-id -> position dedup map is the hottest
//! data structure in batch construction; std::HashMap's SipHash and
//! per-entry layout cost ~3x vs this linear-probing table with a
//! multiply-shift hash (§Perf in EXPERIMENTS.md).

const EMPTY: u32 = u32::MAX;

/// Linear-probing `u32 -> u32` hash map (keys must not be `u32::MAX`).
pub struct U32Map {
    keys: Vec<u32>,
    vals: Vec<u32>,
    mask: usize,
    len: usize,
}

impl U32Map {
    /// Capacity for about `n` entries (load factor <= 0.5).
    pub fn with_capacity(n: usize) -> U32Map {
        let cap = (2 * n.max(8)).next_power_of_two();
        U32Map {
            keys: vec![EMPTY; cap],
            vals: vec![0; cap],
            mask: cap - 1,
            len: 0,
        }
    }

    #[inline]
    fn slot(&self, key: u32) -> usize {
        // Fibonacci multiply-shift
        ((key as u64).wrapping_mul(0x9E3779B97F4A7C15) >> 40) as usize & self.mask
    }

    /// Insert if absent; returns the value now stored for `key`.
    #[inline]
    pub fn get_or_insert_with(
        &mut self,
        key: u32,
        make: impl FnOnce() -> u32,
    ) -> u32 {
        debug_assert!(key != EMPTY);
        if self.len * 2 >= self.keys.len() {
            self.grow();
        }
        let mut i = self.slot(key);
        loop {
            let k = self.keys[i];
            if k == key {
                return self.vals[i];
            }
            if k == EMPTY {
                let v = make();
                self.keys[i] = key;
                self.vals[i] = v;
                self.len += 1;
                return v;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Look a key up (`None` when absent).
    #[inline]
    pub fn get(&self, key: u32) -> Option<u32> {
        let mut i = self.slot(key);
        loop {
            let k = self.keys[i];
            if k == key {
                return Some(self.vals[i]);
            }
            if k == EMPTY {
                return None;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Insert (overwrites existing).
    #[inline]
    pub fn insert(&mut self, key: u32, val: u32) {
        if self.len * 2 >= self.keys.len() {
            self.grow();
        }
        let mut i = self.slot(key);
        loop {
            let k = self.keys[i];
            if k == key {
                self.vals[i] = val;
                return;
            }
            if k == EMPTY {
                self.keys[i] = key;
                self.vals[i] = val;
                self.len += 1;
                return;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Number of distinct keys stored.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the map holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn grow(&mut self) {
        let old_keys = std::mem::take(&mut self.keys);
        let old_vals = std::mem::take(&mut self.vals);
        let cap = old_keys.len() * 2;
        self.keys = vec![EMPTY; cap];
        self.vals = vec![0; cap];
        self.mask = cap - 1;
        self.len = 0;
        for (k, v) in old_keys.into_iter().zip(old_vals) {
            if k != EMPTY {
                self.insert(k, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::HashMap;

    #[test]
    fn matches_std_hashmap() {
        let mut rng = Rng::new(1);
        let mut ours = U32Map::with_capacity(4);
        let mut std_map: HashMap<u32, u32> = HashMap::new();
        for i in 0..5000u32 {
            let k = rng.below(2000) as u32;
            let v = *std_map.entry(k).or_insert(i);
            let v2 = ours.get_or_insert_with(k, || i);
            assert_eq!(v, v2, "key {k}");
        }
        assert_eq!(ours.len(), std_map.len());
        for (k, v) in &std_map {
            assert_eq!(ours.get(*k), Some(*v));
        }
        assert_eq!(ours.get(999_999), None);
    }

    #[test]
    fn grows_from_small() {
        let mut m = U32Map::with_capacity(1);
        for k in 0..1000u32 {
            m.insert(k, k * 2);
        }
        for k in 0..1000u32 {
            assert_eq!(m.get(k), Some(k * 2));
        }
    }
}
