//! Minimal JSON parser + writer (no serde in this offline image).
//!
//! Covers the full JSON grammar we produce/consume: the artifact
//! manifest written by `python/compile/aot.py` and the result files the
//! experiment harness emits. Numbers are kept as f64 with an i64
//! fast-path accessor.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (f64 storage, i64 fast-path accessor).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys, so output is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing input is an error).
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    /// Read and parse a JSON file.
    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&s).with_context(|| format!("parsing {}", path.display()))
    }

    /// Required object-key lookup (error when absent or not an object).
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    /// Optional object-key lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, or an error for any other variant.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// The numeric value, or an error for any other variant.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// The numeric value truncated to i64.
    pub fn as_i64(&self) -> Result<i64> {
        let x = self.as_f64()?;
        Ok(x as i64)
    }

    /// The numeric value as usize (negative numbers are an error).
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 {
            bail!("negative where usize expected: {x}");
        }
        Ok(x as usize)
    }

    /// The boolean value, or an error for any other variant.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// The array elements, or an error for any other variant.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// The object map, or an error for any other variant.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Serialize with indentation (the format every result file uses).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/inf literal; null keeps the
                    // document parseable
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Object builder for emitting result files.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
/// Number builder.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
/// String builder.
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}
/// Array builder.
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}
/// Numeric-array builder.
pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at offset {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // (surrogate pairs unsupported; not produced
                            // by our writers)
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                c => {
                    // multi-byte utf-8: re-decode from the byte slice
                    let start = self.i - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let sl = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated utf-8"))?;
                    out.push_str(std::str::from_utf8(sl)?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| {
            format!("bad number {s:?} at offset {start}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_pretty();
        let v2 = Json::parse(&out).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "hi", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_i64().unwrap(), 42);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("zz").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café → ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café → ok");
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn non_finite_serializes_as_null() {
        let v = Json::Arr(vec![
            Json::Num(f64::NAN),
            Json::Num(f64::INFINITY),
            Json::Num(1.5),
        ]);
        let out = v.to_string_pretty();
        let back = Json::parse(&out).unwrap();
        let a = back.as_arr().unwrap();
        assert_eq!(a[0], Json::Null);
        assert_eq!(a[1], Json::Null);
        assert_eq!(a[2].as_f64().unwrap(), 1.5);
    }

    #[test]
    fn numbers() {
        let v = Json::parse("[0, -1, 3.5, 1e3, 2.5e-2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[3].as_f64().unwrap(), 1000.0);
        assert!((a[4].as_f64().unwrap() - 0.025).abs() < 1e-12);
    }
}
