//! Self-contained utilities (this offline image ships no crates beyond
//! `xla`/`anyhow`, so RNG, JSON, stats and the bench harness are
//! implemented here).

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod umap;
