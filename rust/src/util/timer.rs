//! Wall-clock timing helpers and a phase-accumulating stopwatch used by
//! the trainer's metrics (sampling vs gather vs step time breakdown).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Accumulates named phase durations across an epoch.
#[derive(Default, Clone)]
pub struct Phases {
    acc: BTreeMap<&'static str, Duration>,
}

impl Phases {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase label.
    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        *self.acc.entry(name).or_default() += t.elapsed();
        out
    }

    pub fn add(&mut self, name: &'static str, d: Duration) {
        *self.acc.entry(name).or_default() += d;
    }

    pub fn get_s(&self, name: &str) -> f64 {
        self.acc
            .iter()
            .find(|(k, _)| **k == name)
            .map(|(_, d)| d.as_secs_f64())
            .unwrap_or(0.0)
    }

    pub fn merge(&mut self, other: &Phases) {
        for (k, v) in &other.acc {
            *self.acc.entry(k).or_default() += *v;
        }
    }

    pub fn report(&self) -> Vec<(String, f64)> {
        self.acc
            .iter()
            .map(|(k, v)| (k.to_string(), v.as_secs_f64()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate() {
        let mut p = Phases::new();
        p.time("a", || std::thread::sleep(Duration::from_millis(2)));
        p.time("a", || std::thread::sleep(Duration::from_millis(2)));
        assert!(p.get_s("a") >= 0.004);
        assert_eq!(p.get_s("missing"), 0.0);
    }
}
