//! Wall-clock timing helpers and a phase-accumulating stopwatch used by
//! the trainer's metrics (sampling vs gather vs step time breakdown).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A started wall-clock stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds since [`Timer::start`].
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds since [`Timer::start`].
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Accumulates named phase durations across an epoch.
#[derive(Default, Clone)]
pub struct Phases {
    acc: BTreeMap<&'static str, Duration>,
}

impl Phases {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase label.
    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        *self.acc.entry(name).or_default() += t.elapsed();
        out
    }

    /// Add an externally-measured duration to a phase.
    pub fn add(&mut self, name: &'static str, d: Duration) {
        *self.acc.entry(name).or_default() += d;
    }

    /// Accumulated seconds under `name` (0 for a phase never timed).
    pub fn get_s(&self, name: &str) -> f64 {
        self.acc.get(name).map(|d| d.as_secs_f64()).unwrap_or(0.0)
    }

    /// Fold another accumulator's phases into this one.
    pub fn merge(&mut self, other: &Phases) {
        for (k, v) in &other.acc {
            *self.acc.entry(k).or_default() += *v;
        }
    }

    /// All phases as `(name, seconds)`, sorted by name.
    pub fn report(&self) -> Vec<(String, f64)> {
        self.acc
            .iter()
            .map(|(k, v)| (k.to_string(), v.as_secs_f64()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate() {
        let mut p = Phases::new();
        p.time("a", || std::thread::sleep(Duration::from_millis(2)));
        p.time("a", || std::thread::sleep(Duration::from_millis(2)));
        assert!(p.get_s("a") >= 0.004);
        assert_eq!(p.get_s("missing"), 0.0);
    }

    /// `get_s` is a keyed map lookup: hits return the exact
    /// accumulated duration, misses (including prefixes/suffixes of a
    /// real key, which a substring scan could confuse) return 0.
    #[test]
    fn get_s_hits_and_misses_by_exact_key() {
        let mut p = Phases::new();
        p.add("sample", Duration::from_secs(2));
        p.add("sample_gather", Duration::from_secs(5));
        assert_eq!(p.get_s("sample"), 2.0);
        assert_eq!(p.get_s("sample_gather"), 5.0);
        assert_eq!(p.get_s("sam"), 0.0, "prefix of a key is a miss");
        assert_eq!(p.get_s("gather"), 0.0, "suffix of a key is a miss");
        assert_eq!(p.get_s(""), 0.0);
    }
}
