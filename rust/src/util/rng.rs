//! Deterministic, seedable RNG (xoshiro256**, split-mix seeded).
//!
//! Every stochastic component in the pipeline (graph generation, root
//! shuffling, neighbor sampling, parameter init) draws from an
//! explicitly-seeded [`Rng`], so all experiments are reproducible from
//! the seed recorded in results files.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 64-bit.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a generator (any u64 seed is fine; split-mix expands it).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Independent child stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift, debiased).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[0, n)` as `usize`.
    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal (Box–Muller; one value per call, simple and fine
    /// for init paths).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            let v = self.f64();
            if u > 1e-12 {
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` items without replacement from `0..n` (partial
    /// Fisher–Yates over an index map; O(k) memory for small k).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        use std::collections::HashMap;
        let k = k.min(n);
        let mut swap: HashMap<usize, usize> = HashMap::with_capacity(2 * k);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            let vj = *swap.get(&j).unwrap_or(&j);
            let vi = *swap.get(&i).unwrap_or(&i);
            out.push(vj);
            swap.insert(j, vi);
        }
        out
    }

    /// Geometric-ish power-law sample in [lo, hi] with exponent `alpha`
    /// (used for community-size and degree skews in the generators).
    pub fn powerlaw(&mut self, lo: f64, hi: f64, alpha: f64) -> f64 {
        // inverse-CDF sampling of p(x) ~ x^-alpha on [lo, hi]
        let u = self.f64();
        if (alpha - 1.0).abs() < 1e-9 {
            (lo.ln() + u * (hi.ln() - lo.ln())).exp()
        } else {
            let a1 = 1.0 - alpha;
            ((lo.powf(a1) + u * (hi.powf(a1) - lo.powf(a1))).powf(1.0 / a1))
                .clamp(lo, hi)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_unique() {
        let mut r = Rng::new(9);
        for _ in 0..50 {
            let k = r.usize_below(20) + 1;
            let s = r.sample_indices(100, k);
            assert_eq!(s.len(), k);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), k, "duplicates in {s:?}");
            assert!(s.iter().all(|&x| x < 100));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
