//! Tiny criterion-style bench harness (criterion itself is not
//! available offline). Used by `cargo bench` targets under
//! `rust/benches/`.

use std::time::Instant;

/// Per-iteration timing summary of one [`bench`] run.
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations (after the warmup call).
    pub iters: u64,
    /// Mean nanoseconds per iteration.
    pub mean_ns: f64,
    /// Sample standard deviation, nanoseconds.
    pub stddev_ns: f64,
    /// Fastest observed iteration, nanoseconds.
    pub min_ns: f64,
}

impl BenchResult {
    /// Print the one-line summary `bench` targets emit.
    pub fn print(&self) {
        let (scale, unit) = pick_unit(self.mean_ns);
        println!(
            "{:<48} {:>10.3} {unit}/iter (±{:.1}%, min {:.3} {unit}, n={})",
            self.name,
            self.mean_ns / scale,
            100.0 * self.stddev_ns / self.mean_ns.max(1e-9),
            self.min_ns / scale,
            self.iters,
        );
    }
}

fn pick_unit(ns: f64) -> (f64, &'static str) {
    if ns >= 1e9 {
        (1e9, "s ")
    } else if ns >= 1e6 {
        (1e6, "ms")
    } else if ns >= 1e3 {
        (1e3, "us")
    } else {
        (1.0, "ns")
    }
}

/// Run `f` repeatedly for ~`budget_s` seconds (after one warmup call)
/// and report per-iteration timing.
pub fn bench<T>(name: &str, budget_s: f64, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let target_iters = ((budget_s / once).ceil() as u64).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(target_iters as usize);
    for _ in 0..target_iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / (n - 1.0).max(1.0);
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters: target_iters,
        mean_ns: mean,
        stddev_ns: var.sqrt(),
        min_ns: min,
    };
    r.print();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs() {
        let r = bench("noop-sum", 0.01, || (0..1000u64).sum::<u64>());
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
    }
}
