//! Dataset materialization: generate (SBM + features) → detect
//! communities (Louvain) → community-reorder → cache to `data/*.bin`.
//!
//! All experiments load through [`load_or_build`], so every run shares
//! identical graphs for a given preset. The paper assumes graphs are
//! already community-ordered (§5); `reorder: false` keeps the shuffled
//! generator order for the §3 / §6.3 original-ordering baselines.

use std::path::PathBuf;

use anyhow::Result;

use crate::community::{community_order, louvain::louvain_capped};
use crate::config::DatasetPreset;
use crate::graph::features::synthesize;
use crate::graph::gen::generate_sbm;
use crate::graph::{io, Dataset};
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Dataset cache directory: `$COMM_RAND_DATA` or `./data`.
pub fn data_dir() -> PathBuf {
    std::env::var("COMM_RAND_DATA")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("data"))
}

/// Build a preset dataset from scratch (no cache).
pub fn build(preset: &DatasetPreset, reorder: bool) -> Dataset {
    let mut rng = Rng::new(preset.gen_seed);
    let g = generate_sbm(&preset.sbm, &mut rng);
    let payload = synthesize(
        &g.gt_community,
        preset.sbm.num_comms,
        &preset.feat,
        &mut rng,
    );
    // community detection on the generated topology (the pipeline uses
    // detected communities, never the generator's ground truth)
    let det = louvain_capped(&g.csr, preset.gen_seed ^ 0x10f2, 2 * 256);
    let mut ds = Dataset {
        name: preset.name.to_string(),
        csr: g.csr,
        features: payload.features,
        feat_dim: preset.feat.feat_dim,
        labels: payload.labels,
        num_classes: preset.feat.num_classes,
        split: payload.split,
        community: det.community,
        num_comms: det.num_comms,
        gt_community: g.gt_community,
    };
    if reorder {
        let perm = community_order(&ds.community);
        ds.permute(&perm);
    }
    ds
}

/// Timed variant used by the §6.5.3 pre-processing-overhead study:
/// returns (dataset, louvain_seconds, permute_seconds).
pub fn build_timed(preset: &DatasetPreset) -> (Dataset, f64, f64) {
    let mut rng = Rng::new(preset.gen_seed);
    let g = generate_sbm(&preset.sbm, &mut rng);
    let payload = synthesize(
        &g.gt_community,
        preset.sbm.num_comms,
        &preset.feat,
        &mut rng,
    );
    let t = Timer::start();
    let det = louvain_capped(&g.csr, preset.gen_seed ^ 0x10f2, 2 * 256);
    let t_louvain = t.elapsed_s();
    let mut ds = Dataset {
        name: preset.name.to_string(),
        csr: g.csr,
        features: payload.features,
        feat_dim: preset.feat.feat_dim,
        labels: payload.labels,
        num_classes: preset.feat.num_classes,
        split: payload.split,
        community: det.community,
        num_comms: det.num_comms,
        gt_community: g.gt_community,
    };
    let t = Timer::start();
    let perm = community_order(&ds.community);
    ds.permute(&perm);
    let t_permute = t.elapsed_s();
    (ds, t_louvain, t_permute)
}

/// Load the cached binary if present, otherwise build and cache it.
pub fn load_or_build(preset: &DatasetPreset, reorder: bool) -> Result<Dataset> {
    let suffix = if reorder { "" } else { ".orig" };
    let path = data_dir().join(format!("{}{}.bin", preset.name, suffix));
    if path.exists() {
        return io::load(&path);
    }
    eprintln!(
        "[data] building {} (reorder={reorder}) -> {}",
        preset.name,
        path.display()
    );
    let ds = build(preset, reorder);
    io::save(&ds, &path)?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    #[test]
    fn tiny_builds_and_reorders() {
        let p = preset("tiny").unwrap();
        let ds = build(&p, true);
        assert_eq!(ds.n(), 2048);
        ds.csr.validate().unwrap();
        // after reordering, community ids are non-decreasing in node id
        for v in 0..ds.n() - 1 {
            assert!(ds.community[v] <= ds.community[v + 1]);
        }
        // detected communities should be reasonable
        assert!(ds.num_comms >= 4, "only {} communities", ds.num_comms);
        let q = crate::graph::stats::modularity(&ds.csr, &ds.community);
        assert!(q > 0.4, "modularity {q}");
    }

    #[test]
    fn unordered_variant_is_shuffled() {
        let p = preset("tiny").unwrap();
        let ds = build(&p, false);
        let mut switches = 0;
        for v in 0..ds.n() - 1 {
            if ds.community[v] != ds.community[v + 1] {
                switches += 1;
            }
        }
        // unordered: communities interleave heavily
        assert!(switches > ds.num_comms * 4, "switches {switches}");
    }
}
