//! Host (pure-rust) training fallback: mini-batch SGD over the
//! [`crate::runtime::host`] reference model, with the same checkpoint
//! cadence hooks as the PJRT trainer.
//!
//! This path exists so the train → checkpoint → serve pipeline works
//! end to end in environments without AOT artifacts or a real PJRT
//! (CI, fresh checkouts): `comm-rand train <preset> backend=host
//! ckpt_dir=...` trains the SGC-style linear model on the 1-hop
//! smoothed features, writes CRC-checked checkpoints every
//! `ckpt_every` epochs, and `serve bench ckpt=...` then reports real
//! top-1 accuracy from the trained parameters. When artifacts exist
//! the PJRT trainer is preferred; the checkpoint format is identical
//! either way.

use anyhow::Result;

use crate::ckpt::{Checkpoint, CheckpointWriter, CkptMeta};
use crate::config::TrainConfig;
use crate::graph::Dataset;
use crate::runtime::host::{
    aggregate_table, init_params, logits_into, param_shapes, top1, HOST_MODEL,
};
use crate::util::rng::Rng;

/// Per-epoch metrics of a host training run.
#[derive(Clone, Debug)]
pub struct HostEpoch {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Mean training cross-entropy over the epoch's batches.
    pub train_loss: f64,
    /// Validation top-1 accuracy after the epoch.
    pub val_acc: f64,
    /// Validation cross-entropy after the epoch.
    pub val_loss: f64,
}

/// Result of [`train_host`]: the metric trace plus the best val acc.
#[derive(Clone, Debug)]
pub struct HostTrainReport {
    /// Dataset trained on.
    pub dataset: String,
    /// Per-epoch metrics, in order.
    pub epochs: Vec<HostEpoch>,
    /// Best validation accuracy seen across epochs.
    pub best_val_acc: f64,
}

impl HostTrainReport {
    /// One-line human summary (printed by `comm-rand train backend=host`).
    pub fn summary(&self) -> String {
        let last = self.epochs.last();
        format!(
            "{} [host-sgc]: {} epochs, best val acc {:.4}, final train \
             loss {:.4}",
            self.dataset,
            self.epochs.len(),
            self.best_val_acc,
            last.map(|e| e.train_loss).unwrap_or(f64::NAN),
        )
    }
}

/// Softmax cross-entropy + gradient accumulation for one example.
/// Returns the example's loss; adds its gradient into `gw`/`gb`.
fn accumulate_example(
    params: &[Vec<f32>],
    feat: &[f32],
    label: usize,
    scratch: &mut [f32],
    gw: &mut [f32],
    gb: &mut [f32],
) -> f64 {
    let c = gb.len();
    logits_into(params, feat, scratch);
    // stable softmax
    let mx = scratch.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut denom = 0f32;
    for x in scratch.iter_mut() {
        *x = (*x - mx).exp();
        denom += *x;
    }
    let inv = 1.0 / denom;
    let mut loss = 0.0f64;
    for (j, x) in scratch.iter_mut().enumerate() {
        let p = *x * inv;
        if j == label {
            loss = -(p.max(1e-12) as f64).ln();
        }
        *x = p - if j == label { 1.0 } else { 0.0 }; // dL/dlogit_j
    }
    for (g, &d) in gb.iter_mut().zip(scratch.iter()) {
        *g += d;
    }
    for (i, &x) in feat.iter().enumerate() {
        if x == 0.0 {
            continue;
        }
        let grow = &mut gw[i * c..(i + 1) * c];
        for (g, &d) in grow.iter_mut().zip(scratch.iter()) {
            *g += x * d;
        }
    }
    loss
}

/// Evaluate (cross-entropy, top-1 accuracy) over `nodes` on the
/// aggregated features.
fn evaluate_host(
    params: &[Vec<f32>],
    agg: &[f32],
    feat_dim: usize,
    num_classes: usize,
    nodes: &[u32],
    labels: &[u16],
) -> (f64, f64) {
    let mut logits = vec![0f32; num_classes];
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for &v in nodes {
        let feat = &agg[v as usize * feat_dim..(v as usize + 1) * feat_dim];
        logits_into(params, feat, &mut logits);
        let y = labels[v as usize] as usize;
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 =
            logits.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln() + mx;
        loss += (lse - logits[y]) as f64;
        if top1(&logits) == y {
            correct += 1;
        }
    }
    let n = nodes.len().max(1) as f64;
    (loss / n, correct as f64 / n)
}

/// Train the host reference model; returns the trained parameters and
/// the metric trace. When `writer` is given, a checkpoint (carrying
/// the epoch's validation metrics and the community fingerprint) is
/// written at the writer's cadence — so the CLI contract matches the
/// PJRT trainer exactly.
pub fn train_host(
    ds: &Dataset,
    cfg: &TrainConfig,
    mut writer: Option<&mut CheckpointWriter>,
    verbose: bool,
) -> Result<(Vec<Vec<f32>>, HostTrainReport)> {
    let f = ds.feat_dim;
    let c = ds.num_classes;
    let agg = aggregate_table(ds);
    let mut params = init_params(f, c, cfg.seed);
    let train_nodes = ds.train_nodes();
    let val_nodes = ds.val_nodes();
    let meta_template = CkptMeta::for_run(
        ds,
        HOST_MODEL,
        "host-sgc",
        cfg.seed,
        param_shapes(f, c),
    );

    let mut rng = Rng::new(cfg.seed ^ 0x5051_C0DE);
    let mut report = HostTrainReport {
        dataset: ds.name.clone(),
        epochs: Vec::new(),
        best_val_acc: 0.0,
    };
    let mut order = train_nodes.clone();
    let mut gw = vec![0f32; f * c];
    let mut gb = vec![0f32; c];
    let mut scratch = vec![0f32; c];
    let bs = cfg.batch_size.max(1);

    for epoch in 0..cfg.max_epochs {
        rng.shuffle(&mut order);
        let mut loss_sum = 0.0f64;
        let mut seen = 0usize;
        for chunk in order.chunks(bs) {
            gw.iter_mut().for_each(|x| *x = 0.0);
            gb.iter_mut().for_each(|x| *x = 0.0);
            for &v in chunk {
                let feat = &agg[v as usize * f..(v as usize + 1) * f];
                loss_sum += accumulate_example(
                    &params,
                    feat,
                    ds.labels[v as usize] as usize,
                    &mut scratch,
                    &mut gw,
                    &mut gb,
                );
            }
            seen += chunk.len();
            let step = cfg.lr / chunk.len() as f32;
            let (w, rest) = params.split_at_mut(1);
            for (x, &g) in w[0].iter_mut().zip(gw.iter()) {
                *x -= step * g;
            }
            for (x, &g) in rest[0].iter_mut().zip(gb.iter()) {
                *x -= step * g;
            }
        }
        let train_loss = loss_sum / seen.max(1) as f64;
        let (val_loss, val_acc) =
            evaluate_host(&params, &agg, f, c, &val_nodes, &ds.labels);
        if verbose {
            println!(
                "epoch {epoch:>3}: train loss {train_loss:.4} | val loss \
                 {val_loss:.4} acc {val_acc:.4}"
            );
        }
        report.best_val_acc = report.best_val_acc.max(val_acc);
        report.epochs.push(HostEpoch { epoch, train_loss, val_acc, val_loss });

        if let Some(w) = writer.as_deref_mut() {
            let mut meta = meta_template.clone();
            meta.epoch = epoch;
            meta.val_acc = val_acc;
            meta.val_loss = val_loss;
            let ck = Checkpoint::new(meta, params.clone())?;
            if let Some(path) = w.maybe_write(&ck)? {
                if verbose {
                    println!("[ckpt] wrote {}", path.display());
                }
            }
        }
    }
    Ok((params, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::Retention;
    use crate::config::preset;

    fn quick_cfg(epochs: usize) -> TrainConfig {
        TrainConfig {
            batch_size: 256,
            lr: 0.5,
            max_epochs: epochs,
            seed: 3,
            ..Default::default()
        }
    }

    #[test]
    fn host_training_learns_well_above_chance() {
        let ds = crate::train::dataset::build(&preset("tiny").unwrap(), true);
        let (_, report) = train_host(&ds, &quick_cfg(4), None, false).unwrap();
        let chance = 1.0 / ds.num_classes as f64;
        assert!(
            report.best_val_acc > chance + 0.2,
            "host model failed to learn: acc {:.3} vs chance {:.3}",
            report.best_val_acc,
            chance
        );
        // loss decreases epoch over epoch (at least front to back)
        let first = report.epochs.first().unwrap().train_loss;
        let last = report.epochs.last().unwrap().train_loss;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn host_training_is_deterministic_in_the_seed() {
        let ds = crate::train::dataset::build(&preset("tiny").unwrap(), true);
        let (p1, r1) = train_host(&ds, &quick_cfg(2), None, false).unwrap();
        let (p2, r2) = train_host(&ds, &quick_cfg(2), None, false).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(
            r1.epochs.last().unwrap().val_acc,
            r2.epochs.last().unwrap().val_acc
        );
    }

    #[test]
    fn checkpoints_written_at_cadence_and_loadable() {
        let ds = crate::train::dataset::build(&preset("tiny").unwrap(), true);
        let dir = std::env::temp_dir()
            .join(format!("comm_rand_host_ck_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut w = CheckpointWriter::new(&dir, 2, Retention::All).unwrap();
        let (params, _) =
            train_host(&ds, &quick_cfg(4), Some(&mut w), false).unwrap();
        // every=2 over 4 epochs → epochs 1 and 3
        assert_eq!(w.entries().len(), 2);
        let latest = w.latest().unwrap();
        assert_eq!(latest.epoch, 3);
        let ck = Checkpoint::load(&latest.path).unwrap();
        ck.validate_against(&ds.community, ds.num_comms).unwrap();
        assert_eq!(ck.meta.model, HOST_MODEL);
        assert_eq!(ck.params, params, "latest checkpoint == final params");
        assert!(!ck.meta.hot_nodes.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
