//! Training orchestration: the epoch loop tying together root
//! partitioning, the pipelined dataloader, the PJRT train step,
//! validation, schedulers and the cache-model instrumentation.

pub mod dataset;
pub mod host;
pub mod loader;
pub mod metrics;
pub mod sched;

pub use host::{train_host, HostEpoch, HostTrainReport};
pub use metrics::{EpochMetrics, TrainReport};

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::batch::assemble;
use crate::ckpt::{Checkpoint, CheckpointWriter, CkptMeta, Retention};
use crate::cachesim::lru::CacheConfig;
use crate::cachesim::{DeviceModel, EpochCost, SetAssocCache, SoftwareCache};
use crate::config::{BatchPolicy, TrainConfig};
use crate::graph::Dataset;
use crate::runtime::artifact::{default_dir, ArtifactMeta, Manifest};
use crate::runtime::{step::eval_logits, Runtime, TrainState};
use crate::sampler::clustergcn::epoch_batches;
use crate::sampler::roots::order_roots;
use crate::sampler::{build_mfg, NeighborPolicy, RootPolicy};
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use loader::{BatchGen, EpochPlan};

/// Shares the PJRT client + manifest across runs of a sweep
/// (compilation is seconds; steps are milliseconds).
pub struct Session {
    /// The PJRT runtime every run of the sweep executes on.
    pub rt: Runtime,
    /// The artifact manifest (`artifacts/manifest.json`).
    pub manifest: Manifest,
    metas: HashMap<String, ArtifactMeta>,
}

impl Session {
    /// Load the manifest and stand up the CPU PJRT client.
    pub fn new() -> Result<Session> {
        let manifest = Manifest::load(&default_dir())?;
        Ok(Session {
            rt: Runtime::cpu()?,
            manifest,
            metas: HashMap::new(),
        })
    }

    /// Cached lookup of one artifact's metadata by manifest name.
    pub fn meta(&mut self, name: &str) -> Result<ArtifactMeta> {
        if let Some(m) = self.metas.get(name) {
            return Ok(m.clone());
        }
        let m = self.manifest.get(name)?.clone();
        self.metas.insert(name.to_string(), m.clone());
        Ok(m)
    }
}

/// Variant selector for one training run.
#[derive(Clone)]
pub enum Method {
    /// COMM-RAND or the uniform baseline (paper §4).
    CommRand(BatchPolicy),
    /// LABOR-0 (§6.3).
    Labor,
    /// ClusterGCN with `q` partitions per batch (§6.3).
    ClusterGcn { q: usize },
}

impl Method {
    /// Human/JSON label of the variant (used in reports and tables).
    pub fn label(&self) -> String {
        match self {
            Method::CommRand(p) => p.label(),
            Method::Labor => "LABOR".into(),
            Method::ClusterGcn { q } => format!("ClusterGCN-q{q}"),
        }
    }
}

/// Training-loop checkpoint cadence (`train ckpt_dir=... ckpt_every=N`).
#[derive(Clone, Debug)]
pub struct CkptConfig {
    /// Directory checkpoints are written into (created if absent).
    pub dir: PathBuf,
    /// Write every N epochs (1 = every epoch).
    pub every: usize,
    /// What stays on disk after each write (default: best + latest).
    pub retention: Retention,
}

/// Extra evaluation knobs (cache-model variants, §6.5).
#[derive(Clone)]
pub struct RunOptions {
    /// Relative L2 capacity (1.0 = the dataset's nominal modelled
    /// cache; 0.5/0.25 are the Fig. 10 MIG variants).
    pub l2_scale: f64,
    /// Dataset-nominal modelled L2 as a fraction of the A100's 40MB
    /// (set from `DatasetPreset::l2_base`; see presets.rs docs).
    pub l2_base: f64,
    /// Software feature cache capacity in rows (Fig. 9); None = off.
    pub sw_cache_rows: Option<usize>,
    /// Sampling worker threads.
    pub workers: usize,
    /// Print per-epoch progress.
    pub verbose: bool,
    /// Override the train-set size (Fig. 8's train-size sweep).
    pub train_subset: Option<usize>,
    /// Checkpoint cadence; `None` writes nothing (the default).
    pub ckpt: Option<CkptConfig>,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            l2_scale: 1.0,
            l2_base: 1.0,
            sw_cache_rows: None,
            workers: default_workers(),
            verbose: false,
            train_subset: None,
            ckpt: None,
        }
    }
}

/// Default sampling-worker count: available cores minus two, clamped
/// to `[1, 8]`.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| (n.get().saturating_sub(2)).clamp(1, 8))
        .unwrap_or(4)
}

/// Convenience wrapper used by the CLI: owns a fresh session.
pub fn run_training(
    ds: &Dataset,
    artifact_base: &str,
    policy: &BatchPolicy,
    cfg: &TrainConfig,
    verbose: bool,
    ckpt: Option<CkptConfig>,
) -> Result<TrainReport> {
    let mut session = Session::new()?;
    let l2_base = crate::config::preset(&ds.name)
        .map(|p| p.l2_base)
        .unwrap_or(1.0);
    let opts = RunOptions { verbose, l2_base, ckpt, ..Default::default() };
    train(
        &mut session,
        ds,
        artifact_base,
        &Method::CommRand(policy.clone()),
        cfg,
        &opts,
    )
}

/// Full training run; returns the per-epoch metric trace.
pub fn train(
    session: &mut Session,
    ds: &Dataset,
    artifact_base: &str,
    method: &Method,
    cfg: &TrainConfig,
    opts: &RunOptions,
) -> Result<TrainReport> {
    let train_meta = session.meta(&format!("{artifact_base}.train"))?;
    let infer_meta = session.meta(&format!("{artifact_base}.infer"))?;
    let spec = train_meta.spec.clone();

    let mut state = TrainState::new(
        &session.rt,
        &train_meta,
        Some(&infer_meta),
        Some(ds),
        cfg.lr,
        cfg.seed,
    )?;

    // training set (optionally subsetted for the Fig. 8 sweep)
    let mut train_nodes = ds.train_nodes();
    if let Some(k) = opts.train_subset {
        let mut rng = Rng::new(cfg.seed ^ 0x5b5);
        rng.shuffle(&mut train_nodes);
        train_nodes.truncate(k);
        train_nodes.sort_unstable();
    }
    let val_nodes = ds.val_nodes();

    // ClusterGCN partitions: target |union of q parts| == batch capacity
    let cluster_parts = if let Method::ClusterGcn { q } = method {
        let num_parts = (ds.n() * q).div_ceil(spec.batch_size.max(1)).max(*q);
        let mut rng = Rng::new(cfg.seed ^ 0xC1);
        Some(crate::community::pack_partitions(
            &ds.community,
            ds.num_comms,
            num_parts,
            &mut rng,
        ))
    } else {
        None
    };

    // schedulers
    let mut plateau =
        sched::ReduceLrOnPlateau::new(cfg.lr, cfg.lr_factor, cfg.lr_patience);
    let mut early = sched::EarlyStop::new(cfg.patience);

    // checkpoint sink (ckpt_dir= / ckpt_every=): parameter shapes come
    // from the artifact's own param specs, so a PJRT checkpoint is
    // re-loadable against the same artifact (set_params validates)
    let mut ckpt_sink = match &opts.ckpt {
        Some(cc) => {
            let shapes: Vec<Vec<usize>> = train_meta
                .param_specs()
                .iter()
                .map(|s| s.shape.clone())
                .collect();
            let template = CkptMeta::for_run(
                ds,
                &spec.model,
                &method.label(),
                cfg.seed,
                shapes,
            );
            Some((
                CheckpointWriter::new(&cc.dir, cc.every, cc.retention)?,
                template,
            ))
        }
        None => None,
    };

    // cache models
    let mut sw_cache = opts
        .sw_cache_rows
        .map(|rows| SoftwareCache::new(rows, ds.n()));
    let device = DeviceModel::default();
    let staged = spec.feat_mode == "staged";

    let mut epoch_rng = Rng::new(cfg.seed ^ 0xE90C);
    let mut report = TrainReport {
        dataset: ds.name.clone(),
        policy: method.label(),
        seed: cfg.seed,
        epochs: Vec::new(),
        converged_epoch: 0,
        best_val_acc: 0.0,
        best_val_loss: f64::INFINITY,
        stopped_early: false,
    };

    for epoch in 0..cfg.max_epochs {
        let epoch_timer = Timer::start();
        // ---- plan the epoch's batches ----
        let (mut batch_roots, gen): (Vec<Vec<u32>>, BatchGen) = match method {
            Method::CommRand(pol) => {
                let order = order_roots(
                    pol.roots,
                    &train_nodes,
                    &ds.community,
                    &mut epoch_rng,
                );
                let policy = if pol.p_intra <= 0.5 {
                    NeighborPolicy::Uniform
                } else {
                    NeighborPolicy::Biased { p: pol.p_intra }
                };
                (
                    order
                        .chunks(cfg.batch_size.min(spec.batch_size))
                        .map(|c| c.to_vec())
                        .collect(),
                    BatchGen::Sampled { policy },
                )
            }
            Method::Labor => {
                let order = order_roots(
                    RootPolicy::Rand,
                    &train_nodes,
                    &ds.community,
                    &mut epoch_rng,
                );
                (
                    order
                        .chunks(cfg.batch_size.min(spec.batch_size))
                        .map(|c| c.to_vec())
                        .collect(),
                    BatchGen::Labor,
                )
            }
            Method::ClusterGcn { q } => {
                let parts = cluster_parts.as_ref().unwrap();
                let sched = epoch_batches(parts.len(), *q, &mut epoch_rng);
                let unions: Vec<Vec<u32>> = sched
                    .into_iter()
                    .map(|ids| {
                        let mut u: Vec<u32> = ids
                            .iter()
                            .flat_map(|&i| parts[i].iter().copied())
                            .collect();
                        u.sort_unstable();
                        u
                    })
                    .collect();
                (unions, BatchGen::Cluster)
            }
        };
        if let Some(maxb) = cfg.max_batches {
            batch_roots.truncate(maxb);
        }
        let plan = EpochPlan {
            batch_roots,
            gen,
            seed: cfg.seed ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        };

        // ---- run the epoch ----
        let mut l2 = SetAssocCache::new(CacheConfig::a100_l2(opts.l2_base * opts.l2_scale));
        let mut cost = EpochCost::default();
        let mut loss_sum = 0.0f64;
        let mut correct_sum = 0.0f64;
        let mut labeled_sum = 0usize;
        let mut input_bytes = Vec::new();
        let mut labels_per_batch = Vec::new();
        let mut step_s = 0.0f64;
        let sw_start = sw_cache.as_ref().map(|c| (c.hits, c.misses));

        let dims = model_dims(&spec);
        {
            let state = &mut state;
            let l2 = &mut l2;
            let cost = &mut cost;
            let sw_cache = &mut sw_cache;
            loader::run_epoch(ds, &train_meta, &plan, opts.workers, true, |_i, batch| {
                // cache replay: the device reads each batch's feature
                // rows twice (forward layer-1 gather + backward d_w
                // gather), so intra-batch reuse is part of the model.
                for _pass in 0..2 {
                    for &v in &batch.access_stream {
                        l2.access_row(v, spec.feat_dim);
                    }
                }
                if let Some(sw) = sw_cache.as_mut() {
                    let mut miss_rows = 0u64;
                    for &v in &batch.access_stream {
                        if !sw.access(v) {
                            miss_rows += 1;
                        }
                    }
                    if staged {
                        cost.uva_bytes +=
                            (miss_rows as f64) * (spec.feat_dim * 4) as f64;
                    }
                } else if staged {
                    cost.uva_bytes += batch.stats.input_bytes as f64;
                }
                cost.add_dense(&batch.stats.level_sizes, &dims);
                cost.batches += 1;
                input_bytes.push(batch.stats.input_bytes as f64);
                labels_per_batch.push(batch.stats.distinct_labels as f64);
                labeled_sum += batch.stats.num_labeled;

                let t = Timer::start();
                let out = state.step(&batch)?;
                step_s += t.elapsed_s();
                loss_sum += out.loss as f64 * batch.stats.num_labeled as f64;
                correct_sum += out.correct as f64;
                Ok(())
            })?;
        }
        cost.add_cache(&l2);
        // per-epoch wall time covers training only (sampling + steps);
        // validation is timed separately, as in the paper's metric
        let wall_s = epoch_timer.elapsed_s();

        // ---- validation ----
        let (val_loss, val_acc) =
            evaluate(&state, ds, &infer_meta, &val_nodes, cfg.seed)?;
        let modeled_s = cost.seconds(&device);
        let nb = cost.batches.max(1);
        let sw_miss = sw_cache
            .as_ref()
            .map(|c| {
                let (h0, m0) = sw_start.unwrap();
                let h = c.hits - h0;
                let m = c.misses - m0;
                if h + m == 0 {
                    0.0
                } else {
                    m as f64 / (h + m) as f64
                }
            })
            .unwrap_or(0.0);
        let em = EpochMetrics {
            epoch,
            train_loss: loss_sum / labeled_sum.max(1) as f64,
            train_acc: correct_sum / labeled_sum.max(1) as f64,
            val_loss,
            val_acc,
            wall_s,
            sample_s: (wall_s - step_s).max(0.0),
            step_s,
            modeled_s,
            l2_miss_rate: l2.miss_rate(),
            sw_miss_rate: sw_miss,
            input_bytes_mean: crate::util::stats::mean(&input_bytes),
            labels_per_batch: crate::util::stats::mean(&labels_per_batch),
            batches: nb,
            lr: state.lr,
        };
        if opts.verbose {
            println!(
                "epoch {:>3}: train loss {:.4} acc {:.3} | val loss {:.4} \
                 acc {:.4} | wall {:.2}s modeled {:.4}s miss {:.3}",
                epoch,
                em.train_loss,
                em.train_acc,
                em.val_loss,
                em.val_acc,
                em.wall_s,
                em.modeled_s,
                em.l2_miss_rate
            );
        }
        report.epochs.push(em);
        if let Some((writer, template)) = ckpt_sink.as_mut() {
            let mut meta = template.clone();
            meta.epoch = epoch;
            meta.val_acc = val_acc;
            meta.val_loss = val_loss;
            let ck = Checkpoint::new(meta, state.params.clone())?;
            if let Some(path) = writer.maybe_write(&ck)? {
                if opts.verbose {
                    println!("[ckpt] wrote {}", path.display());
                }
            }
        }
        if val_acc > report.best_val_acc {
            report.best_val_acc = val_acc;
        }
        if val_loss < report.best_val_loss {
            report.best_val_loss = val_loss;
        }
        state.lr = plateau.step(val_loss);
        if early.step(val_loss) {
            report.stopped_early = true;
            break;
        }
    }
    report.converged_epoch = early_best(&early, report.epochs.len());
    Ok(report)
}

fn early_best(early: &sched::EarlyStop, total: usize) -> usize {
    if early.best_epoch > 0 {
        early.best_epoch
    } else {
        total.max(1)
    }
}

fn model_dims(spec: &crate::runtime::artifact::SpecMeta) -> Vec<usize> {
    // hidden width is constant (64) across our artifact specs — see
    // python/compile/specs.py; only used for the modelled FLOP term.
    let mut dims = vec![spec.feat_dim];
    for _ in 0..spec.layers.saturating_sub(1) {
        dims.push(64);
    }
    dims.push(spec.num_classes);
    dims
}

/// Sampled validation with a fixed seed, so early stopping sees a
/// stable objective across epochs and policies.
pub fn evaluate(
    state: &TrainState,
    ds: &Dataset,
    infer_meta: &ArtifactMeta,
    val_nodes: &[u32],
    seed: u64,
) -> Result<(f64, f64)> {
    let spec = &infer_meta.spec;
    let mut rng = Rng::new(seed ^ 0xEAA1);
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut count = 0usize;
    for chunk in val_nodes.chunks(spec.batch_size) {
        let mfg = build_mfg(
            &ds.csr,
            &ds.community,
            chunk,
            &spec.fanouts,
            NeighborPolicy::Uniform,
            &mut rng,
        );
        let batch = assemble(&mfg, ds, infer_meta, false)?;
        let logits = state.infer(&batch)?;
        let (l, c) = eval_logits(&logits, spec.num_classes, chunk, &ds.labels);
        loss_sum += l * chunk.len() as f64;
        correct += c;
        count += chunk.len();
    }
    Ok((
        loss_sum / count.max(1) as f64,
        correct as f64 / count.max(1) as f64,
    ))
}

/// Test-set accuracy with the current parameters (Table 3).
pub fn test_accuracy(
    state: &TrainState,
    ds: &Dataset,
    infer_meta: &ArtifactMeta,
    seed: u64,
) -> Result<f64> {
    let nodes = ds.test_nodes();
    let (_, acc) = evaluate(state, ds, infer_meta, &nodes, seed ^ 0x7E57)?;
    Ok(acc)
}
