//! Training schedulers: ReduceLROnPlateau (torch semantics, paper §5)
//! and validation-loss early stopping (paper: patience 6).

/// torch.optim.lr_scheduler.ReduceLROnPlateau (mode=min, default
/// threshold 1e-4 rel).
pub struct ReduceLrOnPlateau {
    /// Current learning rate (reduced in place on plateaus).
    pub lr: f32,
    factor: f32,
    patience: usize,
    best: f64,
    bad_epochs: usize,
    threshold: f64,
    min_lr: f32,
}

impl ReduceLrOnPlateau {
    /// Scheduler starting at `lr`, multiplying by `factor` after
    /// `patience` epochs without relative improvement.
    pub fn new(lr: f32, factor: f32, patience: usize) -> Self {
        ReduceLrOnPlateau {
            lr,
            factor,
            patience,
            best: f64::INFINITY,
            bad_epochs: 0,
            threshold: 1e-4,
            min_lr: 1e-8,
        }
    }

    /// Feed this epoch's validation loss; returns the (possibly
    /// reduced) learning rate.
    pub fn step(&mut self, val_loss: f64) -> f32 {
        if val_loss < self.best * (1.0 - self.threshold) {
            self.best = val_loss;
            self.bad_epochs = 0;
        } else {
            self.bad_epochs += 1;
            if self.bad_epochs > self.patience {
                self.lr = (self.lr * self.factor).max(self.min_lr);
                self.bad_epochs = 0;
            }
        }
        self.lr
    }
}

/// Early stopping on validation loss (paper: stop after `patience`
/// epochs without improvement).
pub struct EarlyStop {
    patience: usize,
    best: f64,
    bad_epochs: usize,
    /// 1-based epoch of the best validation loss seen so far (0 until
    /// the first improvement).
    pub best_epoch: usize,
    epoch: usize,
}

impl EarlyStop {
    /// Stop after `patience` epochs without validation-loss improvement.
    pub fn new(patience: usize) -> Self {
        EarlyStop {
            patience,
            best: f64::INFINITY,
            bad_epochs: 0,
            best_epoch: 0,
            epoch: 0,
        }
    }

    /// Returns true when training should stop.
    pub fn step(&mut self, val_loss: f64) -> bool {
        self.epoch += 1;
        if val_loss < self.best - 1e-6 {
            self.best = val_loss;
            self.best_epoch = self.epoch;
            self.bad_epochs = 0;
            false
        } else {
            self.bad_epochs += 1;
            self.bad_epochs >= self.patience
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plateau_reduces_after_patience() {
        let mut s = ReduceLrOnPlateau::new(1.0, 0.1, 2);
        assert_eq!(s.step(1.0), 1.0); // best=1.0
        assert_eq!(s.step(1.0), 1.0); // bad 1
        assert_eq!(s.step(1.0), 1.0); // bad 2
        let lr = s.step(1.0); // bad 3 > patience -> reduce
        assert!((lr - 0.1).abs() < 1e-6);
    }

    #[test]
    fn plateau_resets_on_improvement() {
        let mut s = ReduceLrOnPlateau::new(1.0, 0.5, 1);
        s.step(1.0);
        s.step(1.0);
        s.step(0.5); // improvement resets
        s.step(0.49999); // not enough relative improvement -> bad 1
        let lr = s.step(0.49999); // bad 2 -> reduce
        assert!((lr - 0.5).abs() < 1e-6);
    }

    #[test]
    fn early_stop_fires() {
        let mut e = EarlyStop::new(3);
        assert!(!e.step(1.0));
        assert!(!e.step(0.9));
        assert!(!e.step(0.95));
        assert!(!e.step(0.95));
        assert!(e.step(0.95)); // 3 bad epochs
        assert_eq!(e.best_epoch, 2);
    }
}
