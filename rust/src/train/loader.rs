//! Pipelined mini-batch dataloader.
//!
//! Mirrors DGL's DataLoader: sampling + padding run on worker threads
//! while the main thread drives the device. Batches are independent
//! jobs with per-batch RNGs derived from `(seed, epoch, batch index)`,
//! so results are bit-identical regardless of worker count or
//! scheduling; a bounded channel provides backpressure and an in-order
//! reassembly buffer preserves the gradient-update sequence.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;

use anyhow::Result;

use crate::batch::{assemble, PaddedBatch};
use crate::graph::Dataset;
use crate::runtime::artifact::ArtifactMeta;
use crate::sampler::clustergcn::build_mfg_cluster;
use crate::sampler::labor::build_mfg_labor;
use crate::sampler::{build_mfg, NeighborPolicy};
use crate::util::rng::Rng;

/// How batches are generated for one epoch.
#[derive(Clone)]
pub enum BatchGen {
    /// COMM-RAND / baseline: root slices + (possibly biased) sampling.
    Sampled { policy: NeighborPolicy },
    /// LABOR-0 baseline.
    Labor,
    /// ClusterGCN: each "slice" is the union of q partitions.
    Cluster,
}

/// One epoch's worth of batch jobs.
pub struct EpochPlan {
    /// Root sets, one per batch (already policy-ordered).
    pub batch_roots: Vec<Vec<u32>>,
    /// How each root set becomes an MFG.
    pub gen: BatchGen,
    /// Base RNG seed; per-batch streams are forked from this.
    pub seed: u64,
}

fn batch_rng(seed: u64, index: usize) -> Rng {
    Rng::new(
        seed ^ (index as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93) ^ 0xA5A5,
    )
}

/// Build one batch (worker-side work).
fn build_batch(
    ds: &Dataset,
    meta: &ArtifactMeta,
    gen: &BatchGen,
    roots: &[u32],
    rng: &mut Rng,
    use_labels: bool,
) -> Result<PaddedBatch> {
    let spec = &meta.spec;
    let mfg = match gen {
        BatchGen::Sampled { policy } => build_mfg(
            &ds.csr,
            &ds.community,
            roots,
            &spec.fanouts,
            *policy,
            rng,
        ),
        BatchGen::Labor => {
            build_mfg_labor(&ds.csr, roots, &spec.fanouts, rng)
        }
        BatchGen::Cluster => build_mfg_cluster(
            &ds.csr,
            roots,
            &spec.fanouts,
            spec.batch_size,
            rng,
        ),
    };
    assemble(&mfg, ds, meta, use_labels)
}

/// Run `consume(batch_index, batch)` over every batch of the plan, in
/// order, with sampling pipelined over `workers` threads.
pub fn run_epoch<F>(
    ds: &Dataset,
    meta: &ArtifactMeta,
    plan: &EpochPlan,
    workers: usize,
    use_labels: bool,
    mut consume: F,
) -> Result<()>
where
    F: FnMut(usize, PaddedBatch) -> Result<()>,
{
    let n_batches = plan.batch_roots.len();
    if n_batches == 0 {
        return Ok(());
    }
    let workers = workers.clamp(1, n_batches);
    if workers == 1 {
        // in-line fast path (also used by unit tests)
        for (i, roots) in plan.batch_roots.iter().enumerate() {
            let mut rng = batch_rng(plan.seed, i);
            let b = build_batch(ds, meta, &plan.gen, roots, &mut rng, use_labels)?;
            consume(i, b)?;
        }
        return Ok(());
    }

    let next_job = AtomicUsize::new(0);
    let (tx, rx) = sync_channel::<(usize, Result<PaddedBatch>)>(workers * 2);
    let mut result: Result<()> = Ok(());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next_job = &next_job;
            let gen = plan.gen.clone();
            scope.spawn(move || loop {
                let i = next_job.fetch_add(1, Ordering::Relaxed);
                if i >= n_batches {
                    break;
                }
                let mut rng = batch_rng(plan.seed, i);
                let b = build_batch(
                    ds,
                    meta,
                    &gen,
                    &plan.batch_roots[i],
                    &mut rng,
                    use_labels,
                );
                if tx.send((i, b)).is_err() {
                    break; // consumer bailed
                }
            });
        }
        drop(tx);

        // consume in order
        let mut pending: BTreeMap<usize, PaddedBatch> = BTreeMap::new();
        let mut want = 0usize;
        for (i, b) in rx.iter() {
            match b {
                Err(e) => {
                    result = Err(e);
                    break;
                }
                Ok(b) => {
                    pending.insert(i, b);
                }
            }
            while let Some(b) = pending.remove(&want) {
                if let Err(e) = consume(want, b) {
                    result = Err(e);
                    break;
                }
                want += 1;
            }
            if result.is_err() {
                break;
            }
        }
        if result.is_ok() {
            while let Some(b) = pending.remove(&want) {
                if let Err(e) = consume(want, b) {
                    result = Err(e);
                    break;
                }
                want += 1;
            }
        }
        // drain so workers unblock and the scope can join
        drop(rx);
    });
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::runtime::artifact::{DType, IoSpec, SpecMeta};
    use crate::train::dataset::build;

    fn tiny_meta() -> ArtifactMeta {
        ArtifactMeta {
            name: "tiny.test".into(),
            file: "/dev/null".into(),
            kind: "train".into(),
            spec: SpecMeta {
                model: "sage".into(),
                layers: 2,
                fanouts: vec![5, 5],
                idx_widths: vec![5, 5],
                batch_size: 128,
                num_nodes: 2048,
                feat_dim: 32,
                num_classes: 7,
                heads: 1,
                feat_mode: "resident".into(),
                node_caps: vec![2048, 768, 128],
                padded_edges: 0,
                edge_chunk: 0,
            },
            inputs: vec![IoSpec {
                name: "p.x".into(),
                shape: vec![1],
                dtype: DType::F32,
            }],
            outputs: vec![],
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let ds = build(&preset("tiny").unwrap(), true);
        let meta = tiny_meta();
        let train = ds.train_nodes();
        let batch_roots: Vec<Vec<u32>> =
            train.chunks(128).take(6).map(|c| c.to_vec()).collect();
        let plan = EpochPlan {
            batch_roots,
            gen: BatchGen::Sampled { policy: NeighborPolicy::Uniform },
            seed: 99,
        };
        let mut ser: Vec<(usize, usize, Vec<i32>)> = vec![];
        run_epoch(&ds, &meta, &plan, 1, true, |i, b| {
            ser.push((i, b.stats.input_nodes, b.layers[0].idx.clone()));
            Ok(())
        })
        .unwrap();
        let mut par: Vec<(usize, usize, Vec<i32>)> = vec![];
        run_epoch(&ds, &meta, &plan, 4, true, |i, b| {
            par.push((i, b.stats.input_nodes, b.layers[0].idx.clone()));
            Ok(())
        })
        .unwrap();
        assert_eq!(ser.len(), par.len());
        for (a, b) in ser.iter().zip(&par) {
            assert_eq!(a, b, "parallel loader diverged from serial");
        }
        // in-order delivery
        for (k, (i, _, _)) in par.iter().enumerate() {
            assert_eq!(k, *i);
        }
    }

    /// The module-header contract, checked bit-for-bit: every array of
    /// every batch (neighbor indices, weights, self positions, labels,
    /// masks) is identical whether sampling runs on 1 or 4 workers —
    /// including under the community-biased neighbor policy, whose
    /// per-batch RNG must not depend on scheduling.
    #[test]
    fn worker_count_never_changes_batch_bits() {
        let ds = build(&preset("tiny").unwrap(), true);
        let meta = tiny_meta();
        let train = ds.train_nodes();
        let batch_roots: Vec<Vec<u32>> =
            train.chunks(96).take(8).map(|c| c.to_vec()).collect();
        let plan = EpochPlan {
            batch_roots,
            gen: BatchGen::Sampled {
                policy: NeighborPolicy::Biased { p: 0.9 },
            },
            seed: 0xD00D,
        };
        type Snap = (Vec<Vec<i32>>, Vec<Vec<f32>>, Vec<Vec<i32>>, Vec<i32>, Vec<f32>);
        let capture = |workers: usize| -> Vec<Snap> {
            let mut out: Vec<Snap> = vec![];
            run_epoch(&ds, &meta, &plan, workers, true, |_i, b| {
                out.push((
                    b.layers.iter().map(|l| l.idx.clone()).collect(),
                    b.layers.iter().map(|l| l.w.clone()).collect(),
                    b.layers.iter().map(|l| l.self_idx.clone()).collect(),
                    b.labels.clone(),
                    b.lmask.clone(),
                ));
                Ok(())
            })
            .unwrap();
            out
        };
        let one = capture(1);
        let four = capture(4);
        assert_eq!(one.len(), four.len());
        for (k, (a, b)) in one.iter().zip(&four).enumerate() {
            assert_eq!(a, b, "batch {k} differs between 1 and 4 workers");
        }
    }

    #[test]
    fn error_propagates() {
        let ds = build(&preset("tiny").unwrap(), true);
        let meta = tiny_meta();
        let plan = EpochPlan {
            batch_roots: vec![vec![0u32; 16]; 4],
            gen: BatchGen::Sampled { policy: NeighborPolicy::Uniform },
            seed: 1,
        };
        let r = run_epoch(&ds, &meta, &plan, 2, true, |i, _| {
            if i == 1 {
                anyhow::bail!("boom")
            } else {
                Ok(())
            }
        });
        assert!(r.is_err());
    }
}
