//! Per-epoch and per-run training metrics (everything the paper's
//! figures consume), plus JSON result emission.

use crate::util::json::{arr, arr_f64, num, obj, s, Json};

/// Everything measured for one training epoch.
#[derive(Clone, Debug, Default)]
pub struct EpochMetrics {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Mean training cross-entropy over the epoch's labeled roots.
    pub train_loss: f64,
    /// Training top-1 accuracy over the epoch's labeled roots.
    pub train_acc: f64,
    /// Sampled-validation cross-entropy after the epoch.
    pub val_loss: f64,
    /// Sampled-validation top-1 accuracy after the epoch.
    pub val_acc: f64,
    /// Measured wall-clock (s): whole epoch / sampling / device step.
    pub wall_s: f64,
    /// Wall-clock spent sampling/assembling (wall minus device step).
    pub sample_s: f64,
    /// Wall-clock spent in the PJRT train step.
    pub step_s: f64,
    /// Modelled device epoch time (cachesim::timemodel).
    pub modeled_s: f64,
    /// Modelled L2 miss rate over the epoch's feature accesses.
    pub l2_miss_rate: f64,
    /// Software feature-cache miss rate (0 when the cache is off).
    pub sw_miss_rate: f64,
    /// Mean per-batch input feature bytes (Fig. 6 x-axis).
    pub input_bytes_mean: f64,
    /// Mean distinct labels per batch (Fig. 7 x-axis).
    pub labels_per_batch: f64,
    /// Batches processed this epoch.
    pub batches: usize,
    /// Learning rate in effect during the epoch.
    pub lr: f32,
}

/// Full-run training report: per-epoch trace plus run-level summary
/// fields (what every experiment table consumes).
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Dataset trained on.
    pub dataset: String,
    /// Label of the batching policy/method.
    pub policy: String,
    /// Run seed.
    pub seed: u64,
    /// Per-epoch metrics, in order.
    pub epochs: Vec<EpochMetrics>,
    /// Epochs until convergence (early-stop best epoch, or max).
    pub converged_epoch: usize,
    /// Best validation accuracy across epochs.
    pub best_val_acc: f64,
    /// Best validation loss across epochs.
    pub best_val_loss: f64,
    /// Whether early stopping ended the run before `max_epochs`.
    pub stopped_early: bool,
}

impl TrainReport {
    /// Total measured wall time across epochs, seconds.
    pub fn total_wall_s(&self) -> f64 {
        self.epochs.iter().map(|e| e.wall_s).sum()
    }

    /// Total modelled device time across epochs, seconds.
    pub fn total_modeled_s(&self) -> f64 {
        self.epochs.iter().map(|e| e.modeled_s).sum()
    }

    /// Modelled time-to-convergence (the paper's "total training time").
    pub fn modeled_to_convergence(&self) -> f64 {
        self.epochs
            .iter()
            .take(self.converged_epoch)
            .map(|e| e.modeled_s)
            .sum()
    }

    /// Measured wall time to convergence, seconds.
    pub fn wall_to_convergence(&self) -> f64 {
        self.epochs
            .iter()
            .take(self.converged_epoch)
            .map(|e| e.wall_s)
            .sum()
    }

    /// Mean modelled epoch time, seconds.
    pub fn mean_epoch_modeled_s(&self) -> f64 {
        let n = self.epochs.len().max(1);
        self.total_modeled_s() / n as f64
    }

    /// Mean measured epoch wall time, seconds.
    pub fn mean_epoch_wall_s(&self) -> f64 {
        let n = self.epochs.len().max(1);
        self.total_wall_s() / n as f64
    }

    /// Mean per-batch input feature bytes, averaged over epochs.
    pub fn mean_input_bytes(&self) -> f64 {
        let n = self.epochs.len().max(1);
        self.epochs.iter().map(|e| e.input_bytes_mean).sum::<f64>() / n as f64
    }

    /// Mean distinct labels per batch, averaged over epochs.
    pub fn mean_labels_per_batch(&self) -> f64 {
        let n = self.epochs.len().max(1);
        self.epochs.iter().map(|e| e.labels_per_batch).sum::<f64>() / n as f64
    }

    /// One-line human summary (printed by `comm-rand train`).
    pub fn summary(&self) -> String {
        format!(
            "{} [{}] seed {}: {} epochs (converged {}), best val acc {:.4}, \
             per-epoch wall {:.3}s / modeled {:.4}s, total wall {:.1}s",
            self.dataset,
            self.policy,
            self.seed,
            self.epochs.len(),
            self.converged_epoch,
            self.best_val_acc,
            self.mean_epoch_wall_s(),
            self.mean_epoch_modeled_s(),
            self.total_wall_s(),
        )
    }

    /// Serialize the report (the experiment harness' JSON artifact).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("dataset", s(&self.dataset)),
            ("policy", s(&self.policy)),
            ("seed", num(self.seed as f64)),
            ("converged_epoch", num(self.converged_epoch as f64)),
            ("best_val_acc", num(self.best_val_acc)),
            ("best_val_loss", num(self.best_val_loss)),
            ("stopped_early", Json::Bool(self.stopped_early)),
            ("total_wall_s", num(self.total_wall_s())),
            ("total_modeled_s", num(self.total_modeled_s())),
            (
                "val_acc",
                arr_f64(&self.epochs.iter().map(|e| e.val_acc).collect::<Vec<_>>()),
            ),
            (
                "val_loss",
                arr_f64(&self.epochs.iter().map(|e| e.val_loss).collect::<Vec<_>>()),
            ),
            (
                "train_loss",
                arr_f64(&self.epochs.iter().map(|e| e.train_loss).collect::<Vec<_>>()),
            ),
            (
                "epoch_wall_s",
                arr_f64(&self.epochs.iter().map(|e| e.wall_s).collect::<Vec<_>>()),
            ),
            (
                "epoch_modeled_s",
                arr_f64(&self.epochs.iter().map(|e| e.modeled_s).collect::<Vec<_>>()),
            ),
            (
                "l2_miss_rate",
                arr_f64(&self.epochs.iter().map(|e| e.l2_miss_rate).collect::<Vec<_>>()),
            ),
            (
                "input_bytes_mean",
                arr_f64(
                    &self
                        .epochs
                        .iter()
                        .map(|e| e.input_bytes_mean)
                        .collect::<Vec<_>>(),
                ),
            ),
            ("epochs", arr(vec![])),
        ])
    }
}
