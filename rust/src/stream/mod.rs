//! Dynamic-graph mutation subsystem: streaming edge/feature updates
//! with incremental community maintenance and versioned cache
//! invalidation.
//!
//! Everything COMM-RAND builds on — the reorder, the shard plan, the
//! feature caches, the checkpoint fingerprint fence — assumes the
//! Louvain structure is computed once and frozen. Real graphs mutate
//! continuously, and the locality benefits evaporate once the
//! partitioning drifts from the live topology. This subsystem opens
//! that workload:
//!
//! * [`update`] — the ingest log: timestamped edge inserts/deletes and
//!   feature-row rewrites, batched into epochs and applied atomically.
//! * Topology epochs land as a **versioned CSR delta-overlay**
//!   ([`crate::graph::TopoSnapshot`]): immutable snapshots layered
//!   over a frozen base CSR, so in-flight samplers keep reading a
//!   consistent graph; the overlay auto-compacts into a fresh base
//!   when it grows.
//! * [`maintainer`] — **incremental community maintenance**: a bounded
//!   Louvain-style local-move wave re-refines labels only around the
//!   vertices an epoch touched, tracks a modularity-drift metric
//!   against the last full detection, and triggers a stop-the-world
//!   full relabel (new shard plan, flushed caches, new community
//!   fingerprint — fencing stale checkpoints through the existing
//!   [`crate::ckpt`] validation) when drift crosses the threshold.
//! * [`state`] — the shared run state: topology cell, the
//!   **versioned feature overlay** (rewritten rows carry a monotone
//!   feature version; cache slots remember the version they staged,
//!   so rewrites turn cached copies *stale* — counted separately and
//!   served like misses), counters and the end-of-run
//!   [`StreamReport`].
//! * [`churn`] — the synthetic churn generator `serve bench
//!   mutate=RATE` drives alongside the load generator.
//!
//! The serving engine consumes all of this through snapshot-versioned
//! access: workers sample against `Arc<TopoSnapshot>`, route against
//! `Arc<LabelSnapshot>` ([`crate::serve::shard::LabelCell`]) and stage
//! features through the version-tagged cache. `comm-rand exp stream`
//! sweeps throughput and accuracy against churn rate with incremental
//! vs. naive full-relabel maintenance; the update lifecycle diagram
//! lives in `docs/ARCHITECTURE.md`.

pub mod churn;
pub mod maintainer;
pub mod state;
pub mod update;

pub use churn::{churn_loop, churn_loop_observed, churn_loop_traced, ChurnGen};
pub use maintainer::CommunityMaintainer;
pub use state::{
    FeatureOverlay, StreamConfig, StreamCounters, StreamReport, StreamState,
};
pub use update::{Mutation, Timestamped, UpdateEpoch, UpdateLog};

use anyhow::{bail, Result};

/// How the community labeling follows the mutating topology.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaintenanceMode {
    /// Bounded local refinement around touched vertices per epoch;
    /// full relabel only when modularity drift crosses the threshold.
    Incremental,
    /// Naive baseline: a stop-the-world full Louvain relabel (plus
    /// shard-plan rebuild and cache flush) on every update epoch.
    Full,
}

impl MaintenanceMode {
    /// Parse the CLI knob: `incr | full`.
    pub fn parse(s: &str) -> Result<MaintenanceMode> {
        match s {
            "incr" | "incremental" => Ok(MaintenanceMode::Incremental),
            "full" | "naive" => Ok(MaintenanceMode::Full),
            other => {
                bail!("unknown maintenance mode {other:?} (try: incr | full)")
            }
        }
    }

    /// The knob spelling this mode parses from.
    pub fn name(&self) -> &'static str {
        match self {
            MaintenanceMode::Incremental => "incr",
            MaintenanceMode::Full => "full",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maintenance_mode_parses_and_round_trips() {
        assert_eq!(
            MaintenanceMode::parse("incr").unwrap(),
            MaintenanceMode::Incremental
        );
        assert_eq!(
            MaintenanceMode::parse("incremental").unwrap(),
            MaintenanceMode::Incremental
        );
        assert_eq!(
            MaintenanceMode::parse("full").unwrap(),
            MaintenanceMode::Full
        );
        assert_eq!(
            MaintenanceMode::parse("naive").unwrap(),
            MaintenanceMode::Full
        );
        assert_eq!(MaintenanceMode::Incremental.name(), "incr");
        assert_eq!(MaintenanceMode::Full.name(), "full");
        assert!(MaintenanceMode::parse("bogus").is_err());
    }
}
