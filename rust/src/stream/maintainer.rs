//! Incremental community maintenance: keep the Louvain labeling
//! aligned with a mutating topology without re-running Louvain.
//!
//! The maintainer owns the live label array plus O(num_comms)
//! bookkeeping — per-community degree sums and intra-edge counts, the
//! total edge weight and the sum of squared community degrees — which
//! is exactly enough to evaluate Newman modularity in O(1) and a
//! single-vertex move gain in O(deg). Three operations:
//!
//! * [`CommunityMaintainer::note_edge`] — O(1) counter update per
//!   applied edge insert/delete;
//! * [`CommunityMaintainer::refine`] — a bounded local-move wave over
//!   the vertices an update epoch touched (plus a one-hop ripple from
//!   every vertex that moves): each vertex greedily joins the
//!   neighboring community with the best modularity gain, the same
//!   move rule as Louvain's phase 1, but evaluated only where the
//!   graph actually changed;
//! * [`CommunityMaintainer::full_relabel`] — the escape hatch: when
//!   [`CommunityMaintainer::drift`] (relative modularity loss since
//!   the last full detection) crosses the configured threshold, run
//!   [`louvain_capped`] from scratch over the compacted topology and
//!   reset the baseline. The caller is responsible for republishing
//!   the shard plan and the community fingerprint — a full relabel
//!   changes what node labels *mean*, which is why it also fences
//!   checkpoints (see `docs/ARCHITECTURE.md`).
//!
//! Local moves deliberately never create or renumber communities, so
//! between full relabels the community id space — and therefore the
//! community → shard plan and the checkpoint fence fingerprint's
//! *generation* — stays stable; only vertex membership drifts.
//!
//! Under request tracing the maintenance work done here is visible on
//! the dedicated maintainer track: the engine's churn thread brackets
//! each applied epoch with a `Churn` event (updates applied, vertices
//! moved) and marks full relabels with `Relabel` instants
//! ([`crate::stream::churn::churn_loop_traced`]), so refinement stalls
//! line up against the shard tracks' request spans in Perfetto.

use std::collections::HashMap;

use crate::community::louvain::louvain_capped;
use crate::graph::{Csr, Topology};

/// One applied vertex move: `(vertex, old_community, new_community)`.
pub type Move = (u32, u32, u32);

/// Incremental Louvain-label maintainer (see the module docs).
pub struct CommunityMaintainer {
    labels: Vec<u32>,
    num_comms: usize,
    /// Total directed edge weight (2m).
    two_m: f64,
    /// Per-community degree sums.
    deg: Vec<f64>,
    /// Per-community directed intra-edge counts.
    intra: Vec<f64>,
    sum_sq: f64,
    intra_total: f64,
    /// Modularity at the last full detection (the drift baseline).
    q_baseline: f64,
    /// Vertices moved by `refine` since the last full relabel.
    moved_since_full: usize,
}

impl CommunityMaintainer {
    /// Build from a topology and its current labeling (O(E) scan).
    pub fn new<T: Topology + ?Sized>(
        topo: &T,
        labels: Vec<u32>,
        num_comms: usize,
    ) -> CommunityMaintainer {
        let n = topo.num_nodes();
        assert_eq!(labels.len(), n);
        let mut deg = vec![0f64; num_comms.max(1)];
        let mut intra = vec![0f64; num_comms.max(1)];
        let mut two_m = 0f64;
        for v in 0..n as u32 {
            let cv = labels[v as usize] as usize;
            let d = topo.degree(v) as f64;
            deg[cv] += d;
            two_m += d;
            for &u in topo.neighbors(v) {
                if labels[u as usize] as usize == cv {
                    intra[cv] += 1.0;
                }
            }
        }
        let sum_sq = deg.iter().map(|d| d * d).sum();
        let intra_total = intra.iter().sum();
        let mut m = CommunityMaintainer {
            labels,
            num_comms,
            two_m,
            deg,
            intra,
            sum_sq,
            intra_total,
            q_baseline: 0.0,
            moved_since_full: 0,
        };
        m.q_baseline = m.modularity();
        m
    }

    /// The live label array (node → community).
    pub fn labels(&self) -> &[u32] {
        &self.labels
    }

    /// Size of the community id space (fixed between full relabels).
    pub fn num_comms(&self) -> usize {
        self.num_comms
    }

    /// Vertices moved by refinement since the last full relabel.
    pub fn moved_since_full(&self) -> usize {
        self.moved_since_full
    }

    /// Newman modularity of the current labeling over the live
    /// topology, from the incremental counters (O(1)).
    pub fn modularity(&self) -> f64 {
        if self.two_m <= 0.0 {
            return 0.0;
        }
        self.intra_total / self.two_m - self.sum_sq / (self.two_m * self.two_m)
    }

    /// Modularity baseline captured at the last full detection.
    pub fn baseline(&self) -> f64 {
        self.q_baseline
    }

    /// Relative modularity loss since the last full detection, in
    /// `[0, ∞)`; 0 while the labeling still fits the topology.
    pub fn drift(&self) -> f64 {
        (self.q_baseline - self.modularity()).max(0.0)
            / self.q_baseline.abs().max(1e-6)
    }

    /// Fold one *applied* edge insert/delete into the counters. Must
    /// mirror exactly the updates the topology snapshot accepted
    /// ([`crate::graph::TopoSnapshot::apply`]'s `applied` list).
    pub fn note_edge(&mut self, u: u32, v: u32, insert: bool) {
        let s = if insert { 1.0 } else { -1.0 };
        let cu = self.labels[u as usize] as usize;
        let cv = self.labels[v as usize] as usize;
        self.two_m += 2.0 * s;
        for c in [cu, cv] {
            self.sum_sq -= self.deg[c] * self.deg[c];
            self.deg[c] += s;
            self.sum_sq += self.deg[c] * self.deg[c];
        }
        if cu == cv {
            self.intra[cu] += 2.0 * s;
            self.intra_total += 2.0 * s;
        }
    }

    /// One bounded local-move wave over `touched` (plus a one-hop
    /// ripple from every vertex that moves). Returns the applied
    /// moves. `topo` must already include the epoch's edge updates.
    pub fn refine<T: Topology + ?Sized>(
        &mut self,
        topo: &T,
        touched: &[u32],
    ) -> Vec<Move> {
        let mut queue: Vec<u32> = touched.to_vec();
        queue.sort_unstable();
        queue.dedup();
        let budget = (queue.len() * 4).max(64);
        let mut moves = Vec::new();
        let mut visited = 0usize;
        let mut head = 0usize;
        let mut nbr_w: HashMap<u32, f64> = HashMap::new();
        let mut cands: Vec<(u32, f64)> = Vec::new();
        let two_m = self.two_m.max(1e-9);
        while head < queue.len() && visited < budget {
            let v = queue[head];
            head += 1;
            visited += 1;
            let k_v = topo.degree(v) as f64;
            if k_v == 0.0 {
                continue;
            }
            nbr_w.clear();
            for &u in topo.neighbors(v) {
                *nbr_w.entry(self.labels[u as usize]).or_insert(0.0) += 1.0;
            }
            let c_old = self.labels[v as usize];
            let w_own = nbr_w.get(&c_old).copied().unwrap_or(0.0);
            // gain of staying, with v notionally removed from c_old
            let stay =
                w_own - (self.deg[c_old as usize] - k_v) * k_v / two_m;
            // candidates in ascending community order: HashMap
            // iteration order is randomized per process, and exact
            // gain ties must resolve identically across runs (the
            // determinism-per-seed contract); strictly-greater picks
            // the lowest community id on a tie.
            cands.clear();
            cands.extend(nbr_w.iter().map(|(&c, &w)| (c, w)));
            cands.sort_unstable_by_key(|&(c, _)| c);
            let mut best_c = c_old;
            let mut best_gain = stay;
            for &(c, w) in &cands {
                if c == c_old {
                    continue;
                }
                let gain = w - self.deg[c as usize] * k_v / two_m;
                if gain > best_gain + 1e-9 {
                    best_gain = gain;
                    best_c = c;
                }
            }
            if best_c == c_old {
                continue;
            }
            // apply the move: degree mass and intra edges follow v
            let w_new = nbr_w.get(&best_c).copied().unwrap_or(0.0);
            for (c, dk) in [(c_old, -k_v), (best_c, k_v)] {
                let c = c as usize;
                self.sum_sq -= self.deg[c] * self.deg[c];
                self.deg[c] += dk;
                self.sum_sq += self.deg[c] * self.deg[c];
            }
            self.intra[c_old as usize] -= 2.0 * w_own;
            self.intra[best_c as usize] += 2.0 * w_new;
            self.intra_total += 2.0 * (w_new - w_own);
            self.labels[v as usize] = best_c;
            self.moved_since_full += 1;
            moves.push((v, c_old, best_c));
            // ripple: a move can unlock its neighbors' moves
            for &u in topo.neighbors(v) {
                if queue.len() < budget {
                    queue.push(u);
                }
            }
        }
        moves
    }

    /// Stop-the-world re-detection: run [`louvain_capped`] over the
    /// compacted topology, adopt its labeling and reset the drift
    /// baseline. Returns the new community count.
    pub fn full_relabel(
        &mut self,
        csr: &Csr,
        seed: u64,
        max_mean_size: usize,
    ) -> usize {
        let r = louvain_capped(csr, seed, max_mean_size);
        *self = CommunityMaintainer::new(csr, r.community, r.num_comms);
        self.num_comms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::overlay::TopoSnapshot;
    use crate::graph::stats;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn random_graph(n: usize, m: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let edges: Vec<(u32, u32)> = (0..m)
            .map(|_| {
                (rng.below(n as u64) as u32, rng.below(n as u64) as u32)
            })
            .collect();
        Csr::from_edges(n, &edges)
    }

    #[test]
    fn counters_match_reference_modularity() {
        let g = random_graph(80, 300, 1);
        let labels: Vec<u32> = (0..80u32).map(|v| v % 5).collect();
        let m = CommunityMaintainer::new(&g, labels.clone(), 5);
        let q_ref = stats::modularity(&g, &labels);
        assert!((m.modularity() - q_ref).abs() < 1e-9);
        assert!(m.drift() < 1e-12, "fresh maintainer has no drift");
    }

    #[test]
    fn note_edge_tracks_mutations_exactly() {
        let g = random_graph(60, 200, 2);
        let labels: Vec<u32> = (0..60u32).map(|v| v % 4).collect();
        let mut m = CommunityMaintainer::new(&g, labels.clone(), 4);
        let mut snap = TopoSnapshot::from_base(Arc::new(g));
        let mut rng = Rng::new(3);
        for _ in 0..30 {
            let batch: Vec<(u32, u32, bool)> = (0..6)
                .map(|_| {
                    (
                        rng.below(60) as u32,
                        rng.below(60) as u32,
                        rng.f64() < 0.5,
                    )
                })
                .collect();
            let (next, applied) = snap.apply(&batch);
            snap = next;
            for (u, v, ins) in applied {
                m.note_edge(u, v, ins);
            }
        }
        let compacted = snap.compact();
        let q_ref = stats::modularity(&compacted, &labels);
        assert!(
            (m.modularity() - q_ref).abs() < 1e-9,
            "incremental {} vs reference {}",
            m.modularity(),
            q_ref
        );
    }

    #[test]
    fn refine_repairs_a_mislabeled_vertex() {
        // two K4 cliques joined by a bridge; vertex 0 mislabeled
        let g = Csr::from_edges(
            8,
            &[
                (0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3),
                (4, 5), (4, 6), (4, 7), (5, 6), (5, 7), (6, 7),
                (3, 4),
            ],
        );
        let mut labels = vec![0u32, 0, 0, 0, 1, 1, 1, 1];
        labels[0] = 1; // wrong side
        let mut m = CommunityMaintainer::new(&g, labels, 2);
        let q_before = m.modularity();
        let moves = m.refine(&g, &[0]);
        assert_eq!(moves, vec![(0, 1, 0)]);
        assert_eq!(m.labels()[0], 0);
        assert!(m.modularity() > q_before, "refine must improve Q");
        assert_eq!(m.moved_since_full(), 1);
        // counters stay exact after the move
        let q_ref = stats::modularity(&g, m.labels());
        assert!((m.modularity() - q_ref).abs() < 1e-9);
    }

    #[test]
    fn refine_is_a_noop_on_a_stable_labeling() {
        let g = Csr::from_edges(
            6,
            &[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)],
        );
        let labels = vec![0u32, 0, 0, 1, 1, 1];
        let mut m = CommunityMaintainer::new(&g, labels, 2);
        let q = m.modularity();
        let moves = m.refine(&g, &[0, 1, 2, 3, 4, 5]);
        assert!(moves.is_empty(), "stable labeling must not move");
        assert!((m.modularity() - q).abs() < 1e-12);
    }

    #[test]
    fn drift_rises_under_structure_erosion_and_full_relabel_resets_it() {
        // two tight cliques; then rewire to destroy the split
        let mut edges = vec![];
        for a in 0..8u32 {
            for b in (a + 1)..8 {
                edges.push((a, b));
            }
        }
        for a in 8..16u32 {
            for b in (a + 1)..16 {
                edges.push((a, b));
            }
        }
        edges.push((0, 8));
        let g = Csr::from_edges(16, &edges);
        let labels: Vec<u32> =
            (0..16u32).map(|v| if v < 8 { 0 } else { 1 }).collect();
        let mut m = CommunityMaintainer::new(&g, labels, 2);
        let mut snap = TopoSnapshot::from_base(Arc::new(g));
        // delete intra edges, insert inter edges
        let mut batch = vec![];
        for a in 1..8u32 {
            batch.push((0, a, false));
            batch.push((a, 8 + a, true));
        }
        let (next, applied) = snap.apply(&batch);
        snap = next;
        for (u, v, ins) in applied {
            m.note_edge(u, v, ins);
        }
        assert!(m.drift() > 0.05, "erosion must register: {}", m.drift());
        let csr = snap.compact();
        let nc = m.full_relabel(&csr, 7, 64);
        assert!(nc >= 1);
        assert!(m.drift() < 1e-9, "full relabel resets the baseline");
        assert_eq!(m.labels().len(), 16);
        assert!(m.labels().iter().all(|&c| (c as usize) < m.num_comms()));
        let q_ref = stats::modularity(&csr, m.labels());
        assert!((m.modularity() - q_ref).abs() < 1e-9);
    }
}
