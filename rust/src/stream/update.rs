//! The update log: timestamped graph mutations, batched into epochs.
//!
//! Producers ([`super::churn`], tests, a future ingest RPC) append
//! [`Mutation`]s with a monotone sequence number and the run-clock
//! timestamp; the single applier thread seals the pending tail into an
//! [`UpdateEpoch`] and applies it atomically — one topology snapshot,
//! one maintainer wave, one feature-version batch per epoch. Batching
//! is what keeps the delta-overlay cheap: the per-epoch apply cost is
//! proportional to the epoch's touched set, and in-flight samplers
//! only ever observe epoch boundaries, never half-applied updates.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One streaming graph mutation.
#[derive(Clone, Debug, PartialEq)]
pub enum Mutation {
    /// Insert the undirected edge `(u, v)` (no-op if present).
    EdgeInsert {
        /// One endpoint.
        u: u32,
        /// The other endpoint.
        v: u32,
    },
    /// Delete the undirected edge `(u, v)` (no-op if absent).
    EdgeDelete {
        /// One endpoint.
        u: u32,
        /// The other endpoint.
        v: u32,
    },
    /// Replace `node`'s feature row (bumps its feature version, so
    /// cached copies everywhere turn stale).
    FeatureRewrite {
        /// The rewritten node.
        node: u32,
        /// The new feature row (`feat_dim` floats).
        row: Vec<f32>,
    },
}

/// A [`Mutation`] stamped with its ingest order and arrival time.
#[derive(Clone, Debug)]
pub struct Timestamped {
    /// Monotone ingest sequence number (unique within a run).
    pub seq: u64,
    /// [`crate::serve::ServeClock`] microseconds at ingest.
    pub t_us: u64,
    /// The mutation itself.
    pub m: Mutation,
}

/// One sealed batch of updates, applied atomically.
#[derive(Debug)]
pub struct UpdateEpoch {
    /// Epoch number (0-based, monotone).
    pub id: u64,
    /// The epoch's updates, in ingest order.
    pub updates: Vec<Timestamped>,
}

/// Ingest log: concurrent appends, single-consumer epoch sealing.
#[derive(Default)]
pub struct UpdateLog {
    pending: Mutex<Vec<Timestamped>>,
    next_seq: AtomicU64,
    next_epoch: AtomicU64,
}

impl UpdateLog {
    /// Empty log.
    pub fn new() -> UpdateLog {
        UpdateLog::default()
    }

    /// Append one mutation; returns its sequence number.
    pub fn append(&self, t_us: u64, m: Mutation) -> u64 {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        self.pending
            .lock()
            .unwrap()
            .push(Timestamped { seq, t_us, m });
        seq
    }

    /// Updates ingested so far (sealed or not).
    pub fn ingested(&self) -> u64 {
        self.next_seq.load(Ordering::Relaxed)
    }

    /// Updates waiting for the next seal.
    pub fn pending_len(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// Epochs sealed so far.
    pub fn epochs_sealed(&self) -> u64 {
        self.next_epoch.load(Ordering::Relaxed)
    }

    /// Seal the pending tail into an epoch (`None` when nothing is
    /// pending).
    pub fn seal(&self) -> Option<UpdateEpoch> {
        let mut g = self.pending.lock().unwrap();
        if g.is_empty() {
            return None;
        }
        let updates = std::mem::take(&mut *g);
        drop(g);
        let id = self.next_epoch.fetch_add(1, Ordering::Relaxed);
        Some(UpdateEpoch { id, updates })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_are_monotone_and_epochs_drain() {
        let log = UpdateLog::new();
        assert!(log.seal().is_none());
        for i in 0..10u32 {
            let s = log.append(i as u64, Mutation::EdgeInsert { u: i, v: i + 1 });
            assert_eq!(s, i as u64);
        }
        assert_eq!(log.pending_len(), 10);
        let ep = log.seal().unwrap();
        assert_eq!(ep.id, 0);
        assert_eq!(ep.updates.len(), 10);
        assert!(ep.updates.windows(2).all(|w| w[0].seq < w[1].seq));
        assert_eq!(log.pending_len(), 0);
        assert!(log.seal().is_none());
        log.append(99, Mutation::FeatureRewrite { node: 1, row: vec![0.5] });
        let ep2 = log.seal().unwrap();
        assert_eq!(ep2.id, 1);
        assert_eq!(ep2.updates[0].seq, 10);
        assert_eq!(log.epochs_sealed(), 2);
        assert_eq!(log.ingested(), 11);
    }

    #[test]
    fn concurrent_appends_never_lose_updates() {
        let log = UpdateLog::new();
        std::thread::scope(|s| {
            for t in 0..4u32 {
                let log = &log;
                s.spawn(move || {
                    for i in 0..500u32 {
                        log.append(
                            0,
                            Mutation::EdgeInsert { u: t, v: i },
                        );
                    }
                });
            }
        });
        let ep = log.seal().unwrap();
        assert_eq!(ep.updates.len(), 2000);
        let mut seqs: Vec<u64> = ep.updates.iter().map(|u| u.seq).collect();
        seqs.sort_unstable();
        seqs.dedup();
        assert_eq!(seqs.len(), 2000, "duplicate sequence numbers");
    }
}
