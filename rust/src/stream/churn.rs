//! Synthetic churn generation: a paced stream of edge inserts, edge
//! deletes and feature-row rewrites driven alongside the load
//! generator (`serve bench mutate=RATE`).
//!
//! The mix is fixed (≈ 30 % feature rewrites, 35 % inserts, 35 %
//! deletes), endpoints are uniform over the node space, and deletes
//! target *existing* edges (sampled vertex → random live neighbor), so
//! at a steady rate the edge count stays roughly stationary while the
//! community structure erodes — the regime the incremental maintainer
//! exists for. Rewrites perturb the node's current row (overlay row if
//! one exists, the base table otherwise) with gaussian noise, so
//! feature versions advance without the payload wandering off
//! distribution.
//!
//! [`churn_loop`] is the engine's single writer thread: pace updates
//! at `rate_ups`, seal the log every `epoch_updates`, apply the epoch
//! ([`StreamState::apply_epoch`]), repeat until stopped. If an apply
//! runs long (a stop-the-world full relabel), pacing falls behind and
//! the loop catches up by bursting — offered churn is open-loop, like
//! the Poisson request generator.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::graph::{Dataset, Topology, TopoSnapshot};
use crate::obs::{EventKind, Heartbeat, Recorder, TRACK_MAINTAINER};
use crate::serve::cache::ShardedFeatureCache;
use crate::serve::shard::LabelCell;
use crate::serve::ServeClock;
use crate::util::rng::Rng;

use super::state::{FeatureOverlay, StreamState};
use super::update::Mutation;

/// Deterministic churn generator (pure function of its seed and the
/// snapshots it samples from).
pub struct ChurnGen {
    rng: Rng,
    noise: f32,
}

impl ChurnGen {
    /// New generator; `seed` fixes the mutation stream.
    pub fn new(seed: u64) -> ChurnGen {
        ChurnGen { rng: Rng::new(seed ^ 0xC0_FFEE), noise: 0.2 }
    }

    /// Draw the next mutation against the current topology snapshot.
    pub fn generate(
        &mut self,
        topo: &TopoSnapshot,
        ds: &Dataset,
        overlay: &FeatureOverlay,
    ) -> Mutation {
        let n = topo.num_nodes().max(2) as u64;
        let roll = self.rng.f64();
        if roll < 0.30 {
            let node = self.rng.below(n) as u32;
            let (_, cur) = overlay.version_and_row(node);
            let mut row: Vec<f32> = match cur {
                Some(r) => (*r).clone(),
                None => ds.feature_row(node).to_vec(),
            };
            for x in row.iter_mut() {
                *x += self.noise * self.rng.normal() as f32;
            }
            return Mutation::FeatureRewrite { node, row };
        }
        if roll < 0.65 {
            // insert: uniform pair (an existing edge is a no-op, which
            // the applier counts but does not apply)
            let u = self.rng.below(n) as u32;
            let mut v = self.rng.below(n) as u32;
            if v == u {
                v = (v + 1) % n as u32;
            }
            return Mutation::EdgeInsert { u, v };
        }
        // delete: find a vertex with a live neighbor (bounded probes)
        for _ in 0..16 {
            let u = self.rng.below(n) as u32;
            let d = topo.degree(u);
            if d > 0 {
                let v = topo.neighbors(u)[self.rng.usize_below(d)];
                return Mutation::EdgeDelete { u, v };
            }
        }
        // fully disconnected region: fall back to an insert
        let u = self.rng.below(n) as u32;
        let v = (u + 1) % n as u32;
        Mutation::EdgeInsert { u, v }
    }
}

/// Engine thread body: pace → log → seal → apply, until `stop`.
/// Sleeps in short slices so `stop` is honored promptly; drains one
/// final partial epoch on the way out so the report's counters cover
/// every ingested update. Untraced convenience wrapper around
/// [`churn_loop_traced`].
pub fn churn_loop(
    st: &StreamState,
    labels: &LabelCell,
    ds: &Dataset,
    caches: &[ShardedFeatureCache],
    clock: &ServeClock,
    stop: &AtomicBool,
) {
    let rec = Recorder::disabled();
    churn_loop_traced(st, labels, ds, caches, clock, stop, &rec);
}

/// [`churn_loop`] with trace instrumentation: each applied epoch emits
/// a `Churn` span on the maintainer track (args: updates applied and
/// vertices moved by the epoch's refinement wave), and each full
/// relabel an additional `Relabel` instant — so a Perfetto view lines
/// maintenance stalls up against the shard tracks' request spans. The
/// deltas come from [`StreamState::counters`], read around each
/// `apply_epoch`, so the trace and the end-of-run stream report count
/// the same things.
#[allow(clippy::too_many_arguments)]
pub fn churn_loop_traced(
    st: &StreamState,
    labels: &LabelCell,
    ds: &Dataset,
    caches: &[ShardedFeatureCache],
    clock: &ServeClock,
    stop: &AtomicBool,
    rec: &Recorder,
) {
    churn_loop_observed(st, labels, ds, caches, clock, stop, rec, None)
}

/// [`churn_loop_traced`] with an optional watchdog heartbeat: the loop
/// beats busy at every pacing slice and around each epoch apply, so
/// the engine's liveness sweep can tell a maintainer wedged inside an
/// apply from one pacing between updates. `None` skips the beats.
#[allow(clippy::too_many_arguments)]
pub fn churn_loop_observed(
    st: &StreamState,
    labels: &LabelCell,
    ds: &Dataset,
    caches: &[ShardedFeatureCache],
    clock: &ServeClock,
    stop: &AtomicBool,
    rec: &Recorder,
    hb: Option<&Heartbeat>,
) {
    let cfg = st.cfg().clone();
    if cfg.rate_ups <= 0.0 {
        return;
    }
    let mut gen = ChurnGen::new(cfg.seed);
    let per_update_us = 1e6 / cfg.rate_ups;
    let epoch_updates = cfg.epoch_updates.max(1);
    let mut next_us = clock.now_us() as f64;
    let apply = |ep| {
        use std::sync::atomic::Ordering as O;
        if !rec.is_enabled() {
            st.apply_epoch(ep, labels, caches);
            return;
        }
        let c = &st.counters;
        let applied0 = c.edge_inserts.load(O::Relaxed)
            + c.edge_deletes.load(O::Relaxed)
            + c.feature_rewrites.load(O::Relaxed)
            + c.noop_updates.load(O::Relaxed);
        let moved0 = c.moved_vertices.load(O::Relaxed);
        let relabels0 = c.full_relabels.load(O::Relaxed);
        let t0 = rec.now_us();
        st.apply_epoch(ep, labels, caches);
        let t1 = rec.now_us();
        let applied = applied0.abs_diff(c.edge_inserts.load(O::Relaxed)
            + c.edge_deletes.load(O::Relaxed)
            + c.feature_rewrites.load(O::Relaxed)
            + c.noop_updates.load(O::Relaxed));
        let moved = moved0.abs_diff(c.moved_vertices.load(O::Relaxed));
        rec.span(
            TRACK_MAINTAINER,
            EventKind::Churn,
            t0,
            t1.saturating_sub(t0),
            0,
            applied as u32,
            moved as u32,
            0,
        );
        if c.full_relabels.load(O::Relaxed) > relabels0 {
            rec.instant(
                TRACK_MAINTAINER,
                EventKind::Relabel,
                t1,
                0,
                labels.snapshot().num_comms as u32,
                0,
                0,
            );
        }
    };
    'outer: while !stop.load(Ordering::Relaxed) {
        for _ in 0..epoch_updates {
            next_us += per_update_us;
            // sleep to the pace point in ≤ 5 ms slices
            loop {
                if stop.load(Ordering::Relaxed) {
                    break 'outer;
                }
                let now = clock.now_us();
                if let Some(hb) = hb {
                    hb.busy(now);
                }
                if (next_us as u64) <= now {
                    break;
                }
                let wait = ((next_us as u64) - now).min(5_000);
                std::thread::sleep(Duration::from_micros(wait));
            }
            let topo = st.topo();
            let m = gen.generate(&topo, ds, st.feat());
            st.log().append(clock.now_us(), m);
        }
        if let Some(ep) = st.log().seal() {
            apply(ep);
            if let Some(hb) = hb {
                hb.busy(clock.now_us());
            }
        }
    }
    if let Some(ep) = st.log().seal() {
        apply(ep);
    }
    if let Some(hb) = hb {
        hb.retire();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::serve::shard::LabelSnapshot;
    use crate::stream::state::StreamConfig;

    fn tiny() -> Dataset {
        crate::train::dataset::build(&preset("tiny").unwrap(), true)
    }

    #[test]
    fn generator_mix_covers_all_mutation_kinds() {
        let ds = tiny();
        let st = StreamState::new(&ds, StreamConfig::default());
        let mut gen = ChurnGen::new(7);
        let topo = st.topo();
        let (mut ins, mut dels, mut rws) = (0usize, 0usize, 0usize);
        for _ in 0..600 {
            match gen.generate(&topo, &ds, st.feat()) {
                Mutation::EdgeInsert { u, v } => {
                    assert_ne!(u, v);
                    assert!((u as usize) < ds.n() && (v as usize) < ds.n());
                    ins += 1;
                }
                Mutation::EdgeDelete { u, v } => {
                    assert!(topo.has_edge(u, v), "deletes target live edges");
                    dels += 1;
                }
                Mutation::FeatureRewrite { node, row } => {
                    assert!((node as usize) < ds.n());
                    assert_eq!(row.len(), ds.feat_dim);
                    assert!(row.iter().all(|x| x.is_finite()));
                    rws += 1;
                }
            }
        }
        assert!(ins > 100, "inserts missing from the mix: {ins}");
        assert!(dels > 100, "deletes missing from the mix: {dels}");
        assert!(rws > 100, "rewrites missing from the mix: {rws}");
    }

    #[test]
    fn generator_is_deterministic_per_seed() {
        let ds = tiny();
        let st = StreamState::new(&ds, StreamConfig::default());
        let topo = st.topo();
        let mut a = ChurnGen::new(5);
        let mut b = ChurnGen::new(5);
        for _ in 0..50 {
            assert_eq!(
                a.generate(&topo, &ds, st.feat()),
                b.generate(&topo, &ds, st.feat())
            );
        }
    }

    #[test]
    fn churn_loop_applies_epochs_and_stops() {
        let ds = tiny();
        let cfg = StreamConfig {
            rate_ups: 50_000.0,
            epoch_updates: 32,
            ..StreamConfig::default()
        };
        let st = StreamState::new(&ds, cfg);
        let labels = LabelCell::new(LabelSnapshot::initial(
            &ds.community,
            ds.num_comms,
            1,
        ));
        let caches: Vec<ShardedFeatureCache> = vec![];
        let clock = ServeClock::start();
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            let st = &st;
            let labels = &labels;
            let ds = &ds;
            let caches = &caches[..];
            let clock = &clock;
            let stop_ref = &stop;
            let h = s.spawn(move || {
                churn_loop(st, labels, ds, caches, clock, stop_ref);
            });
            std::thread::sleep(Duration::from_millis(60));
            stop.store(true, Ordering::Relaxed);
            h.join().unwrap();
        });
        use std::sync::atomic::Ordering as O;
        let epochs = st.counters.epochs_applied.load(O::Relaxed);
        assert!(epochs >= 1, "at least one epoch must apply in 60 ms");
        assert_eq!(st.log().pending_len(), 0, "final drain leaves nothing");
        let applied = st.counters.edge_inserts.load(O::Relaxed)
            + st.counters.edge_deletes.load(O::Relaxed)
            + st.counters.feature_rewrites.load(O::Relaxed)
            + st.counters.noop_updates.load(O::Relaxed);
        assert_eq!(applied as u64, st.log().ingested());
    }
}
