//! Shared mutable state of a streaming run: the versioned topology
//! cell, the feature-row overlay, the incremental community
//! maintainer, and the epoch applier that ties them together.
//!
//! Concurrency contract: there is exactly **one writer** (the churn /
//! ingest thread driving [`StreamState::apply_epoch`]); everything
//! else — samplers, cache staging, the batcher, load generators —
//! reads immutable snapshots (`Arc<TopoSnapshot>`,
//! `Arc<LabelSnapshot>`) or versioned rows, so readers never observe a
//! half-applied epoch. Incremental maintenance publishes new label
//! snapshots in microseconds; a **full relabel** (naive mode, or the
//! drift trigger in incremental mode) deliberately holds the label
//! cell locked while Louvain recomputes — the stop-the-world cost the
//! `exp stream` sweep measures — and flushes every shard's feature
//! cache, rebuilds the shard plan, and bumps the community fingerprint
//! so the existing checkpoint fence invalidates mismatched
//! checkpoints.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::ckpt::format::community_fingerprint;
use crate::graph::{Dataset, Topology, TopoSnapshot};
use crate::serve::cache::ShardedFeatureCache;
use crate::serve::shard::{LabelCell, LabelSnapshot, ShardPlan};
use crate::util::json::{num, obj, s, Json};

use super::maintainer::CommunityMaintainer;
use super::update::{Mutation, UpdateEpoch, UpdateLog};
use super::MaintenanceMode;

/// Knobs of the streaming-mutation subsystem (`serve bench mutate=`).
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Offered churn in updates per second (0 disables streaming).
    pub rate_ups: f64,
    /// Updates batched per epoch before the log is sealed + applied.
    pub epoch_updates: usize,
    /// Modularity-drift threshold that triggers a full relabel in
    /// incremental mode.
    pub drift_threshold: f64,
    /// Incremental local refinement vs. naive full relabel per epoch.
    pub mode: MaintenanceMode,
    /// Churn-generator / relabel seed.
    pub seed: u64,
    /// `max_mean_size` handed to Louvain on full relabels (matches the
    /// dataset build's community-size cap).
    pub louvain_cap: usize,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig {
            rate_ups: 0.0,
            epoch_updates: 64,
            drift_threshold: 0.15,
            mode: MaintenanceMode::Incremental,
            seed: 0,
            louvain_cap: 512,
        }
    }
}

/// Versioned feature-row overlay: rewritten rows live here, tagged
/// with a globally monotone feature version; nodes never rewritten
/// implicitly carry version 0 and read from the base table. Cache
/// slots remember the version they staged, so a rewrite turns every
/// cached copy stale (counted as `stale_hits`, served like misses).
pub struct FeatureOverlay {
    feat_dim: usize,
    /// node → (version, row); rows are `Arc`-shared so a read is a
    /// refcount bump, not a row copy (this sits on the worker staging
    /// hot path).
    rows: RwLock<HashMap<u32, (u64, Arc<Vec<f32>>)>>,
    latest: AtomicU64,
}

impl FeatureOverlay {
    /// Empty overlay over rows of `feat_dim` floats.
    pub fn new(feat_dim: usize) -> FeatureOverlay {
        FeatureOverlay {
            feat_dim,
            rows: RwLock::new(HashMap::new()),
            latest: AtomicU64::new(0),
        }
    }

    /// Floats per feature row.
    pub fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    /// Current feature version of `node` (0 = never rewritten) and,
    /// when rewritten, its overlay row (`Arc` clone — a refcount bump,
    /// not a copy). The pair is read atomically, so a version always
    /// describes the row returned with it.
    pub fn version_and_row(&self, node: u32) -> (u64, Option<Arc<Vec<f32>>>) {
        let g = self.rows.read().unwrap();
        match g.get(&node) {
            Some((ver, row)) => (*ver, Some(row.clone())),
            None => (0, None),
        }
    }

    /// Install a rewritten row; returns its (strictly increasing)
    /// feature version.
    pub fn apply(&self, node: u32, row: Vec<f32>) -> u64 {
        debug_assert_eq!(row.len(), self.feat_dim);
        let ver = self.latest.fetch_add(1, Ordering::Relaxed) + 1;
        self.rows.write().unwrap().insert(node, (ver, Arc::new(row)));
        ver
    }

    /// Highest feature version issued so far (monotone).
    pub fn latest_version(&self) -> u64 {
        self.latest.load(Ordering::Relaxed)
    }

    /// Nodes currently carrying an overlay row.
    pub fn overlay_len(&self) -> usize {
        self.rows.read().unwrap().len()
    }
}

/// Run counters, all monotone (written by the applier thread, read by
/// the end-of-run report).
#[derive(Default)]
pub struct StreamCounters {
    /// Edge inserts actually applied (no-ops excluded).
    pub edge_inserts: AtomicUsize,
    /// Edge deletes actually applied.
    pub edge_deletes: AtomicUsize,
    /// Feature rows rewritten.
    pub feature_rewrites: AtomicUsize,
    /// Updates that were structural no-ops (insert of an existing
    /// edge, delete of a missing one, out-of-range).
    pub noop_updates: AtomicUsize,
    /// Update epochs applied.
    pub epochs_applied: AtomicUsize,
    /// Refinement waves that moved at least one vertex.
    pub relabel_waves: AtomicUsize,
    /// Vertices moved between communities by refinement.
    pub moved_vertices: AtomicUsize,
    /// Moves whose old and new communities live on different shards.
    pub cross_shard_movers: AtomicUsize,
    /// Stop-the-world full relabels (every epoch in naive mode; drift
    /// triggered in incremental mode).
    pub full_relabels: AtomicUsize,
}

/// End-of-run streaming telemetry embedded in the `ServeReport`.
#[derive(Clone, Debug)]
pub struct StreamReport {
    /// Configured churn rate (updates/s).
    pub mutate_ups: f64,
    /// Maintenance mode label (`incr` / `full`).
    pub maintenance: String,
    /// Updates ingested into the log.
    pub updates_ingested: u64,
    /// Applied edge inserts.
    pub edge_inserts: usize,
    /// Applied edge deletes.
    pub edge_deletes: usize,
    /// Feature rows rewritten.
    pub feature_rewrites: usize,
    /// Structural no-op updates.
    pub noop_updates: usize,
    /// Update epochs applied.
    pub epochs: usize,
    /// Refinement waves that moved ≥ 1 vertex.
    pub relabel_waves: usize,
    /// Vertices moved by refinement.
    pub moved_vertices: usize,
    /// Cross-shard movers (routed via the warm-cache override).
    pub cross_shard_movers: usize,
    /// Stop-the-world full relabels.
    pub full_relabels: usize,
    /// Final modularity drift versus the last full detection.
    pub drift: f64,
    /// Final modularity of the live labeling.
    pub modularity: f64,
    /// Final label-snapshot version (0 = labels never changed).
    pub label_version: u64,
    /// Final topology-snapshot version (epochs with edge updates).
    pub topo_version: u64,
    /// Highest feature version issued (monotone; 0 = no rewrites).
    pub feat_version: u64,
}

impl StreamReport {
    /// Serialize the `stream` section of the `ServeReport` JSON.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("mutate_ups", num(self.mutate_ups)),
            ("maintenance", s(&self.maintenance)),
            ("updates_ingested", num(self.updates_ingested as f64)),
            ("edge_inserts", num(self.edge_inserts as f64)),
            ("edge_deletes", num(self.edge_deletes as f64)),
            ("feature_rewrites", num(self.feature_rewrites as f64)),
            ("noop_updates", num(self.noop_updates as f64)),
            ("epochs", num(self.epochs as f64)),
            ("relabel_waves", num(self.relabel_waves as f64)),
            ("moved_vertices", num(self.moved_vertices as f64)),
            ("cross_shard_movers", num(self.cross_shard_movers as f64)),
            ("full_relabels", num(self.full_relabels as f64)),
            ("drift", num(self.drift)),
            ("modularity", num(self.modularity)),
            ("label_version", num(self.label_version as f64)),
            ("topo_version", num(self.topo_version as f64)),
            ("feat_version", num(self.feat_version as f64)),
        ])
    }
}

/// Shared state of one streaming run (see the module docs for the
/// single-writer contract).
pub struct StreamState {
    cfg: StreamConfig,
    log: UpdateLog,
    topo: Mutex<Arc<TopoSnapshot>>,
    feat: FeatureOverlay,
    maintainer: Mutex<CommunityMaintainer>,
    /// Monotone run counters.
    pub counters: StreamCounters,
}

impl StreamState {
    /// Fresh streaming state over a dataset's topology + detected
    /// labels (topology snapshot version 0, no overlay rows).
    pub fn new(ds: &Dataset, cfg: StreamConfig) -> StreamState {
        let base = Arc::new(ds.csr.clone());
        let maintainer = CommunityMaintainer::new(
            &*base,
            ds.community.clone(),
            ds.num_comms,
        );
        StreamState {
            cfg,
            log: UpdateLog::new(),
            topo: Mutex::new(Arc::new(TopoSnapshot::from_base(base))),
            feat: FeatureOverlay::new(ds.feat_dim),
            maintainer: Mutex::new(maintainer),
            counters: StreamCounters::default(),
        }
    }

    /// The run's configuration.
    pub fn cfg(&self) -> &StreamConfig {
        &self.cfg
    }

    /// The ingest log.
    pub fn log(&self) -> &UpdateLog {
        &self.log
    }

    /// The feature-row overlay.
    pub fn feat(&self) -> &FeatureOverlay {
        &self.feat
    }

    /// The current topology snapshot (cheap: one lock + Arc clone).
    pub fn topo(&self) -> Arc<TopoSnapshot> {
        self.topo.lock().unwrap().clone()
    }

    /// Current modularity drift versus the last full detection.
    pub fn drift(&self) -> f64 {
        self.maintainer.lock().unwrap().drift()
    }

    /// Apply one sealed epoch: topology delta → maintainer counters →
    /// feature versions → label maintenance (refine, or full relabel
    /// per the mode / drift trigger). Single-writer: only the churn /
    /// ingest thread may call this.
    pub fn apply_epoch(
        &self,
        ep: UpdateEpoch,
        labels: &LabelCell,
        caches: &[ShardedFeatureCache],
    ) {
        let mut edge_updates: Vec<(u32, u32, bool)> = Vec::new();
        let mut rewrites: Vec<(u32, Vec<f32>)> = Vec::new();
        for t in ep.updates {
            match t.m {
                Mutation::EdgeInsert { u, v } => {
                    edge_updates.push((u, v, true))
                }
                Mutation::EdgeDelete { u, v } => {
                    edge_updates.push((u, v, false))
                }
                Mutation::FeatureRewrite { node, row } => {
                    rewrites.push((node, row))
                }
            }
        }

        // topology: build the next snapshot off the current one without
        // holding the cell lock (we are the only writer), then swap.
        let cur = self.topo();
        let (next, applied) = cur.apply(&edge_updates);
        let next = Arc::new(next);
        let mut ins = 0usize;
        let mut dels = 0usize;
        for &(_, _, insert) in &applied {
            if insert {
                ins += 1;
            } else {
                dels += 1;
            }
        }
        self.counters.edge_inserts.fetch_add(ins, Ordering::Relaxed);
        self.counters.edge_deletes.fetch_add(dels, Ordering::Relaxed);
        self.counters
            .noop_updates
            .fetch_add(edge_updates.len() - applied.len(), Ordering::Relaxed);

        let mut m = self.maintainer.lock().unwrap();
        for &(u, v, insert) in &applied {
            m.note_edge(u, v, insert);
        }
        *self.topo.lock().unwrap() = next.clone();

        // feature rewrites: bump versions so cached copies turn stale
        let n = next.num_nodes();
        for (node, row) in rewrites {
            if (node as usize) < n && row.len() == self.feat.feat_dim() {
                self.feat.apply(node, row);
                self.counters
                    .feature_rewrites
                    .fetch_add(1, Ordering::Relaxed);
            } else {
                self.counters.noop_updates.fetch_add(1, Ordering::Relaxed);
            }
        }

        match self.cfg.mode {
            MaintenanceMode::Incremental => {
                let mut touched: Vec<u32> =
                    applied.iter().flat_map(|&(u, v, _)| [u, v]).collect();
                touched.sort_unstable();
                touched.dedup();
                let moves = if touched.is_empty() {
                    Vec::new()
                } else {
                    m.refine(&*next, &touched)
                };
                if !moves.is_empty() {
                    self.counters
                        .relabel_waves
                        .fetch_add(1, Ordering::Relaxed);
                    self.counters
                        .moved_vertices
                        .fetch_add(moves.len(), Ordering::Relaxed);
                    let new_labels = m.labels().to_vec();
                    let mut movers = 0usize;
                    labels.replace_blocking(|old| {
                        let mut plan = old.plan.clone();
                        let mut overrides = HashMap::new();
                        for &(v, c_old, c_new) in &moves {
                            let s_old = plan.shard_of_comm(c_old);
                            let s_new = plan.shard_of_comm(c_new);
                            plan.apply_move(c_old, c_new);
                            if s_old != s_new {
                                // warm-cache fallback: keep routing
                                // the mover to its old shard for one
                                // epoch (replaced or cleared by the
                                // next epoch)
                                overrides.insert(v, s_old as u32);
                                movers += 1;
                            }
                        }
                        LabelSnapshot {
                            version: old.version + 1,
                            labels: new_labels,
                            num_comms: old.num_comms,
                            fingerprint: old.fingerprint,
                            plan,
                            overrides,
                        }
                    });
                    self.counters
                        .cross_shard_movers
                        .fetch_add(movers, Ordering::Relaxed);
                } else if !labels.snapshot().overrides.is_empty() {
                    // no moves this epoch: the previous wave's warm-
                    // cache overrides have served their one-epoch
                    // grace window — expire them so movers migrate to
                    // their owning shard
                    labels.replace_blocking(|old| LabelSnapshot {
                        version: old.version + 1,
                        labels: old.labels.clone(),
                        num_comms: old.num_comms,
                        fingerprint: old.fingerprint,
                        plan: old.plan.clone(),
                        overrides: HashMap::new(),
                    });
                }
                if m.drift() > self.cfg.drift_threshold {
                    self.full_relabel(&mut m, labels, caches);
                }
            }
            MaintenanceMode::Full => {
                self.full_relabel(&mut m, labels, caches);
            }
        }
        self.counters.epochs_applied.fetch_add(1, Ordering::Relaxed);
    }

    /// Stop-the-world full relabel: the label cell stays locked while
    /// Louvain recomputes over the compacted live topology, the shard
    /// plan is rebuilt, every shard's feature cache is flushed, and
    /// the community fingerprint changes generation (fencing stale
    /// checkpoints at the existing `ckpt` validation layer).
    fn full_relabel(
        &self,
        m: &mut CommunityMaintainer,
        labels: &LabelCell,
        caches: &[ShardedFeatureCache],
    ) {
        let relabel_id =
            self.counters.full_relabels.fetch_add(1, Ordering::Relaxed);
        let topo = self.topo();
        labels.replace_blocking(|old| {
            let csr = topo.compact();
            let nc = m.full_relabel(
                &csr,
                self.cfg.seed ^ (relabel_id as u64).wrapping_mul(0x9E37),
                self.cfg.louvain_cap,
            );
            for c in caches {
                c.invalidate_all();
            }
            let new_labels = m.labels().to_vec();
            let fingerprint = community_fingerprint(&new_labels, nc);
            let plan = ShardPlan::build(&new_labels, nc, old.plan.n_shards());
            LabelSnapshot {
                version: old.version + 1,
                labels: new_labels,
                num_comms: nc,
                fingerprint,
                plan,
                overrides: HashMap::new(),
            }
        });
    }

    /// Roll the run's streaming telemetry up for the `ServeReport`.
    pub fn report(&self, labels: &LabelCell) -> StreamReport {
        let c = &self.counters;
        let m = self.maintainer.lock().unwrap();
        let snap = labels.snapshot();
        StreamReport {
            mutate_ups: self.cfg.rate_ups,
            maintenance: self.cfg.mode.name().to_string(),
            updates_ingested: self.log.ingested(),
            edge_inserts: c.edge_inserts.load(Ordering::Relaxed),
            edge_deletes: c.edge_deletes.load(Ordering::Relaxed),
            feature_rewrites: c.feature_rewrites.load(Ordering::Relaxed),
            noop_updates: c.noop_updates.load(Ordering::Relaxed),
            epochs: c.epochs_applied.load(Ordering::Relaxed),
            relabel_waves: c.relabel_waves.load(Ordering::Relaxed),
            moved_vertices: c.moved_vertices.load(Ordering::Relaxed),
            cross_shard_movers: c.cross_shard_movers.load(Ordering::Relaxed),
            full_relabels: c.full_relabels.load(Ordering::Relaxed),
            drift: m.drift(),
            modularity: m.modularity(),
            label_version: snap.version,
            topo_version: self.topo().version(),
            feat_version: self.feat.latest_version(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::serve::cache::FeatureCacheConfig;
    use crate::stream::update::Mutation;

    fn tiny() -> Dataset {
        crate::train::dataset::build(&preset("tiny").unwrap(), true)
    }

    fn cell_for(ds: &Dataset, n_shards: usize) -> LabelCell {
        LabelCell::new(LabelSnapshot::initial(
            &ds.community,
            ds.num_comms,
            n_shards,
        ))
    }

    #[test]
    fn feature_overlay_versions_are_monotone_and_atomic() {
        let f = FeatureOverlay::new(4);
        assert_eq!(f.version_and_row(3), (0, None));
        let v1 = f.apply(3, vec![1.0; 4]);
        let v2 = f.apply(9, vec![2.0; 4]);
        let v3 = f.apply(3, vec![3.0; 4]);
        assert!(v1 < v2 && v2 < v3, "versions must strictly increase");
        assert_eq!(f.latest_version(), v3);
        let (ver, row) = f.version_and_row(3);
        assert_eq!(ver, v3);
        assert_eq!(*row.unwrap(), vec![3.0; 4]);
        assert_eq!(f.overlay_len(), 2);
    }

    #[test]
    fn apply_epoch_updates_topology_features_and_counters() {
        let ds = tiny();
        let st = StreamState::new(&ds, StreamConfig::default());
        let labels = cell_for(&ds, 2);
        let caches = vec![ShardedFeatureCache::new(
            &FeatureCacheConfig::for_dataset(ds.n(), ds.feat_dim),
        )];
        // one insert between non-adjacent far-apart nodes, one rewrite
        let (mut a, mut b) = (0u32, (ds.n() - 1) as u32);
        while st.topo().has_edge(a, b) {
            a += 1;
            b -= 1;
        }
        st.log().append(0, Mutation::EdgeInsert { u: a, v: b });
        st.log().append(
            1,
            Mutation::FeatureRewrite { node: 5, row: vec![0.5; ds.feat_dim] },
        );
        let ep = st.log().seal().unwrap();
        st.apply_epoch(ep, &labels, &caches);
        assert!(st.topo().has_edge(a, b));
        assert_eq!(st.topo().version(), 1);
        assert_eq!(st.counters.edge_inserts.load(Ordering::Relaxed), 1);
        assert_eq!(st.counters.feature_rewrites.load(Ordering::Relaxed), 1);
        assert_eq!(st.feat().latest_version(), 1);
        let (ver, row) = st.feat().version_and_row(5);
        assert_eq!(ver, 1);
        assert_eq!(row.unwrap()[0], 0.5);
        assert_eq!(st.counters.epochs_applied.load(Ordering::Relaxed), 1);
        let rep = st.report(&labels);
        assert_eq!(rep.epochs, 1);
        assert_eq!(rep.topo_version, 1);
        assert!(rep.drift >= 0.0);
        let j = rep.to_json().to_string_pretty();
        assert!(j.contains("feat_version"));
    }

    #[test]
    fn full_mode_relabels_every_epoch_and_bumps_the_fence() {
        let ds = tiny();
        let cfg = StreamConfig {
            mode: MaintenanceMode::Full,
            ..StreamConfig::default()
        };
        let st = StreamState::new(&ds, cfg);
        let labels = cell_for(&ds, 2);
        let fp0 = labels.snapshot().fingerprint;
        let caches = vec![ShardedFeatureCache::new(
            &FeatureCacheConfig::for_dataset(ds.n(), ds.feat_dim),
        )];
        st.log().append(0, Mutation::EdgeInsert { u: 0, v: 2000 });
        let ep = st.log().seal().unwrap();
        st.apply_epoch(ep, &labels, &caches);
        assert_eq!(st.counters.full_relabels.load(Ordering::Relaxed), 1);
        let snap = labels.snapshot();
        assert_eq!(snap.version, 1);
        assert_eq!(snap.plan.n_shards(), 2);
        assert_eq!(snap.labels.len(), ds.n());
        // the fence fingerprint matches the NEW labeling, not the old
        assert_eq!(
            snap.fingerprint,
            community_fingerprint(&snap.labels, snap.num_comms)
        );
        // a fresh detection over (almost) the same graph is allowed to
        // agree with the original, but the fingerprint must describe
        // whatever it produced
        let _ = fp0;
    }

    #[test]
    fn incremental_mode_publishes_label_snapshots_on_moves() {
        let ds = tiny();
        let st = StreamState::new(&ds, StreamConfig::default());
        let labels = cell_for(&ds, 2);
        let caches: Vec<ShardedFeatureCache> = vec![];
        // graft node 0 heavily into a far community to force a move:
        // delete its intra edges, connect it to many members of the
        // community of node n-1
        let far = (ds.n() - 1) as u32;
        let far_comm = ds.community[far as usize];
        let mut batch = 0usize;
        for &u in ds.csr.neighbors(0) {
            st.log().append(0, Mutation::EdgeDelete { u: 0, v: u });
            batch += 1;
        }
        let members: Vec<u32> = (0..ds.n() as u32)
            .filter(|&v| ds.community[v as usize] == far_comm && v != 0)
            .take(12)
            .collect();
        for &v in &members {
            st.log().append(0, Mutation::EdgeInsert { u: 0, v });
            batch += 1;
        }
        assert!(batch > 8, "graft needs real volume");
        let ep = st.log().seal().unwrap();
        st.apply_epoch(ep, &labels, &caches);
        let m_moved = st.counters.moved_vertices.load(Ordering::Relaxed);
        assert!(m_moved >= 1, "grafted node must move communities");
        let snap = labels.snapshot();
        assert!(snap.version >= 1, "moves must publish a new snapshot");
        assert_eq!(snap.labels[0], far_comm, "node 0 joins the graft target");
        // fingerprint generation unchanged by incremental refinement
        assert_eq!(
            snap.fingerprint,
            community_fingerprint(&ds.community, ds.num_comms)
        );
    }
}
