//! ClusterGCN baseline (Chiang et al., KDD'19) — §6.3 comparison.
//!
//! ClusterGCN partitions the graph (METIS in the paper; community
//! bin-packing here, see community::partition) and forms a mini-batch
//! as the union of `q` randomly chosen partitions. Training computes on
//! *every* node of the union — not just training-set nodes — with loss
//! masked to labeled roots. Neighborhoods are the full within-union
//! adjacency (capped at the artifact's fanout width).
//!
//! This reproduces the §6.3 behavior: per-epoch cost scales with |V|
//! (all partitions are visited every epoch) rather than with the
//! training-set size, which is why ClusterGCN loses badly on
//! small-train-split datasets (Fig. 8).

use std::collections::HashMap;

use crate::graph::Topology;
use crate::util::rng::Rng;

use super::mfg::{Mfg, MfgLayer};

/// Epoch schedule: shuffled partition ids grouped `q` per batch.
pub fn epoch_batches(
    num_parts: usize,
    q: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let mut ids: Vec<usize> = (0..num_parts).collect();
    rng.shuffle(&mut ids);
    ids.chunks(q.max(1)).map(|c| c.to_vec()).collect()
}

/// Build the MFG for a union of partitions: roots are the union's
/// nodes (truncated to `max_roots`, the artifact's batch capacity);
/// every layer links each node to up to `fanout` *within-union*
/// neighbors.
///
/// Generic over [`Topology`] so that under streaming it reads the
/// delta-overlay snapshot it is handed, not the stale base CSR.
pub fn build_mfg_cluster<T: Topology + ?Sized>(
    csr: &T,
    union_nodes: &[u32],
    fanouts: &[usize],
    max_roots: usize,
    rng: &mut Rng,
) -> Mfg {
    let layers = fanouts.len();
    let mut roots: Vec<u32> = union_nodes.to_vec();
    if roots.len() > max_roots {
        // Oversized unions (partition imbalance) are truncated; the
        // partitioner targets |union| == batch capacity.
        rng.shuffle(&mut roots);
        roots.truncate(max_roots);
        roots.sort_unstable();
    }
    let in_union: HashMap<u32, u32> = roots
        .iter()
        .enumerate()
        .map(|(i, &v)| (v, i as u32))
        .collect();

    // Every level holds the same node set; neighbor positions are
    // direct indices into that set.
    let n = roots.len();
    let mut scratch: Vec<u32> = Vec::with_capacity(64);
    let mut layers_out = Vec::with_capacity(layers);
    for &fanout in fanouts {
        let mut nbr_pos = vec![0u32; n * fanout];
        let mut counts = vec![0u32; n];
        for (i, &v) in roots.iter().enumerate() {
            scratch.clear();
            for &u in csr.neighbors(v) {
                if let Some(&p) = in_union.get(&u) {
                    scratch.push(p);
                }
            }
            let c = if scratch.len() > fanout {
                // cap: random subset of within-union neighbors
                for k in 0..fanout {
                    let j = k + rng.usize_below(scratch.len() - k);
                    scratch.swap(k, j);
                }
                fanout
            } else {
                scratch.len()
            };
            counts[i] = c as u32;
            nbr_pos[i * fanout..i * fanout + c].copy_from_slice(&scratch[..c]);
        }
        layers_out.push(MfgLayer { fanout, nbr_pos, counts });
    }

    let levels = vec![roots.clone(); layers + 1];
    Mfg { levels, layers: layers_out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::community::partition::pack_partitions;
    use crate::graph::gen::{generate_sbm, SbmParams};

    #[test]
    fn batches_cover_all_partitions() {
        let mut rng = Rng::new(1);
        let b = epoch_batches(10, 3, &mut rng);
        assert_eq!(b.len(), 4); // 3+3+3+1
        let mut all: Vec<usize> = b.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn union_mfg_within_union_only() {
        let mut rng = Rng::new(2);
        let g = generate_sbm(
            &SbmParams {
                n: 500,
                num_comms: 10,
                avg_deg: 10.0,
                p_intra: 0.85,
                deg_alpha: 2.1,
                size_alpha: 1.5,
            },
            &mut rng,
        );
        let parts = pack_partitions(&g.gt_community, 10, 5, &mut rng);
        let mut union: Vec<u32> = parts[0].iter().chain(&parts[1]).copied().collect();
        union.sort_unstable();
        let mfg = build_mfg_cluster(&g.csr, &union, &[6, 6], 512, &mut rng);
        let set: std::collections::HashSet<u32> =
            union.iter().copied().collect();
        for lvl in &mfg.levels {
            assert!(lvl.iter().all(|v| set.contains(v)));
        }
        let layer = &mfg.layers[0];
        for (i, &v) in mfg.levels[1].iter().enumerate() {
            for k in 0..layer.counts[i] as usize {
                let u = mfg.levels[0][layer.nbr_pos[i * 6 + k] as usize];
                assert!(g.csr.neighbors(v).binary_search(&u).is_ok());
                assert!(set.contains(&u));
            }
        }
    }

    /// Streaming contract: the builder reads whatever [`Topology`] it
    /// is handed, so a within-union edge inserted through the delta
    /// overlay must show up in the batch adjacency.
    #[test]
    fn observes_overlay_inserted_edge_under_churn() {
        use crate::graph::{Csr, TopoSnapshot};
        use std::sync::Arc;

        // union {0,1,2}; in the base graph 0-1 is the only edge
        let base = Arc::new(Csr::from_edges(3, &[(0, 1)]));
        let union: Vec<u32> = vec![0, 1, 2];
        let mut rng = Rng::new(5);
        let stale = build_mfg_cluster(&*base, &union, &[2], 8, &mut rng);
        assert_eq!(stale.layers[0].counts[2], 0, "node 2 isolated in base");

        let snap0 = TopoSnapshot::from_base(base);
        let (snap1, applied) = snap0.apply(&[(2, 0, true)]);
        assert_eq!(applied.len(), 1);
        let mut rng = Rng::new(5);
        let live = build_mfg_cluster(&snap1, &union, &[2], 8, &mut rng);
        assert_eq!(live.layers[0].counts[2], 1);
        let p = live.layers[0].nbr_pos[2 * 2] as usize;
        assert_eq!(
            live.levels[0][p], 0,
            "overlay-inserted edge 2-0 must appear in the union adjacency"
        );
    }

    #[test]
    fn truncates_oversized_union() {
        let mut rng = Rng::new(3);
        let g = generate_sbm(
            &SbmParams {
                n: 300,
                num_comms: 4,
                avg_deg: 8.0,
                p_intra: 0.8,
                deg_alpha: 2.1,
                size_alpha: 1.5,
            },
            &mut rng,
        );
        let union: Vec<u32> = (0..300u32).collect();
        let mfg = build_mfg_cluster(&g.csr, &union, &[4, 4], 128, &mut rng);
        assert_eq!(mfg.roots().len(), 128);
    }
}
