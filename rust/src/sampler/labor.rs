//! LABOR-0 dependent sampler (Balin & Çatalyürek, NeurIPS'23) — the
//! structure-agnostic state-of-the-art compared in §6.3, and the
//! shared-variate engine behind the serving stack's cooperative
//! cross-request sampling (`sampler=labor`).
//!
//! Key idea: instead of sampling each destination's neighborhood
//! independently, all destinations of a layer share one uniform variate
//! `r_u` per source node; dst `t` adopts neighbor `u` iff
//! `r_u <= fanout / deg(t)`. Expected per-dst sample count matches
//! uniform sampling, but the shared variates make the *union* of
//! sampled sources much smaller (defusing neighborhood explosion —
//! and, in serving, shrinking the per-batch gather footprint).
//!
//! The shared variates are **order-independent**: one seed is drawn
//! from the caller's RNG per layer and `r_u` is a pure hash of
//! `(layer_seed, u)`, so every dst reads the same variate for source
//! `u` no matter which dst is processed first. (An earlier revision
//! drew `r_u` lazily from the sequential RNG during the dst walk,
//! which made dst *B*'s sample depend on whether dst *A* had already
//! consumed draws — breaking the per-seed determinism the other
//! samplers guarantee. The permutation-invariance test below pins the
//! fix.)
//!
//! We implement the LABOR-0 variant (uniform importance); the sampled
//! count per dst is binomial, so rows are truncated at the artifact's
//! fanout width (bias is negligible at our fanouts and noted in
//! DESIGN.md).

use crate::graph::Topology;
use crate::util::rng::Rng;
use crate::util::umap::U32Map;

use super::mfg::{Mfg, MfgLayer};

/// The shared per-source variate `r_u ∈ [0, 1)`: a splitmix-style
/// avalanche of `(layer_seed, u)`. Pure in its inputs, so every dst of
/// a layer observes the same variate for source `u` regardless of
/// iteration order — the property the permutation-invariance test
/// locks in.
#[inline]
fn shared_variate(layer_seed: u64, u: u32) -> f64 {
    let mut z = layer_seed ^ (u as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Build an MFG with LABOR-0 dependent sampling. Generic over
/// [`Topology`], so it samples identically from a frozen
/// [`crate::graph::Csr`] and from a streaming
/// [`crate::graph::TopoSnapshot`] — under churn an in-flight build
/// keeps reading whatever snapshot it was handed.
pub fn build_mfg_labor<T: Topology + ?Sized>(
    topo: &T,
    roots: &[u32],
    fanouts: &[usize],
    rng: &mut Rng,
) -> Mfg {
    let layers = fanouts.len();
    let mut levels_rev: Vec<Vec<u32>> = vec![roots.to_vec()];
    let mut layers_rev: Vec<MfgLayer> = Vec::with_capacity(layers);

    for li in 0..layers {
        let fanout = fanouts[layers - 1 - li];
        // one RNG draw per layer; everything below is a pure function
        // of (layer_seed, node), so the dst walk order cannot leak
        // into the variates
        let layer_seed = rng.next_u64();
        let dst = levels_rev.last().unwrap().clone();
        let n_dst = dst.len();
        let mut prev: Vec<u32> = dst.clone();
        let mut pos = U32Map::with_capacity(n_dst * (fanout + 1));
        for (i, &v) in dst.iter().enumerate() {
            pos.insert(v, i as u32);
        }
        let mut nbr_pos = vec![0u32; n_dst * fanout];
        let mut counts = vec![0u32; n_dst];
        for (i, &v) in dst.iter().enumerate() {
            let nbrs = topo.neighbors(v);
            if nbrs.is_empty() {
                continue;
            }
            let thresh = fanout as f64 / nbrs.len() as f64;
            let mut c = 0usize;
            for &u in nbrs {
                if shared_variate(layer_seed, u) <= thresh {
                    if c < fanout {
                        let p = pos.get_or_insert_with(u, || {
                            prev.push(u);
                            (prev.len() - 1) as u32
                        });
                        nbr_pos[i * fanout + c] = p;
                        c += 1;
                    } else {
                        break; // truncate at artifact width
                    }
                }
            }
            // degenerate case: nothing crossed the threshold — keep the
            // smallest-r neighbor so no dst loses its neighborhood
            if c == 0 {
                let (&u, _) = nbrs
                    .iter()
                    .map(|u| (u, shared_variate(layer_seed, *u)))
                    .reduce(|a, b| if a.1 <= b.1 { a } else { b })
                    .unwrap();
                let p = pos.get_or_insert_with(u, || {
                    prev.push(u);
                    (prev.len() - 1) as u32
                });
                nbr_pos[i * fanout] = p;
                c = 1;
            }
            counts[i] = c as u32;
        }
        layers_rev.push(MfgLayer { fanout, nbr_pos, counts });
        levels_rev.push(prev);
    }

    levels_rev.reverse();
    layers_rev.reverse();
    Mfg { levels: levels_rev, layers: layers_rev }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_sbm, SbmParams};
    use crate::graph::{Csr, TopoSnapshot};
    use crate::sampler::neighbor::NeighborPolicy;
    use std::collections::HashSet;
    use std::sync::Arc;

    fn graph() -> Csr {
        let mut rng = Rng::new(50);
        generate_sbm(
            &SbmParams {
                n: 800,
                num_comms: 8,
                avg_deg: 14.0,
                p_intra: 0.8,
                deg_alpha: 2.1,
                size_alpha: 1.5,
            },
            &mut rng,
        )
        .csr
    }

    #[test]
    fn invariants() {
        let csr = graph();
        let mut rng = Rng::new(1);
        let roots: Vec<u32> = (0..64u32).collect();
        let mfg = build_mfg_labor(&csr, &roots, &[6, 6], &mut rng);
        assert_eq!(mfg.num_layers(), 2);
        for l in 1..=2usize {
            let layer = &mfg.layers[l - 1];
            let dst = &mfg.levels[l];
            let prev = &mfg.levels[l - 1];
            for (i, &v) in dst.iter().enumerate() {
                let c = layer.counts[i] as usize;
                assert!(c <= 6);
                if !csr.neighbors(v).is_empty() {
                    assert!(c >= 1, "dst {v} lost all neighbors");
                }
                for k in 0..c {
                    let u = prev[layer.nbr_pos[i * 6 + k] as usize];
                    assert!(csr.neighbors(v).binary_search(&u).is_ok());
                }
            }
        }
    }

    #[test]
    fn labor_union_smaller_than_independent() {
        // LABOR's whole point: the unique source set is smaller than
        // independent uniform sampling at equal fanout.
        let csr = graph();
        let comm = vec![0u32; csr.n];
        let roots: Vec<u32> = (0..200u32).collect();
        let mut tot_labor = 0usize;
        let mut tot_uni = 0usize;
        for seed in 0..5 {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            tot_labor +=
                build_mfg_labor(&csr, &roots, &[6, 6], &mut r1).input_nodes().len();
            tot_uni += crate::sampler::mfg::build_mfg(
                &csr, &comm, &roots, &[6, 6], NeighborPolicy::Uniform, &mut r2,
            )
            .input_nodes()
            .len();
        }
        assert!(
            tot_labor < tot_uni,
            "labor union {tot_labor} !< uniform union {tot_uni}"
        );
    }

    /// Per-dst sampled neighbor *sets* must not depend on the order
    /// the dsts are processed in: shuffling the roots permutes rows
    /// but every root keeps exactly the same sampled neighborhood.
    /// (This is the regression test for the lazy-draw bug, where the
    /// shared variates were consumed in dst-iteration order.)
    #[test]
    fn permutation_invariant_per_root_samples() {
        let csr = graph();
        let roots_a: Vec<u32> = (0..96u32).collect();
        let mut roots_b = roots_a.clone();
        Rng::new(77).shuffle(&mut roots_b);
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(4);
        let a = build_mfg_labor(&csr, &roots_a, &[5, 5], &mut r1);
        let b = build_mfg_labor(&csr, &roots_b, &[5, 5], &mut r2);

        // compare the top layer: same root → same sampled neighbor set
        let sampled = |mfg: &Mfg| -> std::collections::HashMap<u32, HashSet<u32>> {
            let l = mfg.num_layers();
            let layer = &mfg.layers[l - 1];
            let dst = &mfg.levels[l];
            let prev = &mfg.levels[l - 1];
            dst.iter()
                .enumerate()
                .map(|(i, &v)| {
                    let set: HashSet<u32> = (0..layer.counts[i] as usize)
                        .map(|k| prev[layer.nbr_pos[i * layer.fanout + k] as usize])
                        .collect();
                    (v, set)
                })
                .collect()
        };
        let sa = sampled(&a);
        let sb = sampled(&b);
        for v in &roots_a {
            assert_eq!(
                sa[v], sb[v],
                "root {v}: sampled set depends on dst processing order"
            );
        }
        // the union frontier is the same set either way
        let ua: HashSet<u32> = a.input_nodes().iter().copied().collect();
        let ub: HashSet<u32> = b.input_nodes().iter().copied().collect();
        assert_eq!(ua, ub, "input frontier must be permutation-invariant");
    }

    #[test]
    fn deterministic_given_seed() {
        let csr = graph();
        let roots: Vec<u32> = (10..60u32).collect();
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = build_mfg_labor(&csr, &roots, &[5, 5], &mut r1);
        let b = build_mfg_labor(&csr, &roots, &[5, 5], &mut r2);
        assert_eq!(a.levels, b.levels);
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.nbr_pos, y.nbr_pos);
            assert_eq!(x.counts, y.counts);
        }
    }

    /// Streaming contract: the builder samples whatever [`Topology`]
    /// it is handed. A node whose *only* edge arrives through the
    /// delta overlay must see that edge — sampling the stale base CSR
    /// would lose it.
    #[test]
    fn samples_overlay_inserted_edge_under_churn() {
        // base: a path 0-1-2; node 3 starts isolated
        let base = Arc::new(Csr::from_edges(4, &[(0, 1), (1, 2)]));
        let snap0 = TopoSnapshot::from_base(base.clone());
        let (snap1, applied) = snap0.apply(&[(3, 1, true)]);
        assert_eq!(applied.len(), 1);

        let mut rng = Rng::new(2);
        let stale = build_mfg_labor(&*base, &[3u32], &[4], &mut rng);
        assert_eq!(
            stale.layers[0].counts[0], 0,
            "node 3 has no neighbors in the base CSR"
        );
        let mut rng = Rng::new(2);
        let live = build_mfg_labor(&snap1, &[3u32], &[4], &mut rng);
        assert_eq!(live.layers[0].counts[0], 1);
        let u = live.levels[0][live.layers[0].nbr_pos[0] as usize];
        assert_eq!(u, 1, "the overlay-inserted edge 3-1 must be sampled");
    }
}
