//! LABOR-0 baseline sampler (Balin & Çatalyürek, NeurIPS'23) — the
//! structure-agnostic state-of-the-art compared in §6.3.
//!
//! Key idea: instead of sampling each destination's neighborhood
//! independently, all destinations of a layer share one uniform variate
//! `r_u` per source node; dst `t` adopts neighbor `u` iff
//! `r_u <= fanout / deg(t)`. Expected per-dst sample count matches
//! uniform sampling, but the shared variates make the *union* of
//! sampled sources much smaller (defusing neighborhood explosion).
//!
//! We implement the LABOR-0 variant (uniform importance); the sampled
//! count per dst is binomial, so rows are truncated at the artifact's
//! fanout width (bias is negligible at our fanouts and noted in
//! DESIGN.md).

use std::collections::HashMap;

use crate::graph::Csr;
use crate::util::rng::Rng;
use crate::util::umap::U32Map;

use super::mfg::{Mfg, MfgLayer};

pub fn build_mfg_labor(
    csr: &Csr,
    roots: &[u32],
    fanouts: &[usize],
    rng: &mut Rng,
) -> Mfg {
    let layers = fanouts.len();
    let mut levels_rev: Vec<Vec<u32>> = vec![roots.to_vec()];
    let mut layers_rev: Vec<MfgLayer> = Vec::with_capacity(layers);

    for li in 0..layers {
        let fanout = fanouts[layers - 1 - li];
        let dst = levels_rev.last().unwrap().clone();
        let n_dst = dst.len();
        let mut prev: Vec<u32> = dst.clone();
        let mut pos = U32Map::with_capacity(n_dst * (fanout + 1));
        for (i, &v) in dst.iter().enumerate() {
            pos.insert(v, i as u32);
        }
        // shared per-source variates, lazily drawn
        let mut r_u: HashMap<u32, f64> = HashMap::new();
        let mut nbr_pos = vec![0u32; n_dst * fanout];
        let mut counts = vec![0u32; n_dst];
        for (i, &v) in dst.iter().enumerate() {
            let nbrs = csr.neighbors(v);
            if nbrs.is_empty() {
                continue;
            }
            let thresh = fanout as f64 / nbrs.len() as f64;
            let mut c = 0usize;
            for &u in nbrs {
                let r = *r_u.entry(u).or_insert_with(|| rng.f64());
                if r <= thresh {
                    if c < fanout {
                        let p = pos.get_or_insert_with(u, || {
                            prev.push(u);
                            (prev.len() - 1) as u32
                        });
                        nbr_pos[i * fanout + c] = p;
                        c += 1;
                    } else {
                        break; // truncate at artifact width
                    }
                }
            }
            // degenerate case: nothing crossed the threshold — keep the
            // smallest-r neighbor so no dst loses its neighborhood
            if c == 0 {
                let (&u, _) = nbrs
                    .iter()
                    .map(|u| (u, *r_u.entry(*u).or_insert_with(|| rng.f64())))
                    .reduce(|a, b| if a.1 <= b.1 { a } else { b })
                    .unwrap();
                let p = pos.get_or_insert_with(u, || {
                    prev.push(u);
                    (prev.len() - 1) as u32
                });
                nbr_pos[i * fanout] = p;
                c = 1;
            }
            counts[i] = c as u32;
        }
        layers_rev.push(MfgLayer { fanout, nbr_pos, counts });
        levels_rev.push(prev);
    }

    levels_rev.reverse();
    layers_rev.reverse();
    Mfg { levels: levels_rev, layers: layers_rev }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_sbm, SbmParams};
    use crate::sampler::neighbor::NeighborPolicy;

    fn graph() -> Csr {
        let mut rng = Rng::new(50);
        generate_sbm(
            &SbmParams {
                n: 800,
                num_comms: 8,
                avg_deg: 14.0,
                p_intra: 0.8,
                deg_alpha: 2.1,
                size_alpha: 1.5,
            },
            &mut rng,
        )
        .csr
    }

    #[test]
    fn invariants() {
        let csr = graph();
        let mut rng = Rng::new(1);
        let roots: Vec<u32> = (0..64u32).collect();
        let mfg = build_mfg_labor(&csr, &roots, &[6, 6], &mut rng);
        assert_eq!(mfg.num_layers(), 2);
        for l in 1..=2usize {
            let layer = &mfg.layers[l - 1];
            let dst = &mfg.levels[l];
            let prev = &mfg.levels[l - 1];
            for (i, &v) in dst.iter().enumerate() {
                let c = layer.counts[i] as usize;
                assert!(c <= 6);
                if !csr.neighbors(v).is_empty() {
                    assert!(c >= 1, "dst {v} lost all neighbors");
                }
                for k in 0..c {
                    let u = prev[layer.nbr_pos[i * 6 + k] as usize];
                    assert!(csr.neighbors(v).binary_search(&u).is_ok());
                }
            }
        }
    }

    #[test]
    fn labor_union_smaller_than_independent() {
        // LABOR's whole point: the unique source set is smaller than
        // independent uniform sampling at equal fanout.
        let csr = graph();
        let comm = vec![0u32; csr.n];
        let roots: Vec<u32> = (0..200u32).collect();
        let mut tot_labor = 0usize;
        let mut tot_uni = 0usize;
        for seed in 0..5 {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            tot_labor +=
                build_mfg_labor(&csr, &roots, &[6, 6], &mut r1).input_nodes().len();
            tot_uni += crate::sampler::mfg::build_mfg(
                &csr, &comm, &roots, &[6, 6], NeighborPolicy::Uniform, &mut r2,
            )
            .input_nodes()
            .len();
        }
        assert!(
            tot_labor < tot_uni,
            "labor union {tot_labor} !< uniform union {tot_uni}"
        );
    }
}
