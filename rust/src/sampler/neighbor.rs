//! Neighbor sampling policies (paper §4.2).
//!
//! `Biased { p }` is COMM-RAND's knob: an intra-community edge carries
//! unnormalized weight `p`, an inter-community edge `1-p` (p = 0.5 ⇒
//! uniform, matching DGL's NeighborSampler with per-edge probabilities;
//! p = 1.0 ⇒ only same-community neighbors are sampled whenever the
//! node has any). Sampling is without replacement via exponential-race
//! keys (Efraimidis–Spirakis), O(deg) per node.

use crate::graph::Topology;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NeighborPolicy {
    /// Uniform random `fanout`-sampling (baseline; == Biased{p:0.5}).
    Uniform,
    /// Community-biased sampling with intra probability `p` ∈ [0.5, 1].
    Biased { p: f64 },
    /// LABOR-0 style dependent sampling (see labor.rs); the field is
    /// carried here so the MFG builder can dispatch.
    Labor,
    /// Only neighbors inside a fixed node set (ClusterGCN batches).
    WithinSet,
}

impl NeighborPolicy {
    pub fn label(&self) -> String {
        match self {
            NeighborPolicy::Uniform => "p0.50".into(),
            NeighborPolicy::Biased { p } => format!("p{p:.2}"),
            NeighborPolicy::Labor => "labor".into(),
            NeighborPolicy::WithinSet => "within".into(),
        }
    }
}

/// Sample up to `fanout` distinct neighbors of `v` into `out`.
///
/// For `Biased{p=1.0}` only intra-community edges are eligible unless
/// the node has none (then it falls back to uniform over all; a node
/// must not lose its entire neighborhood).
pub fn sample_neighbors<T: Topology + ?Sized>(
    topo: &T,
    community: &[u32],
    v: u32,
    fanout: usize,
    policy: NeighborPolicy,
    rng: &mut Rng,
    out: &mut Vec<u32>,
) {
    out.clear();
    let nbrs = topo.neighbors(v);
    if nbrs.is_empty() {
        return;
    }
    match policy {
        NeighborPolicy::Uniform => {
            if nbrs.len() <= fanout {
                out.extend_from_slice(nbrs);
            } else {
                for i in rng.sample_indices(nbrs.len(), fanout) {
                    out.push(nbrs[i]);
                }
            }
        }
        NeighborPolicy::Biased { p } => {
            let cv = community[v as usize];
            if p >= 1.0 {
                // hard intra-only: restrict candidate set
                let intra: Vec<u32> = nbrs
                    .iter()
                    .copied()
                    .filter(|&u| community[u as usize] == cv)
                    .collect();
                let cands: &[u32] = if intra.is_empty() { nbrs } else { &intra };
                if cands.len() <= fanout {
                    out.extend_from_slice(cands);
                } else {
                    for i in rng.sample_indices(cands.len(), fanout) {
                        out.push(cands[i]);
                    }
                }
            } else if nbrs.len() <= fanout {
                out.extend_from_slice(nbrs);
            } else {
                // weighted w/o replacement: keep the `fanout` smallest
                // -ln(u)/w keys
                weighted_sample(
                    nbrs,
                    |u| {
                        if community[u as usize] == cv {
                            p
                        } else {
                            1.0 - p
                        }
                    },
                    fanout,
                    rng,
                    out,
                );
            }
        }
        NeighborPolicy::Labor | NeighborPolicy::WithinSet => {
            panic!("{policy:?} is handled by its dedicated builder");
        }
    }
}

/// Efraimidis–Spirakis weighted sampling without replacement.
fn weighted_sample(
    cands: &[u32],
    weight: impl Fn(u32) -> f64,
    k: usize,
    rng: &mut Rng,
    out: &mut Vec<u32>,
) {
    // (key, node) max-heap of size k on smallest keys
    let mut heap: Vec<(f64, u32)> = Vec::with_capacity(k + 1);
    for &u in cands {
        let w = weight(u).max(1e-12);
        let key = -rng.f64().max(1e-300).ln() / w;
        if heap.len() < k {
            heap.push((key, u));
            if heap.len() == k {
                heap.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            }
        } else if key < heap[0].0 {
            // replace current max, restore descending order
            heap[0] = (key, u);
            let mut i = 0;
            while i + 1 < heap.len() && heap[i].0 < heap[i + 1].0 {
                heap.swap(i, i + 1);
                i += 1;
            }
        }
    }
    out.extend(heap.iter().map(|&(_, u)| u));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    /// star graph: node 0 connected to 1..=40; communities: 1..=20 share
    /// community 0 with the center, 21..=40 are community 1.
    fn star() -> (Csr, Vec<u32>) {
        let edges: Vec<(u32, u32)> = (1..=40u32).map(|u| (0, u)).collect();
        let csr = Csr::from_edges(41, &edges);
        let mut comm = vec![0u32; 41];
        for c in comm.iter_mut().skip(21) {
            *c = 1;
        }
        (csr, comm)
    }

    #[test]
    fn uniform_respects_fanout_and_dedup() {
        let (csr, comm) = star();
        let mut rng = Rng::new(1);
        let mut out = Vec::new();
        for _ in 0..50 {
            sample_neighbors(
                &csr, &comm, 0, 10, NeighborPolicy::Uniform, &mut rng, &mut out,
            );
            assert_eq!(out.len(), 10);
            let mut d = out.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 10);
        }
    }

    #[test]
    fn takes_all_when_degree_small() {
        let (csr, comm) = star();
        let mut rng = Rng::new(2);
        let mut out = Vec::new();
        sample_neighbors(
            &csr, &comm, 5, 10, NeighborPolicy::Uniform, &mut rng, &mut out,
        );
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn p1_samples_only_intra() {
        let (csr, comm) = star();
        let mut rng = Rng::new(3);
        let mut out = Vec::new();
        for _ in 0..50 {
            sample_neighbors(
                &csr,
                &comm,
                0,
                10,
                NeighborPolicy::Biased { p: 1.0 },
                &mut rng,
                &mut out,
            );
            assert!(out.iter().all(|&u| comm[u as usize] == 0), "{out:?}");
        }
    }

    #[test]
    fn p1_falls_back_when_no_intra() {
        // node 21 (community 1) has only the center (community 0)
        let (csr, comm) = star();
        let mut rng = Rng::new(4);
        let mut out = Vec::new();
        sample_neighbors(
            &csr,
            &comm,
            21,
            5,
            NeighborPolicy::Biased { p: 1.0 },
            &mut rng,
            &mut out,
        );
        assert_eq!(out, vec![0], "isolated-in-community node lost neighbors");
    }

    #[test]
    fn p09_prefers_intra_statistically() {
        let (csr, comm) = star();
        let mut rng = Rng::new(5);
        let mut out = Vec::new();
        let mut intra = 0usize;
        let mut total = 0usize;
        for _ in 0..400 {
            sample_neighbors(
                &csr,
                &comm,
                0,
                10,
                NeighborPolicy::Biased { p: 0.9 },
                &mut rng,
                &mut out,
            );
            total += out.len();
            intra += out
                .iter()
                .filter(|&&u| comm[u as usize] == 0)
                .count();
        }
        let frac = intra as f64 / total as f64;
        // 20 intra @ w=0.9 vs 20 inter @ w=0.1 -> strongly intra
        assert!(frac > 0.75, "intra fraction {frac}");
    }

    #[test]
    fn p05_is_unbiased() {
        let (csr, comm) = star();
        let mut rng = Rng::new(6);
        let mut out = Vec::new();
        let mut intra = 0usize;
        let mut total = 0usize;
        for _ in 0..600 {
            sample_neighbors(
                &csr,
                &comm,
                0,
                10,
                NeighborPolicy::Biased { p: 0.5 },
                &mut rng,
                &mut out,
            );
            total += out.len();
            intra += out.iter().filter(|&&u| comm[u as usize] == 0).count();
        }
        let frac = intra as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.06, "intra fraction {frac}");
    }

    #[test]
    fn weighted_sample_distinct() {
        let cands: Vec<u32> = (0..30).collect();
        let mut rng = Rng::new(7);
        let mut out = Vec::new();
        for _ in 0..50 {
            out.clear();
            weighted_sample(&cands, |_| 1.0, 7, &mut rng, &mut out);
            assert_eq!(out.len(), 7);
            let mut d = out.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 7);
        }
    }
}
