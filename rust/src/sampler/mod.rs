//! Mini-batch construction — the paper's contribution (§4).
//!
//! Two steps per Algorithm 1:
//!  1. root-node partitioning ([`roots`]) — how the training set is
//!     divided across batches each epoch (Table 1 policies);
//!  2. sub-graph construction ([`mfg`]) — L-hop neighborhood traversal
//!     with neighbor sampling ([`neighbor`]), including the
//!     community-biased scheme with knob `p` (§4.2).
//!
//! [`labor`] implements the LABOR-0 baseline (§6.3), [`clustergcn`] the
//! ClusterGCN baseline (§6.3).

pub mod clustergcn;
pub mod labor;
pub mod mfg;
pub mod neighbor;
pub mod roots;

pub use labor::build_mfg_labor;
pub use mfg::{build_mfg, Mfg};
pub use neighbor::NeighborPolicy;
pub use roots::RootPolicy;

/// Which sampler the serving batch path runs (the `sampler=` knob on
/// `serve bench`). `Uniform` is the default and is bitwise-compatible
/// with pre-knob benches (identical RNG draw sequence); `Biased` wires
/// the paper's `p` into the *sampling* layer (it previously only shaped
/// batch composition); `Labor` shares per-source variates across every
/// request in the micro-batch — cooperative cross-request sampling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// Independent uniform neighbor sampling (default).
    Uniform,
    /// Community-biased independent sampling with intra weight
    /// `sample_p`.
    Biased,
    /// LABOR-0 shared-variate dependent sampling — one merged MFG whose
    /// union frontier shrinks as co-batched requests overlap.
    Labor,
}

impl SamplerKind {
    /// Parse a `sampler=` CLI value.
    pub fn parse(s: &str) -> Option<SamplerKind> {
        match s {
            "uniform" => Some(SamplerKind::Uniform),
            "biased" => Some(SamplerKind::Biased),
            "labor" => Some(SamplerKind::Labor),
            _ => None,
        }
    }

    /// The CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Uniform => "uniform",
            SamplerKind::Biased => "biased",
            SamplerKind::Labor => "labor",
        }
    }
}
