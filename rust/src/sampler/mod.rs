//! Mini-batch construction — the paper's contribution (§4).
//!
//! Two steps per Algorithm 1:
//!  1. root-node partitioning ([`roots`]) — how the training set is
//!     divided across batches each epoch (Table 1 policies);
//!  2. sub-graph construction ([`mfg`]) — L-hop neighborhood traversal
//!     with neighbor sampling ([`neighbor`]), including the
//!     community-biased scheme with knob `p` (§4.2).
//!
//! [`labor`] implements the LABOR-0 baseline (§6.3), [`clustergcn`] the
//! ClusterGCN baseline (§6.3).

pub mod clustergcn;
pub mod labor;
pub mod mfg;
pub mod neighbor;
pub mod roots;

pub use mfg::{build_mfg, Mfg};
pub use neighbor::NeighborPolicy;
pub use roots::RootPolicy;
