//! Root-node partitioning policies (paper §4.1, Table 1).
//!
//! Given the training set and the node->community map, produce the
//! epoch's ordering of root nodes; consecutive `batch_size` slices form
//! the mini-batches.
//!
//! * `Rand` — uniform random shuffle (the DGL baseline).
//! * `NoRand` — community-sorted static order (no per-epoch change).
//! * `CommRandMix { pct }` — COMM-RAND: shuffle communities as whole
//!   blocks, merge consecutive groups of `ceil(pct * #comms)`
//!   communities into super-blocks, then shuffle *within* each
//!   super-block. `pct = 0` keeps single-community blocks (maximum
//!   structure bias with randomization); larger `pct` mixes more.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RootPolicy {
    Rand,
    NoRand,
    /// `pct` ∈ [0, 1]: fraction of the training set's communities
    /// merged into one shuffling super-block (paper's k%).
    CommRandMix { pct: f64 },
}

impl RootPolicy {
    pub fn label(&self) -> String {
        match self {
            RootPolicy::Rand => "RAND-ROOTS".to_string(),
            RootPolicy::NoRand => "NORAND-ROOTS".to_string(),
            RootPolicy::CommRandMix { pct } => {
                format!("COMM-RAND-MIX-{}%", pct * 100.0)
            }
        }
    }

    /// All policies evaluated in Figure 5.
    pub fn figure5_set() -> Vec<RootPolicy> {
        vec![
            RootPolicy::Rand,
            RootPolicy::NoRand,
            RootPolicy::CommRandMix { pct: 0.0 },
            RootPolicy::CommRandMix { pct: 0.125 },
            RootPolicy::CommRandMix { pct: 0.25 },
            RootPolicy::CommRandMix { pct: 0.50 },
        ]
    }
}

/// Produce this epoch's root-node order.
///
/// `train_nodes` must be sorted ascending (stable input); `community`
/// maps every graph node to its community id.
pub fn order_roots(
    policy: RootPolicy,
    train_nodes: &[u32],
    community: &[u32],
    rng: &mut Rng,
) -> Vec<u32> {
    match policy {
        RootPolicy::Rand => {
            let mut v = train_nodes.to_vec();
            rng.shuffle(&mut v);
            v
        }
        RootPolicy::NoRand => {
            // static community-sorted order, identical every epoch
            let mut v = train_nodes.to_vec();
            v.sort_by_key(|&x| (community[x as usize], x));
            v
        }
        RootPolicy::CommRandMix { pct } => {
            // group the training set by community
            let mut sorted = train_nodes.to_vec();
            sorted.sort_by_key(|&x| (community[x as usize], x));
            let mut blocks: Vec<Vec<u32>> = Vec::new();
            for &v in &sorted {
                let c = community[v as usize];
                match blocks.last() {
                    Some(b) if community[b[0] as usize] == c => {
                        blocks.last_mut().unwrap().push(v)
                    }
                    _ => blocks.push(vec![v]),
                }
            }
            // (1) shuffle communities as whole blocks
            rng.shuffle(&mut blocks);
            // (2) merge into super-blocks of ceil(pct * #comms) comms
            let ncomm = blocks.len();
            let group = if pct <= 0.0 {
                1
            } else {
                ((pct * ncomm as f64).ceil() as usize).clamp(1, ncomm)
            };
            let mut out = Vec::with_capacity(train_nodes.len());
            for chunk in blocks.chunks(group) {
                let start = out.len();
                for b in chunk {
                    out.extend_from_slice(b);
                }
                // (3) shuffle within the super-block
                rng.shuffle(&mut out[start..]);
            }
            out
        }
    }
}

/// Slice an epoch order into mini-batches of `batch_size` roots (last
/// batch may be smaller).
pub fn batches(order: &[u32], batch_size: usize) -> Vec<&[u32]> {
    order.chunks(batch_size).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Vec<u32>, Vec<u32>) {
        // 300 nodes, 10 communities of 30 consecutive nodes
        let community: Vec<u32> = (0..300u32).map(|v| v / 30).collect();
        let train: Vec<u32> = (0..300u32).filter(|v| v % 3 != 2).collect();
        (train, community)
    }

    fn is_perm_of(a: &[u32], b: &[u32]) -> bool {
        let mut x = a.to_vec();
        let mut y = b.to_vec();
        x.sort_unstable();
        y.sort_unstable();
        x == y
    }

    #[test]
    fn all_policies_are_exact_covers() {
        let (train, comm) = setup();
        let mut rng = Rng::new(1);
        for pol in RootPolicy::figure5_set() {
            let order = order_roots(pol, &train, &comm, &mut rng);
            assert!(is_perm_of(&order, &train), "{pol:?}");
        }
    }

    #[test]
    fn norand_is_static() {
        let (train, comm) = setup();
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(999);
        let a = order_roots(RootPolicy::NoRand, &train, &comm, &mut r1);
        let b = order_roots(RootPolicy::NoRand, &train, &comm, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn rand_changes_across_epochs() {
        let (train, comm) = setup();
        let mut rng = Rng::new(1);
        let a = order_roots(RootPolicy::Rand, &train, &comm, &mut rng);
        let b = order_roots(RootPolicy::Rand, &train, &comm, &mut rng);
        assert_ne!(a, b);
    }

    #[test]
    fn mix0_keeps_communities_contiguous() {
        let (train, comm) = setup();
        let mut rng = Rng::new(5);
        let order = order_roots(
            RootPolicy::CommRandMix { pct: 0.0 },
            &train,
            &comm,
            &mut rng,
        );
        // community changes exactly ncomm-1 times along the order
        let mut switches = 0;
        for w in order.windows(2) {
            if comm[w[0] as usize] != comm[w[1] as usize] {
                switches += 1;
            }
        }
        assert_eq!(switches, 9, "communities fragmented");
        // but contents within a community are shuffled
        let first_comm: Vec<u32> = order
            .iter()
            .copied()
            .take_while(|&v| comm[v as usize] == comm[order[0] as usize])
            .collect();
        let mut sorted = first_comm.clone();
        sorted.sort_unstable();
        assert_ne!(first_comm, sorted, "intra-community order not shuffled");
    }

    #[test]
    fn mix50_creates_two_superblocks() {
        let (train, comm) = setup();
        let mut rng = Rng::new(6);
        let order = order_roots(
            RootPolicy::CommRandMix { pct: 0.5 },
            &train,
            &comm,
            &mut rng,
        );
        // each half of the order should contain exactly 5 communities
        let half = order.len() / 2;
        let mut first: Vec<u32> =
            order[..half].iter().map(|&v| comm[v as usize]).collect();
        first.sort_unstable();
        first.dedup();
        assert_eq!(first.len(), 5, "first super-block has {first:?}");
    }

    #[test]
    fn mix_partial_groups_handled() {
        // 7 communities with pct=0.25 -> groups of 2: 2+2+2+1
        let comm: Vec<u32> = (0..70u32).map(|v| v / 10).collect();
        let train: Vec<u32> = (0..70u32).collect();
        let mut rng = Rng::new(7);
        let order = order_roots(
            RootPolicy::CommRandMix { pct: 0.25 },
            &train,
            &comm,
            &mut rng,
        );
        assert_eq!(order.len(), 70);
        let mut s = order.to_vec();
        s.sort_unstable();
        assert_eq!(s, train);
    }

    #[test]
    fn batches_cover_order() {
        let order: Vec<u32> = (0..10u32).collect();
        let b = batches(&order, 4);
        assert_eq!(b.len(), 3);
        assert_eq!(b[2], &[8, 9]);
    }
}
