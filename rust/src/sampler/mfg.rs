//! Message-flow-graph (MFG) construction: the L-hop sampled sub-graph
//! of one mini-batch (Algorithm 1, step 2).
//!
//! Built output-to-input, DGL-block style: level `L` holds the roots;
//! expanding layer `l` seeds the previous level with the layer's dst
//! nodes (so the self connection always resolves) and appends sampled
//! neighbors, deduplicated via a global→position map. Neighbor slots
//! store *positions into the previous level*, which is exactly the
//! local-index layout the padded artifact consumes; the batch builder
//! rewrites layer-1 positions to global ids in resident-feature mode.

use crate::graph::Topology;
use crate::util::rng::Rng;
use crate::util::umap::U32Map;

use super::neighbor::{sample_neighbors, NeighborPolicy};

/// One sampled L-layer sub-graph.
pub struct Mfg {
    /// Node arrays per level: `levels[0]` = input frontier,
    /// `levels[L]` = roots. Values are global node ids.
    pub levels: Vec<Vec<u32>>,
    /// Per layer `l` (1-based, `layers[l-1]`): flattened neighbor
    /// positions into `levels[l-1]`, `counts[i]` valid slots for dst i,
    /// row stride = `fanout`.
    pub layers: Vec<MfgLayer>,
}

pub struct MfgLayer {
    pub fanout: usize,
    /// `[n_dst * fanout]`, positions into the previous level;
    /// only the first `counts[i]` of row i are valid.
    pub nbr_pos: Vec<u32>,
    pub counts: Vec<u32>,
}

impl Mfg {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn roots(&self) -> &[u32] {
        self.levels.last().unwrap()
    }

    pub fn input_nodes(&self) -> &[u32] {
        &self.levels[0]
    }

    /// Total unique nodes across the input frontier (the batch's input
    /// feature footprint, Fig. 6's x-axis).
    pub fn input_bytes(&self, feat_dim: usize) -> usize {
        self.levels[0].len() * feat_dim * 4
    }

    /// Input-frontier references *with multiplicity*: every layer-1 dst
    /// (each needs its own x⁰ row via the self connection) plus every
    /// valid sampled-neighbor slot. This is the number of feature rows
    /// the batch would gather if nothing were shared; `input_nodes()`
    /// is what it gathers after cross-request dedup.
    pub fn frontier_refs(&self) -> u64 {
        if self.layers.is_empty() {
            return self.levels[0].len() as u64;
        }
        self.levels[1].len() as u64
            + self.layers[0].counts.iter().map(|&c| c as u64).sum::<u64>()
    }

    /// Cooperative-sampling win for this batch: refs ÷ unique inputs.
    /// `1.0` means fully disjoint neighborhoods (dedup saved nothing);
    /// `> 1` means co-batched requests shared sources. Always ≥ 1 —
    /// every unique input node is referenced at least once.
    pub fn dedup_factor(&self) -> f64 {
        let unique = self.levels[0].len() as u64;
        if unique == 0 {
            return 1.0;
        }
        self.frontier_refs() as f64 / unique as f64
    }
}

/// Sample an MFG for `roots`; `fanouts` lists per-layer fanouts,
/// input-most first (layer `l` samples `fanouts[l-1]` neighbors).
///
/// Generic over [`Topology`], so it samples identically from a frozen
/// [`crate::graph::Csr`] and from a streaming
/// [`crate::graph::TopoSnapshot`] — an in-flight build keeps reading
/// whatever snapshot it was handed.
pub fn build_mfg<T: Topology + ?Sized>(
    csr: &T,
    community: &[u32],
    roots: &[u32],
    fanouts: &[usize],
    policy: NeighborPolicy,
    rng: &mut Rng,
) -> Mfg {
    let layers = fanouts.len();
    // build output -> input, then reverse
    let mut levels_rev: Vec<Vec<u32>> = vec![roots.to_vec()];
    let mut layers_rev: Vec<MfgLayer> = Vec::with_capacity(layers);
    let mut scratch: Vec<u32> = Vec::with_capacity(32);

    for li in 0..layers {
        let fanout = fanouts[layers - 1 - li]; // output-most first here
        let dst = levels_rev.last().unwrap().clone();
        let n_dst = dst.len();
        // previous level starts with the dst nodes themselves
        let mut prev: Vec<u32> = dst.clone();
        let mut pos = U32Map::with_capacity(n_dst * (fanout + 1));
        for (i, &v) in dst.iter().enumerate() {
            pos.insert(v, i as u32);
        }
        let mut nbr_pos = vec![0u32; n_dst * fanout];
        let mut counts = vec![0u32; n_dst];
        for (i, &v) in dst.iter().enumerate() {
            sample_neighbors(csr, community, v, fanout, policy, rng, &mut scratch);
            counts[i] = scratch.len() as u32;
            for (k, &u) in scratch.iter().enumerate() {
                let p = pos.get_or_insert_with(u, || {
                    prev.push(u);
                    (prev.len() - 1) as u32
                });
                nbr_pos[i * fanout + k] = p;
            }
        }
        layers_rev.push(MfgLayer { fanout, nbr_pos, counts });
        levels_rev.push(prev);
    }

    levels_rev.reverse();
    layers_rev.reverse();
    Mfg { levels: levels_rev, layers: layers_rev }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_sbm, SbmParams};
    use crate::graph::Csr;

    fn test_graph() -> (Csr, Vec<u32>) {
        let mut rng = Rng::new(100);
        let g = generate_sbm(
            &SbmParams {
                n: 600,
                num_comms: 8,
                avg_deg: 10.0,
                p_intra: 0.85,
                deg_alpha: 2.1,
                size_alpha: 1.5,
            },
            &mut rng,
        );
        (g.csr, g.gt_community)
    }

    #[test]
    fn structure_invariants() {
        let (csr, comm) = test_graph();
        let mut rng = Rng::new(1);
        let roots: Vec<u32> = (0..64u32).collect();
        let mfg = build_mfg(
            &csr, &comm, &roots, &[5, 5], NeighborPolicy::Uniform, &mut rng,
        );
        assert_eq!(mfg.num_layers(), 2);
        assert_eq!(mfg.levels.len(), 3);
        assert_eq!(mfg.roots(), &roots[..]);
        // each level's nodes are unique
        for lvl in &mfg.levels {
            let mut d = lvl.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), lvl.len(), "duplicate nodes in level");
        }
        // dst nodes are a prefix of the previous level
        for l in 1..=2usize {
            let dst = &mfg.levels[l];
            let prev = &mfg.levels[l - 1];
            assert!(prev.len() >= dst.len());
            assert_eq!(&prev[..dst.len()], &dst[..]);
        }
        // neighbor positions are in range and refer to real neighbors
        for l in 1..=2usize {
            let layer = &mfg.layers[l - 1];
            let dst = &mfg.levels[l];
            let prev = &mfg.levels[l - 1];
            for (i, &v) in dst.iter().enumerate() {
                let c = layer.counts[i] as usize;
                assert!(c <= 5);
                for k in 0..c {
                    let p = layer.nbr_pos[i * 5 + k] as usize;
                    assert!(p < prev.len());
                    let u = prev[p];
                    assert!(
                        csr.neighbors(v).binary_search(&u).is_ok(),
                        "{u} is not a neighbor of {v}"
                    );
                }
            }
        }
    }

    #[test]
    fn level_sizes_bounded() {
        let (csr, comm) = test_graph();
        let mut rng = Rng::new(2);
        let roots: Vec<u32> = (0..32u32).collect();
        let mfg = build_mfg(
            &csr, &comm, &roots, &[4, 4, 4], NeighborPolicy::Uniform, &mut rng,
        );
        let mut bound = roots.len();
        for l in (0..3).rev() {
            bound *= 4 + 1;
            assert!(
                mfg.levels[l].len() <= bound.min(csr.n),
                "level {l} too large: {} > {bound}",
                mfg.levels[l].len()
            );
        }
    }

    #[test]
    fn biased_p1_shrinks_frontier() {
        let (csr, comm) = test_graph();
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let roots: Vec<u32> = (0..64u32).collect();
        let uni = build_mfg(
            &csr, &comm, &roots, &[8, 8], NeighborPolicy::Uniform, &mut r1,
        );
        let biased = build_mfg(
            &csr, &comm, &roots, &[8, 8],
            NeighborPolicy::Biased { p: 1.0 }, &mut r2,
        );
        // intra-only sampling must touch no more unique inputs
        assert!(
            biased.input_nodes().len() <= uni.input_nodes().len(),
            "biased {} vs uniform {}",
            biased.input_nodes().len(),
            uni.input_nodes().len()
        );
    }

    /// Disjoint star components: every sampled neighbor is referenced
    /// exactly once, so refs == unique and the dedup factor is exactly
    /// 1.0 — cooperative sampling saves nothing when nothing is shared.
    #[test]
    fn dedup_factor_one_for_disjoint_neighborhoods() {
        // 8 disjoint stars: center c = 5k, leaves 5k+1..5k+4
        let mut edges = Vec::new();
        for k in 0..8u32 {
            for l in 1..5u32 {
                edges.push((5 * k, 5 * k + l));
            }
        }
        let csr = Csr::from_edges(40, &edges);
        let comm = vec![0u32; 40];
        let roots: Vec<u32> = (0..8u32).map(|k| 5 * k).collect();
        let mut rng = Rng::new(7);
        // fanout ≥ degree → every leaf sampled, each exactly once
        let mfg = build_mfg(
            &csr, &comm, &roots, &[4], NeighborPolicy::Uniform, &mut rng,
        );
        assert_eq!(mfg.frontier_refs(), 8 + 8 * 4);
        assert_eq!(mfg.input_nodes().len(), 40);
        assert_eq!(mfg.dedup_factor(), 1.0);
    }

    /// Shared-hub batch: every root's only neighbor is one hub, so the
    /// hub is referenced once per root but gathered once — dedup > 1.
    #[test]
    fn dedup_factor_above_one_for_shared_hub() {
        let hub = 0u32;
        let edges: Vec<(u32, u32)> = (1..9u32).map(|v| (hub, v)).collect();
        let csr = Csr::from_edges(9, &edges);
        let comm = vec![0u32; 9];
        let roots: Vec<u32> = (1..9u32).collect();
        let mut rng = Rng::new(7);
        let mfg = build_mfg(
            &csr, &comm, &roots, &[2], NeighborPolicy::Uniform, &mut rng,
        );
        // refs = 8 dsts + 8 hub samples; unique = 8 roots + 1 hub
        assert_eq!(mfg.frontier_refs(), 16);
        assert_eq!(mfg.input_nodes().len(), 9);
        assert!(mfg.dedup_factor() > 1.5, "got {}", mfg.dedup_factor());
    }

    /// refs ≥ unique holds for any sampled MFG: each unique input node
    /// is referenced at least once (dsts via the self connection,
    /// appended sources via the sample that appended them).
    #[test]
    fn frontier_refs_at_least_unique() {
        let (csr, comm) = test_graph();
        for seed in 0..8u64 {
            let mut rng = Rng::new(seed);
            let mut roots: Vec<u32> = (0..600u32).collect();
            rng.shuffle(&mut roots);
            roots.truncate(48);
            roots.sort_unstable();
            for policy in [
                NeighborPolicy::Uniform,
                NeighborPolicy::Biased { p: 0.9 },
            ] {
                let mfg =
                    build_mfg(&csr, &comm, &roots, &[6, 6], policy, &mut rng);
                assert!(
                    mfg.frontier_refs() >= mfg.input_nodes().len() as u64,
                    "refs {} < unique {} (seed {seed})",
                    mfg.frontier_refs(),
                    mfg.input_nodes().len()
                );
                assert!(mfg.dedup_factor() >= 1.0);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (csr, comm) = test_graph();
        let roots: Vec<u32> = (10..42u32).collect();
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = build_mfg(&csr, &comm, &roots, &[5, 5], NeighborPolicy::Uniform, &mut r1);
        let b = build_mfg(&csr, &comm, &roots, &[5, 5], NeighborPolicy::Uniform, &mut r2);
        assert_eq!(a.levels, b.levels);
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.nbr_pos, y.nbr_pos);
            assert_eq!(x.counts, y.counts);
        }
    }
}
