//! PJRT runtime: loads the AOT artifacts emitted by `make artifacts`
//! (HLO text + manifest.json) and executes them on the CPU PJRT client.
//! This is the only place the `xla` crate is touched; python never runs
//! on the training path.

pub mod artifact;
pub mod host;
pub mod pjrt;
pub mod step;

pub use artifact::{ArtifactMeta, IoSpec, Manifest};
pub use pjrt::{Executable, Runtime};
pub use step::{FullBatchState, InferState, TrainState};
