//! Execution runtimes: the PJRT path for AOT artifacts, the pure-rust
//! host reference model, and the quantized integer kernels.
//!
//! * [`pjrt`] / [`artifact`] / [`step`] — load the AOT artifacts
//!   emitted by `make artifacts` (HLO text + manifest.json) and
//!   execute them on the CPU PJRT client. This is the only place the
//!   `xla` crate is touched; python never runs on the training path.
//! * [`host`] — the SGC-style host model: a pure-rust f32 reference
//!   implementation with real logits and no artifact dependency.
//! * [`kernels`] — i8/i16 integer SIMD kernels with runtime dispatch
//!   (scalar / AVX2 / optional AVX-512), serving `i16q`-quantized
//!   checkpoints ([`crate::ckpt::quant`]) through the host executor.
//!   Every variant returns bitwise-identical accumulators, so kernel
//!   choice is purely a throughput knob.

pub mod artifact;
pub mod host;
pub mod kernels;
pub mod pjrt;
pub mod step;

pub use artifact::{ArtifactMeta, IoSpec, Manifest};
pub use kernels::KernelBackend;
pub use pjrt::{Executable, Runtime};
pub use step::{FullBatchState, InferState, TrainState};
