//! Host (pure-rust) reference model: an SGC-style classifier that
//! runs anywhere — no AOT artifacts, no PJRT.
//!
//! The model is deliberately minimal: features are smoothed once over
//! the graph (`agg[v] = mean of x over {v} ∪ N(v)`, the 1-hop SGC
//! propagation) and a single linear layer maps the smoothed feature to
//! class logits. That is enough to (a) learn the synthetic datasets'
//! class signal well above chance, (b) give `serve bench` *real*
//! trained-parameter accuracy in environments without XLA, and (c)
//! exercise the full checkpoint → param-store → hot-swap path with a
//! parameter layout ([`param_shapes`]) the checkpoint subsystem treats
//! exactly like a PJRT artifact's. When real artifacts exist, the PJRT
//! executor takes precedence and this model is not used.
//!
//! Parameter layout: `params[0]` is `W` with shape
//! `[feat_dim, num_classes]` (row-major), `params[1]` is the bias `b`
//! with shape `[num_classes]`.

use anyhow::{bail, Result};

use crate::graph::Dataset;
use crate::util::rng::Rng;

use super::step::init_param;

/// Model name recorded in checkpoints produced by the host trainer.
pub const HOST_MODEL: &str = "host-sgc";

/// Parameter shapes of the host model for a dataset geometry.
pub fn param_shapes(feat_dim: usize, num_classes: usize) -> Vec<Vec<usize>> {
    vec![vec![feat_dim, num_classes], vec![num_classes]]
}

/// Seed-initialized host parameters (Glorot `W`, zero `b`) — the same
/// init family the PJRT states use, so "seed params" means the same
/// thing on every backend.
pub fn init_params(
    feat_dim: usize,
    num_classes: usize,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed ^ 0x9a27_11f3);
    param_shapes(feat_dim, num_classes)
        .iter()
        .map(|sh| init_param(sh, &mut rng))
        .collect()
}

/// Check a parameter set against the host layout; errors name the
/// offending tensor so checkpoint-mismatch reports are actionable.
pub fn check_params(
    params: &[Vec<f32>],
    feat_dim: usize,
    num_classes: usize,
) -> Result<()> {
    let shapes = param_shapes(feat_dim, num_classes);
    if params.len() != shapes.len() {
        bail!(
            "host model wants {} tensors ({feat_dim}x{num_classes} + bias), \
             got {}",
            shapes.len(),
            params.len()
        );
    }
    for (i, (p, sh)) in params.iter().zip(&shapes).enumerate() {
        let want: usize = sh.iter().product();
        if p.len() != want {
            bail!(
                "host model tensor {i} has {} elements, shape {sh:?} \
                 wants {want}",
                p.len()
            );
        }
    }
    Ok(())
}

/// The 1-hop SGC propagation, materialized once: row `v` is the mean
/// of the raw features over `{v} ∪ N(v)`. `n * feat_dim` f32 — the
/// same footprint as the feature table itself.
pub fn aggregate_table(ds: &Dataset) -> Vec<f32> {
    let n = ds.n();
    let f = ds.feat_dim;
    let mut agg = vec![0f32; n * f];
    for v in 0..n as u32 {
        let row = &mut agg[v as usize * f..(v as usize + 1) * f];
        row.copy_from_slice(ds.feature_row(v));
        let nbrs = ds.csr.neighbors(v);
        for &u in nbrs {
            for (r, &x) in row.iter_mut().zip(ds.feature_row(u)) {
                *r += x;
            }
        }
        let inv = 1.0 / (nbrs.len() + 1) as f32;
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
    agg
}

/// Logits for one (already aggregated) feature row into `out`
/// (`len == num_classes`).
pub fn logits_into(params: &[Vec<f32>], feat: &[f32], out: &mut [f32]) {
    let c = out.len();
    let w = &params[0];
    let b = &params[1];
    out.copy_from_slice(&b[..c]);
    for (i, &x) in feat.iter().enumerate() {
        if x == 0.0 {
            continue;
        }
        let wrow = &w[i * c..(i + 1) * c];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += x * wv;
        }
    }
}

/// Index of the largest logit (ties → lowest index).
pub fn top1(logits: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate().skip(1) {
        if x > logits[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    #[test]
    fn shapes_and_init_agree() {
        let p = init_params(8, 3, 42);
        check_params(&p, 8, 3).unwrap();
        assert_eq!(p[0].len(), 24);
        assert_eq!(p[1], vec![0.0; 3]);
        // deterministic in the seed
        assert_eq!(init_params(8, 3, 42), p);
        assert_ne!(init_params(8, 3, 43)[0], p[0]);
        // wrong layouts are named
        assert!(check_params(&p, 7, 3).is_err());
        assert!(check_params(&p[..1], 8, 3).is_err());
    }

    #[test]
    fn aggregate_is_mean_over_closed_neighborhood() {
        let ds = crate::train::dataset::build(&preset("tiny").unwrap(), true);
        let agg = aggregate_table(&ds);
        let f = ds.feat_dim;
        for &v in &[0u32, 7, 100] {
            let nbrs = ds.csr.neighbors(v);
            let mut want = ds.feature_row(v).to_vec();
            for &u in nbrs {
                for (j, x) in ds.feature_row(u).iter().enumerate() {
                    want[j] += x;
                }
            }
            let inv = 1.0 / (nbrs.len() + 1) as f32;
            for (j, w) in want.iter().enumerate() {
                let got = agg[v as usize * f + j];
                assert!((got - w * inv).abs() < 1e-5, "node {v} dim {j}");
            }
        }
    }

    #[test]
    fn logits_are_affine_in_features() {
        // W = identity-ish, b = [1, 2]
        let params = vec![vec![1.0, 0.0, 0.0, 1.0], vec![1.0, 2.0]];
        let mut out = vec![0f32; 2];
        logits_into(&params, &[3.0, 5.0], &mut out);
        assert_eq!(out, vec![4.0, 7.0]);
        assert_eq!(top1(&out), 1);
        assert_eq!(top1(&[2.0, 2.0, 1.0]), 0, "ties break low");
    }
}
