//! Training/inference state wrappers around compiled artifacts.
//!
//! Parameters and Adam moments live in host vectors (copied in/out each
//! step — sub-millisecond at our model sizes); the resident feature
//! table is uploaded to the device once and its buffer reused across
//! every step of a run.

use anyhow::{bail, Context, Result};

use super::artifact::ArtifactMeta;
use super::pjrt::{Executable, Runtime};
use crate::batch::PaddedBatch;
use crate::graph::Dataset;
use crate::util::rng::Rng;

/// Glorot-uniform for matrices, zeros for vectors/scalars.
pub fn init_param(shape: &[usize], rng: &mut Rng) -> Vec<f32> {
    let n: usize = shape.iter().product();
    if shape.len() >= 2 {
        let fin = shape[0] as f64;
        let fout = shape[1..].iter().product::<usize>() as f64;
        let s = (6.0 / (fin + fout)).sqrt() as f32;
        (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * s).collect()
    } else {
        vec![0.0; n]
    }
}

/// Mini-batch training state over a `<name>.train` artifact.
pub struct TrainState {
    /// Compiled train-step executable.
    pub exe: Executable,
    /// Compiled infer executable (validation), when loaded.
    pub infer: Option<Executable>,
    /// Current parameter tensors, flattened.
    pub params: Vec<Vec<f32>>,
    /// Adam first moments, per tensor.
    pub m: Vec<Vec<f32>>,
    /// Adam second moments, per tensor.
    pub v: Vec<Vec<f32>>,
    /// Step counter (Adam bias correction).
    pub t: u64,
    /// Learning rate.
    pub lr: f32,
    /// Device-resident full feature table (resident mode).
    x_full: Option<xla::PjRtBuffer>,
    rt_client: xla::PjRtClient,
}

/// One train step's scalar outputs.
pub struct StepOut {
    /// Mean cross-entropy over the batch's real roots.
    pub loss: f32,
    /// Correct top-1 predictions over the batch's real roots.
    pub correct: f32,
}

impl TrainState {
    /// Create a state: compile the train (and optionally infer)
    /// artifacts, initialize parameters from `seed`, and upload the
    /// resident feature table if the artifact wants one.
    pub fn new(
        rt: &Runtime,
        train_meta: &ArtifactMeta,
        infer_meta: Option<&ArtifactMeta>,
        ds: Option<&Dataset>,
        lr: f32,
        seed: u64,
    ) -> Result<TrainState> {
        let exe = rt.load(train_meta)?;
        let infer = infer_meta.map(|m| rt.load(m)).transpose()?;
        let mut rng = Rng::new(seed ^ 0x9a27_11f3);
        let pspecs = train_meta.param_specs();
        let params: Vec<Vec<f32>> = pspecs
            .iter()
            .map(|s| init_param(&s.shape, &mut rng))
            .collect();
        let m = pspecs.iter().map(|s| vec![0f32; s.elements()]).collect();
        let v = pspecs.iter().map(|s| vec![0f32; s.elements()]).collect();

        let x_full = if train_meta.spec.feat_mode == "resident" {
            let ds = ds.context("resident artifact needs a dataset")?;
            let nv = train_meta.spec.num_nodes;
            let f = train_meta.spec.feat_dim;
            if ds.n() != nv || ds.feat_dim != f {
                bail!(
                    "dataset {}x{} does not match artifact {}x{}",
                    ds.n(),
                    ds.feat_dim,
                    nv,
                    f
                );
            }
            Some(rt.buf_f32(&ds.features, &[nv, f])?)
        } else {
            None
        };
        Ok(TrainState {
            exe,
            infer,
            params,
            m,
            v,
            t: 0,
            lr,
            x_full,
            rt_client: rt.client.clone(),
        })
    }

    /// Execute one training step on a padded batch.
    pub fn step(&mut self, batch: &PaddedBatch) -> Result<StepOut> {
        self.t += 1;
        let meta = self.exe.meta.clone();
        let np = self.params.len();
        let client = self.rt_client.clone();

        // owned per-step buffers in input order, with x_full skipped
        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(meta.inputs.len());
        let up = |data: &[f32], shape: &[usize]| -> Result<xla::PjRtBuffer> {
            client
                .buffer_from_host_buffer(data, shape, None)
                .map_err(|e| anyhow::anyhow!("param upload: {e:?}"))
        };
        for (i, spec) in meta.inputs.iter().take(3 * np).enumerate() {
            let host = if i < np {
                &self.params[i]
            } else if i < 2 * np {
                &self.m[i - np]
            } else {
                &self.v[i - 2 * np]
            };
            args.push(up(host, &spec.shape)?);
        }
        args.push(up(&[self.t as f32], &[])?);
        args.push(up(&[self.lr], &[])?);

        // feature table (resident) comes right after t, lr; it is
        // referenced, not copied — PJRT CPU does not donate inputs
        // unless aliasing is declared, and we declare none.
        let mut start = 3 * np + 2;
        if self.x_full.is_some() {
            start += 1;
        }
        push_batch_inputs(&client, &meta, batch, &mut args, start)?;

        // interleave: args[..3np+2], x_full?, args[3np+2..]
        let refs = interleave_refs(&args, self.x_full.as_ref(), 3 * np + 2);
        let outs = self.exe.run(&refs)?;
        // outputs: params', m', v', loss, correct
        for i in 0..np {
            self.params[i] = outs[i].f32()?.to_vec();
            self.m[i] = outs[np + i].f32()?.to_vec();
            self.v[i] = outs[2 * np + i].f32()?.to_vec();
        }
        Ok(StepOut {
            loss: outs[3 * np].scalar_f32()?,
            correct: outs[3 * np + 1].scalar_f32()?,
        })
    }

    /// Run the inference artifact on a batch; returns logits
    /// `[batch_cap * num_classes]`.
    pub fn infer(&self, batch: &PaddedBatch) -> Result<Vec<f32>> {
        let infer = self.infer.as_ref().context("no infer artifact loaded")?;
        run_infer(
            infer,
            &self.rt_client,
            &self.params,
            self.x_full.as_ref(),
            batch,
        )
    }
}

/// Inference-only state over a `<name>.infer` artifact: parameters +
/// the (optional) resident feature table, with no optimizer moments.
/// This is what the online serving path
/// ([`crate::serve::worker::PjrtExecutor`]) drives; a fresh state
/// carries seed-initialized parameters and [`InferState::set_params`]
/// installs trained ones.
pub struct InferState {
    /// Compiled infer executable.
    pub exe: Executable,
    /// Installed parameter tensors, flattened.
    pub params: Vec<Vec<f32>>,
    /// Device-resident full feature table (resident mode).
    x_full: Option<xla::PjRtBuffer>,
    rt_client: xla::PjRtClient,
}

impl InferState {
    /// Compile the infer artifact, initialize parameters from `seed`
    /// (same stream as [`TrainState::new`], so equal seeds produce the
    /// same initial model) and upload the resident feature table if the
    /// artifact wants one.
    pub fn new(
        rt: &Runtime,
        infer_meta: &ArtifactMeta,
        ds: Option<&Dataset>,
        seed: u64,
    ) -> Result<InferState> {
        let exe = rt.load(infer_meta)?;
        let mut rng = Rng::new(seed ^ 0x9a27_11f3);
        let params: Vec<Vec<f32>> = infer_meta
            .param_specs()
            .iter()
            .map(|s| init_param(&s.shape, &mut rng))
            .collect();
        let x_full = if infer_meta.spec.feat_mode == "resident" {
            let ds = ds.context("resident artifact needs a dataset")?;
            let nv = infer_meta.spec.num_nodes;
            let f = infer_meta.spec.feat_dim;
            if ds.n() != nv || ds.feat_dim != f {
                bail!(
                    "dataset {}x{} does not match artifact {}x{}",
                    ds.n(),
                    ds.feat_dim,
                    nv,
                    f
                );
            }
            Some(rt.buf_f32(&ds.features, &[nv, f])?)
        } else {
            None
        };
        Ok(InferState {
            exe,
            params,
            x_full,
            rt_client: rt.client.clone(),
        })
    }

    /// Install trained parameters (copied out of a [`TrainState`], or
    /// loaded from a checkpoint by the serving hot-swap path).
    /// Validates tensor count *and* per-tensor element counts against
    /// the artifact's param specs, so a checkpoint from a different
    /// model/geometry fails loudly here instead of corrupting an
    /// upload.
    pub fn set_params(&mut self, params: Vec<Vec<f32>>) -> Result<()> {
        let specs = self.exe.meta.param_specs();
        if params.len() != specs.len() {
            bail!(
                "artifact {} wants {} params, got {}",
                self.exe.meta.name,
                specs.len(),
                params.len()
            );
        }
        for (i, (p, s)) in params.iter().zip(&specs).enumerate() {
            if p.len() != s.elements() {
                bail!(
                    "artifact {} param {i} ({}) wants shape {:?} = {} \
                     elements, got {}",
                    self.exe.meta.name,
                    s.name,
                    s.shape,
                    s.elements(),
                    p.len()
                );
            }
        }
        self.params = params;
        Ok(())
    }

    /// Run inference on a batch; returns logits
    /// `[batch_cap * num_classes]`.
    pub fn infer(&self, batch: &PaddedBatch) -> Result<Vec<f32>> {
        run_infer(
            &self.exe,
            &self.rt_client,
            &self.params,
            self.x_full.as_ref(),
            batch,
        )
    }
}

/// Upload the per-batch inputs (`meta.inputs[start..]`) in artifact
/// order; shared by the train step and both inference paths.
fn push_batch_inputs(
    client: &xla::PjRtClient,
    meta: &ArtifactMeta,
    batch: &PaddedBatch,
    args: &mut Vec<xla::PjRtBuffer>,
    start: usize,
) -> Result<()> {
    for spec in &meta.inputs[start..] {
        let name = spec.name.as_str();
        let buf = if name == "x0" {
            let x0 = batch.x0.as_ref().context("batch lacks x0")?;
            client
                .buffer_from_host_buffer(x0, &spec.shape, None)
                .map_err(|e| anyhow::anyhow!("{name}: {e:?}"))?
        } else if let Some(rest) = name.strip_prefix("idx_") {
            let l: usize = rest.parse()?;
            client
                .buffer_from_host_buffer(
                    &batch.layers[l - 1].idx,
                    &spec.shape,
                    None,
                )
                .map_err(|e| anyhow::anyhow!("{name}: {e:?}"))?
        } else if let Some(rest) = name.strip_prefix("w_") {
            let l: usize = rest.parse()?;
            client
                .buffer_from_host_buffer(
                    &batch.layers[l - 1].w,
                    &spec.shape,
                    None,
                )
                .map_err(|e| anyhow::anyhow!("{name}: {e:?}"))?
        } else if let Some(rest) = name.strip_prefix("self_") {
            let l: usize = rest.parse()?;
            client
                .buffer_from_host_buffer(
                    &batch.layers[l - 1].self_idx,
                    &spec.shape,
                    None,
                )
                .map_err(|e| anyhow::anyhow!("{name}: {e:?}"))?
        } else if name == "labels" {
            client
                .buffer_from_host_buffer(&batch.labels, &spec.shape, None)
                .map_err(|e| anyhow::anyhow!("{name}: {e:?}"))?
        } else if name == "lmask" {
            client
                .buffer_from_host_buffer(&batch.lmask, &spec.shape, None)
                .map_err(|e| anyhow::anyhow!("{name}: {e:?}"))?
        } else {
            bail!("unhandled input {name} in {}", meta.name);
        };
        args.push(buf);
    }
    Ok(())
}

/// Interleave owned per-step buffers with the (optional) resident
/// feature table at position `split`.
fn interleave_refs<'a>(
    own: &'a [xla::PjRtBuffer],
    resident: Option<&'a xla::PjRtBuffer>,
    split: usize,
) -> Vec<&'a xla::PjRtBuffer> {
    let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(own.len() + 1);
    let split = split.min(own.len());
    refs.extend(own[..split].iter());
    if let Some(xf) = resident {
        refs.push(xf);
    }
    refs.extend(own[split..].iter());
    refs
}

/// Run an infer executable: upload `params`, splice in the resident
/// feature table, push the batch inputs, execute, return logits
/// `[batch_cap * num_classes]`. Shared by [`TrainState::infer`]
/// (validation) and [`InferState::infer`] (serving).
fn run_infer(
    exe: &Executable,
    client: &xla::PjRtClient,
    params: &[Vec<f32>],
    x_full: Option<&xla::PjRtBuffer>,
    batch: &PaddedBatch,
) -> Result<Vec<f32>> {
    let meta = exe.meta.clone();
    let np = meta.num_params();
    if params.len() != np {
        bail!(
            "artifact {} wants {np} params, state holds {}",
            meta.name,
            params.len()
        );
    }
    let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(meta.inputs.len());
    for (i, spec) in meta.inputs.iter().take(np).enumerate() {
        args.push(
            client
                .buffer_from_host_buffer(&params[i], &spec.shape, None)
                .map_err(|e| anyhow::anyhow!("param upload: {e:?}"))?,
        );
    }
    let mut start = np;
    if x_full.is_some() {
        start += 1;
    }
    push_batch_inputs(client, &meta, batch, &mut args, start)?;
    let refs = interleave_refs(&args, x_full, np);
    let outs = exe.run(&refs)?;
    Ok(outs[0].f32()?.to_vec())
}

/// Full-batch GCN training state (`<name>_fb.train` artifacts).
pub struct FullBatchState {
    /// Compiled full-batch train-step executable.
    pub exe: Executable,
    /// Current parameter tensors, flattened.
    pub params: Vec<Vec<f32>>,
    /// Adam first moments, per tensor.
    pub m: Vec<Vec<f32>>,
    /// Adam second moments, per tensor.
    pub v: Vec<Vec<f32>>,
    /// Step counter (Adam bias correction).
    pub t: u64,
    /// Learning rate.
    pub lr: f32,
    // resident graph inputs
    x: xla::PjRtBuffer,
    e_src: xla::PjRtBuffer,
    e_dst: xla::PjRtBuffer,
    e_w: xla::PjRtBuffer,
    labels: xla::PjRtBuffer,
    train_mask: xla::PjRtBuffer,
    val_mask: xla::PjRtBuffer,
    client: xla::PjRtClient,
}

/// One full-batch step's scalar outputs.
pub struct FullBatchOut {
    /// Training-mask cross-entropy.
    pub loss: f32,
    /// Training-split accuracy this step.
    pub acc_train: f32,
    /// Validation-split accuracy this step.
    pub acc_val: f32,
}

impl FullBatchState {
    /// Compile the full-batch artifact, initialize parameters from
    /// `seed` and upload the normalized edge list + masks once.
    pub fn new(
        rt: &Runtime,
        meta: &ArtifactMeta,
        ds: &Dataset,
        lr: f32,
        seed: u64,
    ) -> Result<FullBatchState> {
        let exe = rt.load(meta)?;
        let mut rng = Rng::new(seed ^ 0x51ef_22aa);
        let pspecs = meta.param_specs();
        let params: Vec<Vec<f32>> = pspecs
            .iter()
            .map(|s| init_param(&s.shape, &mut rng))
            .collect();
        let m = pspecs.iter().map(|s| vec![0f32; s.elements()]).collect();
        let v = pspecs.iter().map(|s| vec![0f32; s.elements()]).collect();

        let n = meta.spec.num_nodes;
        let e_cap = meta.spec.padded_edges;
        if ds.n() != n {
            bail!("dataset has {} nodes, artifact {}", ds.n(), n);
        }
        // symmetric-normalized edge list incl. self loops, padded with
        // zero-weight edges
        let mut src = vec![0i32; e_cap];
        let mut dst = vec![0i32; e_cap];
        let mut w = vec![0f32; e_cap];
        let deg: Vec<f64> = (0..n as u32)
            .map(|v| (ds.csr.degree(v) + 1) as f64)
            .collect();
        let mut k = 0usize;
        for vtx in 0..n as u32 {
            // self loop
            src[k] = vtx as i32;
            dst[k] = vtx as i32;
            w[k] = (1.0 / deg[vtx as usize]) as f32;
            k += 1;
            for &u in ds.csr.neighbors(vtx) {
                src[k] = u as i32;
                dst[k] = vtx as i32;
                w[k] = (1.0 / (deg[vtx as usize] * deg[u as usize]).sqrt()) as f32;
                k += 1;
            }
        }
        if k > e_cap {
            bail!("graph needs {k} edge slots, artifact has {e_cap}");
        }

        let labels_host: Vec<i32> = ds.labels.iter().map(|&x| x as i32).collect();
        let tmask: Vec<f32> = ds
            .split
            .iter()
            .map(|&s| if s == crate::graph::SPLIT_TRAIN { 1.0 } else { 0.0 })
            .collect();
        let vmask: Vec<f32> = ds
            .split
            .iter()
            .map(|&s| if s == crate::graph::SPLIT_VAL { 1.0 } else { 0.0 })
            .collect();

        Ok(FullBatchState {
            exe,
            params,
            m,
            v,
            t: 0,
            lr,
            x: rt.buf_f32(&ds.features, &[n, ds.feat_dim])?,
            e_src: rt.buf_i32(&src, &[e_cap])?,
            e_dst: rt.buf_i32(&dst, &[e_cap])?,
            e_w: rt.buf_f32(&w, &[e_cap])?,
            labels: rt.buf_i32(&labels_host, &[n])?,
            train_mask: rt.buf_f32(&tmask, &[n])?,
            val_mask: rt.buf_f32(&vmask, &[n])?,
            client: rt.client.clone(),
        })
    }

    /// Execute one full-batch training step.
    pub fn step(&mut self, n_train: usize, n_val: usize) -> Result<FullBatchOut> {
        self.t += 1;
        let meta = self.exe.meta.clone();
        let np = self.params.len();
        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(meta.inputs.len());
        for (i, spec) in meta.inputs.iter().take(3 * np).enumerate() {
            let host = if i < np {
                &self.params[i]
            } else if i < 2 * np {
                &self.m[i - np]
            } else {
                &self.v[i - 2 * np]
            };
            args.push(
                self.client
                    .buffer_from_host_buffer(host, &spec.shape, None)
                    .map_err(|e| anyhow::anyhow!("upload: {e:?}"))?,
            );
        }
        let up_scalar = |x: f32| -> Result<xla::PjRtBuffer> {
            self.client
                .buffer_from_host_buffer(&[x], &[], None)
                .map_err(|e| anyhow::anyhow!("scalar: {e:?}"))
        };
        args.push(up_scalar(self.t as f32)?);
        args.push(up_scalar(self.lr)?);
        let mut refs: Vec<&xla::PjRtBuffer> = args.iter().collect();
        refs.extend([
            &self.x,
            &self.e_src,
            &self.e_dst,
            &self.e_w,
            &self.labels,
            &self.train_mask,
            &self.val_mask,
        ]);
        let outs = self.exe.run(&refs)?;
        for i in 0..np {
            self.params[i] = outs[i].f32()?.to_vec();
            self.m[i] = outs[np + i].f32()?.to_vec();
            self.v[i] = outs[2 * np + i].f32()?.to_vec();
        }
        let loss = outs[3 * np].scalar_f32()?;
        let ct = outs[3 * np + 1].scalar_f32()?;
        let cv = outs[3 * np + 2].scalar_f32()?;
        Ok(FullBatchOut {
            loss,
            acc_train: ct / n_train.max(1) as f32,
            acc_val: cv / n_val.max(1) as f32,
        })
    }
}

/// Shared helper: cross-entropy + accuracy from host logits for the
/// (unpadded) roots of an eval batch.
pub fn eval_logits(
    logits: &[f32],
    num_classes: usize,
    roots: &[u32],
    labels: &[u16],
) -> (f64, usize) {
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for (i, &v) in roots.iter().enumerate() {
        let row = &logits[i * num_classes..(i + 1) * num_classes];
        let y = labels[v as usize] as usize;
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 = row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln() + mx;
        loss += (lse - row[y]) as f64;
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == y {
            correct += 1;
        }
    }
    (loss / roots.len().max(1) as f64, correct)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glorot_init_bounds() {
        let mut rng = Rng::new(1);
        let w = init_param(&[64, 32], &mut rng);
        let s = (6.0f64 / 96.0).sqrt() as f32;
        assert!(w.iter().all(|&x| x.abs() <= s));
        assert!(w.iter().any(|&x| x.abs() > s * 0.5));
        let b = init_param(&[32], &mut rng);
        assert!(b.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn eval_logits_basic() {
        // 2 roots, 3 classes
        let logits = vec![5.0, 0.0, 0.0, 0.0, 0.0, 5.0];
        let labels = vec![0u16, 1u16];
        let (loss, correct) = eval_logits(&logits, 3, &[0, 1], &labels);
        assert_eq!(correct, 1); // root 1 predicted class 2, label 1
        assert!(loss > 0.0);
    }
}
