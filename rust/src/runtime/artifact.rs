//! Artifact manifest: the ABI contract between `python/compile/aot.py`
//! and the rust runtime. The manifest records, for every artifact, the
//! exact flattened input/output signature (names, shapes, dtypes) plus
//! the model spec it was lowered from; the runtime wires buffers by
//! this record and validates shapes before every compile.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Element dtype of one artifact input/output buffer.
#[derive(Clone, Debug, PartialEq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer (index arrays, labels).
    I32,
}

/// One flattened input or output in an artifact's signature.
#[derive(Clone, Debug)]
pub struct IoSpec {
    /// Buffer name (`p.*` marks a parameter input).
    pub name: String,
    /// Static shape, outermost dimension first.
    pub shape: Vec<usize>,
    /// Element dtype.
    pub dtype: DType,
}

impl IoSpec {
    /// Total element count (product of the shape).
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Model-spec fields the sampler/trainer need (subset of the python
/// `ModelSpec`/`FullBatchSpec`).
#[derive(Clone, Debug)]
pub struct SpecMeta {
    /// Model family the artifact was lowered from (`sage`/`gcn`/`gat`).
    pub model: String,
    /// Message-passing layer count.
    pub layers: usize,
    /// Per-layer fanouts, input-most first.
    pub fanouts: Vec<usize>,
    /// Per-layer neighbor-slot widths (fanout, +1 for GCN/GAT self).
    pub idx_widths: Vec<usize>,
    /// Padded root-batch capacity.
    pub batch_size: usize,
    /// Node count of the dataset the artifact was sized for.
    pub num_nodes: usize,
    /// Input feature width.
    pub feat_dim: usize,
    /// Logit columns.
    pub num_classes: usize,
    /// Attention heads (1 for non-GAT models).
    pub heads: usize,
    /// Feature residency (`resident` = full table on device, `staged`
    /// = the batch carries its own x0 payload).
    pub feat_mode: String,
    /// Padded per-layer dst capacities, input-most first (len layers+1).
    pub node_caps: Vec<usize>,
    /// Padded edge capacity (full-batch artifacts only, else 0).
    pub padded_edges: usize,
    /// Edge-chunk size (full-batch artifacts only, else 0).
    pub edge_chunk: usize,
}

/// One artifact's manifest record: where its HLO lives and the exact
/// buffer signature the runtime must honor.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Manifest key (`<preset>.<kind>`).
    pub name: String,
    /// HLO text file the artifact compiles from.
    pub file: PathBuf,
    /// Artifact kind (`train` / `infer` / `fullbatch`).
    pub kind: String,
    /// Model-spec subset the sampler/trainer size batches against.
    pub spec: SpecMeta,
    /// Flattened input signature, in call order.
    pub inputs: Vec<IoSpec>,
    /// Flattened output signature, in result order.
    pub outputs: Vec<IoSpec>,
}

impl ArtifactMeta {
    /// Number of parameter inputs (names prefixed `p.`).
    pub fn num_params(&self) -> usize {
        self.inputs
            .iter()
            .filter(|i| i.name.starts_with("p."))
            .count()
    }

    /// The parameter inputs (names prefixed `p.`), in call order.
    pub fn param_specs(&self) -> Vec<&IoSpec> {
        self.inputs
            .iter()
            .filter(|i| i.name.starts_with("p."))
            .collect()
    }

    /// Position of input `name` in the flattened call signature.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .with_context(|| format!("artifact {} has no input {name}", self.name))
    }
}

/// Parsed `manifest.json`: every artifact in an artifacts directory.
pub struct Manifest {
    /// Directory the manifest (and the HLO files) live in.
    pub dir: PathBuf,
    /// All artifact records, in manifest order.
    pub artifacts: Vec<ArtifactMeta>,
}

fn parse_io(v: &Json) -> Result<IoSpec> {
    let dtype = match v.get("dtype")?.as_str()? {
        "f32" => DType::F32,
        "i32" => DType::I32,
        d => bail!("unsupported dtype {d}"),
    };
    Ok(IoSpec {
        name: v.get("name")?.as_str()?.to_string(),
        shape: v
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Result<_>>()?,
        dtype,
    })
}

fn parse_spec(v: &Json) -> Result<SpecMeta> {
    let get_usize = |k: &str| -> usize {
        v.opt(k).and_then(|x| x.as_usize().ok()).unwrap_or(0)
    };
    let get_str = |k: &str| -> String {
        v.opt(k)
            .and_then(|x| x.as_str().ok())
            .unwrap_or("")
            .to_string()
    };
    let usize_arr = |k: &str| -> Result<Vec<usize>> {
        match v.opt(k) {
            Some(a) => a.as_arr()?.iter().map(|x| x.as_usize()).collect(),
            None => Ok(Vec::new()),
        }
    };
    let node_caps = usize_arr("node_caps")?;
    Ok(SpecMeta {
        model: get_str("model"),
        layers: get_usize("layers"),
        fanouts: usize_arr("fanouts")?,
        idx_widths: usize_arr("idx_widths")?,
        batch_size: get_usize("batch_size"),
        num_nodes: get_usize("num_nodes"),
        feat_dim: get_usize("feat_dim"),
        num_classes: get_usize("num_classes"),
        heads: get_usize("heads"),
        feat_mode: get_str("feat_mode"),
        node_caps,
        padded_edges: get_usize("padded_edges"),
        edge_chunk: get_usize("edge_chunk"),
    })
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let root = Json::parse_file(&path)?;
        let mut artifacts = Vec::new();
        for (name, entry) in root.get("artifacts")?.as_obj()? {
            let inputs = entry
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("inputs of {name}"))?;
            let outputs = entry
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactMeta {
                name: name.clone(),
                file: dir.join(entry.get("file")?.as_str()?),
                kind: entry.get("kind")?.as_str()?.to_string(),
                spec: parse_spec(entry.get("spec")?)?,
                inputs,
                outputs,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Look an artifact up by manifest key, with a helpful error
    /// listing what exists.
    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| {
                format!(
                    "artifact {name} not in manifest (have: {})",
                    self.artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }
}

/// Default artifacts directory: `$COMM_RAND_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("COMM_RAND_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = r#"{
          "artifacts": {
            "x.train": {
              "file": "x.train.hlo.txt",
              "kind": "train",
              "spec": {"model": "sage", "layers": 2, "fanouts": [5, 5],
                       "idx_widths": [5, 5], "batch_size": 128,
                       "num_nodes": 2048, "feat_dim": 32,
                       "num_classes": 7, "heads": 1,
                       "feat_mode": "resident",
                       "node_caps": [2048, 768, 128]},
              "inputs": [
                {"name": "p.w0", "shape": [32, 32], "dtype": "f32"},
                {"name": "idx_1", "shape": [768, 5], "dtype": "i32"}
              ],
              "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]
            }
          }
        }"#;
        let tmp = std::env::temp_dir().join("comm_rand_manifest_test");
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), j).unwrap();
        let m = Manifest::load(&tmp).unwrap();
        let a = m.get("x.train").unwrap();
        assert_eq!(a.spec.layers, 2);
        assert_eq!(a.spec.fanouts, vec![5, 5]);
        assert_eq!(a.spec.node_caps, vec![2048, 768, 128]);
        assert_eq!(a.inputs[1].shape, vec![768, 5]);
        assert_eq!(a.inputs[1].dtype, super::DType::I32);
        assert_eq!(a.num_params(), 1);
        assert!(m.get("missing").is_err());
    }
}
