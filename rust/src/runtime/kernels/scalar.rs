//! Portable wrapping-integer reference kernels. Every SIMD variant is
//! pinned bitwise to these (see the module docs of
//! [`super`](crate::runtime::kernels) for why wrapping arithmetic
//! makes that unconditional).

pub fn matvec_i16_i32(
    wt: &[i16],
    x: &[i16],
    bias: &[i32],
    feat_pad: usize,
    out: &mut [i32],
) {
    for (c, o) in out.iter_mut().enumerate() {
        let row = &wt[c * feat_pad..(c + 1) * feat_pad];
        let mut acc = bias[c];
        for (&w, &xv) in row.iter().zip(x) {
            acc = acc.wrapping_add((w as i32).wrapping_mul(xv as i32));
        }
        *o = acc;
    }
}

pub fn accumulate_rows_i8(
    table: &[i8],
    feat_pad: usize,
    nodes: &[u32],
    out: &mut [i32],
) {
    for &v in nodes {
        let row = &table[v as usize * feat_pad..(v as usize + 1) * feat_pad];
        for (o, &x) in out.iter_mut().zip(row) {
            *o = o.wrapping_add(x as i32);
        }
    }
}
