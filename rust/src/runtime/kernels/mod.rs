//! Quantized integer inference kernels with CPU-feature runtime
//! dispatch.
//!
//! Two kernels back the quantized host-model path ([`matvec_i16_i32`]
//! and [`accumulate_rows_i8`]), each implemented three ways behind one
//! dispatching facade:
//!
//! | backend  | requirement                  | selected when |
//! |----------|------------------------------|---------------|
//! | `scalar` | none (portable integer rust) | fallback, or forced |
//! | `avx2`   | runtime `avx2` CPU feature   | default on x86-64 with AVX2 |
//! | `avx512` | `avx512` cargo feature + runtime `avx512bw` | forced only |
//!
//! The backend is picked **once at executor construction** via
//! [`KernelBackend::resolve`]: the `kernel=` serve knob (`auto`
//! consults the `COMM_RAND_KERNEL` env var, so CI can force the
//! portable path across an entire test run) and explicit values
//! (`scalar`/`avx2`/`avx512`) fail loudly when the machine lacks the
//! feature. AVX-512 intrinsics are gated behind the off-by-default
//! `avx512` cargo feature so the crate builds on older stable
//! toolchains.
//!
//! # Bitwise cross-variant equivalence
//!
//! Every variant of every kernel produces **bit-identical** `i32`
//! accumulators, unconditionally. This works because all arithmetic is
//! *wrapping*: wrapping add/multiply is exactly associative and
//! commutative mod 2³², so the SIMD variants' different summation
//! orders (pairwise `madd` partials, lane-wise accumulators, one
//! horizontal reduction at the end) cannot change the result. Inputs
//! are zero-padded to a multiple of [`LANES`] so vector tails
//! contribute exact zeros. `rust/tests/quant_kernels.rs` pins this
//! property over randomized shapes for every backend the host CPU can
//! run.
//!
//! Wrapping arithmetic means a genuine magnitude overflow would wrap
//! silently *inside* the kernel — so the quantized executor proves at
//! install time that no accumulator can exceed `i32::MAX` (see
//! `serve::worker`), and quantization itself refuses out-of-range
//! tensors (see [`crate::ckpt::quant`]). Within that envelope the
//! wrapped value *is* the true sum.

use anyhow::{bail, Result};

mod avx2;
#[cfg(feature = "avx512")]
mod avx512;
mod scalar;

/// i16 lanes per 256-bit vector: inputs are zero-padded to a multiple
/// of this so every SIMD variant can run full-width with no tail loop.
pub const LANES: usize = 16;

/// Round `n` up to the next multiple of [`LANES`].
pub fn pad_to_lanes(n: usize) -> usize {
    n.div_ceil(LANES) * LANES
}

/// Which kernel implementation executes. Carried by the executor;
/// resolved once at startup, never per batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelBackend {
    /// Portable wrapping-integer rust — runs everywhere.
    Scalar,
    /// 256-bit AVX2 intrinsics (`_mm256_madd_epi16` et al.).
    Avx2,
    /// 512-bit AVX-512BW intrinsics; requires the `avx512` cargo
    /// feature at compile time *and* CPU support at run time.
    Avx512,
}

impl KernelBackend {
    /// Knob/report name of this backend.
    pub fn name(&self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Avx512 => "avx512",
        }
    }

    /// Can this backend execute on the current machine + build?
    pub fn available(&self) -> bool {
        match self {
            KernelBackend::Scalar => true,
            KernelBackend::Avx2 => avx2_detected(),
            KernelBackend::Avx512 => avx512_detected(),
        }
    }

    /// The best backend the current machine can run (never fails:
    /// scalar is always available).
    pub fn detect() -> KernelBackend {
        if avx512_detected() {
            KernelBackend::Avx512
        } else if avx2_detected() {
            KernelBackend::Avx2
        } else {
            KernelBackend::Scalar
        }
    }

    /// Every backend the current machine + build can execute (used by
    /// the equivalence tests to cover all runnable variants).
    pub fn all_available() -> Vec<KernelBackend> {
        [KernelBackend::Scalar, KernelBackend::Avx2, KernelBackend::Avx512]
            .into_iter()
            .filter(|b| b.available())
            .collect()
    }

    /// Resolve the `kernel=` knob to a concrete backend.
    ///
    /// `auto` consults the `COMM_RAND_KERNEL` env var (itself allowed
    /// to be `auto`/unset, meaning [`KernelBackend::detect`]); any
    /// explicit name — from the knob or the env var — must be runnable
    /// here or this errors, so a forced backend never silently
    /// degrades.
    pub fn resolve(knob: &str) -> Result<KernelBackend> {
        let forced = match knob {
            "auto" => match std::env::var("COMM_RAND_KERNEL") {
                Ok(v) if !v.is_empty() && v != "auto" => Some(v),
                _ => None,
            },
            other => Some(other.to_string()),
        };
        let Some(name) = forced else {
            return Ok(KernelBackend::detect());
        };
        let b = match name.as_str() {
            "scalar" => KernelBackend::Scalar,
            "avx2" => KernelBackend::Avx2,
            "avx512" => KernelBackend::Avx512,
            other => {
                bail!("unknown kernel backend {other:?} (kernel=auto|scalar|avx2|avx512)")
            }
        };
        if !b.available() {
            bail!(
                "kernel backend {} forced but not available on this \
                 machine/build (detected: {})",
                b.name(),
                KernelBackend::detect().name()
            );
        }
        Ok(b)
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_detected() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_detected() -> bool {
    false
}

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
fn avx512_detected() -> bool {
    std::arch::is_x86_feature_detected!("avx512bw")
}

#[cfg(not(all(target_arch = "x86_64", feature = "avx512")))]
fn avx512_detected() -> bool {
    false
}

/// Quantized affine layer: for every output class `c`,
/// `out[c] = bias[c] + Σ_k wt[c * feat_pad + k] · x[k]` with wrapping
/// i32 accumulation.
///
/// `wt` is class-major (`out.len()` rows of `feat_pad` i16 each,
/// zero-padded), `x` is one activation row of `feat_pad` i16, `bias`
/// is one i32 per class at the combined weight×activation scale.
///
/// # Panics
/// Debug-asserts the slice geometry (`feat_pad` a multiple of
/// [`LANES`], `wt.len() == out.len() * feat_pad`, `x.len() ==
/// feat_pad`, `bias.len() == out.len()`).
pub fn matvec_i16_i32(
    backend: KernelBackend,
    wt: &[i16],
    x: &[i16],
    bias: &[i32],
    feat_pad: usize,
    out: &mut [i32],
) {
    debug_assert_eq!(feat_pad % LANES, 0);
    debug_assert_eq!(x.len(), feat_pad);
    debug_assert_eq!(wt.len(), out.len() * feat_pad);
    debug_assert_eq!(bias.len(), out.len());
    match backend {
        KernelBackend::Scalar => {
            scalar::matvec_i16_i32(wt, x, bias, feat_pad, out)
        }
        KernelBackend::Avx2 => avx2::matvec_i16_i32(wt, x, bias, feat_pad, out),
        KernelBackend::Avx512 => {
            avx512_matvec(wt, x, bias, feat_pad, out)
        }
    }
}

/// Quantized neighbor aggregation: `out[k] += Σ_v table[nodes[v] *
/// feat_pad + k]` with wrapping i32 accumulation over i8 feature rows.
///
/// `out` is **accumulated into**, not overwritten, so the caller seeds
/// it (typically with zeros, or the root's own row for closed
/// neighborhoods) and divides by the neighbor count afterwards. An
/// empty `nodes` list leaves `out` untouched.
///
/// # Panics
/// Debug-asserts the geometry (`feat_pad` a multiple of [`LANES`],
/// `out.len() == feat_pad`, every row index in range).
pub fn accumulate_rows_i8(
    backend: KernelBackend,
    table: &[i8],
    feat_pad: usize,
    nodes: &[u32],
    out: &mut [i32],
) {
    debug_assert_eq!(feat_pad % LANES, 0);
    debug_assert_eq!(out.len(), feat_pad);
    debug_assert!(nodes
        .iter()
        .all(|&v| (v as usize + 1) * feat_pad <= table.len()));
    match backend {
        KernelBackend::Scalar => {
            scalar::accumulate_rows_i8(table, feat_pad, nodes, out)
        }
        KernelBackend::Avx2 => {
            avx2::accumulate_rows_i8(table, feat_pad, nodes, out)
        }
        KernelBackend::Avx512 => avx512_accumulate(table, feat_pad, nodes, out),
    }
}

#[cfg(feature = "avx512")]
fn avx512_matvec(
    wt: &[i16],
    x: &[i16],
    bias: &[i32],
    feat_pad: usize,
    out: &mut [i32],
) {
    avx512::matvec_i16_i32(wt, x, bias, feat_pad, out)
}

#[cfg(not(feature = "avx512"))]
fn avx512_matvec(
    _wt: &[i16],
    _x: &[i16],
    _bias: &[i32],
    _feat_pad: usize,
    _out: &mut [i32],
) {
    unreachable!("avx512 backend without the avx512 cargo feature")
}

#[cfg(feature = "avx512")]
fn avx512_accumulate(
    table: &[i8],
    feat_pad: usize,
    nodes: &[u32],
    out: &mut [i32],
) {
    avx512::accumulate_rows_i8(table, feat_pad, nodes, out)
}

#[cfg(not(feature = "avx512"))]
fn avx512_accumulate(
    _table: &[i8],
    _feat_pad: usize,
    _nodes: &[u32],
    _out: &mut [i32],
) {
    unreachable!("avx512 backend without the avx512 cargo feature")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_rounds_up_to_lane_multiples() {
        assert_eq!(pad_to_lanes(0), 0);
        assert_eq!(pad_to_lanes(1), LANES);
        assert_eq!(pad_to_lanes(LANES), LANES);
        assert_eq!(pad_to_lanes(LANES + 1), 2 * LANES);
    }

    #[test]
    fn detect_is_available_and_resolve_honors_forcing() {
        let d = KernelBackend::detect();
        assert!(d.available());
        assert!(KernelBackend::Scalar.available());
        assert!(KernelBackend::all_available().contains(&KernelBackend::Scalar));
        // forcing scalar always works; forcing garbage never does
        assert_eq!(
            KernelBackend::resolve("scalar").unwrap(),
            KernelBackend::Scalar
        );
        assert!(KernelBackend::resolve("neon").is_err());
        // an unavailable backend errors instead of degrading
        if !KernelBackend::Avx512.available() {
            assert!(KernelBackend::resolve("avx512").is_err());
        }
    }

    #[test]
    fn scalar_matvec_matches_hand_computation() {
        // 2 classes, feat_dim 3 padded to one lane group
        let fp = LANES;
        let mut wt = vec![0i16; 2 * fp];
        wt[..3].copy_from_slice(&[1, 2, 3]); // class 0
        wt[fp..fp + 3].copy_from_slice(&[-1, 0, 10]); // class 1
        let mut x = vec![0i16; fp];
        x[..3].copy_from_slice(&[5, -4, 2]);
        let bias = [100, -7];
        let mut out = [0i32; 2];
        matvec_i16_i32(KernelBackend::Scalar, &wt, &x, &bias, fp, &mut out);
        assert_eq!(out, [100 + 5 - 8 + 6, -7 - 5 + 0 + 20]);
    }

    #[test]
    fn scalar_accumulate_sums_selected_rows() {
        let fp = LANES;
        let mut table = vec![0i8; 3 * fp];
        table[0] = 7; // row 0
        table[fp] = -2; // row 1
        table[2 * fp] = 1; // row 2
        let mut out = vec![0i32; fp];
        accumulate_rows_i8(
            KernelBackend::Scalar,
            &table,
            fp,
            &[0, 2, 2],
            &mut out,
        );
        assert_eq!(out[0], 7 + 1 + 1);
        assert_eq!(&out[1..], &vec![0i32; fp - 1][..]);
        // empty node list is a no-op
        accumulate_rows_i8(KernelBackend::Scalar, &table, fp, &[], &mut out);
        assert_eq!(out[0], 9);
    }
}
