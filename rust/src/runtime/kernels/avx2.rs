//! AVX2 kernel variants: 16 i16 lanes per 256-bit vector for the
//! matvec (`_mm256_madd_epi16` pairwise products), 8 i32 lanes per
//! step for the i8 row aggregation (`_mm256_cvtepi8_epi32`).
//!
//! All vector adds are wrapping, so these produce the same mod-2³²
//! accumulators as the scalar reference in every summation order —
//! see the parent module docs. Callers must only dispatch here when
//! the `avx2` CPU feature was detected
//! ([`KernelBackend::available`](super::KernelBackend::available)).

#[cfg(target_arch = "x86_64")]
pub fn matvec_i16_i32(
    wt: &[i16],
    x: &[i16],
    bias: &[i32],
    feat_pad: usize,
    out: &mut [i32],
) {
    debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
    // SAFETY: the dispatcher only selects this backend after runtime
    // AVX2 detection; slice geometry is debug-asserted by the facade.
    unsafe { matvec_impl(wt, x, bias, feat_pad, out) }
}

#[cfg(target_arch = "x86_64")]
pub fn accumulate_rows_i8(
    table: &[i8],
    feat_pad: usize,
    nodes: &[u32],
    out: &mut [i32],
) {
    debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
    // SAFETY: as above.
    unsafe { accumulate_impl(table, feat_pad, nodes, out) }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn matvec_impl(
    wt: &[i16],
    x: &[i16],
    bias: &[i32],
    feat_pad: usize,
    out: &mut [i32],
) {
    use std::arch::x86_64::*;
    for (c, o) in out.iter_mut().enumerate() {
        let row = wt.as_ptr().add(c * feat_pad);
        let mut acc = _mm256_setzero_si256();
        let mut k = 0usize;
        while k < feat_pad {
            let w = _mm256_loadu_si256(row.add(k) as *const __m256i);
            let xv =
                _mm256_loadu_si256(x.as_ptr().add(k) as *const __m256i);
            // madd: adjacent i16 products summed pairwise into 8 i32
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(w, xv));
            k += super::LANES;
        }
        // horizontal wrapping reduction of the 8 partials
        let lo = _mm256_castsi256_si128(acc);
        let hi = _mm256_extracti128_si256::<1>(acc);
        let s = _mm_add_epi32(lo, hi);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b01_00_11_10>(s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b00_00_00_01>(s));
        *o = bias[c].wrapping_add(_mm_cvtsi128_si32(s));
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn accumulate_impl(
    table: &[i8],
    feat_pad: usize,
    nodes: &[u32],
    out: &mut [i32],
) {
    use std::arch::x86_64::*;
    for &v in nodes {
        let row = table.as_ptr().add(v as usize * feat_pad);
        let mut k = 0usize;
        while k < feat_pad {
            let o = out.as_mut_ptr().add(k) as *mut __m256i;
            // 8 i8 → 8 i32, then a wrapping lane-wise add into out
            let bytes = _mm_loadl_epi64(row.add(k) as *const __m128i);
            let wide = _mm256_cvtepi8_epi32(bytes);
            _mm256_storeu_si256(
                o,
                _mm256_add_epi32(_mm256_loadu_si256(o), wide),
            );
            k += 8;
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
pub fn matvec_i16_i32(
    _wt: &[i16],
    _x: &[i16],
    _bias: &[i32],
    _feat_pad: usize,
    _out: &mut [i32],
) {
    unreachable!("avx2 backend dispatched on a non-x86_64 target")
}

#[cfg(not(target_arch = "x86_64"))]
pub fn accumulate_rows_i8(
    _table: &[i8],
    _feat_pad: usize,
    _nodes: &[u32],
    _out: &mut [i32],
) {
    unreachable!("avx2 backend dispatched on a non-x86_64 target")
}
