//! AVX-512BW kernel variants: 32 i16 lanes per 512-bit vector.
//!
//! Compiled only with the off-by-default `avx512` cargo feature
//! (AVX-512 intrinsics need a recent stable toolchain) and dispatched
//! only after runtime `avx512bw` detection. Same wrapping-arithmetic
//! bitwise contract as the other variants.

#[cfg(target_arch = "x86_64")]
pub fn matvec_i16_i32(
    wt: &[i16],
    x: &[i16],
    bias: &[i32],
    feat_pad: usize,
    out: &mut [i32],
) {
    debug_assert!(std::arch::is_x86_feature_detected!("avx512bw"));
    // SAFETY: the dispatcher only selects this backend after runtime
    // avx512bw detection; slice geometry is debug-asserted upstream.
    unsafe { matvec_impl(wt, x, bias, feat_pad, out) }
}

#[cfg(target_arch = "x86_64")]
pub fn accumulate_rows_i8(
    table: &[i8],
    feat_pad: usize,
    nodes: &[u32],
    out: &mut [i32],
) {
    debug_assert!(std::arch::is_x86_feature_detected!("avx512bw"));
    // SAFETY: as above.
    unsafe { accumulate_impl(table, feat_pad, nodes, out) }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn matvec_impl(
    wt: &[i16],
    x: &[i16],
    bias: &[i32],
    feat_pad: usize,
    out: &mut [i32],
) {
    use std::arch::x86_64::*;
    for (c, o) in out.iter_mut().enumerate() {
        let row = wt.as_ptr().add(c * feat_pad);
        let mut acc = _mm512_setzero_si512();
        let mut k = 0usize;
        while k < feat_pad {
            // zero-padded inputs: a 16-lane (256-bit) tail group is
            // loaded as a zero-extended 512-bit vector
            let (w, xv) = if k + 2 * super::LANES <= feat_pad {
                (
                    _mm512_loadu_si512(row.add(k) as *const i32),
                    _mm512_loadu_si512(x.as_ptr().add(k) as *const i32),
                )
            } else {
                (
                    _mm512_zextsi256_si512(_mm256_loadu_si256(
                        row.add(k) as *const __m256i
                    )),
                    _mm512_zextsi256_si512(_mm256_loadu_si256(
                        x.as_ptr().add(k) as *const __m256i,
                    )),
                )
            };
            acc = _mm512_add_epi32(acc, _mm512_madd_epi16(w, xv));
            k += 2 * super::LANES;
        }
        // reduce_add is a wrapping shuffle/add sequence
        *o = bias[c].wrapping_add(_mm512_reduce_add_epi32(acc));
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn accumulate_impl(
    table: &[i8],
    feat_pad: usize,
    nodes: &[u32],
    out: &mut [i32],
) {
    use std::arch::x86_64::*;
    for &v in nodes {
        let row = table.as_ptr().add(v as usize * feat_pad);
        let mut k = 0usize;
        while k < feat_pad {
            let o = out.as_mut_ptr().add(k) as *mut i32;
            // 16 i8 → 16 i32, wrapping lane-wise add into out
            let bytes = _mm_loadu_si128(row.add(k) as *const __m128i);
            let wide = _mm512_cvtepi8_epi32(bytes);
            _mm512_storeu_si512(
                o,
                _mm512_add_epi32(_mm512_loadu_si512(o as *const i32), wide),
            );
            k += super::LANES;
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
pub fn matvec_i16_i32(
    _wt: &[i16],
    _x: &[i16],
    _bias: &[i32],
    _feat_pad: usize,
    _out: &mut [i32],
) {
    unreachable!("avx512 backend dispatched on a non-x86_64 target")
}

#[cfg(not(target_arch = "x86_64"))]
pub fn accumulate_rows_i8(
    _table: &[i8],
    _feat_pad: usize,
    _nodes: &[u32],
    _out: &mut [i32],
) {
    unreachable!("avx512 backend dispatched on a non-x86_64 target")
}
