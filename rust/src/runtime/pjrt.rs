//! PJRT client wrapper: compile HLO-text artifacts once, execute many
//! times with mixed host/device-resident arguments.
//!
//! Execution model: the lowered entry computation returns a single
//! tuple (jax lowered with `return_tuple=True`); the wrapper
//! decomposes the result literal into per-output host vectors. Inputs
//! are device buffers; long-lived ones (the resident feature table)
//! are uploaded once and reused across steps.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::artifact::{ArtifactMeta, DType};

/// A PJRT client plus compile/upload helpers.
pub struct Runtime {
    /// The underlying PJRT client (CPU platform).
    pub client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    /// Load + compile an artifact. Compilation is the expensive step
    /// (~seconds); executables are cached by callers and reused.
    pub fn load(&self, meta: &ArtifactMeta) -> Result<Executable> {
        let exe = self.compile_file(&meta.file)?;
        Ok(Executable { exe, meta: meta.clone(), client: self.client.clone() })
    }

    /// Parse an HLO text file and compile it on this client.
    pub fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
    }

    /// Upload an f32 host buffer with the given dims.
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    /// Upload an i32 host buffer with the given dims.
    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
    }
}

/// One output of an execution, copied back to the host.
#[derive(Clone, Debug)]
pub enum HostValue {
    /// An f32 output buffer.
    F32(Vec<f32>),
    /// An i32 output buffer.
    I32(Vec<i32>),
}

impl HostValue {
    /// The f32 payload, or an error for non-f32 outputs.
    pub fn f32(&self) -> Result<&[f32]> {
        match self {
            HostValue::F32(v) => Ok(v),
            _ => bail!("output is not f32"),
        }
    }

    /// A single-element f32 output as a scalar.
    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.f32()?;
        if v.len() != 1 {
            bail!("not a scalar: {} elements", v.len());
        }
        Ok(v[0])
    }
}

/// A compiled artifact, ready to execute many times.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// The manifest record this executable was compiled from.
    pub meta: ArtifactMeta,
    client: xla::PjRtClient,
}

impl Executable {
    /// Execute with device buffers; decompose the tuple result into
    /// host vectors ordered like `meta.outputs`.
    pub fn run<L: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        args: &[L],
    ) -> Result<Vec<HostValue>> {
        if args.len() != self.meta.inputs.len() {
            bail!(
                "artifact {} expects {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                args.len()
            );
        }
        let outs = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.meta.name))?;
        let first = outs
            .first()
            .and_then(|d| d.first())
            .context("no output buffer")?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("tuple decompose: {e:?}"))?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "artifact {}: manifest says {} outputs, runtime returned {}",
                self.meta.name,
                self.meta.outputs.len(),
                parts.len()
            );
        }
        let mut host = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&self.meta.outputs) {
            let v = match spec.dtype {
                DType::F32 => HostValue::F32(
                    lit.to_vec::<f32>()
                        .map_err(|e| anyhow!("output {}: {e:?}", spec.name))?,
                ),
                DType::I32 => HostValue::I32(
                    lit.to_vec::<i32>()
                        .map_err(|e| anyhow!("output {}: {e:?}", spec.name))?,
                ),
            };
            host.push(v);
        }
        Ok(host)
    }

    /// The client this executable runs on (for uploading arguments).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}
