//! Padded batch assembly: MFG -> fixed-shape arrays matching the AOT
//! artifact ABI (see python/compile/model.py's layout docs), plus the
//! per-batch instrumentation the evaluation consumes (input feature
//! footprint for Fig. 6, label diversity for Fig. 7, and the feature
//! access stream fed to the cache simulator).

use anyhow::{bail, Result};

use crate::graph::Dataset;
use crate::runtime::artifact::ArtifactMeta;
use crate::sampler::Mfg;

/// One layer of a padded batch (input-most first).
pub struct PaddedLayer {
    /// `[cap * width]` neighbor indices (global node ids at layer 1 in
    /// resident mode; positions into the previous level otherwise).
    pub idx: Vec<i32>,
    /// `[cap * width]` aggregation weights (model-specific, mask folded).
    pub w: Vec<f32>,
    /// `[cap]` self positions (SAGE/GAT artifacts only).
    pub self_idx: Vec<i32>,
}

/// Instrumentation captured during assembly.
#[derive(Clone, Debug, Default)]
pub struct BatchStats {
    /// Unique input-frontier nodes (feature rows fetched).
    pub input_nodes: usize,
    /// Bytes of input features this batch reads (Fig. 6 x-axis).
    pub input_bytes: usize,
    /// Actual (unpadded) dst rows per layer, input-most first.
    pub level_sizes: Vec<usize>,
    /// Distinct labels among the batch's labeled roots (Fig. 7).
    pub distinct_labels: usize,
    /// Labeled roots in this batch.
    pub num_labeled: usize,
}

/// A fully padded batch, ready for upload.
pub struct PaddedBatch {
    pub layers: Vec<PaddedLayer>,
    /// Global node ids of the (unpadded) roots, in root-row order —
    /// logits row `i` of an infer executable answers `roots[i]`.
    pub roots: Vec<u32>,
    /// `[batch_cap]`
    pub labels: Vec<i32>,
    pub lmask: Vec<f32>,
    /// Staged mode only: gathered input features `[cap0 * feat_dim]`.
    pub x0: Option<Vec<f32>>,
    /// Global node ids whose features the model reads, in first-touch
    /// order (cache-simulator input).
    pub access_stream: Vec<u32>,
    pub stats: BatchStats,
}

/// Assemble a padded batch from a sampled MFG.
///
/// `use_labels = false` builds an inference batch (labels left empty).
pub fn assemble(
    mfg: &Mfg,
    ds: &Dataset,
    meta: &ArtifactMeta,
    use_labels: bool,
) -> Result<PaddedBatch> {
    let spec = &meta.spec;
    let layers = spec.layers;
    if mfg.num_layers() != layers {
        bail!("MFG has {} layers, artifact {}", mfg.num_layers(), layers);
    }
    let caps = &spec.node_caps;
    let model = spec.model.as_str();
    let resident = spec.feat_mode == "resident";

    let mut out_layers = Vec::with_capacity(layers);
    for l in 1..=layers {
        let cap = caps[l];
        let width = spec.idx_widths[l - 1];
        let lvl = &mfg.levels[l];
        let lay = &mfg.layers[l - 1];
        // read stride = the MFG's own sampling fanout: it may be
        // smaller than the artifact's (degraded serving batches sample
        // fewer neighbors into the same padded shape), never larger
        let fanout = lay.fanout;
        if fanout > spec.fanouts[l - 1] {
            bail!(
                "layer {l} sampled fanout {fanout} exceeds artifact \
                 fanout {} ({})",
                spec.fanouts[l - 1],
                meta.name
            );
        }
        if lvl.len() > cap {
            bail!(
                "layer {l} has {} dst rows, cap {cap} (artifact {})",
                lvl.len(),
                meta.name
            );
        }
        let mut idx = vec![0i32; cap * width];
        let mut w = vec![0f32; cap * width];
        let mut self_idx = vec![0i32; cap];

        // position -> artifact index value: at layer 1 in resident mode
        // the artifact gathers from the full feature table, so indices
        // are global node ids.
        let prev = &mfg.levels[l - 1];
        let to_val = |pos: u32| -> i32 {
            if l == 1 && resident {
                prev[pos as usize] as i32
            } else {
                pos as i32
            }
        };

        for i in 0..lvl.len() {
            let c = lay.counts[i] as usize;
            let row = &lay.nbr_pos[i * fanout..i * fanout + c];
            self_idx[i] = to_val(i as u32); // dsts are a prefix of prev
            match model {
                "sage" => {
                    // mean over sampled neighbors
                    let wgt = if c > 0 { 1.0 / c as f32 } else { 0.0 };
                    for (k, &p) in row.iter().enumerate() {
                        idx[i * width + k] = to_val(p);
                        w[i * width + k] = wgt;
                    }
                }
                "gcn" => {
                    // self loop in slot 0, mean over (self + neighbors)
                    let wgt = 1.0 / (c + 1) as f32;
                    idx[i * width] = to_val(i as u32);
                    w[i * width] = wgt;
                    for (k, &p) in row.iter().enumerate() {
                        idx[i * width + 1 + k] = to_val(p);
                        w[i * width + 1 + k] = wgt;
                    }
                }
                "gat" => {
                    // self loop slot 0; w is a 0/1 attention mask
                    idx[i * width] = to_val(i as u32);
                    w[i * width] = 1.0;
                    for (k, &p) in row.iter().enumerate() {
                        idx[i * width + 1 + k] = to_val(p);
                        w[i * width + 1 + k] = 1.0;
                    }
                }
                m => bail!("unknown model {m}"),
            }
        }
        out_layers.push(PaddedLayer { idx, w, self_idx });
    }

    // roots / labels
    let bcap = caps[layers];
    let roots = mfg.roots();
    let mut labels = vec![0i32; if use_labels { bcap } else { 0 }];
    let mut lmask = vec![0f32; if use_labels { bcap } else { 0 }];
    let mut label_seen = std::collections::HashSet::new();
    let mut num_labeled = 0usize;
    if use_labels {
        for (i, &v) in roots.iter().enumerate() {
            labels[i] = ds.labels[v as usize] as i32;
            // ClusterGCN roots include unlabeled nodes: mask to train set
            let is_train = ds.split[v as usize] == crate::graph::SPLIT_TRAIN;
            if is_train {
                lmask[i] = 1.0;
                label_seen.insert(ds.labels[v as usize]);
                num_labeled += 1;
            }
        }
    } else {
        for &v in roots.iter() {
            label_seen.insert(ds.labels[v as usize]);
        }
    }

    // staged feature gather
    let input = mfg.input_nodes();
    let x0 = if resident {
        None
    } else {
        let f = spec.feat_dim;
        let cap0 = caps[0];
        if input.len() > cap0 {
            bail!("input frontier {} exceeds cap0 {cap0}", input.len());
        }
        let mut x = vec![0f32; cap0 * f];
        for (i, &v) in input.iter().enumerate() {
            x[i * f..(i + 1) * f].copy_from_slice(ds.feature_row(v));
        }
        Some(x)
    };

    let stats = BatchStats {
        input_nodes: input.len(),
        input_bytes: input.len() * spec.feat_dim * 4,
        level_sizes: mfg.levels.iter().map(|l| l.len()).collect(),
        distinct_labels: label_seen.len(),
        num_labeled,
    };

    Ok(PaddedBatch {
        layers: out_layers,
        roots: roots.to_vec(),
        labels,
        lmask,
        x0,
        access_stream: input.to_vec(),
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{DType, IoSpec, SpecMeta};
    use crate::sampler::{build_mfg, NeighborPolicy};
    use crate::util::rng::Rng;

    fn tiny_dataset() -> Dataset {
        let mut rng = Rng::new(20);
        let g = crate::graph::gen::generate_sbm(
            &crate::graph::gen::SbmParams {
                n: 512,
                num_comms: 8,
                avg_deg: 10.0,
                p_intra: 0.85,
                deg_alpha: 2.1,
                size_alpha: 1.5,
            },
            &mut rng,
        );
        let p = crate::graph::features::synthesize(
            &g.gt_community,
            8,
            &crate::graph::features::FeatureParams {
                feat_dim: 16,
                num_classes: 5,
                label_noise: 0.1,
                class_signal: 1.0,
                comm_signal: 0.3,
                noise: 0.5,
                train_frac: 0.5,
                val_frac: 0.1,
                labeled_frac: 0.9,
            },
            &mut rng,
        );
        Dataset {
            name: "t".into(),
            csr: g.csr,
            features: p.features,
            feat_dim: 16,
            labels: p.labels,
            num_classes: 5,
            split: p.split,
            community: g.gt_community.clone(),
            num_comms: 8,
            gt_community: g.gt_community,
        }
    }

    fn meta(model: &str, width: usize) -> ArtifactMeta {
        ArtifactMeta {
            name: format!("{model}.test"),
            file: "/dev/null".into(),
            kind: "train".into(),
            spec: SpecMeta {
                model: model.into(),
                layers: 2,
                fanouts: vec![5, 5],
                idx_widths: vec![width, width],
                batch_size: 64,
                num_nodes: 512,
                feat_dim: 16,
                num_classes: 5,
                heads: 1,
                feat_mode: "resident".into(),
                node_caps: vec![512, 384, 64],
                padded_edges: 0,
                edge_chunk: 0,
            },
            inputs: vec![IoSpec {
                name: "p.w".into(),
                shape: vec![16, 16],
                dtype: DType::F32,
            }],
            outputs: vec![],
        }
    }

    #[test]
    fn sage_batch_weights_sum_to_one() {
        let ds = tiny_dataset();
        let mut rng = Rng::new(1);
        let roots: Vec<u32> = ds.train_nodes()[..64].to_vec();
        let mfg = build_mfg(
            &ds.csr, &ds.community, &roots, &[5, 5],
            NeighborPolicy::Uniform, &mut rng,
        );
        let m = meta("sage", 5);
        let b = assemble(&mfg, &ds, &m, true).unwrap();
        assert_eq!(b.layers.len(), 2);
        for (l, lay) in b.layers.iter().enumerate() {
            let nreal = b.stats.level_sizes[l + 1];
            for i in 0..nreal {
                let s: f32 = lay.w[i * 5..(i + 1) * 5].iter().sum();
                let v = mfg.levels[l + 1][i];
                if ds.csr.degree(v) > 0 {
                    assert!((s - 1.0).abs() < 1e-5, "row {i} weights {s}");
                }
            }
            // padded rows are all-zero
            for i in nreal..lay.self_idx.len() {
                assert!(lay.w[i * 5..(i + 1) * 5].iter().all(|&x| x == 0.0));
            }
        }
        assert_eq!(b.lmask.iter().filter(|&&x| x > 0.0).count(), 64);
        assert!(b.x0.is_none());
        assert_eq!(b.stats.input_nodes, mfg.input_nodes().len());
    }

    #[test]
    fn gcn_includes_self_slot() {
        let ds = tiny_dataset();
        let mut rng = Rng::new(2);
        let roots: Vec<u32> = ds.train_nodes()[..32].to_vec();
        let mfg = build_mfg(
            &ds.csr, &ds.community, &roots, &[5, 5],
            NeighborPolicy::Uniform, &mut rng,
        );
        let m = meta("gcn", 6);
        let b = assemble(&mfg, &ds, &m, true).unwrap();
        let lay = &b.layers[1]; // output layer: positions, not globals
        for i in 0..b.stats.level_sizes[2] {
            assert_eq!(lay.idx[i * 6], i as i32, "self slot");
            let c = mfg.layers[1].counts[i] as usize;
            let expect = 1.0 / (c + 1) as f32;
            assert!((lay.w[i * 6] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn layer1_uses_global_ids_in_resident_mode() {
        let ds = tiny_dataset();
        let mut rng = Rng::new(3);
        let roots: Vec<u32> = ds.train_nodes()[..32].to_vec();
        let mfg = build_mfg(
            &ds.csr, &ds.community, &roots, &[5, 5],
            NeighborPolicy::Uniform, &mut rng,
        );
        let m = meta("sage", 5);
        let b = assemble(&mfg, &ds, &m, true).unwrap();
        let lay = &b.layers[0];
        for i in 0..b.stats.level_sizes[1] {
            let c = mfg.layers[0].counts[i] as usize;
            for k in 0..c {
                let global = lay.idx[i * 5 + k];
                let pos = mfg.layers[0].nbr_pos[i * 5 + k] as usize;
                assert_eq!(global as u32, mfg.levels[0][pos]);
            }
            assert_eq!(lay.self_idx[i] as u32, mfg.levels[1][i]);
        }
    }

    /// Dataset of isolated nodes: every neighbor frontier is empty.
    fn isolated_dataset(n: usize) -> Dataset {
        Dataset {
            name: "iso".into(),
            csr: crate::graph::Csr::from_edges(n, &[]),
            features: vec![0.5; n * 16],
            feat_dim: 16,
            labels: vec![1; n],
            num_classes: 5,
            split: vec![crate::graph::SPLIT_TRAIN; n],
            community: vec![0; n],
            num_comms: 1,
            gt_community: vec![0; n],
        }
    }

    #[test]
    fn empty_neighbor_frontier_assembles() {
        let ds = isolated_dataset(512);
        let mut rng = Rng::new(8);
        let roots: Vec<u32> = (0..16u32).collect();
        let mfg = build_mfg(
            &ds.csr, &ds.community, &roots, &[5, 5],
            NeighborPolicy::Uniform, &mut rng,
        );
        let m = meta("sage", 5);
        let b = assemble(&mfg, &ds, &m, true).unwrap();
        // no neighbors anywhere: frontier is exactly the roots, and
        // every aggregation weight is zero (no row sums to garbage)
        assert_eq!(b.stats.input_nodes, roots.len());
        assert_eq!(b.stats.level_sizes, vec![16, 16, 16]);
        for lay in &b.layers {
            assert!(lay.w.iter().all(|&x| x == 0.0));
            assert!(lay.idx.iter().all(|&x| x == 0));
        }
        // labels/masks still line up with the roots
        assert_eq!(b.lmask.iter().filter(|&&x| x > 0.0).count(), 16);
        assert!(b.labels[..16].iter().all(|&l| l == 1));
    }

    #[test]
    fn batch_smaller_than_pad_capacity_zero_pads() {
        let ds = tiny_dataset();
        let mut rng = Rng::new(9);
        // 3 roots against a 64-root capacity
        let roots: Vec<u32> = ds.train_nodes()[..3].to_vec();
        let mfg = build_mfg(
            &ds.csr, &ds.community, &roots, &[5, 5],
            NeighborPolicy::Uniform, &mut rng,
        );
        let m = meta("sage", 5);
        let b = assemble(&mfg, &ds, &m, true).unwrap();
        assert_eq!(b.stats.level_sizes[2], 3);
        assert_eq!(b.labels.len(), 64);
        assert_eq!(b.lmask.len(), 64);
        assert_eq!(b.lmask.iter().filter(|&&x| x > 0.0).count(), 3);
        assert!(b.lmask[3..].iter().all(|&x| x == 0.0));
        assert!(b.labels[3..].iter().all(|&l| l == 0));
        // padded dst rows beyond the real ones stay all-zero
        let lay = &b.layers[1];
        for i in b.stats.level_sizes[2]..64 {
            assert!(lay.w[i * 5..(i + 1) * 5].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn root_set_overflowing_capacity_errors_not_truncates() {
        let ds = tiny_dataset();
        let mut rng = Rng::new(10);
        // 100 roots > the artifact's 64-root capacity
        let roots: Vec<u32> = ds.train_nodes()[..100].to_vec();
        let mfg = build_mfg(
            &ds.csr, &ds.community, &roots, &[5, 5],
            NeighborPolicy::Uniform, &mut rng,
        );
        let m = meta("sage", 5);
        let err = assemble(&mfg, &ds, &m, true).unwrap_err();
        assert!(
            format!("{err:#}").contains("cap"),
            "error should name the violated capacity: {err:#}"
        );
    }

    #[test]
    fn staged_frontier_overflowing_cap0_errors() {
        let ds = tiny_dataset();
        let mut rng = Rng::new(11);
        let roots: Vec<u32> = ds.train_nodes()[..64].to_vec();
        let mfg = build_mfg(
            &ds.csr, &ds.community, &roots, &[5, 5],
            NeighborPolicy::Uniform, &mut rng,
        );
        let mut m = meta("sage", 5);
        m.spec.feat_mode = "staged".into();
        m.spec.node_caps[0] = 4; // absurdly small staging buffer
        let err = assemble(&mfg, &ds, &m, true).unwrap_err();
        assert!(format!("{err:#}").contains("cap0"), "{err:#}");
    }

    #[test]
    fn staged_mode_gathers_x0() {
        let ds = tiny_dataset();
        let mut rng = Rng::new(4);
        let roots: Vec<u32> = ds.train_nodes()[..32].to_vec();
        let mfg = build_mfg(
            &ds.csr, &ds.community, &roots, &[5, 5],
            NeighborPolicy::Uniform, &mut rng,
        );
        let mut m = meta("sage", 5);
        m.spec.feat_mode = "staged".into();
        let b = assemble(&mfg, &ds, &m, true).unwrap();
        let x0 = b.x0.as_ref().unwrap();
        assert_eq!(x0.len(), 512 * 16);
        // row i of x0 == features of input node i
        for (i, &v) in mfg.input_nodes().iter().enumerate().take(10) {
            assert_eq!(&x0[i * 16..(i + 1) * 16], ds.feature_row(v));
        }
        // layer-1 indices are local rows now
        let lay = &b.layers[0];
        for i in 0..b.stats.level_sizes[1] {
            assert!(
                (lay.self_idx[i] as usize) < mfg.input_nodes().len()
            );
        }
    }
}
