//! Binary dataset serialization (datasets are generated once by
//! `comm-rand gen-data` and memory-loaded by every experiment).
//!
//! Format: magic, version, header dims, then raw little-endian arrays
//! in a fixed order. No compression — load speed matters more than the
//! ~100MB on disk.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Csr, Dataset};

const MAGIC: &[u8; 8] = b"COMMRND1";

fn w_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn r_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn w_u32s(w: &mut impl Write, xs: &[u32]) -> Result<()> {
    w_u64(w, xs.len() as u64)?;
    // bulk-write via byte view
    let bytes = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
    };
    w.write_all(bytes)?;
    Ok(())
}

fn r_u32s(r: &mut impl Read) -> Result<Vec<u32>> {
    let n = r_u64(r)? as usize;
    let mut out = vec![0u32; n];
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, n * 4)
    };
    r.read_exact(bytes)?;
    Ok(out)
}

fn w_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    w_u64(w, xs.len() as u64)?;
    let bytes = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
    };
    w.write_all(bytes)?;
    Ok(())
}

fn r_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = r_u64(r)? as usize;
    let mut out = vec![0f32; n];
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, n * 4)
    };
    r.read_exact(bytes)?;
    Ok(out)
}

fn w_u16s(w: &mut impl Write, xs: &[u16]) -> Result<()> {
    w_u64(w, xs.len() as u64)?;
    let bytes = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 2)
    };
    w.write_all(bytes)?;
    Ok(())
}

fn r_u16s(r: &mut impl Read) -> Result<Vec<u16>> {
    let n = r_u64(r)? as usize;
    let mut out = vec![0u16; n];
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, n * 2)
    };
    r.read_exact(bytes)?;
    Ok(out)
}

fn w_u8s(w: &mut impl Write, xs: &[u8]) -> Result<()> {
    w_u64(w, xs.len() as u64)?;
    w.write_all(xs)?;
    Ok(())
}

fn r_u8s(r: &mut impl Read) -> Result<Vec<u8>> {
    let n = r_u64(r)? as usize;
    let mut out = vec![0u8; n];
    r.read_exact(&mut out)?;
    Ok(out)
}

pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    let name = ds.name.as_bytes();
    w_u64(&mut w, name.len() as u64)?;
    w.write_all(name)?;
    w_u64(&mut w, ds.csr.n as u64)?;
    w_u64(&mut w, ds.feat_dim as u64)?;
    w_u64(&mut w, ds.num_classes as u64)?;
    w_u64(&mut w, ds.num_comms as u64)?;
    w_u32s(&mut w, &ds.csr.offsets)?;
    w_u32s(&mut w, &ds.csr.adj)?;
    w_f32s(&mut w, &ds.features)?;
    w_u16s(&mut w, &ds.labels)?;
    w_u8s(&mut w, &ds.split)?;
    w_u32s(&mut w, &ds.community)?;
    w_u32s(&mut w, &ds.gt_community)?;
    w.flush()?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a comm-rand dataset", path.display());
    }
    let name_len = r_u64(&mut r)? as usize;
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let n = r_u64(&mut r)? as usize;
    let feat_dim = r_u64(&mut r)? as usize;
    let num_classes = r_u64(&mut r)? as usize;
    let num_comms = r_u64(&mut r)? as usize;
    let offsets = r_u32s(&mut r)?;
    let adj = r_u32s(&mut r)?;
    let features = r_f32s(&mut r)?;
    let labels = r_u16s(&mut r)?;
    let split = r_u8s(&mut r)?;
    let community = r_u32s(&mut r)?;
    let gt_community = r_u32s(&mut r)?;
    let csr = Csr { n, offsets, adj };
    Ok(Dataset {
        name: String::from_utf8(name)?,
        csr,
        features,
        feat_dim,
        labels,
        num_classes,
        split,
        community,
        num_comms,
        gt_community,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_sbm, SbmParams};
    use crate::util::rng::Rng;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(12);
        let g = generate_sbm(
            &SbmParams {
                n: 500,
                num_comms: 8,
                avg_deg: 8.0,
                p_intra: 0.8,
                deg_alpha: 2.1,
                size_alpha: 1.5,
            },
            &mut rng,
        );
        let payload = crate::graph::features::synthesize(
            &g.gt_community,
            8,
            &crate::graph::features::FeatureParams {
                feat_dim: 8,
                num_classes: 4,
                label_noise: 0.1,
                class_signal: 1.0,
                comm_signal: 0.3,
                noise: 0.3,
                train_frac: 0.5,
                val_frac: 0.1,
                labeled_frac: 0.8,
            },
            &mut rng,
        );
        let ds = Dataset {
            name: "unit".into(),
            csr: g.csr,
            features: payload.features,
            feat_dim: 8,
            labels: payload.labels,
            num_classes: 4,
            split: payload.split,
            community: g.gt_community.clone(),
            num_comms: 8,
            gt_community: g.gt_community,
        };
        let dir = std::env::temp_dir().join("comm_rand_io_test");
        let path = dir.join("unit.bin");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.csr.offsets, ds.csr.offsets);
        assert_eq!(back.csr.adj, ds.csr.adj);
        assert_eq!(back.features, ds.features);
        assert_eq!(back.labels, ds.labels);
        assert_eq!(back.split, ds.split);
        assert_eq!(back.community, ds.community);
        std::fs::remove_file(&path).ok();
    }
}
