//! Versioned CSR delta-overlay: a consistent, immutable topology
//! snapshot that layers streaming edge mutations over a frozen base
//! [`Csr`] without rebuilding it.
//!
//! A [`TopoSnapshot`] is `base ⊕ patched`: vertices untouched since the
//! last compaction read their adjacency straight out of the base CSR;
//! a vertex with at least one inserted/deleted incident edge carries a
//! full replacement list in the `patched` map (sorted + deduplicated,
//! same invariants as the CSR). Snapshots are immutable — applying an
//! update epoch produces a *new* snapshot with a bumped version, so
//! in-flight samplers holding an `Arc` of the old one keep reading a
//! consistent graph while the new version is published beside them.
//!
//! When the patch map grows past [`TopoSnapshot::COMPACT_FRAC`] of the
//! node count, [`TopoSnapshot::apply`] folds everything into a fresh
//! base CSR (an O(E) rebuild, done off the serving path by the single
//! writer) and the overlay starts empty again — so per-epoch apply
//! cost stays proportional to the epoch's touched set, not run length.

use std::collections::HashMap;
use std::sync::Arc;

use super::{Csr, Topology};

/// One immutable, versioned view of the mutating topology (see the
/// module docs).
pub struct TopoSnapshot {
    version: u64,
    base: Arc<Csr>,
    /// Vertex → full replacement adjacency (sorted, deduplicated).
    /// Lists are `Arc`-shared between snapshot generations and cloned
    /// copy-on-write only when an epoch touches them, so applying an
    /// epoch costs O(touched set), not O(overlay size).
    patched: HashMap<u32, Arc<Vec<u32>>>,
    /// Directed-edge delta of `patched` versus `base`.
    edge_delta: i64,
}

impl TopoSnapshot {
    /// Compact when the patch map covers more than 1/8 of the nodes.
    pub const COMPACT_FRAC: usize = 8;

    /// Version-0 snapshot over an unmodified base CSR.
    pub fn from_base(base: Arc<Csr>) -> TopoSnapshot {
        TopoSnapshot {
            version: 0,
            base,
            patched: HashMap::new(),
            edge_delta: 0,
        }
    }

    /// Monotone snapshot version (0 = the pristine base).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Vertices currently carrying a patched adjacency list.
    pub fn patched_len(&self) -> usize {
        self.patched.len()
    }

    /// Directed edge slots in this snapshot (base ± the overlay delta).
    pub fn num_directed_edges(&self) -> usize {
        (self.base.num_directed_edges() as i64 + self.edge_delta).max(0)
            as usize
    }

    fn adj_of(&self, v: u32) -> &[u32] {
        match self.patched.get(&v) {
            Some(list) => list.as_slice(),
            None => self.base.neighbors(v),
        }
    }

    /// Whether the undirected edge `(u, v)` is present.
    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        self.adj_of(u).binary_search(&v).is_ok()
    }

    /// Apply a batch of undirected edge updates (`(u, v, insert)`)
    /// and return `(next_snapshot, applied)` where `applied` lists the
    /// updates that actually changed the graph — inserting an existing
    /// edge, deleting a missing one, self loops and out-of-range
    /// endpoints are all no-ops and are filtered out.
    ///
    /// The returned snapshot has `version + 1`; `self` is untouched.
    /// When the patch map outgrows `n / COMPACT_FRAC` the result is
    /// compacted into a fresh base CSR with an empty overlay.
    pub fn apply(
        &self,
        updates: &[(u32, u32, bool)],
    ) -> (TopoSnapshot, Vec<(u32, u32, bool)>) {
        let n = self.base.n;
        let mut patched = self.patched.clone();
        let mut edge_delta = self.edge_delta;
        let mut applied = Vec::with_capacity(updates.len());
        for &(u, v, insert) in updates {
            if u == v || u as usize >= n || v as usize >= n {
                continue;
            }
            let present = match patched.get(&u) {
                Some(list) => list.binary_search(&v).is_ok(),
                None => self.base.neighbors(u).binary_search(&v).is_ok(),
            };
            if present == insert {
                continue; // no-op
            }
            for (a, b) in [(u, v), (v, u)] {
                let entry = patched.entry(a).or_insert_with(|| {
                    Arc::new(self.base.neighbors(a).to_vec())
                });
                // copy-on-write: clones the list only if an older
                // snapshot still shares it
                let list = Arc::make_mut(entry);
                match list.binary_search(&b) {
                    Ok(i) if !insert => {
                        list.remove(i);
                    }
                    Err(i) if insert => {
                        list.insert(i, b);
                    }
                    _ => {}
                }
            }
            edge_delta += if insert { 2 } else { -2 };
            applied.push((u, v, insert));
        }
        let next = TopoSnapshot {
            version: self.version + 1,
            base: self.base.clone(),
            patched,
            edge_delta,
        };
        if next.patched.len() > n.max(Self::COMPACT_FRAC) / Self::COMPACT_FRAC
        {
            let compacted = TopoSnapshot {
                version: next.version,
                base: Arc::new(next.compact()),
                patched: HashMap::new(),
                edge_delta: 0,
            };
            return (compacted, applied);
        }
        (next, applied)
    }

    /// Materialize the overlay into a standalone CSR (used for full
    /// community relabels and by the compaction path).
    pub fn compact(&self) -> Csr {
        let n = self.base.n;
        let mut edges: Vec<(u32, u32)> =
            Vec::with_capacity(self.num_directed_edges() / 2);
        for v in 0..n as u32 {
            for &u in self.adj_of(v) {
                if v < u {
                    edges.push((v, u));
                }
            }
        }
        Csr::from_edges(n, &edges)
    }
}

impl Topology for TopoSnapshot {
    fn num_nodes(&self) -> usize {
        self.base.n
    }

    fn neighbors(&self, v: u32) -> &[u32] {
        self.adj_of(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn base_graph() -> Arc<Csr> {
        Arc::new(Csr::from_edges(
            8,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7)],
        ))
    }

    #[test]
    fn pristine_snapshot_mirrors_base() {
        let base = base_graph();
        let s = TopoSnapshot::from_base(base.clone());
        assert_eq!(s.version(), 0);
        assert_eq!(s.num_nodes(), 8);
        assert_eq!(s.num_directed_edges(), base.num_directed_edges());
        for v in 0..8u32 {
            assert_eq!(Topology::neighbors(&s, v), base.neighbors(v));
        }
    }

    #[test]
    fn insert_and_delete_round_trip() {
        let s0 = TopoSnapshot::from_base(base_graph());
        let (s1, applied) = s0.apply(&[(0, 7, true), (3, 4, false)]);
        assert_eq!(applied.len(), 2);
        assert_eq!(s1.version(), 1);
        assert!(s1.has_edge(0, 7) && s1.has_edge(7, 0));
        assert!(!s1.has_edge(3, 4) && !s1.has_edge(4, 3));
        // the old snapshot is untouched — consistent for in-flight readers
        assert!(!s0.has_edge(0, 7));
        assert!(s0.has_edge(3, 4));
        assert_eq!(
            s1.num_directed_edges() as i64,
            s0.num_directed_edges() as i64
        );
        // lists stay sorted
        for v in 0..8u32 {
            let l = Topology::neighbors(&s1, v);
            assert!(l.windows(2).all(|w| w[0] < w[1]), "unsorted at {v}");
        }
    }

    #[test]
    fn noop_updates_are_filtered() {
        let s0 = TopoSnapshot::from_base(base_graph());
        let (s1, applied) = s0.apply(&[
            (0, 1, true),   // already present
            (0, 5, false),  // absent
            (2, 2, true),   // self loop
            (0, 100, true), // out of range
        ]);
        assert!(applied.is_empty());
        assert_eq!(s1.version(), 1, "version still advances per epoch");
        assert_eq!(s1.num_directed_edges(), s0.num_directed_edges());
    }

    #[test]
    fn compact_matches_incremental_state() {
        let mut rng = Rng::new(11);
        let n = 64usize;
        let mut edges = vec![];
        for _ in 0..200 {
            edges.push((rng.below(n as u64) as u32, rng.below(n as u64) as u32));
        }
        let base = Arc::new(Csr::from_edges(n, &edges));
        let mut snap = TopoSnapshot::from_base(base);
        // random churn, tracked against a reference edge set
        for _ in 0..40 {
            let mut batch = vec![];
            for _ in 0..8 {
                let u = rng.below(n as u64) as u32;
                let v = rng.below(n as u64) as u32;
                batch.push((u, v, rng.f64() < 0.5));
            }
            let (next, _) = snap.apply(&batch);
            snap = next;
        }
        let compacted = snap.compact();
        compacted.validate().unwrap();
        assert_eq!(compacted.num_directed_edges(), snap.num_directed_edges());
        for v in 0..n as u32 {
            assert_eq!(
                compacted.neighbors(v),
                Topology::neighbors(&snap, v),
                "adjacency mismatch at {v}"
            );
        }
    }

    #[test]
    fn auto_compaction_preserves_the_graph() {
        let n = 32usize;
        let base = Arc::new(Csr::from_edges(n, &[(0, 1)]));
        let mut snap = TopoSnapshot::from_base(base);
        // touch every vertex so the patch map exceeds n / COMPACT_FRAC
        let mut rng = Rng::new(3);
        for round in 0..16u64 {
            let mut batch = vec![];
            for _ in 0..6 {
                let u = rng.below(n as u64) as u32;
                let v = rng.below(n as u64) as u32;
                batch.push((u, v, true));
            }
            let (next, _) = snap.apply(&batch);
            snap = next;
            assert_eq!(snap.version(), round + 1);
            assert!(
                snap.patched_len() <= n / TopoSnapshot::COMPACT_FRAC,
                "overlay never exceeds the compaction bound after apply"
            );
        }
        let csr = snap.compact();
        csr.validate().unwrap();
        assert_eq!(csr.num_directed_edges(), snap.num_directed_edges());
    }
}
