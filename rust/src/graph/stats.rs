//! Structural statistics: degree distribution summary, modularity and
//! intra-community edge fraction (used to sanity-check generation and
//! community detection, and reported by `comm-rand inspect`).

use super::Csr;

pub struct DegreeStats {
    pub min: usize,
    pub max: usize,
    pub mean: f64,
    pub median: usize,
}

pub fn degree_stats(csr: &Csr) -> DegreeStats {
    let mut degs: Vec<usize> = (0..csr.n as u32).map(|v| csr.degree(v)).collect();
    degs.sort_unstable();
    DegreeStats {
        min: *degs.first().unwrap_or(&0),
        max: *degs.last().unwrap_or(&0),
        mean: csr.num_directed_edges() as f64 / csr.n.max(1) as f64,
        median: degs.get(csr.n / 2).copied().unwrap_or(0),
    }
}

/// Newman modularity Q of a node->community assignment.
/// Q = (1/2m) Σ_ij [A_ij - k_i k_j / 2m] δ(c_i, c_j)
pub fn modularity(csr: &Csr, comm: &[u32]) -> f64 {
    assert_eq!(comm.len(), csr.n);
    let two_m = csr.num_directed_edges() as f64;
    if two_m == 0.0 {
        return 0.0;
    }
    let num_comms = comm.iter().map(|&c| c as usize + 1).max().unwrap_or(0);
    let mut intra = vec![0f64; num_comms]; // directed intra-edge count
    let mut deg_sum = vec![0f64; num_comms];
    for v in 0..csr.n as u32 {
        let cv = comm[v as usize] as usize;
        deg_sum[cv] += csr.degree(v) as f64;
        for &u in csr.neighbors(v) {
            if comm[u as usize] as usize == cv {
                intra[cv] += 1.0;
            }
        }
    }
    let mut q = 0.0;
    for c in 0..num_comms {
        q += intra[c] / two_m - (deg_sum[c] / two_m).powi(2);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modularity_two_cliques() {
        // two triangles joined by one edge: clear 2-community structure
        let g = Csr::from_edges(
            6,
            &[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5), (2, 3)],
        );
        let good = modularity(&g, &[0, 0, 0, 1, 1, 1]);
        let bad = modularity(&g, &[0, 1, 0, 1, 0, 1]);
        let trivial = modularity(&g, &[0, 0, 0, 0, 0, 0]);
        assert!(good > 0.3, "good={good}");
        assert!(good > bad);
        assert!(trivial.abs() < 1e-9);
    }

    #[test]
    fn degree_stats_basic() {
        let g = Csr::from_edges(4, &[(0, 1), (0, 2), (0, 3)]);
        let s = degree_stats(&g);
        assert_eq!(s.max, 3);
        assert_eq!(s.min, 1);
        assert!((s.mean - 1.5).abs() < 1e-9);
    }
}
