//! Synthetic community-structured graph generation.
//!
//! The paper evaluates on reddit / igb-small / ogbn-products /
//! ogbn-papers100M — graphs we can neither download in this offline
//! image nor train on a CPU-only testbed at full scale. The substitute
//! (DESIGN.md §Substitutions) is a degree-corrected stochastic block
//! model (DC-SBM) with power-law community sizes and degrees, which
//! preserves the two properties COMM-RAND exploits: strong community
//! structure (dense intra-community connectivity) and skewed degrees.
//! Nodes are emitted in *shuffled* order, so the "original ordering"
//! baseline genuinely lacks locality until community reordering runs.

use super::csr::Csr;
use crate::util::rng::Rng;

/// Generator parameters for one synthetic dataset.
#[derive(Clone, Debug)]
pub struct SbmParams {
    pub n: usize,
    /// Target number of ground-truth communities.
    pub num_comms: usize,
    /// Mean degree (undirected edges ~ n * avg_deg / 2).
    pub avg_deg: f64,
    /// Probability that an edge stub stays inside its community.
    pub p_intra: f64,
    /// Power-law exponent for degree skew (2.1 ≈ heavy tail).
    pub deg_alpha: f64,
    /// Power-law exponent for community sizes.
    pub size_alpha: f64,
}

/// Generated topology plus ground-truth block assignment (the
/// assignment is used only for validating community detection).
pub struct SbmGraph {
    pub csr: Csr,
    pub gt_community: Vec<u32>,
}

pub fn generate_sbm(p: &SbmParams, rng: &mut Rng) -> SbmGraph {
    assert!(p.num_comms >= 1 && p.n >= p.num_comms);
    // --- community sizes: power-law, normalized to n ---
    let mut raw: Vec<f64> = (0..p.num_comms)
        .map(|_| rng.powerlaw(1.0, (p.n / 4).max(2) as f64, p.size_alpha))
        .collect();
    let total: f64 = raw.iter().sum();
    let mut sizes: Vec<usize> = raw
        .iter_mut()
        .map(|r| ((*r / total) * p.n as f64).floor() as usize + 1)
        .collect();
    // adjust to exactly n
    let mut diff = p.n as i64 - sizes.iter().sum::<usize>() as i64;
    let mut i = 0;
    while diff != 0 {
        let k = i % sizes.len();
        if diff > 0 {
            sizes[k] += 1;
            diff -= 1;
        } else if sizes[k] > 1 {
            sizes[k] -= 1;
            diff += 1;
        }
        i += 1;
    }

    // --- assign nodes to communities, then shuffle the labelling so the
    // emitted graph has no locality in its node order ---
    let mut gt = vec![0u32; p.n];
    {
        let mut v = 0usize;
        for (c, &sz) in sizes.iter().enumerate() {
            for _ in 0..sz {
                gt[v] = c as u32;
                v += 1;
            }
        }
    }
    let mut shuffle_map: Vec<u32> = (0..p.n as u32).collect();
    rng.shuffle(&mut shuffle_map);
    let mut gt_shuffled = vec![0u32; p.n];
    for v in 0..p.n {
        gt_shuffled[shuffle_map[v] as usize] = gt[v];
    }
    let gt = gt_shuffled;

    // membership lists for intra-edge sampling
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); p.num_comms];
    for v in 0..p.n as u32 {
        members[gt[v as usize] as usize].push(v);
    }

    // --- per-node degree targets (power-law) ---
    let max_deg = (p.avg_deg * 20.0).min(p.n as f64 / 4.0);
    let mut degs: Vec<f64> = (0..p.n)
        .map(|_| rng.powerlaw(1.0, max_deg, p.deg_alpha))
        .collect();
    let mean: f64 = degs.iter().sum::<f64>() / p.n as f64;
    let scale = p.avg_deg / mean;
    for d in degs.iter_mut() {
        *d *= scale;
    }

    // --- emit edges: each node spends its stubs, intra with p_intra ---
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(
        (p.n as f64 * p.avg_deg / 2.0) as usize + p.n,
    );
    for v in 0..p.n as u32 {
        let c = gt[v as usize] as usize;
        // stub count: round stochastically to keep fractional degrees fair
        let want = degs[v as usize] / 2.0; // each edge gives 2 stubs
        let mut k = want.floor() as usize;
        if rng.f64() < want.fract() {
            k += 1;
        }
        for _ in 0..k.max(1) {
            let intra = rng.f64() < p.p_intra && members[c].len() > 1;
            let u = if intra {
                // uniform member of own community
                loop {
                    let cand = members[c][rng.usize_below(members[c].len())];
                    if cand != v {
                        break cand;
                    }
                }
            } else {
                // preferential-ish random remote node (uniform is fine)
                loop {
                    let cand = rng.below(p.n as u64) as u32;
                    if cand != v {
                        break cand;
                    }
                }
            };
            edges.push((v, u));
        }
    }

    let csr = Csr::from_edges(p.n, &edges);
    SbmGraph { csr, gt_community: gt }
}

/// Fraction of directed edges whose endpoints share a block.
pub fn intra_fraction(csr: &Csr, comm: &[u32]) -> f64 {
    let mut intra = 0usize;
    let mut total = 0usize;
    for v in 0..csr.n as u32 {
        for &u in csr.neighbors(v) {
            total += 1;
            if comm[v as usize] == comm[u as usize] {
                intra += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        intra as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> SbmParams {
        SbmParams {
            n: 2000,
            num_comms: 24,
            avg_deg: 12.0,
            p_intra: 0.85,
            deg_alpha: 2.1,
            size_alpha: 1.5,
        }
    }

    #[test]
    fn sbm_basic_shape() {
        let mut rng = Rng::new(1);
        let g = generate_sbm(&small_params(), &mut rng);
        g.csr.validate().unwrap();
        assert_eq!(g.csr.n, 2000);
        let avg = g.csr.num_directed_edges() as f64 / g.csr.n as f64;
        assert!(avg > 6.0 && avg < 24.0, "avg degree {avg}");
    }

    #[test]
    fn sbm_has_community_structure() {
        let mut rng = Rng::new(2);
        let g = generate_sbm(&small_params(), &mut rng);
        let f = intra_fraction(&g.csr, &g.gt_community);
        // p_intra=0.85 minus dedup/symmetry noise still ≫ random (~1/24)
        assert!(f > 0.6, "intra fraction {f}");
    }

    #[test]
    fn sbm_node_order_is_shuffled() {
        let mut rng = Rng::new(3);
        let g = generate_sbm(&small_params(), &mut rng);
        // consecutive nodes should rarely share a community after the
        // label shuffle (strong locality would mean order ≈ community)
        let mut same = 0;
        for v in 0..g.csr.n - 1 {
            if g.gt_community[v] == g.gt_community[v + 1] {
                same += 1;
            }
        }
        let frac = same as f64 / (g.csr.n - 1) as f64;
        assert!(frac < 0.3, "adjacent-same-community fraction {frac}");
    }

    #[test]
    fn sbm_deterministic() {
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = generate_sbm(&small_params(), &mut r1);
        let b = generate_sbm(&small_params(), &mut r2);
        assert_eq!(a.csr.adj, b.csr.adj);
        assert_eq!(a.gt_community, b.gt_community);
    }
}
