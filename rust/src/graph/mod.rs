//! Graph substrate: CSR topology, synthetic community-structured graph
//! generation (stand-ins for reddit / ogbn-products / igb-small /
//! ogbn-papers100M — see DESIGN.md §Datasets), node features/labels,
//! binary dataset IO and structural statistics.

pub mod csr;
pub mod features;
pub mod gen;
pub mod io;
pub mod overlay;
pub mod stats;

pub use csr::Csr;
pub use overlay::TopoSnapshot;

/// Read-only adjacency access, implemented by both the frozen
/// [`Csr`] and the versioned delta-overlay snapshots
/// ([`overlay::TopoSnapshot`]) the streaming-mutation subsystem
/// publishes. Samplers are generic over this trait, so an in-flight
/// sampler keeps reading one consistent snapshot while newer versions
/// are published next to it.
pub trait Topology: Sync {
    /// Number of nodes.
    fn num_nodes(&self) -> usize;
    /// Sorted, deduplicated neighbor list of `v`.
    fn neighbors(&self, v: u32) -> &[u32];
    /// Degree of `v`.
    fn degree(&self, v: u32) -> usize {
        self.neighbors(v).len()
    }
}

impl Topology for Csr {
    fn num_nodes(&self) -> usize {
        self.n
    }

    fn neighbors(&self, v: u32) -> &[u32] {
        Csr::neighbors(self, v)
    }
}

/// Train/val/test membership of a node.
pub const SPLIT_TRAIN: u8 = 0;
pub const SPLIT_VAL: u8 = 1;
pub const SPLIT_TEST: u8 = 2;
pub const SPLIT_NONE: u8 = 3;

/// A fully materialized dataset: topology + node payload + the
/// community structure used by COMM-RAND.
///
/// `community` is whatever the detection pass (community::louvain)
/// produced — the pipeline never reads the generator's ground truth.
pub struct Dataset {
    pub name: String,
    pub csr: Csr,
    /// Row-major `[n, feat_dim]`.
    pub features: Vec<f32>,
    pub feat_dim: usize,
    pub labels: Vec<u16>,
    pub num_classes: usize,
    pub split: Vec<u8>,
    /// Community id per node (from detection, contiguous 0..num_comms).
    pub community: Vec<u32>,
    pub num_comms: usize,
    /// Ground-truth block of the generator (kept for tests only).
    pub gt_community: Vec<u32>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.csr.n
    }

    pub fn train_nodes(&self) -> Vec<u32> {
        self.nodes_in_split(SPLIT_TRAIN)
    }

    pub fn val_nodes(&self) -> Vec<u32> {
        self.nodes_in_split(SPLIT_VAL)
    }

    pub fn test_nodes(&self) -> Vec<u32> {
        self.nodes_in_split(SPLIT_TEST)
    }

    pub fn nodes_in_split(&self, s: u8) -> Vec<u32> {
        (0..self.n() as u32)
            .filter(|&v| self.split[v as usize] == s)
            .collect()
    }

    pub fn feature_row(&self, v: u32) -> &[f32] {
        let f = self.feat_dim;
        &self.features[v as usize * f..(v as usize + 1) * f]
    }

    /// Apply a node permutation `perm` (new-id -> old-id is
    /// `perm_inv`): node `v` becomes `perm[v]`.
    pub fn permute(&mut self, perm: &[u32]) {
        let n = self.n();
        assert_eq!(perm.len(), n);
        self.csr = self.csr.permute(perm);
        let old = std::mem::take(&mut self.features);
        let f = self.feat_dim;
        let mut feats = vec![0f32; old.len()];
        let mut labels = vec![0u16; n];
        let mut split = vec![0u8; n];
        let mut comm = vec![0u32; n];
        let mut gt = vec![0u32; n];
        for old_v in 0..n {
            let new_v = perm[old_v] as usize;
            feats[new_v * f..(new_v + 1) * f]
                .copy_from_slice(&old[old_v * f..(old_v + 1) * f]);
            labels[new_v] = self.labels[old_v];
            split[new_v] = self.split[old_v];
            comm[new_v] = self.community[old_v];
            gt[new_v] = self.gt_community[old_v];
        }
        self.features = feats;
        self.labels = labels;
        self.split = split;
        self.community = comm;
        self.gt_community = gt;
    }
}
