//! Node feature / label / split synthesis.
//!
//! Labels correlate with ground-truth communities (homophily): each
//! community draws a dominant class and nodes flip away from it with
//! `label_noise`. Features are class centroid + community centroid +
//! gaussian noise. This reproduces the property COMM-RAND's evaluation
//! hinges on: community-pure mini-batches have low label entropy
//! (Fig. 7), which slows convergence, while the feature signal still
//! lets all policies reach comparable final accuracy.

use crate::util::rng::Rng;

use super::{SPLIT_NONE, SPLIT_TEST, SPLIT_TRAIN, SPLIT_VAL};

#[derive(Clone, Debug)]
pub struct FeatureParams {
    pub feat_dim: usize,
    pub num_classes: usize,
    /// Probability a node's label deviates from its community's class.
    pub label_noise: f64,
    /// Scale of the class-centroid signal in features.
    pub class_signal: f32,
    /// Scale of the community-centroid signal in features.
    pub comm_signal: f32,
    /// Gaussian feature noise sigma.
    pub noise: f32,
    /// Train/val fractions (test = rest, unlabeled beyond labeled_frac).
    pub train_frac: f64,
    pub val_frac: f64,
    /// Fraction of nodes that carry labels at all.
    pub labeled_frac: f64,
}

pub struct NodePayload {
    pub features: Vec<f32>,
    pub labels: Vec<u16>,
    pub split: Vec<u8>,
}

pub fn synthesize(
    gt_community: &[u32],
    num_comms: usize,
    p: &FeatureParams,
    rng: &mut Rng,
) -> NodePayload {
    let n = gt_community.len();
    let f = p.feat_dim;
    let c = p.num_classes;

    // community -> dominant class (roughly balanced across classes)
    let mut comm_class = vec![0u16; num_comms];
    for (i, cc) in comm_class.iter_mut().enumerate() {
        *cc = ((i % c) as u16 + (rng.below(c as u64 / 2 + 1) as u16)) % c as u16;
    }

    // centroids
    let mut class_centroid = vec![0f32; c * f];
    for x in class_centroid.iter_mut() {
        *x = rng.normal() as f32;
    }
    let mut comm_centroid = vec![0f32; num_comms * f];
    for x in comm_centroid.iter_mut() {
        *x = rng.normal() as f32;
    }

    let mut labels = vec![0u16; n];
    let mut features = vec![0f32; n * f];
    for v in 0..n {
        let comm = gt_community[v] as usize;
        let mut label = comm_class[comm];
        if rng.f64() < p.label_noise {
            label = rng.below(c as u64) as u16;
        }
        labels[v] = label;
        let row = &mut features[v * f..(v + 1) * f];
        let cc = &class_centroid[label as usize * f..(label as usize + 1) * f];
        let mc = &comm_centroid[comm * f..(comm + 1) * f];
        for j in 0..f {
            row[j] = p.class_signal * cc[j]
                + p.comm_signal * mc[j]
                + p.noise * rng.normal() as f32;
        }
    }

    // splits: shuffle nodes, take labeled_frac, then train/val/test
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let labeled = ((n as f64) * p.labeled_frac).round() as usize;
    let ntrain = ((n as f64) * p.train_frac).round() as usize;
    let nval = ((n as f64) * p.val_frac).round() as usize;
    assert!(
        ntrain + nval <= labeled,
        "train+val exceed labeled fraction"
    );
    let mut split = vec![SPLIT_NONE; n];
    for (i, &v) in order.iter().enumerate().take(labeled) {
        split[v as usize] = if i < ntrain {
            SPLIT_TRAIN
        } else if i < ntrain + nval {
            SPLIT_VAL
        } else {
            SPLIT_TEST
        };
    }

    NodePayload { features, labels, split }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> FeatureParams {
        FeatureParams {
            feat_dim: 16,
            num_classes: 5,
            label_noise: 0.1,
            class_signal: 1.0,
            comm_signal: 0.4,
            noise: 0.5,
            train_frac: 0.5,
            val_frac: 0.1,
            labeled_frac: 0.9,
        }
    }

    #[test]
    fn splits_sum() {
        let gt: Vec<u32> = (0..1000u32).map(|v| v % 10).collect();
        let mut rng = Rng::new(4);
        let d = synthesize(&gt, 10, &params(), &mut rng);
        let count = |s: u8| d.split.iter().filter(|&&x| x == s).count();
        assert_eq!(count(SPLIT_TRAIN), 500);
        assert_eq!(count(SPLIT_VAL), 100);
        assert_eq!(count(SPLIT_TEST), 300);
        assert_eq!(count(SPLIT_NONE), 100);
    }

    #[test]
    fn labels_correlate_with_communities() {
        let gt: Vec<u32> = (0..2000u32).map(|v| v % 8).collect();
        let mut rng = Rng::new(5);
        let d = synthesize(&gt, 8, &params(), &mut rng);
        // majority label within a community should dominate
        let mut hit = 0;
        let mut tot = 0;
        for comm in 0..8u32 {
            let mut hist = [0usize; 5];
            for v in 0..2000 {
                if gt[v] == comm {
                    hist[d.labels[v] as usize] += 1;
                }
            }
            let maxc = *hist.iter().max().unwrap();
            let sum: usize = hist.iter().sum();
            hit += maxc;
            tot += sum;
        }
        let frac = hit as f64 / tot as f64;
        assert!(frac > 0.8, "community label purity {frac}");
    }

    #[test]
    fn features_separate_classes() {
        let gt: Vec<u32> = (0..500u32).map(|v| v % 5).collect();
        let mut rng = Rng::new(6);
        let d = synthesize(&gt, 5, &params(), &mut rng);
        // mean intra-class feature distance < inter-class distance
        let f = 16;
        let dist = |a: usize, b: usize| -> f64 {
            (0..f)
                .map(|j| {
                    (d.features[a * f + j] - d.features[b * f + j]) as f64
                })
                .map(|x| x * x)
                .sum::<f64>()
        };
        let mut rng2 = Rng::new(7);
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut ni = 0;
        let mut nx = 0;
        for _ in 0..2000 {
            let a = rng2.usize_below(500);
            let b = rng2.usize_below(500);
            if a == b {
                continue;
            }
            if d.labels[a] == d.labels[b] {
                intra += dist(a, b);
                ni += 1;
            } else {
                inter += dist(a, b);
                nx += 1;
            }
        }
        assert!(intra / ni as f64 + 0.5 < inter / nx as f64);
    }
}
