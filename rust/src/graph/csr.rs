//! Compressed-sparse-row topology for undirected graphs (both edge
//! directions stored). Node ids and offsets are u32 — the simulated
//! datasets top out at ~131k nodes / ~4M directed edges.

/// CSR adjacency. Invariants (checked by `validate`):
/// * `offsets.len() == n + 1`, monotonically non-decreasing
/// * `adj.len() == offsets[n]`
/// * neighbor lists are sorted and deduplicated, no self loops
/// * symmetric: `(u,v)` present iff `(v,u)` present
#[derive(Clone, Debug)]
pub struct Csr {
    pub n: usize,
    pub offsets: Vec<u32>,
    pub adj: Vec<u32>,
}

impl Csr {
    /// Build from an undirected edge list (u,v); self loops dropped,
    /// duplicates merged, both directions stored.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Csr {
        let mut deg = vec![0u32; n];
        for &(u, v) in edges {
            if u == v {
                continue;
            }
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut adj = vec![0u32; offsets[n] as usize];
        let mut cursor = offsets.clone();
        for &(u, v) in edges {
            if u == v {
                continue;
            }
            adj[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
            adj[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        // sort + dedup each list, then rebuild compactly
        let mut out_adj = Vec::with_capacity(adj.len());
        let mut out_off = vec![0u32; n + 1];
        for v in 0..n {
            let s = offsets[v] as usize;
            let e = offsets[v + 1] as usize;
            let list = &mut adj[s..e];
            list.sort_unstable();
            let mut prev = u32::MAX;
            for &x in list.iter() {
                if x != prev {
                    out_adj.push(x);
                    prev = x;
                }
            }
            out_off[v + 1] = out_adj.len() as u32;
        }
        Csr { n, offsets: out_off, adj: out_adj }
    }

    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.adj[s..e]
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Directed edge slots (2x undirected edge count).
    pub fn num_directed_edges(&self) -> usize {
        self.adj.len()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.offsets.len() != self.n + 1 {
            return Err("offsets length".into());
        }
        if self.offsets[0] != 0 || *self.offsets.last().unwrap() as usize != self.adj.len() {
            return Err("offset endpoints".into());
        }
        for v in 0..self.n {
            if self.offsets[v] > self.offsets[v + 1] {
                return Err(format!("non-monotone offsets at {v}"));
            }
            let list = self.neighbors(v as u32);
            for w in list.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("unsorted/dup adj at {v}"));
                }
            }
            for &u in list {
                if u as usize >= self.n {
                    return Err(format!("out-of-range neighbor {u}"));
                }
                if u as usize == v {
                    return Err(format!("self loop at {v}"));
                }
                if self.neighbors(u).binary_search(&(v as u32)).is_err() {
                    return Err(format!("asymmetric edge {v}->{u}"));
                }
            }
        }
        Ok(())
    }

    /// Relabel nodes: node `v` becomes `perm[v]`.
    pub fn permute(&self, perm: &[u32]) -> Csr {
        assert_eq!(perm.len(), self.n);
        let mut edges = Vec::with_capacity(self.adj.len() / 2);
        for v in 0..self.n as u32 {
            for &u in self.neighbors(v) {
                if v < u {
                    edges.push((perm[v as usize], perm[u as usize]));
                }
            }
        }
        Csr::from_edges(self.n, &edges)
    }

    /// Undirected edge iterator (u < v).
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.n as u32).flat_map(move |v| {
            self.neighbors(v)
                .iter()
                .filter(move |&&u| v < u)
                .map(move |&u| (v, u))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn small_graph() {
        let g = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3), (0, 1), (3, 3)]);
        g.validate().unwrap();
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.degree(3), 1);
        assert_eq!(g.num_directed_edges(), 6);
    }

    #[test]
    fn permute_preserves_structure() {
        let mut r = Rng::new(5);
        let n = 64;
        let mut edges = vec![];
        for _ in 0..300 {
            edges.push((r.below(n as u64) as u32, r.below(n as u64) as u32));
        }
        let g = Csr::from_edges(n, &edges);
        g.validate().unwrap();
        let mut perm: Vec<u32> = (0..n as u32).collect();
        r.shuffle(&mut perm);
        let p = g.permute(&perm);
        p.validate().unwrap();
        assert_eq!(p.num_directed_edges(), g.num_directed_edges());
        // degree multiset preserved
        let mut d1: Vec<usize> = (0..n as u32).map(|v| g.degree(v)).collect();
        let mut d2: Vec<usize> = (0..n as u32).map(|v| p.degree(v)).collect();
        d1.sort_unstable();
        d2.sort_unstable();
        assert_eq!(d1, d2);
        // specific edge mapping
        for (u, v) in g.edges() {
            assert!(p
                .neighbors(perm[u as usize])
                .binary_search(&perm[v as usize])
                .is_ok());
        }
    }

    /// Property: from_edges is idempotent under edge-list round-trip.
    #[test]
    fn roundtrip_random_graphs() {
        let mut r = Rng::new(77);
        for trial in 0..20 {
            let n = 8 + r.usize_below(64);
            let m = r.usize_below(4 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (r.below(n as u64) as u32, r.below(n as u64) as u32))
                .collect();
            let g = Csr::from_edges(n, &edges);
            g.validate().unwrap_or_else(|e| panic!("trial {trial}: {e}"));
            let back: Vec<(u32, u32)> = g.edges().collect();
            let g2 = Csr::from_edges(n, &back);
            assert_eq!(g.offsets, g2.offsets);
            assert_eq!(g.adj, g2.adj);
        }
    }
}
