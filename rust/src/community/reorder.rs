//! Node reordering strategies (paper §3, Figure 1).
//!
//! `community_order` is the RABBIT-style relabeling: nodes of the same
//! community receive consecutive ids (communities ordered by id, ties
//! by old id). `random_order` and `degree_order` are the baselines used
//! by the §3 inference study.
//!
//! All functions return a permutation `perm` with the convention
//! `new_id = perm[old_id]` (apply with `Dataset::permute`).

use crate::util::rng::Rng;

/// Community-sorted relabeling: consecutive ids within each community.
pub fn community_order(community: &[u32]) -> Vec<u32> {
    let n = community.len();
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&v| (community[v as usize], v));
    let mut perm = vec![0u32; n];
    for (new_id, &old_id) in order.iter().enumerate() {
        perm[old_id as usize] = new_id as u32;
    }
    perm
}

/// Uniform random relabeling (destroys locality; §3 baseline).
pub fn random_order(n: usize, rng: &mut Rng) -> Vec<u32> {
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    perm
}

/// Descending-degree relabeling (hub-sort; lightweight reordering
/// baseline from the graph-analytics literature).
pub fn degree_order(degrees: &[usize]) -> Vec<u32> {
    let n = degrees.len();
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(degrees[v as usize]), v));
    let mut perm = vec![0u32; n];
    for (new_id, &old_id) in order.iter().enumerate() {
        perm[old_id as usize] = new_id as u32;
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_permutation(p: &[u32]) -> bool {
        let mut seen = vec![false; p.len()];
        for &x in p {
            if x as usize >= p.len() || seen[x as usize] {
                return false;
            }
            seen[x as usize] = true;
        }
        true
    }

    #[test]
    fn community_order_groups() {
        let comm = vec![2, 0, 1, 0, 2, 1];
        let perm = community_order(&comm);
        assert!(is_permutation(&perm));
        // nodes 1,3 (comm 0) -> ids 0,1; nodes 2,5 (comm 1) -> 2,3; ...
        assert_eq!(perm[1], 0);
        assert_eq!(perm[3], 1);
        assert_eq!(perm[2], 2);
        assert_eq!(perm[5], 3);
        assert_eq!(perm[0], 4);
        assert_eq!(perm[4], 5);
    }

    #[test]
    fn random_order_is_permutation() {
        let mut rng = Rng::new(1);
        assert!(is_permutation(&random_order(1000, &mut rng)));
    }

    #[test]
    fn degree_order_descending() {
        let degs = vec![1usize, 5, 3, 5];
        let perm = degree_order(&degs);
        assert!(is_permutation(&perm));
        assert_eq!(perm[1], 0); // highest degree, lowest old id first
        assert_eq!(perm[3], 1);
        assert_eq!(perm[2], 2);
        assert_eq!(perm[0], 3);
    }
}
