//! Balanced graph partitioning for the ClusterGCN baseline (§6.3).
//!
//! ClusterGCN partitions with METIS; METIS is unavailable offline, so
//! we build balanced partitions by greedy bin-packing of Louvain
//! communities (largest-first into the lightest bin), splitting
//! communities larger than the target partition size. This preserves
//! the property ClusterGCN relies on — partitions are internally dense
//! — which is what its mini-batches are made of (DESIGN.md
//! §Substitutions).

use crate::util::rng::Rng;

/// Pack nodes into `num_parts` balanced partitions respecting community
/// boundaries where possible. Returns partition membership lists.
pub fn pack_partitions(
    community: &[u32],
    num_comms: usize,
    num_parts: usize,
    rng: &mut Rng,
) -> Vec<Vec<u32>> {
    assert!(num_parts >= 1);
    let n = community.len();
    let target = n.div_ceil(num_parts);

    // gather members per community
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); num_comms];
    for v in 0..n as u32 {
        members[community[v as usize] as usize].push(v);
    }

    // split oversized communities into target-sized chunks
    let mut blocks: Vec<Vec<u32>> = Vec::new();
    for mut m in members {
        if m.is_empty() {
            continue;
        }
        rng.shuffle(&mut m);
        while m.len() > target {
            let rest = m.split_off(target);
            blocks.push(std::mem::replace(&mut m, rest));
        }
        blocks.push(m);
    }

    // largest-first into lightest bin
    blocks.sort_by_key(|b| std::cmp::Reverse(b.len()));
    let mut parts: Vec<Vec<u32>> = vec![Vec::new(); num_parts];
    for b in blocks {
        let lightest = (0..num_parts)
            .min_by_key(|&i| parts[i].len())
            .unwrap();
        parts[lightest].extend_from_slice(&b);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_nodes_once() {
        let mut rng = Rng::new(2);
        let comm: Vec<u32> = (0..997u32).map(|v| v % 13).collect();
        let parts = pack_partitions(&comm, 13, 8, &mut rng);
        assert_eq!(parts.len(), 8);
        let mut all: Vec<u32> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..997u32).collect::<Vec<_>>());
    }

    #[test]
    fn partitions_balanced() {
        let mut rng = Rng::new(3);
        // one giant community + several small ones
        let mut comm = vec![0u32; 800];
        comm.extend((0..200u32).map(|v| 1 + v % 7));
        let parts = pack_partitions(&comm, 8, 4, &mut rng);
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert!(max - min <= 260, "unbalanced: {sizes:?}");
    }

    #[test]
    fn keeps_small_communities_together() {
        let mut rng = Rng::new(4);
        // 4 equal communities of 25, 4 partitions
        let comm: Vec<u32> = (0..100u32).map(|v| v / 25).collect();
        let parts = pack_partitions(&comm, 4, 4, &mut rng);
        for p in &parts {
            assert_eq!(p.len(), 25);
            let c0 = comm[p[0] as usize];
            assert!(p.iter().all(|&v| comm[v as usize] == c0));
        }
    }
}
