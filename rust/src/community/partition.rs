//! Balanced graph partitioning for the ClusterGCN baseline (§6.3).
//!
//! ClusterGCN partitions with METIS; METIS is unavailable offline, so
//! we build balanced partitions by greedy bin-packing of Louvain
//! communities (largest-first into the lightest bin), splitting
//! communities larger than the target partition size. This preserves
//! the property ClusterGCN relies on — partitions are internally dense
//! — which is what its mini-batches are made of (DESIGN.md
//! §Substitutions).

use crate::util::rng::Rng;

/// Pack nodes into `num_parts` balanced partitions respecting community
/// boundaries where possible. Returns partition membership lists.
pub fn pack_partitions(
    community: &[u32],
    num_comms: usize,
    num_parts: usize,
    rng: &mut Rng,
) -> Vec<Vec<u32>> {
    assert!(num_parts >= 1);
    let n = community.len();
    let target = n.div_ceil(num_parts);

    // gather members per community
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); num_comms];
    for v in 0..n as u32 {
        members[community[v as usize] as usize].push(v);
    }

    // split oversized communities into target-sized chunks
    let mut blocks: Vec<Vec<u32>> = Vec::new();
    for mut m in members {
        if m.is_empty() {
            continue;
        }
        rng.shuffle(&mut m);
        while m.len() > target {
            let rest = m.split_off(target);
            blocks.push(std::mem::replace(&mut m, rest));
        }
        blocks.push(m);
    }

    // largest-first into lightest bin
    blocks.sort_by_key(|b| std::cmp::Reverse(b.len()));
    let mut parts: Vec<Vec<u32>> = vec![Vec::new(); num_parts];
    for b in blocks {
        let lightest = (0..num_parts)
            .min_by_key(|&i| parts[i].len())
            .unwrap();
        parts[lightest].extend_from_slice(&b);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_nodes_once() {
        let mut rng = Rng::new(2);
        let comm: Vec<u32> = (0..997u32).map(|v| v % 13).collect();
        let parts = pack_partitions(&comm, 13, 8, &mut rng);
        assert_eq!(parts.len(), 8);
        let mut all: Vec<u32> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..997u32).collect::<Vec<_>>());
    }

    #[test]
    fn partitions_balanced() {
        let mut rng = Rng::new(3);
        // one giant community + several small ones
        let mut comm = vec![0u32; 800];
        comm.extend((0..200u32).map(|v| 1 + v % 7));
        let parts = pack_partitions(&comm, 8, 4, &mut rng);
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert!(max - min <= 260, "unbalanced: {sizes:?}");
    }

    /// Edge case: `num_comms` declares ids that no node carries (an
    /// empty community — exactly what incremental label maintenance
    /// can produce when a community drains). Empty blocks must be
    /// skipped, every real node placed exactly once.
    #[test]
    fn empty_communities_are_skipped() {
        let mut rng = Rng::new(9);
        // ids 0 and 3 populated; 1, 2, 4 declared but empty
        let comm: Vec<u32> =
            (0..60u32).map(|v| if v % 2 == 0 { 0 } else { 3 }).collect();
        let parts = pack_partitions(&comm, 5, 3, &mut rng);
        assert_eq!(parts.len(), 3);
        let mut all: Vec<u32> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..60u32).collect::<Vec<_>>());
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert!(*sizes.iter().max().unwrap() <= 30, "unbalanced: {sizes:?}");
    }

    /// Edge case: one giant community holding every node must be split
    /// across partitions (never one partition with everything) and
    /// still cover each node exactly once.
    #[test]
    fn single_giant_community_is_split_and_balanced() {
        let mut rng = Rng::new(10);
        let comm = vec![0u32; 1000];
        let parts = pack_partitions(&comm, 1, 4, &mut rng);
        assert_eq!(parts.len(), 4);
        let mut all: Vec<u32> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all.len(), 1000);
        all.dedup();
        assert_eq!(all.len(), 1000, "duplicated nodes across partitions");
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert_eq!(max, 250, "giant community must split evenly: {sizes:?}");
        assert_eq!(min, 250, "giant community must split evenly: {sizes:?}");
    }

    /// Degenerate but legal: a single partition swallows everything.
    #[test]
    fn one_partition_takes_all() {
        let mut rng = Rng::new(11);
        let comm: Vec<u32> = (0..40u32).map(|v| v % 4).collect();
        let parts = pack_partitions(&comm, 4, 1, &mut rng);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].len(), 40);
    }

    #[test]
    fn keeps_small_communities_together() {
        let mut rng = Rng::new(4);
        // 4 equal communities of 25, 4 partitions
        let comm: Vec<u32> = (0..100u32).map(|v| v / 25).collect();
        let parts = pack_partitions(&comm, 4, 4, &mut rng);
        for p in &parts {
            assert_eq!(p.len(), 25);
            let c0 = comm[p[0] as usize];
            assert!(p.iter().all(|&v| comm[v as usize] == c0));
        }
    }
}
