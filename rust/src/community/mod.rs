//! Community detection and structure-aware node reordering.
//!
//! The paper uses RABBIT (hierarchical community detection by
//! modularity maximization + just-in-time relabeling). RABBIT's source
//! is not available here, so we implement the same recipe: Louvain
//! modularity maximization ([`louvain`]) followed by community-sorted
//! relabeling ([`reorder`]). COMM-RAND only needs the community id of
//! each node (paper §6.5.3), which both produce.
//!
//! Everything downstream keys on this module's output being a pure
//! function of `(graph, seed)`: the shard plan
//! ([`crate::serve::ShardPlan`]) and the checkpoint fence fingerprint
//! ([`crate::ckpt::community_fingerprint`]) are derived directly from
//! the label array, and the streaming incremental maintainer
//! ([`crate::stream::CommunityMaintainer`]) refines these labels in
//! place between full re-detections — so determinism per seed is a
//! tested contract here, not a nicety. [`partition`] reuses the same
//! greedy largest-first packing for the ClusterGCN baseline.

pub mod louvain;
pub mod partition;
pub mod reorder;

pub use louvain::{louvain, LouvainResult};
pub use partition::pack_partitions;
pub use reorder::{community_order, degree_order, random_order};
