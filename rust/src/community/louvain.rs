//! Louvain community detection (modularity maximization).
//!
//! Standard two-phase algorithm [Blondel et al. 2008]:
//!  1. local-move phase — greedily move nodes to the neighboring
//!     community with the largest modularity gain until convergence;
//!  2. aggregation phase — collapse communities into super-nodes and
//!     recurse on the quotient graph.
//!
//! The final assignment is propagated back to leaf nodes and relabeled
//! to a contiguous `0..num_comms`, ordered by first appearance so that
//! community ids are stable across runs with the same seed.

use crate::graph::Csr;
use crate::util::rng::Rng;

/// Output of one Louvain run: the per-node assignment plus the
/// summary statistics the pipeline and tests key on.
pub struct LouvainResult {
    /// node -> community (contiguous ids).
    pub community: Vec<u32>,
    /// Number of distinct communities in `community` (ids are
    /// `0..num_comms`, every id populated).
    pub num_comms: usize,
    /// Final modularity of the assignment.
    pub modularity: f64,
    /// Number of aggregation levels executed.
    pub levels: usize,
}

/// Weighted graph used for aggregation levels.
struct WGraph {
    n: usize,
    offsets: Vec<u32>,
    adj: Vec<u32>,
    w: Vec<f64>,
    /// Self-loop weight per node (intra-community collapsed edges).
    self_w: Vec<f64>,
}

impl WGraph {
    fn from_csr(csr: &Csr) -> WGraph {
        WGraph {
            n: csr.n,
            offsets: csr.offsets.clone(),
            adj: csr.adj.clone(),
            w: vec![1.0; csr.adj.len()],
            self_w: vec![0.0; csr.n],
        }
    }

    fn weighted_degree(&self, v: usize) -> f64 {
        let s = self.offsets[v] as usize;
        let e = self.offsets[v + 1] as usize;
        self.w[s..e].iter().sum::<f64>() + self.self_w[v]
    }

    fn total_weight(&self) -> f64 {
        // 2m = sum of all directed weights + self loops counted twice
        self.w.iter().sum::<f64>() + 2.0 * self.self_w.iter().sum::<f64>()
    }
}

/// One local-move pass; returns (assignment, improved?).
fn local_move(
    g: &WGraph,
    rng: &mut Rng,
    min_gain: f64,
) -> (Vec<u32>, bool) {
    let n = g.n;
    let two_m = g.total_weight().max(1e-12);
    let mut comm: Vec<u32> = (0..n as u32).collect();
    // sum of weighted degrees per community
    let mut sigma_tot: Vec<f64> = (0..n).map(|v| g.weighted_degree(v)).collect();
    let k: Vec<f64> = sigma_tot.clone();

    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);

    // scratch: neighbor-community weights
    let mut nbr_w: Vec<f64> = vec![0.0; n];
    let mut touched: Vec<u32> = Vec::new();

    let mut improved_any = false;
    let mut moved = 1usize;
    let mut rounds = 0;
    while moved > 0 && rounds < 32 {
        moved = 0;
        rounds += 1;
        for &v in &order {
            let v = v as usize;
            let cv = comm[v] as usize;
            // accumulate edge weight to each neighboring community
            let s = g.offsets[v] as usize;
            let e = g.offsets[v + 1] as usize;
            for i in s..e {
                let u = g.adj[i] as usize;
                if u == v {
                    continue;
                }
                let cu = comm[u] as usize;
                if nbr_w[cu] == 0.0 {
                    touched.push(cu as u32);
                }
                nbr_w[cu] += g.w[i];
            }
            // remove v from its community
            sigma_tot[cv] -= k[v];
            let w_own = nbr_w[cv];
            // best destination
            let mut best_c = cv;
            let mut best_gain = w_own - sigma_tot[cv] * k[v] / two_m;
            for &cu in &touched {
                let cu = cu as usize;
                if cu == cv {
                    continue;
                }
                let gain = nbr_w[cu] - sigma_tot[cu] * k[v] / two_m;
                if gain > best_gain + min_gain {
                    best_gain = gain;
                    best_c = cu;
                }
            }
            sigma_tot[best_c] += k[v];
            if best_c != cv {
                comm[v] = best_c as u32;
                moved += 1;
                improved_any = true;
            }
            for &c in &touched {
                nbr_w[c as usize] = 0.0;
            }
            touched.clear();
        }
    }
    (comm, improved_any)
}

/// Aggregate: build the quotient graph over communities.
fn aggregate(g: &WGraph, comm: &[u32]) -> (WGraph, Vec<u32>) {
    // relabel communities to contiguous ids
    let mut remap = vec![u32::MAX; g.n];
    let mut next = 0u32;
    for &c in comm {
        if remap[c as usize] == u32::MAX {
            remap[c as usize] = next;
            next += 1;
        }
    }
    let nc = next as usize;
    let dense: Vec<u32> = comm.iter().map(|&c| remap[c as usize]).collect();

    // accumulate inter-community weights
    use std::collections::HashMap;
    let mut inter: Vec<HashMap<u32, f64>> = vec![HashMap::new(); nc];
    let mut self_w = vec![0.0f64; nc];
    for v in 0..g.n {
        let cv = dense[v];
        self_w[cv as usize] += g.self_w[v];
        let s = g.offsets[v] as usize;
        let e = g.offsets[v + 1] as usize;
        for i in s..e {
            let u = g.adj[i] as usize;
            let cu = dense[u];
            if cu == cv {
                // each intra edge appears twice in directed form
                self_w[cv as usize] += g.w[i] / 2.0;
            } else {
                *inter[cv as usize].entry(cu).or_insert(0.0) += g.w[i];
            }
        }
    }
    let mut offsets = vec![0u32; nc + 1];
    for c in 0..nc {
        offsets[c + 1] = offsets[c] + inter[c].len() as u32;
    }
    let mut adj = vec![0u32; offsets[nc] as usize];
    let mut w = vec![0f64; offsets[nc] as usize];
    for c in 0..nc {
        let mut items: Vec<(u32, f64)> =
            inter[c].iter().map(|(&k, &v)| (k, v)).collect();
        items.sort_unstable_by_key(|x| x.0);
        let s = offsets[c] as usize;
        for (j, (u, wt)) in items.into_iter().enumerate() {
            adj[s + j] = u;
            w[s + j] = wt;
        }
    }
    (
        WGraph { n: nc, offsets, adj, w, self_w },
        dense,
    )
}

fn wgraph_modularity(g: &WGraph, comm: &[u32]) -> f64 {
    let two_m = g.total_weight().max(1e-12);
    let nc = comm.iter().map(|&c| c as usize + 1).max().unwrap_or(0);
    let mut intra = vec![0f64; nc];
    let mut deg = vec![0f64; nc];
    for v in 0..g.n {
        let cv = comm[v] as usize;
        deg[cv] += g.weighted_degree(v);
        intra[cv] += 2.0 * g.self_w[v];
        let s = g.offsets[v] as usize;
        let e = g.offsets[v + 1] as usize;
        for i in s..e {
            if comm[g.adj[i] as usize] as usize == cv {
                intra[cv] += g.w[i];
            }
        }
    }
    (0..nc)
        .map(|c| intra[c] / two_m - (deg[c] / two_m).powi(2))
        .sum()
}

/// Run Louvain to convergence. `seed` fixes the node visit order.
pub fn louvain(csr: &Csr, seed: u64) -> LouvainResult {
    louvain_capped(csr, seed, usize::MAX)
}

/// Like [`louvain`], but selects the deepest hierarchy level whose
/// mean community size stays at or below `max_mean_size`.
///
/// RABBIT exploits the community *hierarchy*: cache-friendly batching
/// wants communities whose feature footprint is cache-scale, not the
/// modularity-maximal top level (which on large graphs merges into a
/// handful of giant communities). The mini-batching pipeline uses
/// `max_mean_size ≈ 2x batch size`.
pub fn louvain_capped(
    csr: &Csr,
    seed: u64,
    max_mean_size: usize,
) -> LouvainResult {
    let mut rng = Rng::new(seed);
    let mut g = WGraph::from_csr(csr);
    // leaf -> current-level community
    let mut assign: Vec<u32> = (0..csr.n as u32).collect();
    let mut levels = 0;
    // leaf assignment snapshot after each level
    let mut snapshots: Vec<Vec<u32>> = Vec::new();

    loop {
        let (comm, improved) = local_move(&g, &mut rng, 1e-9);
        if !improved {
            break;
        }
        let (agg, dense) = aggregate(&g, &comm);
        // propagate to leaves
        for a in assign.iter_mut() {
            *a = dense[*a as usize];
        }
        snapshots.push(assign.clone());
        g = agg;
        levels += 1;
        if g.n <= 1 {
            break;
        }
    }

    // pick the deepest level whose mean community size fits the cap,
    // falling back to the finest level when even it is too coarse
    let mut picked = false;
    for snap in snapshots.iter().rev() {
        let nc = snap.iter().map(|&c| c as usize + 1).max().unwrap_or(1);
        let mean = csr.n as f64 / nc as f64;
        if mean <= max_mean_size as f64 {
            assign = snap.clone();
            picked = true;
            break;
        }
    }
    if !picked {
        if let Some(finest) = snapshots.first() {
            assign = finest.clone();
        }
    }

    // contiguous relabel by first appearance
    let max_c = assign.iter().map(|&c| c as usize + 1).max().unwrap_or(0);
    let mut remap = vec![u32::MAX; max_c];
    let mut next = 0u32;
    for &c in &assign {
        if remap[c as usize] == u32::MAX {
            remap[c as usize] = next;
            next += 1;
        }
    }
    let community: Vec<u32> = assign.iter().map(|&c| remap[c as usize]).collect();
    let q = crate::graph::stats::modularity(csr, &community);
    LouvainResult {
        community,
        num_comms: next as usize,
        modularity: q,
        levels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_sbm, SbmParams};

    #[test]
    fn two_cliques() {
        let g = Csr::from_edges(
            8,
            &[
                (0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3), // K4
                (4, 5), (4, 6), (4, 7), (5, 6), (5, 7), (6, 7), // K4
                (3, 4), // bridge
            ],
        );
        let r = louvain(&g, 1);
        assert_eq!(r.num_comms, 2);
        assert_eq!(r.community[0], r.community[1]);
        assert_eq!(r.community[0], r.community[3]);
        assert_eq!(r.community[4], r.community[7]);
        assert_ne!(r.community[0], r.community[4]);
        assert!(r.modularity > 0.3);
    }

    #[test]
    fn recovers_sbm_blocks() {
        let mut rng = Rng::new(42);
        let g = generate_sbm(
            &SbmParams {
                n: 1500,
                num_comms: 10,
                avg_deg: 16.0,
                p_intra: 0.9,
                deg_alpha: 2.3,
                size_alpha: 1.2,
            },
            &mut rng,
        );
        let r = louvain(&g.csr, 7);
        assert!(r.modularity > 0.5, "Q={}", r.modularity);
        // detected communities should align with ground truth:
        // measure purity = fraction of nodes whose detected community's
        // majority gt block matches their own gt block
        let nc = r.num_comms;
        let ngt = 10;
        let mut table = vec![vec![0usize; ngt]; nc];
        for v in 0..g.csr.n {
            table[r.community[v] as usize][g.gt_community[v] as usize] += 1;
        }
        let mut pure = 0usize;
        for row in &table {
            pure += row.iter().max().unwrap();
        }
        let purity = pure as f64 / g.csr.n as f64;
        assert!(purity > 0.8, "purity={purity}, nc={nc}");
    }

    #[test]
    fn assignment_is_contiguous_and_total() {
        let mut rng = Rng::new(3);
        let g = generate_sbm(
            &SbmParams {
                n: 400,
                num_comms: 6,
                avg_deg: 10.0,
                p_intra: 0.85,
                deg_alpha: 2.1,
                size_alpha: 1.5,
            },
            &mut rng,
        );
        let r = louvain(&g.csr, 5);
        assert_eq!(r.community.len(), 400);
        let mut seen = vec![false; r.num_comms];
        for &c in &r.community {
            assert!((c as usize) < r.num_comms);
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&x| x), "community ids not contiguous");
    }

    #[test]
    fn wgraph_modularity_matches_csr_modularity() {
        // on the level-0 weighted graph (unit weights, no self loops),
        // the internal modularity must equal graph::stats::modularity
        let g = Csr::from_edges(
            8,
            &[(0, 1), (0, 2), (1, 2), (3, 4), (4, 5), (3, 5), (2, 3), (6, 7)],
        );
        let wg = WGraph::from_csr(&g);
        let comm = vec![0u32, 0, 0, 1, 1, 1, 2, 2];
        let a = wgraph_modularity(&wg, &comm);
        let b = crate::graph::stats::modularity(&g, &comm);
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }

    #[test]
    fn capped_levels_are_finer() {
        let mut rng = Rng::new(8);
        let g = generate_sbm(
            &SbmParams {
                n: 2000,
                num_comms: 24,
                avg_deg: 14.0,
                p_intra: 0.9,
                deg_alpha: 2.2,
                size_alpha: 1.3,
            },
            &mut rng,
        );
        let fine = louvain_capped(&g.csr, 3, 64);
        let coarse = louvain(&g.csr, 3);
        assert!(fine.num_comms >= coarse.num_comms);
        // still a valid total contiguous assignment
        let mut seen = vec![false; fine.num_comms];
        for &c in &fine.community {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn deterministic_for_seed() {
        let g = Csr::from_edges(
            10,
            &[(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3), (6, 7),
              (7, 8), (8, 9), (9, 6), (2, 3), (5, 6)],
        );
        let a = louvain(&g, 11);
        let b = louvain(&g, 11);
        assert_eq!(a.community, b.community);
    }

    /// Determinism at realistic scale: same seed ⇒ bitwise-identical
    /// labels (and identical summary stats) for both the plain and the
    /// size-capped variant — the property the shard plan, the
    /// checkpoint fence fingerprint and the incremental maintainer all
    /// build on. A different seed is allowed to differ, but must still
    /// produce a valid contiguous assignment.
    #[test]
    fn sbm_runs_are_bitwise_identical_per_seed() {
        let mut rng = Rng::new(21);
        let g = generate_sbm(
            &SbmParams {
                n: 1200,
                num_comms: 12,
                avg_deg: 12.0,
                p_intra: 0.88,
                deg_alpha: 2.2,
                size_alpha: 1.3,
            },
            &mut rng,
        );
        for seed in [0u64, 7, 1234] {
            let a = louvain(&g.csr, seed);
            let b = louvain(&g.csr, seed);
            assert_eq!(a.community, b.community, "seed {seed}");
            assert_eq!(a.num_comms, b.num_comms, "seed {seed}");
            assert_eq!(a.levels, b.levels, "seed {seed}");
            assert!((a.modularity - b.modularity).abs() < 1e-15);
            let ac = louvain_capped(&g.csr, seed, 96);
            let bc = louvain_capped(&g.csr, seed, 96);
            assert_eq!(ac.community, bc.community, "capped, seed {seed}");
            assert_eq!(ac.num_comms, bc.num_comms, "capped, seed {seed}");
        }
        // another seed must still be a total contiguous assignment
        let other = louvain(&g.csr, 999);
        let mut seen = vec![false; other.num_comms];
        for &c in &other.community {
            assert!((c as usize) < other.num_comms);
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }
}
