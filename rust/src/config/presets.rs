//! Dataset presets — the simulated stand-ins for the paper's four
//! benchmarks. Dimensions (feature width, class count, split
//! fractions) follow Table 2 of the paper; node/edge scale is reduced
//! to fit a CPU-only testbed and the class/feature dims of the largest
//! graphs are trimmed accordingly (documented in DESIGN.md
//! §Substitutions). **These must stay in sync with
//! `python/compile/specs.py`** — the artifact manifest is
//! shape-checked at load time, so a drift fails fast.
//!
//! `l2_base` scales the modelled A100 L2 so that the ratio of the
//! dataset's feature footprint to the cache matches the *real*
//! dataset-vs-40MB pairing (e.g. reddit's 561MB/40MB ≈ 14x ⇒ the 8MB
//! sim footprint gets a ~0.6MB modelled L2). Without this, the scaled
//! datasets would fit entirely in the modelled cache and every policy
//! would look identical — see DESIGN.md §Cache-Model.

use crate::graph::features::FeatureParams;
use crate::graph::gen::SbmParams;

/// One synthetic stand-in dataset: the SBM graph recipe, the feature
/// generator parameters, and the cache-model scaling that together
/// reproduce one of the paper's benchmarks at testbed scale.
#[derive(Clone, Debug)]
pub struct DatasetPreset {
    /// Preset name as accepted by the CLI (`tiny`, `reddit_sim`, …).
    pub name: &'static str,
    /// Artifact base name for the GraphSAGE model on this dataset.
    pub artifact: &'static str,
    /// Stochastic-block-model graph recipe (size, degree, mixing).
    pub sbm: SbmParams,
    /// Feature/label generator parameters (dims, signal, splits).
    pub feat: FeatureParams,
    /// Seed used by `gen-data` (fixed so all experiments share graphs).
    pub gen_seed: u64,
    /// Features stay host-side and are staged per batch (UVA-style)?
    pub staged: bool,
    /// Modelled-L2 scale (fraction of 40MB) matching the real
    /// footprint:cache ratio.
    pub l2_base: f64,
}

/// Every preset name `preset` resolves, in gen-data order.
pub fn preset_names() -> &'static [&'static str] {
    &["reddit_sim", "igb_sim", "products_sim", "papers_sim", "tiny"]
}

/// Resolve a preset by CLI name; `None` for unknown names.
pub fn preset(name: &str) -> Option<DatasetPreset> {
    let p = match name {
        // reddit: 233k nodes / 492 avg-deg / 41 cls / 602 feat / 66-10-24
        // sim   : 16k nodes / 40 avg-deg / 41 cls / 128 feat / same split
        // footprint 8.4MB, real ratio 561MB/40MB=14 -> L2 0.6MB = 0.015
        "reddit_sim" => DatasetPreset {
            name: "reddit_sim",
            artifact: "reddit_sim",
            sbm: SbmParams {
                n: 16384,
                num_comms: 96,
                avg_deg: 40.0,
                p_intra: 0.88,
                deg_alpha: 2.1,
                size_alpha: 1.3,
            },
            feat: FeatureParams {
                feat_dim: 128,
                num_classes: 41,
                label_noise: 0.35,
                class_signal: 0.6,
                comm_signal: 0.4,
                noise: 1.6,
                train_frac: 0.66,
                val_frac: 0.10,
                labeled_frac: 1.0,
            },
            gen_seed: 0xEDD17,
            staged: false,
            // nominal cache ≈ the baseline's per-batch working set
            // (~5MB): at full capacity the baseline still reuses its
            // own batch (fwd+bwd passes), and shrinking the cache
            // (Fig. 10) strips that reuse away first — the regime the
            // paper's MIG study sweeps.
            l2_base: 0.25,
        },
        // igb-small: 1M nodes / 13 deg / 19 cls / 1024 feat / 60-20-20
        // sim      : 32k nodes / 13 deg / 19 cls / 128 feat / same split
        // footprint 16.8MB, real ratio 4.1GB/40MB=102 -> L2 0.16MB
        "igb_sim" => DatasetPreset {
            name: "igb_sim",
            artifact: "igb_sim",
            sbm: SbmParams {
                n: 32768,
                num_comms: 160,
                avg_deg: 13.0,
                p_intra: 0.85,
                deg_alpha: 2.2,
                size_alpha: 1.3,
            },
            feat: FeatureParams {
                feat_dim: 128,
                num_classes: 19,
                label_noise: 0.40,
                class_signal: 0.6,
                comm_signal: 0.4,
                noise: 1.7,
                train_frac: 0.60,
                val_frac: 0.20,
                labeled_frac: 1.0,
            },
            gen_seed: 0x16B,
            staged: false,
            l2_base: 0.004,
        },
        // ogbn-products: 2.4M nodes / 50 deg / 47 cls / 100 feat / 8-2-90
        // sim          : 32k nodes / 32 deg / 47 cls / 100 feat / same
        // footprint 13.1MB, real ratio 980MB/40MB=24.5 -> L2 0.53MB
        "products_sim" => DatasetPreset {
            name: "products_sim",
            artifact: "products_sim",
            sbm: SbmParams {
                n: 32768,
                num_comms: 160,
                avg_deg: 32.0,
                p_intra: 0.88,
                deg_alpha: 2.1,
                size_alpha: 1.3,
            },
            feat: FeatureParams {
                feat_dim: 100,
                num_classes: 47,
                label_noise: 0.35,
                class_signal: 0.6,
                comm_signal: 0.4,
                noise: 1.6,
                train_frac: 0.08,
                val_frac: 0.02,
                labeled_frac: 1.0,
            },
            gen_seed: 0x9120D,
            staged: false,
            l2_base: 0.013,
        },
        // ogbn-papers100M: 111M nodes / 29 deg / 172 cls / 128 feat /
        //                  1.1-0.1 split; features exceed GPU memory →
        //                  UVA. sim: 64k nodes, staged features, 64 cls.
        "papers_sim" => DatasetPreset {
            name: "papers_sim",
            artifact: "papers_sim",
            sbm: SbmParams {
                n: 65536,
                num_comms: 256,
                avg_deg: 15.0,
                p_intra: 0.85,
                deg_alpha: 2.2,
                size_alpha: 1.3,
            },
            feat: FeatureParams {
                feat_dim: 128,
                num_classes: 64,
                label_noise: 0.35,
                class_signal: 0.6,
                comm_signal: 0.4,
                noise: 1.6,
                train_frac: 0.011,
                val_frac: 0.001,
                labeled_frac: 0.014,
            },
            gen_seed: 0xBA9E5,
            staged: true,
            l2_base: 0.002,
        },
        // tiny: integration-test dataset for the `tiny*` artifacts.
        // footprint 256KB -> L2 64KB = 0.0016 (keeps misses non-trivial)
        "tiny" => DatasetPreset {
            name: "tiny",
            artifact: "tiny",
            sbm: SbmParams {
                n: 2048,
                num_comms: 16,
                avg_deg: 12.0,
                p_intra: 0.85,
                deg_alpha: 2.1,
                size_alpha: 1.3,
            },
            feat: FeatureParams {
                feat_dim: 32,
                num_classes: 7,
                label_noise: 0.30,
                class_signal: 0.7,
                comm_signal: 0.4,
                noise: 1.2,
                train_frac: 0.50,
                val_frac: 0.15,
                labeled_frac: 0.9,
            },
            gen_seed: 0x717,
            staged: false,
            l2_base: 0.0016,
        },
        _ => return None,
    };
    Some(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_resolve() {
        for name in preset_names() {
            let p = preset(name).unwrap();
            assert_eq!(p.name, *name);
            assert!(p.feat.train_frac + p.feat.val_frac <= p.feat.labeled_frac + 1e-9);
            assert!(p.l2_base > 0.0 && p.l2_base <= 1.0);
        }
        assert!(preset("nope").is_none());
    }
}
