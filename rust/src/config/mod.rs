//! Configuration: dataset presets (matched 1:1 with
//! `python/compile/specs.py`), mini-batching policy knobs, and training
//! hyper-parameters.

pub mod presets;

pub use presets::{preset, preset_names, DatasetPreset};

use crate::sampler::roots::RootPolicy;

/// The two COMM-RAND knobs (paper §4) plus the baseline policies.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchPolicy {
    /// Root-node partitioning scheme (Table 1).
    pub roots: RootPolicy,
    /// Intra-community sampling probability p ∈ [0.5, 1.0] (§4.2);
    /// 0.5 = uniform, 1.0 = only same-community neighbors when present.
    pub p_intra: f64,
}

impl BatchPolicy {
    /// The paper's baseline: fully random roots, uniform (p = 0.5)
    /// neighbor sampling — plain DGL-style mini-batching.
    pub fn baseline() -> Self {
        BatchPolicy { roots: RootPolicy::Rand, p_intra: 0.5 }
    }

    /// Stable label used in result tables and artifact file names,
    /// e.g. `rand+p0.50`.
    pub fn label(&self) -> String {
        format!("{}+p{:.2}", self.roots.label(), self.p_intra)
    }
}

/// Hyper-parameters of a training run (defaults mirror the paper's DGL
/// reference configuration, scaled where noted in DESIGN.md).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Mini-batch size in root nodes (paper: 256).
    pub batch_size: usize,
    /// Adam learning rate (paper: 1e-3).
    pub lr: f32,
    /// Hard epoch cap; early stopping usually ends the run first.
    pub max_epochs: usize,
    /// Early stopping: stop when val loss hasn't improved for this many
    /// epochs (paper: 6).
    pub patience: usize,
    /// ReduceLROnPlateau patience (paper: 3) and factor (torch default 0.1).
    pub lr_patience: usize,
    /// Multiplier applied to the learning rate on plateau.
    pub lr_factor: f32,
    /// Run seed: root shuffling, neighbor sampling, weight init.
    pub seed: u64,
    /// Cap on batches per epoch (None = full epoch); used by quick tests.
    pub max_batches: Option<usize>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            batch_size: 256,
            lr: 1e-3,
            max_epochs: 60,
            patience: 6,
            lr_patience: 3,
            lr_factor: 0.1,
            seed: 0,
            max_batches: None,
        }
    }
}
