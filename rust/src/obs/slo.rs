//! Declarative SLOs with multi-window burn-rate alerting.
//!
//! An SLO here is "at most a `budget` fraction of traffic may be bad",
//! where *bad* is per-target: slower than the p99 latency target, shed,
//! errored, served stale, or mispredicted. Each evaluation tick (one
//! sealed window of the health series, [`crate::obs::series`])
//! computes a **burn rate** per target — the observed bad fraction
//! divided by the budget, so `1.0` means the error budget is being
//! consumed exactly as fast as allowed — over two lookbacks:
//!
//! * a **fast** window (last `fast_windows` windows) that reacts
//!   quickly, and
//! * a **slow** window (last `slow_windows` windows) that filters
//!   one-window blips.
//!
//! The alert **fires** only when *both* burns are at or above
//! `burn_threshold` (the classic SRE multi-window rule: fast alone is
//! jumpy, slow alone is sluggish), and **clears** with hysteresis:
//! both burns must stay below `clear_ratio × burn_threshold` for
//! `clear_evals` consecutive ticks. Between those bands the alert
//! holds its state, so a burn oscillating around the threshold cannot
//! flap.
//!
//! Transitions are recorded as trace instants
//! ([`crate::obs::span::EventKind::SloFire`] / `SloClear`), exported
//! in the Prometheus snapshot ([`SloRuntime::export_prom`]), surfaced
//! in `ServeReport.health{}`, and the first fire can trigger a flight
//! recorder dump ([`crate::obs::flight`]).

use anyhow::{bail, Result};

use super::export::PromText;
use super::series::{Window, WindowedSeries};

/// What a single SLO target constrains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloKind {
    /// p99 request latency at most the target (threshold in µs; the
    /// implied budget is the 1 % of requests a p99 may exceed).
    LatencyP99,
    /// Shed fraction of offered load at most the target.
    ShedRate,
    /// Executor-error fraction of completions at most the target.
    ErrorRate,
    /// Stale fraction of cache lookups at most the target.
    StaleRate,
    /// Top-1 accuracy at least the target (a floor, not a cap).
    AccuracyFloor,
}

impl SloKind {
    /// Stable label used in traces, Prometheus and the report.
    pub fn label(self) -> &'static str {
        match self {
            SloKind::LatencyP99 => "p99_latency",
            SloKind::ShedRate => "shed_rate",
            SloKind::ErrorRate => "error_rate",
            SloKind::StaleRate => "stale_rate",
            SloKind::AccuracyFloor => "accuracy",
        }
    }
}

/// One target: a kind plus its threshold (µs for
/// [`SloKind::LatencyP99`], a fraction in `[0, 1]` for everything
/// else).
#[derive(Clone, Copy, Debug)]
pub struct SloTarget {
    /// What is constrained.
    pub kind: SloKind,
    /// The constraint value (see [`SloTarget::kind`] for units).
    pub threshold: f64,
}

impl SloTarget {
    /// The error budget: the bad fraction at which the burn rate reads
    /// exactly 1.0.
    fn budget(&self) -> f64 {
        let b = match self.kind {
            // "p99 <= target" tolerates 1% of requests over target
            SloKind::LatencyP99 => 0.01,
            SloKind::ShedRate | SloKind::ErrorRate | SloKind::StaleRate => {
                self.threshold
            }
            SloKind::AccuracyFloor => 1.0 - self.threshold,
        };
        b.max(1e-9)
    }

    /// Observed bad fraction over `w`, or `None` when the window holds
    /// no evidence for this target (no traffic / nothing evaluated) —
    /// absence of data never burns budget.
    fn bad_fraction(&self, w: &Window) -> Option<f64> {
        match self.kind {
            SloKind::LatencyP99 => {
                if w.lat.is_empty() {
                    return None;
                }
                Some(
                    w.lat.count_above(self.threshold as u64) as f64
                        / w.lat.count() as f64,
                )
            }
            SloKind::ShedRate => {
                let offered = w.completed + w.shed;
                (offered > 0).then(|| w.shed as f64 / offered as f64)
            }
            SloKind::ErrorRate => (w.completed > 0)
                .then(|| w.errors as f64 / w.completed as f64),
            SloKind::StaleRate => {
                let lookups = w.cache_hits + w.cache_misses + w.stale_hits;
                (lookups > 0).then(|| w.stale_hits as f64 / lookups as f64)
            }
            SloKind::AccuracyFloor => w.accuracy().map(|a| 1.0 - a),
        }
    }

    /// Burn rate over `w`: bad fraction ÷ budget (0 with no evidence).
    pub fn burn(&self, w: &Window) -> f64 {
        self.bad_fraction(w).map(|b| b / self.budget()).unwrap_or(0.0)
    }
}

/// The declarative SLO set plus the alerting policy, parsed from the
/// `slo=` knob.
#[derive(Clone, Debug)]
pub struct SloSpec {
    /// The targets under watch.
    pub targets: Vec<SloTarget>,
    /// Fast lookback, in windows (reactivity).
    pub fast_windows: usize,
    /// Slow lookback, in windows (blip filtering).
    pub slow_windows: usize,
    /// Both burns must reach this to fire (1.0 = budget consumed
    /// exactly as fast as allowed).
    pub burn_threshold: f64,
    /// Clearing band: both burns must drop below
    /// `clear_ratio × burn_threshold` to count as calm.
    pub clear_ratio: f64,
    /// Consecutive calm evaluations required to clear (hysteresis).
    pub clear_evals: usize,
}

impl SloSpec {
    /// The `slo=default` policy: p99 ≤ 50 ms, shed ≤ 5 %, errors
    /// ≤ 2 %; fast 1 / slow 6 windows, fire at burn ≥ 1, clear after
    /// 3 calm ticks below half the threshold. Stale-rate and accuracy
    /// targets are opt-in (they depend on churn/executor setup).
    pub fn default_spec() -> SloSpec {
        SloSpec {
            targets: vec![
                SloTarget { kind: SloKind::LatencyP99, threshold: 50_000.0 },
                SloTarget { kind: SloKind::ShedRate, threshold: 0.05 },
                SloTarget { kind: SloKind::ErrorRate, threshold: 0.02 },
            ],
            fast_windows: 1,
            slow_windows: 6,
            burn_threshold: 1.0,
            clear_ratio: 0.5,
            clear_evals: 3,
        }
    }

    /// Parse the `slo=` knob: `default`, or a comma-separated list of
    /// `key=value` pairs replacing the default targets — `p99_ms=`,
    /// `shed=`, `err=`, `stale=`, `acc=` (targets; only the named ones
    /// are installed) and `fast=`, `slow=`, `burn=`, `clear_ratio=`,
    /// `clear=` (policy). Example:
    /// `slo=p99_ms=20,shed=0.02,slow=8`.
    pub fn parse(spec: &str) -> Result<SloSpec> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "default" {
            return Ok(SloSpec::default_spec());
        }
        let mut out = SloSpec { targets: Vec::new(), ..SloSpec::default_spec() };
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("slo: {part:?} is not k=v"))?;
            let fv: f64 = v
                .parse()
                .map_err(|_| anyhow::anyhow!("slo: bad value in {part:?}"))?;
            let target = |kind, threshold| SloTarget { kind, threshold };
            match k {
                "p99_ms" => out
                    .targets
                    .push(target(SloKind::LatencyP99, fv * 1_000.0)),
                "shed" => out.targets.push(target(SloKind::ShedRate, fv)),
                "err" => out.targets.push(target(SloKind::ErrorRate, fv)),
                "stale" => out.targets.push(target(SloKind::StaleRate, fv)),
                "acc" => out.targets.push(target(SloKind::AccuracyFloor, fv)),
                "fast" => out.fast_windows = fv as usize,
                "slow" => out.slow_windows = fv as usize,
                "burn" => out.burn_threshold = fv,
                "clear_ratio" => out.clear_ratio = fv,
                "clear" => out.clear_evals = fv as usize,
                other => bail!(
                    "slo: unknown key {other:?} (targets: p99_ms shed err \
                     stale acc; policy: fast slow burn clear_ratio clear)"
                ),
            }
        }
        if out.targets.is_empty() {
            out.targets = SloSpec::default_spec().targets;
        }
        out.validate()?;
        Ok(out)
    }

    fn validate(&self) -> Result<()> {
        if self.fast_windows == 0 || self.slow_windows < self.fast_windows {
            bail!(
                "slo: need 1 <= fast ({}) <= slow ({})",
                self.fast_windows,
                self.slow_windows
            );
        }
        if self.burn_threshold <= 0.0 {
            bail!("slo: burn threshold must be > 0");
        }
        if !(0.0..=1.0).contains(&self.clear_ratio) {
            bail!("slo: clear_ratio must be in [0, 1]");
        }
        if self.clear_evals == 0 {
            bail!("slo: clear must be >= 1");
        }
        for t in &self.targets {
            let ok = match t.kind {
                SloKind::LatencyP99 => t.threshold > 0.0,
                _ => (0.0..=1.0).contains(&t.threshold),
            };
            if !ok {
                bail!(
                    "slo: {} threshold {} out of range",
                    t.kind.label(),
                    t.threshold
                );
            }
        }
        Ok(())
    }

    /// Human-readable one-liner (CLI / report headers).
    pub fn label(&self) -> String {
        let targets: Vec<String> = self
            .targets
            .iter()
            .map(|t| match t.kind {
                SloKind::LatencyP99 => {
                    format!("p99<={:.0}ms", t.threshold / 1_000.0)
                }
                _ => format!("{}<={:.3}", t.kind.label(), t.threshold),
            })
            .collect();
        format!(
            "{} [fast={} slow={} burn>={}]",
            targets.join(" "),
            self.fast_windows,
            self.slow_windows,
            self.burn_threshold
        )
    }
}

/// Live alert state for one target.
#[derive(Clone, Debug)]
pub struct AlertState {
    /// The target under watch.
    pub target: SloTarget,
    /// Currently firing?
    pub firing: bool,
    /// Fire transitions so far.
    pub fired: u64,
    /// Clear transitions so far.
    pub cleared: u64,
    /// First tick (µs) the **fast** burn crossed the threshold — the
    /// moment the breach became observable; the fire-delay the `exp
    /// health` gate bounds is `first_fire_us - first_breach_us`.
    pub first_breach_us: Option<u64>,
    /// First tick (µs) the alert fired.
    pub first_fire_us: Option<u64>,
    /// Most recent fast burn.
    pub burn_fast: f64,
    /// Most recent slow burn.
    pub burn_slow: f64,
    calm: usize,
}

/// One recorded fire/clear transition.
#[derive(Clone, Debug)]
pub struct Transition {
    /// Index of the target in the spec (the trace instant's `a`).
    pub index: usize,
    /// The target's stable label.
    pub slo: &'static str,
    /// `true` = fired, `false` = cleared.
    pub fired: bool,
    /// Tick timestamp, µs on the run clock.
    pub ts_us: u64,
    /// Fast burn at the transition.
    pub burn_fast: f64,
    /// Slow burn at the transition.
    pub burn_slow: f64,
}

/// The evaluator: owns per-target [`AlertState`] and the transition
/// log. Drive it with one [`SloRuntime::evaluate`] call per sealed
/// window.
#[derive(Debug)]
pub struct SloRuntime {
    spec: SloSpec,
    states: Vec<AlertState>,
    transitions: Vec<Transition>,
}

impl SloRuntime {
    /// Evaluator for `spec` with all alerts quiet.
    pub fn new(spec: SloSpec) -> SloRuntime {
        let states = spec
            .targets
            .iter()
            .map(|&target| AlertState {
                target,
                firing: false,
                fired: 0,
                cleared: 0,
                first_breach_us: None,
                first_fire_us: None,
                burn_fast: 0.0,
                burn_slow: 0.0,
                calm: 0,
            })
            .collect();
        SloRuntime { spec, states, transitions: Vec::new() }
    }

    /// The spec being evaluated.
    pub fn spec(&self) -> &SloSpec {
        &self.spec
    }

    /// Per-target alert states.
    pub fn states(&self) -> &[AlertState] {
        &self.states
    }

    /// Every transition recorded so far, oldest first.
    pub fn transitions(&self) -> &[Transition] {
        &self.transitions
    }

    /// Any alert currently firing?
    pub fn any_firing(&self) -> bool {
        self.states.iter().any(|s| s.firing)
    }

    /// One evaluation tick against the series' current windows.
    /// Returns the transitions that happened *this* tick (also
    /// appended to the log) so the caller can emit trace events and
    /// trigger the flight recorder.
    pub fn evaluate(
        &mut self,
        series: &WindowedSeries,
        now_us: u64,
    ) -> Vec<Transition> {
        let (Some(fast), Some(slow)) = (
            series.merged_last(self.spec.fast_windows),
            series.merged_last(self.spec.slow_windows),
        ) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for (i, st) in self.states.iter_mut().enumerate() {
            st.burn_fast = st.target.burn(&fast);
            st.burn_slow = st.target.burn(&slow);
            let hot = self.spec.burn_threshold;
            let cold = self.spec.burn_threshold * self.spec.clear_ratio;
            if st.burn_fast >= hot && st.first_breach_us.is_none() {
                st.first_breach_us = Some(now_us);
            }
            let transition = if !st.firing {
                if st.burn_fast >= hot && st.burn_slow >= hot {
                    st.firing = true;
                    st.fired += 1;
                    st.calm = 0;
                    st.first_fire_us.get_or_insert(now_us);
                    true
                } else {
                    false
                }
            } else {
                if st.burn_fast < cold && st.burn_slow < cold {
                    st.calm += 1;
                } else {
                    st.calm = 0;
                }
                if st.calm >= self.spec.clear_evals {
                    st.firing = false;
                    st.cleared += 1;
                    st.calm = 0;
                    true
                } else {
                    false
                }
            };
            if transition {
                out.push(Transition {
                    index: i,
                    slo: st.target.kind.label(),
                    fired: st.firing,
                    ts_us: now_us,
                    burn_fast: st.burn_fast,
                    burn_slow: st.burn_slow,
                });
            }
        }
        self.transitions.extend(out.iter().cloned());
        out
    }

    /// Append the SLO families to a Prometheus snapshot: per-target
    /// burn gauges (fast/slow), firing state and transition counters.
    pub fn export_prom(&self, p: &mut PromText) {
        p.family(
            "serve_slo_burn_rate",
            "gauge",
            "error-budget burn rate (1.0 = budget consumed at the \
             allowed rate)",
        );
        for st in &self.states {
            let slo = st.target.kind.label();
            p.sample(
                "serve_slo_burn_rate",
                &[("slo", slo), ("window", "fast")],
                st.burn_fast,
            );
            p.sample(
                "serve_slo_burn_rate",
                &[("slo", slo), ("window", "slow")],
                st.burn_slow,
            );
        }
        p.family(
            "serve_slo_alert_firing",
            "gauge",
            "1 while the target's burn-rate alert is firing",
        );
        for st in &self.states {
            p.sample(
                "serve_slo_alert_firing",
                &[("slo", st.target.kind.label())],
                if st.firing { 1.0 } else { 0.0 },
            );
        }
        p.family(
            "serve_slo_alert_transitions_total",
            "counter",
            "alert state transitions since the run started",
        );
        for st in &self.states {
            let slo = st.target.kind.label();
            p.sample(
                "serve_slo_alert_transitions_total",
                &[("slo", slo), ("state", "fire")],
                st.fired as f64,
            );
            p.sample(
                "serve_slo_alert_transitions_total",
                &[("slo", slo), ("state", "clear")],
                st.cleared as f64,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::series::{HealthSample, SeriesConfig};

    /// Drive a series with a given per-tick shed fraction.
    struct Driver {
        series: WindowedSeries,
        cum_completed: u64,
        cum_shed: u64,
        t: u64,
    }

    impl Driver {
        fn new() -> Driver {
            Driver {
                series: WindowedSeries::new(
                    SeriesConfig { window_us: 1_000, retention: 32 },
                    0,
                ),
                cum_completed: 0,
                cum_shed: 0,
                t: 0,
            }
        }

        fn tick(&mut self, completed: u64, shed: u64) -> u64 {
            self.cum_completed += completed;
            self.cum_shed += shed;
            self.t += 1_000;
            let samp = HealthSample {
                completed: self.cum_completed,
                shed: self.cum_shed,
                ..Default::default()
            };
            self.series.observe(self.t, samp);
            self.t
        }
    }

    fn shed_spec() -> SloSpec {
        SloSpec {
            targets: vec![SloTarget {
                kind: SloKind::ShedRate,
                threshold: 0.05,
            }],
            fast_windows: 1,
            slow_windows: 4,
            burn_threshold: 1.0,
            clear_ratio: 0.5,
            clear_evals: 3,
        }
    }

    /// Satellite test: the alert fires once both windows burn hot,
    /// holds through the in-between band, and clears only after the
    /// hysteresis run of calm ticks — no flapping.
    #[test]
    fn fires_and_clears_with_hysteresis() {
        let mut d = Driver::new();
        let mut rt = SloRuntime::new(shed_spec());
        // healthy traffic: 1% shed, well under the 5% target
        for _ in 0..6 {
            let now = d.tick(99, 1);
            assert!(rt.evaluate(&d.series, now).is_empty());
        }
        assert!(!rt.any_firing());
        // shed storm: 50% shed. Fast crosses immediately; slow needs
        // enough hot windows to drag the 4-window average over budget.
        let mut fired_at = None;
        let mut breach_tick = None;
        for k in 0..6 {
            let now = d.tick(50, 50);
            let tr = rt.evaluate(&d.series, now);
            if breach_tick.is_none()
                && rt.states()[0].first_breach_us.is_some()
            {
                breach_tick = Some(k);
            }
            if let Some(t) = tr.first() {
                assert!(t.fired);
                assert_eq!(t.slo, "shed_rate");
                fired_at = Some(k);
                break;
            }
        }
        let fired_at = fired_at.expect("alert never fired");
        assert_eq!(breach_tick, Some(0), "fast burn crosses on tick one");
        assert!(
            fired_at <= 2,
            "slow window took too long to agree: {fired_at}"
        );
        assert!(rt.any_firing());
        let st = &rt.states()[0];
        assert!(st.first_fire_us.unwrap() >= st.first_breach_us.unwrap());

        // burn oscillating between the clear band and the fire
        // threshold: the alert must neither clear nor double-fire.
        // (Odd count so the phase ends on a hot tick and the calm
        // streak is zero going into the sustained-calm phase below.)
        for k in 0..5 {
            // alternate 4% shed (burn 0.8: under the fire threshold
            // but over the 0.5 clear bar) and 0.1% shed (calm)
            let now = if k % 2 == 0 { d.tick(96, 4) } else { d.tick(999, 1) };
            let tr = rt.evaluate(&d.series, now);
            assert!(tr.is_empty(), "flapped at oscillation tick {k}");
        }
        assert!(rt.any_firing(), "cleared mid-oscillation");
        assert_eq!(rt.states()[0].fired, 1, "double fire");

        // sustained calm: clears after exactly clear_evals calm ticks
        let mut calm_ticks = 0;
        loop {
            let now = d.tick(1000, 0);
            calm_ticks += 1;
            let tr = rt.evaluate(&d.series, now);
            if !tr.is_empty() {
                assert!(!tr[0].fired);
                break;
            }
            assert!(calm_ticks < 10, "never cleared");
        }
        // the slow window must first drain the storm, then 3 calm
        // evaluations in the clear band
        assert!(calm_ticks >= 3, "cleared before the hysteresis run");
        assert!(!rt.any_firing());
        assert_eq!(rt.states()[0].cleared, 1);
        assert_eq!(rt.transitions().len(), 2);
    }

    /// Quiet traffic never fires, and an empty window (no traffic at
    /// all) burns nothing.
    #[test]
    fn no_false_positives_on_healthy_or_idle_traffic() {
        let mut d = Driver::new();
        let mut rt = SloRuntime::new(shed_spec());
        for k in 0..20 {
            let now = if k % 5 == 4 {
                d.tick(0, 0) // idle window: no evidence, no burn
            } else {
                d.tick(98, 2) // 2% shed, burn 0.4
            };
            assert!(rt.evaluate(&d.series, now).is_empty());
        }
        assert!(!rt.any_firing());
        assert_eq!(rt.states()[0].fired, 0);
        assert!(rt.states()[0].first_breach_us.is_none());
    }

    #[test]
    fn latency_target_burns_on_fraction_over_threshold() {
        let mut series = WindowedSeries::new(
            SeriesConfig { window_us: 1_000, retention: 8 },
            0,
        );
        let mut lat = crate::obs::LogHist::new();
        // 2% of requests over the 50ms target => burn 2.0 vs the 1%
        // p99 budget
        for i in 0..1_000u64 {
            lat.record(if i < 980 { 10_000 } else { 80_000 });
        }
        let samp = HealthSample {
            lat,
            completed: 1_000,
            ..Default::default()
        };
        series.observe(1_000, samp);
        let t = SloTarget { kind: SloKind::LatencyP99, threshold: 50_000.0 };
        let w = series.last().unwrap();
        let burn = t.burn(w);
        assert!(
            (burn - 2.0).abs() < 0.2,
            "2% over target vs 1% budget => burn ~2, got {burn}"
        );
        let mut rt = SloRuntime::new(SloSpec {
            targets: vec![t],
            fast_windows: 1,
            slow_windows: 1,
            ..SloSpec::default_spec()
        });
        let tr = rt.evaluate(&series, 1_000);
        assert_eq!(tr.len(), 1);
        assert!(tr[0].fired);
    }

    #[test]
    fn spec_parsing_and_validation() {
        let d = SloSpec::parse("default").unwrap();
        assert_eq!(d.targets.len(), 3);
        assert_eq!(d.fast_windows, 1);
        assert_eq!(d.slow_windows, 6);

        let c = SloSpec::parse("p99_ms=20,shed=0.02,slow=8,clear=2").unwrap();
        assert_eq!(c.targets.len(), 2);
        assert_eq!(c.targets[0].kind, SloKind::LatencyP99);
        assert_eq!(c.targets[0].threshold, 20_000.0);
        assert_eq!(c.slow_windows, 8);
        assert_eq!(c.clear_evals, 2);

        // policy-only spec keeps the default targets
        let p = SloSpec::parse("slow=10").unwrap();
        assert_eq!(p.targets.len(), 3);
        assert_eq!(p.slow_windows, 10);

        for bad in [
            "nope=1",
            "shed",
            "shed=abc",
            "shed=1.5",
            "fast=3,slow=2",
            "burn=0",
            "clear=0",
            "clear_ratio=2",
        ] {
            assert!(SloSpec::parse(bad).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn prom_export_contains_all_families() {
        let mut rt = SloRuntime::new(SloSpec::default_spec());
        let mut d = Driver::new();
        let now = d.tick(100, 0);
        rt.evaluate(&d.series, now);
        let mut p = PromText::new();
        rt.export_prom(&mut p);
        let t = p.text();
        assert!(t.contains("# TYPE serve_slo_burn_rate gauge"));
        assert!(t.contains(
            "serve_slo_burn_rate{slo=\"shed_rate\",window=\"fast\"}"
        ));
        assert!(t.contains("serve_slo_alert_firing{slo=\"p99_latency\"} 0"));
        assert!(t.contains(
            "serve_slo_alert_transitions_total{slo=\"error_rate\",\
             state=\"fire\"} 0"
        ));
    }
}
