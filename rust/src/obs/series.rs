//! Rolling windowed time-series over the serving run's health signals.
//!
//! PR 6's exporters only ever show *cumulative-since-start* numbers —
//! fine for a final report, useless for noticing that p99 started
//! climbing thirty seconds ago. This module turns those same
//! cumulative counters into **recent history**: every `health_ms=`
//! tick the engine's telemetry thread snapshots the run's cumulative
//! [`HealthSample`] (merged latency [`LogHist`], completion / error /
//! shed / cache / accuracy counters), and [`WindowedSeries::observe`]
//! seals the *delta* against the previous snapshot into one
//! fixed-width [`Window`], kept in a bounded ring of the most recent
//! `retention` windows.
//!
//! Because [`LogHist`] merges (and therefore subtracts, see
//! [`LogHist::diff`]) bucket-wise, a window's latency histogram is
//! exact at bucket resolution, and re-merging any run of windows
//! ([`WindowedSeries::merged_last`]) reproduces the cumulative
//! histogram over that span — which is what the SLO burn-rate
//! evaluator ([`crate::obs::slo`]) leans on for its fast/slow window
//! pair, and what the flight recorder ([`crate::obs::flight`]) dumps
//! as the last-N-windows section of a postmortem bundle.

use crate::util::json::{num, obj, Json};

use super::hist::LogHist;

/// Geometry of a windowed series: how wide each window is and how many
/// are retained.
#[derive(Clone, Copy, Debug)]
pub struct SeriesConfig {
    /// Window width in µs (the engine's `health_ms=` knob × 1000).
    pub window_us: u64,
    /// Windows kept in the ring; older windows are evicted.
    pub retention: usize,
}

/// One **cumulative** observation of the run's health counters, taken
/// at a point in time. The series stores deltas, not these; callers
/// build one per tick from the live cells and hand it to
/// [`WindowedSeries::observe`].
#[derive(Clone, Debug, Default)]
pub struct HealthSample {
    /// Cumulative request-latency histogram (µs), merged over shards.
    pub lat: LogHist,
    /// Requests completed (replies delivered, including errors).
    pub completed: u64,
    /// Completed requests whose executor errored.
    pub errors: u64,
    /// Completed requests that missed their deadline.
    pub deadline_missed: u64,
    /// Completed requests with a real (non-empty-logits) prediction.
    pub evaluated: u64,
    /// Evaluated requests whose top-1 prediction was correct.
    pub correct: u64,
    /// Requests shed by admission or queue overflow.
    pub shed: u64,
    /// Requests admitted with degraded fanouts.
    pub degraded: u64,
    /// Feature-cache fresh hits.
    pub cache_hits: u64,
    /// Feature-cache misses.
    pub cache_misses: u64,
    /// Feature-cache stale hits (version-invalidated rows).
    pub stale_hits: u64,
    /// MFG frontier references with multiplicity (dedup numerator).
    pub frontier_refs: u64,
    /// Unique MFG input nodes (dedup denominator).
    pub input_nodes: u64,
    /// Sum of per-micro-batch community purity, in permille.
    pub purity_permille_sum: u64,
    /// Micro-batches formed (denominator for the purity mean).
    pub batches: u64,
    /// Requests waiting on the serving queue **right now** (gauge, not
    /// a cumulative counter — copied into the window as-is).
    pub queue_depth: u64,
    /// Cumulative scaled reuse-distance histogram from the locality
    /// profiler (empty when `locality=` is off).
    pub reuse_dist: LogHist,
    /// Locality-profiler sampled gather accesses.
    pub loc_sampled: u64,
    /// Sampled first-touch (cold) accesses.
    pub loc_cold: u64,
    /// Sampled reuses preceded by a same-community access.
    pub loc_self: u64,
    /// Sampled reuses preceded by a different-community access.
    pub loc_cross: u64,
}

/// One sealed window: the counter **deltas** between two consecutive
/// cumulative samples, plus derived-rate helpers.
#[derive(Clone, Debug)]
pub struct Window {
    /// 0-based sequence number since the run started (keeps counting
    /// past ring eviction).
    pub seq: u64,
    /// Window start, µs on the run clock.
    pub start_us: u64,
    /// Window end (the tick that sealed it), µs on the run clock.
    pub end_us: u64,
    /// Latencies of requests completed inside this window.
    pub lat: LogHist,
    /// Completions inside this window.
    pub completed: u64,
    /// Executor errors inside this window.
    pub errors: u64,
    /// Deadline misses inside this window.
    pub deadline_missed: u64,
    /// Evaluated predictions inside this window.
    pub evaluated: u64,
    /// Correct predictions inside this window.
    pub correct: u64,
    /// Requests shed inside this window.
    pub shed: u64,
    /// Requests degraded inside this window.
    pub degraded: u64,
    /// Cache fresh hits inside this window.
    pub cache_hits: u64,
    /// Cache misses inside this window.
    pub cache_misses: u64,
    /// Cache stale hits inside this window.
    pub stale_hits: u64,
    /// Frontier references sampled inside this window.
    pub frontier_refs: u64,
    /// Unique input nodes sampled inside this window.
    pub input_nodes: u64,
    /// Purity permille summed over this window's micro-batches.
    pub purity_permille_sum: u64,
    /// Micro-batches formed inside this window.
    pub batches: u64,
    /// Queue depth gauge at seal time.
    pub queue_depth: u64,
    /// Reuse distances of gather accesses sampled inside this window.
    pub reuse_dist: LogHist,
    /// Locality-sampled accesses inside this window.
    pub loc_sampled: u64,
    /// Sampled cold (first-touch) accesses inside this window.
    pub loc_cold: u64,
    /// Self-community sampled reuses inside this window.
    pub loc_self: u64,
    /// Cross-community sampled reuses inside this window.
    pub loc_cross: u64,
}

impl Window {
    /// Shed fraction of offered load: `shed / (completed + shed)`
    /// (0 when the window saw no traffic).
    pub fn shed_rate(&self) -> f64 {
        ratio(self.shed, self.completed + self.shed)
    }

    /// Error fraction of completions (0 when none completed).
    pub fn error_rate(&self) -> f64 {
        ratio(self.errors, self.completed)
    }

    /// Stale fraction of cache lookups
    /// (`stale / (hits + misses + stale)`).
    pub fn stale_rate(&self) -> f64 {
        ratio(
            self.stale_hits,
            self.cache_hits + self.cache_misses + self.stale_hits,
        )
    }

    /// Top-1 accuracy over this window's evaluated predictions, or
    /// `None` when nothing was evaluated (no-op executor, idle window).
    pub fn accuracy(&self) -> Option<f64> {
        (self.evaluated > 0)
            .then(|| self.correct as f64 / self.evaluated as f64)
    }

    /// Cross-request sampling dedup factor (`refs / unique nodes`, 1.0
    /// when nothing was sampled).
    pub fn dedup_factor(&self) -> f64 {
        if self.input_nodes == 0 {
            1.0
        } else {
            self.frontier_refs as f64 / self.input_nodes as f64
        }
    }

    /// Mean community purity of this window's micro-batches, in
    /// `[0, 1]` (0 when no batch formed).
    pub fn purity(&self) -> f64 {
        ratio(self.purity_permille_sum, self.batches * 1000)
    }

    /// Mean estimated reuse distance of this window's sampled gather
    /// reuses (0 when the locality profiler is off or saw no reuse).
    pub fn mean_reuse_distance(&self) -> f64 {
        self.reuse_dist.mean()
    }

    /// Self-community fraction of this window's sampled reuses (0 when
    /// none were observed).
    pub fn self_reuse_frac(&self) -> f64 {
        ratio(self.loc_self, self.loc_self + self.loc_cross)
    }

    /// Flat JSON object for the postmortem bundle and `ServeReport`:
    /// counters plus derived latency quantiles (the full bucket array
    /// stays in memory only).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("seq", num(self.seq as f64)),
            ("start_us", num(self.start_us as f64)),
            ("end_us", num(self.end_us as f64)),
            ("completed", num(self.completed as f64)),
            ("errors", num(self.errors as f64)),
            ("deadline_missed", num(self.deadline_missed as f64)),
            ("evaluated", num(self.evaluated as f64)),
            ("correct", num(self.correct as f64)),
            ("shed", num(self.shed as f64)),
            ("degraded", num(self.degraded as f64)),
            ("cache_hits", num(self.cache_hits as f64)),
            ("cache_misses", num(self.cache_misses as f64)),
            ("stale_hits", num(self.stale_hits as f64)),
            ("batches", num(self.batches as f64)),
            ("queue_depth", num(self.queue_depth as f64)),
            ("shed_rate", num(self.shed_rate())),
            ("error_rate", num(self.error_rate())),
            ("dedup_factor", num(self.dedup_factor())),
            ("purity", num(self.purity())),
            ("lat_count", num(self.lat.count() as f64)),
            ("lat_p50_us", num(self.lat.quantile(0.5) as f64)),
            ("lat_p95_us", num(self.lat.quantile(0.95) as f64)),
            ("lat_p99_us", num(self.lat.quantile(0.99) as f64)),
            ("lat_max_us", num(self.lat.max() as f64)),
            ("loc_sampled", num(self.loc_sampled as f64)),
            ("loc_cold", num(self.loc_cold as f64)),
            ("mean_reuse_distance", num(self.mean_reuse_distance())),
            ("self_reuse_frac", num(self.self_reuse_frac())),
        ])
    }
}

fn ratio(n: u64, d: u64) -> f64 {
    if d == 0 {
        0.0
    } else {
        n as f64 / d as f64
    }
}

/// The bounded ring of recent [`Window`]s plus the previous cumulative
/// snapshot the next delta will be taken against. Single-writer by
/// design: only the engine's telemetry thread observes; readers (SLO
/// evaluation, flight dumps, the final report) run on that same thread
/// or after it quiesces.
#[derive(Debug)]
pub struct WindowedSeries {
    cfg: SeriesConfig,
    prev_ts_us: u64,
    prev: HealthSample,
    ring: std::collections::VecDeque<Window>,
    sealed: u64,
}

impl WindowedSeries {
    /// Empty series; deltas start against a zero sample at `start_us`
    /// (the run clock's origin), so the first observed window covers
    /// the run's actual beginning.
    pub fn new(cfg: SeriesConfig, start_us: u64) -> WindowedSeries {
        WindowedSeries {
            cfg: SeriesConfig {
                window_us: cfg.window_us.max(1),
                retention: cfg.retention.max(1),
            },
            prev_ts_us: start_us,
            prev: HealthSample::default(),
            ring: std::collections::VecDeque::new(),
            sealed: 0,
        }
    }

    /// The series geometry.
    pub fn config(&self) -> SeriesConfig {
        self.cfg
    }

    /// Seal one window: the delta between `cur` and the previous
    /// cumulative sample, spanning `[prev_ts, ts_us)`. Returns the
    /// sealed window's ring position. Counters in `cur` must be
    /// cumulative and monotone (subtraction saturates defensively).
    pub fn observe(&mut self, ts_us: u64, cur: HealthSample) -> &Window {
        let w = Window {
            seq: self.sealed,
            start_us: self.prev_ts_us,
            end_us: ts_us,
            lat: cur.lat.diff(&self.prev.lat),
            completed: cur.completed.saturating_sub(self.prev.completed),
            errors: cur.errors.saturating_sub(self.prev.errors),
            deadline_missed: cur
                .deadline_missed
                .saturating_sub(self.prev.deadline_missed),
            evaluated: cur.evaluated.saturating_sub(self.prev.evaluated),
            correct: cur.correct.saturating_sub(self.prev.correct),
            shed: cur.shed.saturating_sub(self.prev.shed),
            degraded: cur.degraded.saturating_sub(self.prev.degraded),
            cache_hits: cur.cache_hits.saturating_sub(self.prev.cache_hits),
            cache_misses: cur
                .cache_misses
                .saturating_sub(self.prev.cache_misses),
            stale_hits: cur.stale_hits.saturating_sub(self.prev.stale_hits),
            frontier_refs: cur
                .frontier_refs
                .saturating_sub(self.prev.frontier_refs),
            input_nodes: cur.input_nodes.saturating_sub(self.prev.input_nodes),
            purity_permille_sum: cur
                .purity_permille_sum
                .saturating_sub(self.prev.purity_permille_sum),
            batches: cur.batches.saturating_sub(self.prev.batches),
            queue_depth: cur.queue_depth,
            reuse_dist: cur.reuse_dist.diff(&self.prev.reuse_dist),
            loc_sampled: cur.loc_sampled.saturating_sub(self.prev.loc_sampled),
            loc_cold: cur.loc_cold.saturating_sub(self.prev.loc_cold),
            loc_self: cur.loc_self.saturating_sub(self.prev.loc_self),
            loc_cross: cur.loc_cross.saturating_sub(self.prev.loc_cross),
        };
        self.prev_ts_us = ts_us;
        self.prev = cur;
        self.sealed += 1;
        if self.ring.len() == self.cfg.retention {
            self.ring.pop_front();
        }
        self.ring.push_back(w);
        self.ring.back().expect("just pushed")
    }

    /// Windows ever sealed (keeps counting past eviction).
    pub fn sealed(&self) -> u64 {
        self.sealed
    }

    /// Retained windows, oldest first.
    pub fn windows(&self) -> impl Iterator<Item = &Window> {
        self.ring.iter()
    }

    /// The most recently sealed window.
    pub fn last(&self) -> Option<&Window> {
        self.ring.back()
    }

    /// Merge the newest `n` retained windows (fewer early in the run)
    /// into one synthetic window spanning them — the burn-rate
    /// evaluator's fast/slow lookback. `None` before the first seal.
    pub fn merged_last(&self, n: usize) -> Option<Window> {
        let n = n.max(1).min(self.ring.len());
        if n == 0 {
            return None;
        }
        let slice: Vec<&Window> = self.ring.iter().rev().take(n).collect();
        let newest = slice.first().expect("n >= 1");
        let oldest = slice.last().expect("n >= 1");
        let mut m = Window {
            seq: newest.seq,
            start_us: oldest.start_us,
            end_us: newest.end_us,
            lat: LogHist::new(),
            completed: 0,
            errors: 0,
            deadline_missed: 0,
            evaluated: 0,
            correct: 0,
            shed: 0,
            degraded: 0,
            cache_hits: 0,
            cache_misses: 0,
            stale_hits: 0,
            frontier_refs: 0,
            input_nodes: 0,
            purity_permille_sum: 0,
            batches: 0,
            queue_depth: newest.queue_depth,
            reuse_dist: LogHist::new(),
            loc_sampled: 0,
            loc_cold: 0,
            loc_self: 0,
            loc_cross: 0,
        };
        for w in slice {
            m.lat.merge(&w.lat);
            m.reuse_dist.merge(&w.reuse_dist);
            m.loc_sampled += w.loc_sampled;
            m.loc_cold += w.loc_cold;
            m.loc_self += w.loc_self;
            m.loc_cross += w.loc_cross;
            m.completed += w.completed;
            m.errors += w.errors;
            m.deadline_missed += w.deadline_missed;
            m.evaluated += w.evaluated;
            m.correct += w.correct;
            m.shed += w.shed;
            m.degraded += w.degraded;
            m.cache_hits += w.cache_hits;
            m.cache_misses += w.cache_misses;
            m.stale_hits += w.stale_hits;
            m.frontier_refs += w.frontier_refs;
            m.input_nodes += w.input_nodes;
            m.purity_permille_sum += w.purity_permille_sum;
            m.batches += w.batches;
        }
        Some(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_at(k: u64) -> HealthSample {
        // cumulative counters that grow k-per-tick in distinct ratios
        let mut lat = LogHist::new();
        for i in 0..k * 10 {
            lat.record(100 + i);
        }
        HealthSample {
            lat,
            completed: k * 10,
            errors: k,
            deadline_missed: k * 2,
            evaluated: k * 8,
            correct: k * 6,
            shed: k * 3,
            degraded: k,
            cache_hits: k * 100,
            cache_misses: k * 20,
            stale_hits: k * 5,
            frontier_refs: k * 400,
            input_nodes: k * 200,
            purity_permille_sum: k * 900,
            batches: k,
            queue_depth: k % 7,
            reuse_dist: {
                let mut d = LogHist::new();
                for i in 0..k * 4 {
                    d.record(10 + i);
                }
                d
            },
            loc_sampled: k * 6,
            loc_cold: k * 2,
            loc_self: k * 3,
            loc_cross: k,
        }
    }

    /// Satellite test: the ring rotates — sealing more windows than
    /// the retention keeps only the newest, with sequence numbers that
    /// keep counting.
    #[test]
    fn ring_rotation_keeps_newest_windows() {
        let mut s = WindowedSeries::new(
            SeriesConfig { window_us: 1_000, retention: 4 },
            0,
        );
        for t in 1..=10u64 {
            s.observe(t * 1_000, sample_at(t));
        }
        assert_eq!(s.sealed(), 10);
        let seqs: Vec<u64> = s.windows().map(|w| w.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        let w = s.last().unwrap();
        assert_eq!(w.start_us, 9_000);
        assert_eq!(w.end_us, 10_000);
        // every retained window is a one-tick delta
        for w in s.windows() {
            assert_eq!(w.completed, 10);
            assert_eq!(w.shed, 3);
            assert_eq!(w.lat.count(), 10);
        }
        // merged_last never exceeds what is retained
        let m = s.merged_last(100).unwrap();
        assert_eq!(m.completed, 40);
        assert_eq!(m.start_us, 6_000);
        assert_eq!(m.end_us, 10_000);
    }

    /// Satellite test: merging all windows reproduces the whole-run
    /// cumulative `LogHist` — identical buckets, count and sum, and
    /// therefore identical quantiles at bucket resolution.
    #[test]
    fn window_merge_matches_whole_run_hist() {
        let mut rng = Rng::new(77);
        let mut cum = LogHist::new();
        let mut cum_completed = 0u64;
        let mut s = WindowedSeries::new(
            SeriesConfig { window_us: 500, retention: 64 },
            0,
        );
        for t in 1..=20u64 {
            // a bursty tick: 0..400 new samples
            for _ in 0..rng.below(400) {
                cum.record(50 + rng.below(1_000_000));
                cum_completed += 1;
            }
            let samp = HealthSample {
                lat: cum.clone(),
                completed: cum_completed,
                ..Default::default()
            };
            s.observe(t * 500, samp);
        }
        let merged = s.merged_last(20).unwrap();
        assert_eq!(merged.lat.count(), cum.count());
        assert_eq!(merged.lat.sum(), cum.sum());
        assert!(merged.lat.buckets().eq(cum.buckets()));
        assert_eq!(merged.completed, cum_completed);
        for q in [0.5, 0.9, 0.99] {
            let a = merged.lat.quantile(q) as f64;
            let b = cum.quantile(q) as f64;
            let rel = (a - b).abs() / b.max(1.0);
            assert!(rel <= 1.0 / 32.0 + 1e-9, "q={q}: {a} vs {b}");
        }
    }

    #[test]
    fn derived_rates_are_the_documented_ratios() {
        let mut s = WindowedSeries::new(
            SeriesConfig { window_us: 1_000, retention: 8 },
            0,
        );
        let w = s.observe(1_000, sample_at(4)).clone();
        assert!((w.shed_rate() - 12.0 / 52.0).abs() < 1e-12);
        assert!((w.error_rate() - 4.0 / 40.0).abs() < 1e-12);
        assert!(
            (w.stale_rate() - 20.0 / (400.0 + 80.0 + 20.0)).abs() < 1e-12
        );
        assert_eq!(w.accuracy(), Some(0.75));
        assert!((w.dedup_factor() - 2.0).abs() < 1e-12);
        assert!((w.purity() - 0.9).abs() < 1e-12);
        assert!((w.self_reuse_frac() - 12.0 / 16.0).abs() < 1e-12);
        assert!(w.mean_reuse_distance() > 0.0);
        assert_eq!(w.reuse_dist.count(), 16);
        // an idle window has no accuracy and zero rates
        let idle = s.observe(2_000, sample_at(4)).clone();
        assert_eq!(idle.accuracy(), None);
        assert_eq!(idle.shed_rate(), 0.0);
        assert_eq!(idle.lat.count(), 0);
        // JSON shape parses back
        let j = crate::util::json::Json::parse(&w.to_json().to_string_pretty())
            .unwrap();
        assert_eq!(j.get("completed").unwrap().as_usize().unwrap(), 40);
        assert!(j.get("lat_p99_us").unwrap().as_f64().unwrap() > 0.0);
        assert!(
            j.get("self_reuse_frac").unwrap().as_f64().unwrap() > 0.7
        );
        assert_eq!(j.get("loc_sampled").unwrap().as_usize().unwrap(), 24);
    }
}
