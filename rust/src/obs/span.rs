//! Per-request span timeline: compact events in lock-free rings.
//!
//! Every stage of a request's life — enqueue, admission decision,
//! queue wait, batcher coalesce, sample, feature gather (with cache
//! hit/stale/miss tags), execute, reply — is recorded as one fixed-size
//! [`Event`] pushed into a per-track [`EventRing`]. The hot path does
//! **no allocation and takes no lock**: a push is one relaxed
//! `fetch_add` on the ring's head plus five relaxed word stores, and a
//! disabled [`Recorder`] short-circuits to a single branch, which is
//! how tracing stays always-compiled-in at ≤ 5% overhead (gated by
//! `exp obs`).
//!
//! Rings have fixed capacity and **wrap**: once full, new events
//! overwrite the oldest and the overwritten count is surfaced via
//! [`EventRing::dropped`] / [`Recorder::total_dropped`] — the exporter
//! and the CLI print it, so truncation is never silent. Sampling
//! (`trace_sample=`) is decided statelessly per request by hashing the
//! request id ([`Recorder::traced`]), so every pipeline stage agrees
//! on whether a request is traced without coordination.
//!
//! Tracks map to Chrome-trace threads: one per shard's worker pool
//! plus dedicated tracks for the batcher, the churn/maintainer thread,
//! the checkpoint watcher, and the client/admission side (see
//! [`track_name`] and [`crate::obs::export`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// What a single [`Event`] describes. Span kinds carry a non-zero
/// duration; instant kinds mark a point in time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Request accepted onto the serving queue (instant; per request).
    Enqueue = 0,
    /// Admission degraded this request's fanouts (instant; `a` =
    /// first-layer capped fanout).
    Degrade = 1,
    /// Admission shed this request (instant).
    Shed = 2,
    /// Enqueue → picked into a formed micro-batch (span; per request).
    QueueWait = 3,
    /// Micro-batch formation in the batcher (span; `a` = batch size,
    /// `b` = community purity in permille, `c` = distinct communities).
    Coalesce = 4,
    /// MFG neighborhood sampling for one micro-batch (span; `a` =
    /// input-frontier references with multiplicity, `b` = unique MFG
    /// input nodes, `c` = cross-request neighborhood overlap in
    /// permille, `1000·(a−b)/a` — so `a/b` is the batch's dedup
    /// factor and summing `a`/`b` over all sample spans reproduces the
    /// run's `ServeReport.dedup_factor` exactly).
    Sample = 5,
    /// Feature gather through the cache (span; `a` = hits, `b` =
    /// misses, `c` = stale hits).
    Gather = 6,
    /// Executor inference on the assembled batch (span; `a` = batch
    /// size, `b` = parameter version).
    Execute = 7,
    /// Reply delivered (instant; per request; `a` = 1 if the deadline
    /// was missed, `b` = 1 on executor error).
    Reply = 8,
    /// One churn epoch of edge mutations applied (instant; `a` =
    /// applied updates, `b` = refine moves).
    Churn = 9,
    /// Incremental refine wave (instant; `a` = vertices visited, `b` =
    /// moves applied).
    Refine = 10,
    /// Stop-the-world full relabel (instant; `a` = new community
    /// count).
    Relabel = 11,
    /// Checkpoint hot-swap installed (instant; `a` = epoch).
    CkptSwap = 12,
    /// Metrics snapshot written (instant; `a` = snapshot sequence).
    MetricsFlush = 13,
    /// SLO burn-rate alert transitioned to firing (instant; `a` = SLO
    /// index in the run's [`crate::obs::slo::SloSpec`], `b` = fast
    /// burn rate ×100, `c` = slow burn rate ×100).
    SloFire = 14,
    /// SLO burn-rate alert cleared after its hysteresis window
    /// (instant; payload as [`EventKind::SloFire`]).
    SloClear = 15,
    /// Watchdog declared a thread stalled (instant; `a` = watchdog
    /// slot index, `b` = ms since the thread's last heartbeat).
    Stall = 16,
    /// One sealed locality window (counter; `a` = mean estimated
    /// reuse distance in rows, `b` = MRC-predicted miss permille at
    /// the current cache size, `c` = self-community reuse permille).
    /// Exported as a Chrome-trace counter-track sample (`ph:"C"`), so
    /// Perfetto plots the run's locality as a live curve.
    Locality = 17,
}

impl EventKind {
    /// Chrome-trace event name for this kind.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Enqueue => "enqueue",
            EventKind::Degrade => "degrade",
            EventKind::Shed => "shed",
            EventKind::QueueWait => "queue_wait",
            EventKind::Coalesce => "coalesce",
            EventKind::Sample => "sample",
            EventKind::Gather => "gather",
            EventKind::Execute => "execute",
            EventKind::Reply => "reply",
            EventKind::Churn => "churn",
            EventKind::Refine => "refine",
            EventKind::Relabel => "relabel",
            EventKind::CkptSwap => "ckpt_swap",
            EventKind::MetricsFlush => "metrics_flush",
            EventKind::SloFire => "slo_fire",
            EventKind::SloClear => "slo_clear",
            EventKind::Stall => "stall",
            EventKind::Locality => "locality",
        }
    }

    /// True for kinds recorded as Chrome-trace complete spans (`ph:X`)
    /// rather than instants (`ph:i`).
    pub fn is_span(self) -> bool {
        matches!(
            self,
            EventKind::QueueWait
                | EventKind::Coalesce
                | EventKind::Sample
                | EventKind::Gather
                | EventKind::Execute
        )
    }

    fn from_u8(v: u8) -> EventKind {
        match v {
            0 => EventKind::Enqueue,
            1 => EventKind::Degrade,
            2 => EventKind::Shed,
            3 => EventKind::QueueWait,
            4 => EventKind::Coalesce,
            5 => EventKind::Sample,
            6 => EventKind::Gather,
            7 => EventKind::Execute,
            8 => EventKind::Reply,
            9 => EventKind::Churn,
            10 => EventKind::Refine,
            11 => EventKind::Relabel,
            12 => EventKind::CkptSwap,
            14 => EventKind::SloFire,
            15 => EventKind::SloClear,
            16 => EventKind::Stall,
            17 => EventKind::Locality,
            _ => EventKind::MetricsFlush,
        }
    }
}

/// One compact trace event (five 64-bit words in the ring).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// Start timestamp, µs on the run's shared clock.
    pub ts_us: u64,
    /// Duration in µs (0 for instant events).
    pub dur_us: u64,
    /// Request id this event belongs to (0 for batch/thread-level
    /// events).
    pub req_id: u64,
    /// Event kind.
    pub kind: EventKind,
    /// Counter payload; meaning is per-kind (see [`EventKind`]).
    pub a: u32,
    /// Second counter payload.
    pub b: u32,
    /// Third counter payload.
    pub c: u32,
}

const WORDS: usize = 5;

impl Event {
    fn encode(&self) -> [u64; WORDS] {
        [
            self.ts_us,
            self.dur_us,
            self.req_id,
            (self.kind as u64) | ((self.c as u64) << 32),
            (self.a as u64) | ((self.b as u64) << 32),
        ]
    }

    fn decode(w: &[u64; WORDS]) -> Event {
        Event {
            ts_us: w[0],
            dur_us: w[1],
            req_id: w[2],
            kind: EventKind::from_u8((w[3] & 0xFF) as u8),
            c: (w[3] >> 32) as u32,
            a: (w[4] & 0xFFFF_FFFF) as u32,
            b: (w[4] >> 32) as u32,
        }
    }
}

/// Fixed-capacity lock-free event ring. Writers claim a slot with one
/// `fetch_add` and store the event's words with relaxed atomics; once
/// the ring wraps, the oldest events are overwritten and counted as
/// dropped. Reading back ([`EventRing::snapshot`]) is meant for after
/// the writers have quiesced (end of run); a concurrent snapshot can
/// observe a torn event but never unsoundness.
pub struct EventRing {
    slots: Box<[[AtomicU64; WORDS]]>,
    head: AtomicU64,
}

impl EventRing {
    /// Ring holding up to `capacity` events (rounded up to 1 minimum).
    pub fn new(capacity: usize) -> EventRing {
        let capacity = capacity.max(1);
        let slots = (0..capacity)
            .map(|_| std::array::from_fn(|_| AtomicU64::new(0)))
            .collect::<Vec<[AtomicU64; WORDS]>>()
            .into_boxed_slice();
        EventRing { slots, head: AtomicU64::new(0) }
    }

    /// Slots in the ring.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Append one event, overwriting the oldest once full.
    #[inline]
    pub fn push(&self, ev: Event) {
        let i = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(i % self.slots.len() as u64) as usize];
        for (cell, word) in slot.iter().zip(ev.encode()) {
            cell.store(word, Ordering::Relaxed);
        }
    }

    /// Total events ever pushed (kept + overwritten).
    pub fn written(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Events lost to wraparound (`written - capacity`, floored at 0).
    pub fn dropped(&self) -> u64 {
        self.written().saturating_sub(self.slots.len() as u64)
    }

    /// The retained events, oldest first. Call after writers quiesce
    /// for an exact snapshot.
    pub fn snapshot(&self) -> Vec<Event> {
        let head = self.written();
        let cap = self.slots.len() as u64;
        let kept = head.min(cap);
        let start = head - kept; // oldest retained logical index
        (start..head)
            .map(|i| {
                let slot = &self.slots[(i % cap) as usize];
                let words: [u64; WORDS] =
                    std::array::from_fn(|k| slot[k].load(Ordering::Relaxed));
                Event::decode(&words)
            })
            .collect()
    }
}

/// Dedicated track for the micro-batcher thread.
pub const TRACK_BATCHER: usize = 0;
/// Dedicated track for the churn / community-maintainer thread.
pub const TRACK_MAINTAINER: usize = 1;
/// Dedicated track for the checkpoint hot-swap watcher.
pub const TRACK_WATCHER: usize = 2;
/// Track for client-side events (enqueue, admission, reply).
pub const TRACK_CLIENT: usize = 3;
const FIXED_TRACKS: usize = 4;

/// Track id for shard `s`'s worker pool.
pub fn shard_track(s: usize) -> usize {
    FIXED_TRACKS + s
}

/// Human name for a track id (Chrome-trace thread name).
pub fn track_name(track: usize) -> String {
    match track {
        TRACK_BATCHER => "batcher".to_string(),
        TRACK_MAINTAINER => "churn/maintainer".to_string(),
        TRACK_WATCHER => "ckpt-watcher".to_string(),
        TRACK_CLIENT => "clients/admission".to_string(),
        s => format!("shard{}", s - FIXED_TRACKS),
    }
}

/// Stateless per-request sampling decision: hash the id, keep the low
/// ten bits under `permille`. Every stage of the pipeline calls this
/// with the same id and gets the same answer.
#[inline]
pub fn id_sampled(req_id: u64, permille: u32) -> bool {
    if permille >= 1000 {
        return true;
    }
    if permille == 0 {
        return false;
    }
    // splitmix-style avalanche so sequential ids sample uniformly
    let mut z = req_id.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    ((z >> 32) % 1000) < permille as u64
}

/// The run-wide trace recorder: one [`EventRing`] per track plus the
/// sampling knob, shared by reference across every thread of a serving
/// run. A disabled recorder ([`Recorder::disabled`]) makes every
/// recording call a single-branch no-op, so the instrumentation is
/// always compiled in.
pub struct Recorder {
    enabled: bool,
    sample_permille: u32,
    origin: Instant,
    rings: Vec<EventRing>,
}

impl Recorder {
    /// Recorder with tracing off: every `record`/`traced` call is a
    /// cheap no-op.
    pub fn disabled() -> Recorder {
        Recorder {
            enabled: false,
            sample_permille: 0,
            origin: Instant::now(),
            rings: Vec::new(),
        }
    }

    /// Enabled recorder for `num_shards` shards with `ring_capacity`
    /// events per track. `sample_permille` (0..=1000) is the fraction
    /// of requests whose per-request events are recorded; batch- and
    /// thread-level events are always recorded when enabled. `origin`
    /// must be the same instant the run's `ServeClock` starts from, so
    /// event timestamps share the request timeline.
    pub fn new(
        num_shards: usize,
        ring_capacity: usize,
        sample_permille: u32,
        origin: Instant,
    ) -> Recorder {
        let rings = (0..FIXED_TRACKS + num_shards.max(1))
            .map(|_| EventRing::new(ring_capacity))
            .collect();
        Recorder {
            enabled: true,
            sample_permille: sample_permille.min(1000),
            origin,
            rings,
        }
    }

    /// Whether tracing is on at all.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// The configured sampling rate in permille.
    pub fn sample_permille(&self) -> u32 {
        self.sample_permille
    }

    /// Number of tracks (rings).
    pub fn num_tracks(&self) -> usize {
        self.rings.len()
    }

    /// µs since the recorder's origin (same timeline as `ServeClock`).
    pub fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    /// Should per-request events for `req_id` be recorded?
    #[inline]
    pub fn traced(&self, req_id: u64) -> bool {
        self.enabled && id_sampled(req_id, self.sample_permille)
    }

    /// Record a span event on `track` (no-op when disabled).
    #[inline]
    pub fn span(
        &self,
        track: usize,
        kind: EventKind,
        ts_us: u64,
        dur_us: u64,
        req_id: u64,
        a: u32,
        b: u32,
        c: u32,
    ) {
        if !self.enabled {
            return;
        }
        self.rings[track].push(Event { ts_us, dur_us, req_id, kind, a, b, c });
    }

    /// Record an instant event on `track` (no-op when disabled).
    #[inline]
    pub fn instant(
        &self,
        track: usize,
        kind: EventKind,
        ts_us: u64,
        req_id: u64,
        a: u32,
        b: u32,
        c: u32,
    ) {
        self.span(track, kind, ts_us, 0, req_id, a, b, c);
    }

    /// Per-track rings (exporters iterate these).
    pub fn rings(&self) -> &[EventRing] {
        &self.rings
    }

    /// Total events pushed across tracks.
    pub fn total_written(&self) -> u64 {
        self.rings.iter().map(|r| r.written()).sum()
    }

    /// Total events lost to ring wraparound across tracks. Surfaced by
    /// the exporter and the CLI — truncation is never silent.
    pub fn total_dropped(&self) -> u64 {
        self.rings.iter().map(|r| r.dropped()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ts: u64, kind: EventKind, req: u64) -> Event {
        Event { ts_us: ts, dur_us: 1, req_id: req, kind, a: 1, b: 2, c: 3 }
    }

    #[test]
    fn event_encode_decode_round_trips() {
        let e = Event {
            ts_us: 123_456_789,
            dur_us: 42,
            req_id: (7 << 32) | 9,
            kind: EventKind::Gather,
            a: u32::MAX,
            b: 17,
            c: 0xDEAD_BEEF,
        };
        assert_eq!(Event::decode(&e.encode()), e);
    }

    #[test]
    fn health_event_kinds_round_trip() {
        for kind in [
            EventKind::SloFire,
            EventKind::SloClear,
            EventKind::Stall,
            EventKind::Locality,
        ] {
            let e = Event {
                ts_us: 7,
                dur_us: 0,
                req_id: 0,
                kind,
                a: 1,
                b: 250,
                c: 90,
            };
            assert_eq!(Event::decode(&e.encode()), e);
            assert!(!kind.is_span(), "{kind:?} must export as an instant");
        }
    }

    #[test]
    fn ring_keeps_everything_under_capacity() {
        let r = EventRing::new(64);
        for i in 0..50u64 {
            r.push(ev(i, EventKind::Sample, i));
        }
        assert_eq!(r.written(), 50);
        assert_eq!(r.dropped(), 0);
        let snap = r.snapshot();
        assert_eq!(snap.len(), 50);
        for (i, e) in snap.iter().enumerate() {
            assert_eq!(e.ts_us, i as u64);
        }
    }

    #[test]
    fn ring_wraparound_drops_oldest_and_counts_them() {
        let r = EventRing::new(16);
        for i in 0..100u64 {
            r.push(ev(i, EventKind::Execute, i));
        }
        assert_eq!(r.written(), 100);
        assert_eq!(r.dropped(), 84, "written - capacity overwritten");
        let snap = r.snapshot();
        assert_eq!(snap.len(), 16);
        // the newest 16 events survive, oldest first
        for (k, e) in snap.iter().enumerate() {
            assert_eq!(e.ts_us, 84 + k as u64);
        }
    }

    /// Concurrent writers: every push is either retained or accounted
    /// as dropped — no silent loss.
    #[test]
    fn ring_drop_accounting_is_exact_under_concurrent_writers() {
        let r = EventRing::new(128);
        let per_thread = 10_000u64;
        let threads = 4u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let r = &r;
                s.spawn(move || {
                    for i in 0..per_thread {
                        r.push(ev(i, EventKind::Gather, (t << 32) | i));
                    }
                });
            }
        });
        let total = threads * per_thread;
        assert_eq!(r.written(), total);
        assert_eq!(r.dropped(), total - 128);
        assert_eq!(r.snapshot().len(), 128);
    }

    #[test]
    fn sampling_is_stateless_and_roughly_proportional() {
        // full and zero rates are exact
        for id in 0..1000u64 {
            assert!(id_sampled(id, 1000));
            assert!(!id_sampled(id, 0));
        }
        // a mid rate keeps roughly its share of sequential ids (the
        // avalanche hash decorrelates the low bits)
        let kept = (0..100_000u64).filter(|&i| id_sampled(i, 100)).count();
        let frac = kept as f64 / 100_000.0;
        assert!(
            (frac - 0.1).abs() < 0.01,
            "sampled {frac:.3} of ids at 10% rate"
        );
        // deterministic: same id, same answer
        for id in [3u64, 999, 123_456_789] {
            assert_eq!(id_sampled(id, 250), id_sampled(id, 250));
        }
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let r = Recorder::disabled();
        assert!(!r.is_enabled());
        assert!(!r.traced(42));
        r.instant(TRACK_CLIENT, EventKind::Enqueue, 1, 42, 0, 0, 0);
        assert_eq!(r.total_written(), 0);
        assert_eq!(r.total_dropped(), 0);
    }

    #[test]
    fn recorder_routes_tracks_and_counts_drops() {
        let r = Recorder::new(2, 8, 1000, Instant::now());
        assert_eq!(r.num_tracks(), 6); // 4 fixed + 2 shards
        assert!(r.traced(7));
        r.instant(TRACK_BATCHER, EventKind::Coalesce, 5, 0, 4, 900, 2);
        for i in 0..20u64 {
            r.span(shard_track(1), EventKind::Sample, i, 2, i, 1, 1, 0);
        }
        assert_eq!(r.rings()[TRACK_BATCHER].written(), 1);
        assert_eq!(r.rings()[shard_track(1)].written(), 20);
        assert_eq!(r.rings()[shard_track(1)].dropped(), 12);
        assert_eq!(r.total_dropped(), 12);
        assert_eq!(r.total_written(), 21);
        let names: Vec<String> =
            (0..r.num_tracks()).map(track_name).collect();
        assert_eq!(names[0], "batcher");
        assert_eq!(names[4], "shard0");
        assert_eq!(names[5], "shard1");
    }
}
