//! Online sampled reuse-distance profiling on the feature-gather path.
//!
//! The paper's speedup story is **cache locality**: community-aware
//! micro-batching turns an irregular feature-access stream into a
//! cache-friendly one. Until now the only way to see that was the
//! offline trace replay in [`crate::cachesim`] — the live engine
//! reported hit *rates* but nothing about access *structure*. This
//! module watches the gather stream itself:
//!
//! * **SHARDS-style spatial sampling** — a node is profiled iff a
//!   stateless hash of its id lands under `locality_sample=` permille
//!   ([`node_sampled`]), so every worker agrees on the sampled set
//!   with no coordination and the profiler's cost scales with the
//!   sampling rate, not the traffic.
//! * **Mattson stack distances** — for each sampled re-access, the
//!   number of *distinct* sampled nodes touched since that node's
//!   previous access, computed in O(log n) per access with a Fenwick
//!   tree over last-access positions (periodically compacted). Scaled
//!   by the inverse sampling rate, that estimates the true LRU stack
//!   distance, and the histogram of those distances
//!   ([`LocalitySample::dist`], a [`LogHist`]) is everything a
//!   miss-ratio curve needs ([`crate::obs::mrc`]).
//! * **Access-affinity counters** — every sampled reuse is classified
//!   *self-community* (the immediately preceding sampled access
//!   belonged to the same community) or *cross-community*, so the `p`
//!   knob's effect on stream coherence is a first-class number.
//! * **A bounded access-trace prefix** — the first `trace_cap`
//!   observed accesses (node id + hit/miss outcome) are retained so
//!   the live stream can be replayed offline through
//!   [`crate::cachesim::SetAssocCore`] and cross-checked against the
//!   serving cache's own counters (the two consumers of the
//!   set-associative core must never disagree).
//!
//! One [`LocalityShard`] lives next to each device shard's feature
//! cache; workers batch their gather taps into a single
//! [`LocalityShard::observe_batch`] call per micro-batch (one mutex
//! acquisition, entries pre-filtered by the lock-free
//! [`LocalityShard::is_sampled`] / [`LocalityShard::wants_trace`]
//! checks), which is how the profiler stays inside the ≤ 5 % overhead
//! budget `exp locality` enforces. The engine's telemetry thread
//! snapshots the cumulative [`LocalitySample`] every health window and
//! seals per-window deltas via [`LocalitySample::diff`], the same
//! cumulative-snapshot discipline as [`crate::obs::series`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use super::hist::LogHist;

/// Geometry of a [`LocalityShard`].
#[derive(Clone, Copy, Debug)]
pub struct LocalityConfig {
    /// SHARDS spatial sampling rate in permille (`locality_sample=`):
    /// a node is profiled iff `hash(node) % 1000 < sample_permille`.
    /// 1000 profiles every access (exact Mattson), 0 disables distance
    /// profiling (the shard still counts raw accesses and captures the
    /// trace prefix).
    pub sample_permille: u32,
    /// Retain the first `trace_cap` observed accesses for the offline
    /// [`crate::cachesim::SetAssocCore`] cross-check (0 disables
    /// capture).
    pub trace_cap: usize,
}

/// One observed feature-gather access, built by the worker's tap.
#[derive(Clone, Copy, Debug)]
pub struct Access {
    /// Global node id whose feature row was gathered.
    pub node: u32,
    /// The node's community label at access time.
    pub comm: u32,
    /// Whether the serving cache returned a *fresh* hit (stale hits
    /// refetch the row, so they count as misses here).
    pub hit: bool,
}

/// Cumulative locality counters plus the scaled reuse-distance
/// histogram. Snapshots are cumulative-monotone, so two of them
/// subtract into a per-window delta ([`LocalitySample::diff`]) and
/// per-shard samples roll up by [`LocalitySample::merge`].
#[derive(Clone, Debug, Default)]
pub struct LocalitySample {
    /// Histogram of estimated reuse distances: per sampled re-access,
    /// the distinct-sampled-nodes-since-last-access count scaled by
    /// `1000 / sample_permille`. `dist.count()` is the number of
    /// sampled reuses.
    pub dist: LogHist,
    /// Every gather access observed (sampled or not).
    pub accesses: u64,
    /// Accesses that fell in the sampled node set.
    pub sampled: u64,
    /// Sampled first-touches (no previous access ⇒ compulsory miss at
    /// any capacity).
    pub cold: u64,
    /// Sampled reuses whose immediately preceding sampled access was
    /// in the **same** community.
    pub self_reuses: u64,
    /// Sampled reuses whose immediately preceding sampled access was
    /// in a **different** community.
    pub cross_reuses: u64,
}

impl LocalitySample {
    /// Sampled re-accesses (`self_reuses + cross_reuses`, and exactly
    /// `dist.count()`).
    pub fn reuses(&self) -> u64 {
        self.dist.count()
    }

    /// Mean estimated reuse distance over sampled reuses (0 when no
    /// reuse was observed).
    pub fn mean_distance(&self) -> f64 {
        self.dist.mean()
    }

    /// Fraction of sampled accesses that were first-touches (0 when
    /// nothing was sampled).
    pub fn cold_frac(&self) -> f64 {
        if self.sampled == 0 {
            0.0
        } else {
            self.cold as f64 / self.sampled as f64
        }
    }

    /// Fraction of sampled reuses that were self-community (0 when no
    /// reuse was observed).
    pub fn self_reuse_frac(&self) -> f64 {
        let reuses = self.self_reuses + self.cross_reuses;
        if reuses == 0 {
            0.0
        } else {
            self.self_reuses as f64 / reuses as f64
        }
    }

    /// True when nothing at all has been observed.
    pub fn is_empty(&self) -> bool {
        self.accesses == 0
    }

    /// Absorb another sample (per-shard roll-up into the run total).
    pub fn merge(&mut self, other: &LocalitySample) {
        self.dist.merge(&other.dist);
        self.accesses += other.accesses;
        self.sampled += other.sampled;
        self.cold += other.cold;
        self.self_reuses += other.self_reuses;
        self.cross_reuses += other.cross_reuses;
    }

    /// Delta `self − earlier` between two cumulative snapshots, for
    /// per-window sealing (counter subtraction saturates defensively;
    /// the histogram delta follows [`LogHist::diff`]).
    pub fn diff(&self, earlier: &LocalitySample) -> LocalitySample {
        LocalitySample {
            dist: self.dist.diff(&earlier.dist),
            accesses: self.accesses.saturating_sub(earlier.accesses),
            sampled: self.sampled.saturating_sub(earlier.sampled),
            cold: self.cold.saturating_sub(earlier.cold),
            self_reuses: self.self_reuses.saturating_sub(earlier.self_reuses),
            cross_reuses: self
                .cross_reuses
                .saturating_sub(earlier.cross_reuses),
        }
    }
}

#[inline]
fn spatial_hash(v: u32) -> u64 {
    // splitmix-style avalanche (same shape as span::id_sampled) so
    // dense node-id ranges sample uniformly
    let mut z = (v as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z >> 32
}

/// Stateless SHARDS membership test: is `node` in the sampled set at
/// `permille`? Every caller — worker taps, tests, offline replays —
/// gets the same answer for the same node, with no shared state.
#[inline]
pub fn node_sampled(node: u32, permille: u32) -> bool {
    if permille >= 1000 {
        return true;
    }
    if permille == 0 {
        return false;
    }
    (spatial_hash(node) % 1000) < permille as u64
}

const NIL: u32 = u32::MAX;

/// Fenwick (binary indexed) tree over last-access positions: prefix
/// sums in O(log n) give the count of active positions ≤ i, which is
/// all a stack-distance query needs.
struct Fenwick {
    tree: Vec<i64>,
}

impl Fenwick {
    fn new(n: usize) -> Fenwick {
        Fenwick { tree: vec![0; n + 1] }
    }

    fn add(&mut self, i: usize, d: i64) {
        let mut i = i + 1;
        while i < self.tree.len() {
            self.tree[i] += d;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum over positions `0..=i`.
    fn prefix(&self, i: usize) -> i64 {
        let mut i = i + 1;
        let mut s = 0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }
}

/// Exact Mattson stack-distance engine over the (sampled) access
/// stream. Each node's last access holds one *active* position in a
/// monotonically growing sequence; the stack distance of a re-access
/// is the number of active positions after the node's previous one
/// (= distinct nodes touched in between). When the position space
/// fills, active positions are compacted down to `0..active`
/// (amortized O(1) per access), doubling the space while more than
/// half of it is live.
struct Mattson {
    fen: Fenwick,
    pos_node: Vec<u32>,
    last_pos: HashMap<u32, usize>,
    next: usize,
    active: usize,
    cap: usize,
}

impl Mattson {
    fn new() -> Mattson {
        let cap = 1024;
        Mattson {
            fen: Fenwick::new(cap),
            pos_node: vec![NIL; cap],
            last_pos: HashMap::new(),
            next: 0,
            active: 0,
            cap,
        }
    }

    /// Observe one access; `Some(d)` = stack distance of a reuse
    /// (distinct nodes since the previous access, 0 = immediate
    /// re-access), `None` = first touch.
    fn access(&mut self, node: u32) -> Option<u64> {
        if self.next == self.cap {
            self.compact();
        }
        let q = self.next;
        self.next += 1;
        let dist = match self.last_pos.get(&node).copied() {
            Some(p) => {
                let after = self.active as i64 - self.fen.prefix(p);
                self.fen.add(p, -1);
                self.pos_node[p] = NIL;
                self.active -= 1;
                debug_assert!(after >= 0, "negative stack distance");
                Some(after.max(0) as u64)
            }
            None => None,
        };
        self.fen.add(q, 1);
        self.pos_node[q] = node;
        self.last_pos.insert(node, q);
        self.active += 1;
        dist
    }

    fn compact(&mut self) {
        let new_cap =
            if self.active * 2 >= self.cap { self.cap * 2 } else { self.cap };
        let mut pos_node = vec![NIL; new_cap];
        let mut fen = Fenwick::new(new_cap);
        let mut k = 0usize;
        for i in 0..self.cap {
            let n = self.pos_node[i];
            if n != NIL {
                pos_node[k] = n;
                fen.add(k, 1);
                self.last_pos.insert(n, k);
                k += 1;
            }
        }
        debug_assert_eq!(k, self.active);
        self.pos_node = pos_node;
        self.fen = fen;
        self.cap = new_cap;
        self.next = k;
    }
}

struct Inner {
    mat: Mattson,
    prev_comm: Option<u32>,
    cum: LocalitySample,
    trace: Vec<(u32, bool)>,
}

/// One device shard's locality profiler: accepts batched gather taps
/// from that shard's workers, maintains the Mattson state for the
/// sampled node set, and hands cumulative [`LocalitySample`] snapshots
/// to the telemetry thread and the final report.
pub struct LocalityShard {
    permille: u32,
    trace_cap: usize,
    trace_full: AtomicBool,
    inner: Mutex<Inner>,
}

impl LocalityShard {
    /// Fresh profiler for one device shard.
    pub fn new(cfg: LocalityConfig) -> LocalityShard {
        LocalityShard {
            permille: cfg.sample_permille.min(1000),
            trace_cap: cfg.trace_cap,
            trace_full: AtomicBool::new(cfg.trace_cap == 0),
            inner: Mutex::new(Inner {
                mat: Mattson::new(),
                prev_comm: None,
                cum: LocalitySample::default(),
                trace: Vec::new(),
            }),
        }
    }

    /// The configured sampling rate in permille.
    pub fn sample_permille(&self) -> u32 {
        self.permille
    }

    /// Lock-free membership test for the worker's tap: should this
    /// node's accesses be forwarded for distance profiling?
    #[inline]
    pub fn is_sampled(&self, node: u32) -> bool {
        node_sampled(node, self.permille)
    }

    /// Lock-free check: is the trace prefix still being captured? When
    /// true, the worker forwards **every** access of the batch (not
    /// just sampled ones) so the captured prefix mirrors the cache's
    /// real access order.
    #[inline]
    pub fn wants_trace(&self) -> bool {
        !self.trace_full.load(Ordering::Relaxed)
    }

    /// Ingest one micro-batch worth of gather taps under a single lock
    /// acquisition. `total_accesses` is the batch's full gather count
    /// (including nodes the worker filtered out); `batch` carries the
    /// accesses that are sampled and/or trace-captured, in cache
    /// access order.
    pub fn observe_batch(&self, total_accesses: u64, batch: &[Access]) {
        if total_accesses == 0 && batch.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        inner.cum.accesses += total_accesses;
        for a in batch {
            if inner.trace.len() < self.trace_cap {
                inner.trace.push((a.node, a.hit));
                if inner.trace.len() == self.trace_cap {
                    self.trace_full.store(true, Ordering::Relaxed);
                }
            }
            if !node_sampled(a.node, self.permille) {
                continue;
            }
            inner.cum.sampled += 1;
            match inner.mat.access(a.node) {
                Some(d) => {
                    let est = if self.permille >= 1000 {
                        d
                    } else {
                        d.saturating_mul(1000) / self.permille as u64
                    };
                    inner.cum.dist.record(est);
                    match inner.prev_comm {
                        Some(pc) if pc == a.comm => {
                            inner.cum.self_reuses += 1
                        }
                        _ => inner.cum.cross_reuses += 1,
                    }
                }
                None => inner.cum.cold += 1,
            }
            inner.prev_comm = Some(a.comm);
        }
    }

    /// Clone of the cumulative sample (telemetry ticks and the final
    /// report diff/merge these).
    pub fn snapshot(&self) -> LocalitySample {
        self.inner.lock().unwrap().cum.clone()
    }

    /// The captured access-trace prefix as `(node, fresh_hit)` pairs,
    /// in cache access order.
    pub fn trace(&self) -> Vec<(u32, bool)> {
        self.inner.lock().unwrap().trace.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Exact (unsampled) reference: LRU recency list, distance = index
    /// of the node in it. O(n·d) but fine for test-sized streams.
    struct NaiveMattson {
        order: Vec<u32>, // most-recent first
    }

    impl NaiveMattson {
        fn new() -> NaiveMattson {
            NaiveMattson { order: Vec::new() }
        }

        fn access(&mut self, node: u32) -> Option<u64> {
            match self.order.iter().position(|&v| v == node) {
                Some(i) => {
                    self.order.remove(i);
                    self.order.insert(0, node);
                    Some(i as u64)
                }
                None => {
                    self.order.insert(0, node);
                    None
                }
            }
        }
    }

    fn zipfish_stream(n_nodes: u32, len: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..len)
            .map(|_| {
                // square the uniform to skew toward low ids
                let u = rng.below(n_nodes as u64) as f64
                    / n_nodes as f64;
                ((u * u) * n_nodes as f64) as u32
            })
            .collect()
    }

    /// At permille=1000 the profiler *is* exact Mattson: its distance
    /// histogram must match a naive reference bucket-for-bucket, over
    /// a stream long enough to force several position-space
    /// compactions.
    #[test]
    fn full_rate_profiler_matches_exact_mattson() {
        let stream = zipfish_stream(300, 50_000, 7);
        let shard = LocalityShard::new(LocalityConfig {
            sample_permille: 1000,
            trace_cap: 0,
        });
        let batch: Vec<Access> = stream
            .iter()
            .map(|&v| Access { node: v, comm: v % 4, hit: false })
            .collect();
        // feed in micro-batch sized chunks like the worker does
        for chunk in batch.chunks(97) {
            shard.observe_batch(chunk.len() as u64, chunk);
        }
        let mut naive = NaiveMattson::new();
        let mut want = LogHist::new();
        let mut cold = 0u64;
        for &v in &stream {
            match naive.access(v) {
                Some(d) => want.record(d),
                None => cold += 1,
            }
        }
        let got = shard.snapshot();
        assert_eq!(got.accesses, stream.len() as u64);
        assert_eq!(got.sampled, stream.len() as u64);
        assert_eq!(got.cold, cold);
        assert_eq!(got.reuses(), want.count());
        assert_eq!(got.self_reuses + got.cross_reuses, got.reuses());
        assert!(got.dist.buckets().eq(want.buckets()), "distance buckets");
        assert_eq!(got.dist.sum(), want.sum());
    }

    /// Satellite test: the SHARDS-sampled estimate stays within
    /// bounded error of the exact computation. A cyclic scan over N
    /// nodes has true stack distance N−1 for every reuse; the sampled
    /// profiler sees only its hash-selected subset and scales back up.
    #[test]
    fn sampled_estimate_is_within_bounded_error_of_exact() {
        let n: u32 = 2_000;
        let stream: Vec<u32> =
            (0..6 * n).map(|i| i % n).collect();
        let exact_mean = (n - 1) as f64;
        for permille in [250u32, 500] {
            let shard = LocalityShard::new(LocalityConfig {
                sample_permille: permille,
                trace_cap: 0,
            });
            let batch: Vec<Access> = stream
                .iter()
                .map(|&v| Access { node: v, comm: 0, hit: false })
                .collect();
            shard.observe_batch(batch.len() as u64, &batch);
            let s = shard.snapshot();
            // the sampled set is ~permille/1000 of the nodes
            let frac = s.cold as f64 / n as f64;
            assert!(
                (frac - permille as f64 / 1000.0).abs() < 0.05,
                "sampled-set fraction {frac} at {permille}‰"
            );
            let est = s.mean_distance();
            let rel = (est - exact_mean).abs() / exact_mean;
            assert!(
                rel < 0.15,
                "estimated mean {est:.0} vs exact {exact_mean:.0} \
                 (rel {rel:.3}) at {permille}‰"
            );
            // all accesses observed, only the sampled subset profiled
            assert_eq!(s.accesses, stream.len() as u64);
            assert!(s.sampled < s.accesses);
        }
    }

    /// Community-coherent streams score high self-reuse affinity;
    /// interleaved streams score low — the counter the `p` knob moves.
    #[test]
    fn affinity_separates_coherent_from_interleaved_streams() {
        let mk = |interleave: bool| {
            let shard = LocalityShard::new(LocalityConfig {
                sample_permille: 1000,
                trace_cap: 0,
            });
            let mut batch = Vec::new();
            for _round in 0..6 {
                for i in 0..40u32 {
                    let comm = if interleave {
                        // alternate communities access to access
                        i % 2
                    } else {
                        // one community's nodes, then the other's
                        u32::from(i >= 20)
                    };
                    batch.push(Access { node: i, comm, hit: false });
                }
            }
            shard.observe_batch(batch.len() as u64, &batch);
            shard.snapshot().self_reuse_frac()
        };
        let coherent = mk(false);
        let interleaved = mk(true);
        assert!(
            coherent > 0.9,
            "coherent stream self-reuse {coherent:.2}"
        );
        assert!(
            interleaved < 0.1,
            "interleaved stream self-reuse {interleaved:.2}"
        );
    }

    /// The trace prefix is bounded, ordered, and closes itself.
    #[test]
    fn trace_capture_is_a_bounded_prefix() {
        let shard = LocalityShard::new(LocalityConfig {
            sample_permille: 0,
            trace_cap: 8,
        });
        assert!(shard.wants_trace());
        let batch: Vec<Access> = (0..20u32)
            .map(|i| Access { node: i, comm: 0, hit: i % 2 == 0 })
            .collect();
        shard.observe_batch(batch.len() as u64, &batch);
        assert!(!shard.wants_trace());
        let trace = shard.trace();
        assert_eq!(trace.len(), 8);
        for (i, &(node, hit)) in trace.iter().enumerate() {
            assert_eq!(node, i as u32);
            assert_eq!(hit, i % 2 == 0);
        }
        // permille=0 still counts raw accesses but profiles nothing
        let s = shard.snapshot();
        assert_eq!(s.accesses, 20);
        assert_eq!(s.sampled, 0);
        assert!(s.dist.is_empty());
    }

    /// Cumulative snapshots diff into exact per-window deltas and
    /// per-shard samples merge into the run total.
    #[test]
    fn snapshot_diff_and_merge_follow_the_window_discipline() {
        let shard = LocalityShard::new(LocalityConfig {
            sample_permille: 1000,
            trace_cap: 0,
        });
        let early: Vec<Access> = (0..50u32)
            .map(|i| Access { node: i % 10, comm: 0, hit: false })
            .collect();
        shard.observe_batch(early.len() as u64, &early);
        let snap1 = shard.snapshot();
        let late: Vec<Access> = (0..70u32)
            .map(|i| Access { node: i % 7, comm: 1, hit: true })
            .collect();
        shard.observe_batch(late.len() as u64, &late);
        let snap2 = shard.snapshot();
        let w = snap2.diff(&snap1);
        assert_eq!(w.accesses, 70);
        assert_eq!(w.sampled, 70);
        // every sampled access in the window is either a reuse or cold
        assert_eq!(w.reuses() + w.cold, 70);
        // merging the window back onto the earlier snapshot restores
        // the cumulative counters
        let mut merged = snap1.clone();
        merged.merge(&w);
        assert_eq!(merged.accesses, snap2.accesses);
        assert_eq!(merged.sampled, snap2.sampled);
        assert_eq!(merged.cold, snap2.cold);
        assert_eq!(merged.reuses(), snap2.reuses());
        assert_eq!(merged.dist.sum(), snap2.dist.sum());
    }

    #[test]
    fn node_sampling_is_spatial_and_proportional() {
        for v in 0..100 {
            assert!(node_sampled(v, 1000));
            assert!(!node_sampled(v, 0));
            // deterministic per node
            assert_eq!(node_sampled(v, 300), node_sampled(v, 300));
        }
        let kept =
            (0..100_000u32).filter(|&v| node_sampled(v, 100)).count();
        let frac = kept as f64 / 100_000.0;
        assert!((frac - 0.1).abs() < 0.01, "kept {frac:.3} at 10%");
    }
}
