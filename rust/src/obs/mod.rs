//! Low-overhead observability for the serving stack.
//!
//! Three pieces, all always compiled in and threaded through the
//! serving pipeline (`serve/`), the streaming maintainer and the
//! checkpoint watcher:
//!
//! * [`span`] — per-request span timelines (enqueue → admission →
//!   queue wait → coalesce → sample → gather → execute → reply) in
//!   fixed-capacity lock-free per-track rings, with stateless
//!   per-request sampling (`trace_sample=`) and explicit dropped-event
//!   accounting;
//! * [`hist`] — mergeable log-bucketed (HDR-style) histograms that
//!   replace the collect-then-sort percentile path in `ServeReport` /
//!   `ShardReport`, bounding quantile error at ~3% in fixed memory;
//! * [`export`] — Chrome trace-event JSON (`trace=PATH`, loadable in
//!   Perfetto) and Prometheus text-exposition snapshots
//!   (`metrics_ms=`).
//!
//! On top of those sit the **temporal health layer**'s four pieces,
//! driven by the engine's telemetry thread when `health_ms=` is set:
//!
//! * [`series`] — rolling windowed time-series: per-window [`LogHist`]
//!   deltas + counter deltas in a bounded ring, so
//!   latency/shed/stale/dedup/purity/accuracy are queryable *recent
//!   history* instead of run-lifetime aggregates;
//! * [`slo`] — declarative SLO targets evaluated with multi-window
//!   fast/slow burn-rate alerting and hysteresis (`slo=` knob), alert
//!   transitions recorded as trace events and exported in [`PromText`];
//! * [`watchdog`] — heartbeat liveness for every long-lived serving
//!   thread, with busy/idle semantics so blocking-on-work is healthy
//!   but wedged-mid-batch is a detected stall;
//! * [`flight`] — the flight recorder: on first alert fire or stall
//!   (`flight=` knob) it atomically dumps a postmortem bundle — span
//!   rings, recent windows, alert history, resolved config, per-shard
//!   state — to `results/postmortem-*/`.
//!
//! Alongside the health layer sits the **locality observatory**
//! (`locality=` knob), which watches memory-access *structure* rather
//! than time:
//!
//! * [`locality`] — an online SHARDS-sampled Mattson reuse-distance
//!   profiler tapped into every shard's feature-gather path, with
//!   self/cross-community access-affinity counters and a bounded
//!   access-trace prefix for offline [`crate::cachesim`] cross-checks;
//! * [`mrc`] — turns the distance histogram into a miss-ratio curve
//!   (predicted hit rate at *every* capacity from one pass) and a
//!   cache right-sizing advisor, cross-checked live against the
//!   serving cache's observed hit rate.
//!
//! The overhead contract — full-rate tracing costs ≤ 5% serve
//! throughput — is enforced by `exp obs`
//! ([`crate::exp::obs`]), which runs the same bench with tracing off /
//! sampled / full and fails the run if the gap exceeds the budget; the
//! health layer carries the same ≤ 5% bound, enforced by `exp health`
//! ([`crate::exp::health`]), and the locality profiler the same bound
//! again, enforced by `exp locality` ([`crate::exp::locality`]).

pub mod export;
pub mod flight;
pub mod hist;
pub mod locality;
pub mod mrc;
pub mod series;
pub mod slo;
pub mod span;
pub mod watchdog;

pub use export::{write_chrome_trace, ExportSummary, PromText};
pub use flight::{dump_postmortem, read_postmortem, PostmortemBundle};
pub use hist::LogHist;
pub use locality::{
    node_sampled, Access, LocalityConfig, LocalitySample, LocalityShard,
};
pub use mrc::{
    advise, curve, miss_ratio_at, CacheAdvice, MrcPoint,
    DEFAULT_TARGET_HIT_RATE,
};
pub use series::{HealthSample, SeriesConfig, Window, WindowedSeries};
pub use slo::{SloKind, SloRuntime, SloSpec, SloTarget};
pub use span::{
    shard_track, track_name, Event, EventKind, EventRing, Recorder,
    TRACK_BATCHER, TRACK_CLIENT, TRACK_MAINTAINER, TRACK_WATCHER,
};
pub use watchdog::{Heartbeat, HeartbeatState, Stall, Watchdog};
