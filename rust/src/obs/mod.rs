//! Low-overhead observability for the serving stack.
//!
//! Three pieces, all always compiled in and threaded through the
//! serving pipeline (`serve/`), the streaming maintainer and the
//! checkpoint watcher:
//!
//! * [`span`] — per-request span timelines (enqueue → admission →
//!   queue wait → coalesce → sample → gather → execute → reply) in
//!   fixed-capacity lock-free per-track rings, with stateless
//!   per-request sampling (`trace_sample=`) and explicit dropped-event
//!   accounting;
//! * [`hist`] — mergeable log-bucketed (HDR-style) histograms that
//!   replace the collect-then-sort percentile path in `ServeReport` /
//!   `ShardReport`, bounding quantile error at ~3% in fixed memory;
//! * [`export`] — Chrome trace-event JSON (`trace=PATH`, loadable in
//!   Perfetto) and Prometheus text-exposition snapshots
//!   (`metrics_ms=`).
//!
//! The overhead contract — full-rate tracing costs ≤ 5% serve
//! throughput — is enforced by `exp obs`
//! ([`crate::exp::obs`]), which runs the same bench with tracing off /
//! sampled / full and fails the run if the gap exceeds the budget.

pub mod export;
pub mod hist;
pub mod span;

pub use export::{write_chrome_trace, ExportSummary, PromText};
pub use hist::LogHist;
pub use span::{
    shard_track, track_name, Event, EventKind, EventRing, Recorder,
    TRACK_BATCHER, TRACK_CLIENT, TRACK_MAINTAINER, TRACK_WATCHER,
};
