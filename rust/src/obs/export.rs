//! Trace and metrics exporters.
//!
//! Two output formats, both hand-emitted (no serde offline):
//!
//! * **Chrome trace-event JSON** ([`write_chrome_trace`]) — the
//!   `{"traceEvents": [...]}` format Perfetto and `chrome://tracing`
//!   load directly. Each recorder track becomes one named thread
//!   (`pid` 0): span events (`ph:"X"`, with `ts`/`dur` in µs on the
//!   run's shared clock) for queue wait / coalesce / sample / gather /
//!   execute, instant events (`ph:"i"`) for enqueue, admission
//!   outcomes, replies and the churn / maintainer / checkpoint-watcher
//!   markers. Per-kind counters ride in `args` (cache hit/stale/miss
//!   tags on gather, community purity on coalesce, …), so the `p`
//!   knob's locality effect is visible directly in the trace UI.
//! * **Prometheus text exposition** ([`PromText`]) — a plain-text
//!   snapshot of counters, gauges and histogram summaries, rewritten
//!   atomically every `metrics_ms=` by the engine's metrics thread.
//!
//! The Chrome exporter returns an [`ExportSummary`] (span / instant /
//! dropped counts) that the CLI prints and the CI trace-smoke job
//! gates on: an empty trace or an unaccounted drop is an error, never
//! a silently small file.

use std::path::Path;

use anyhow::{bail, Result};

use crate::util::json::{arr, num, obj, s, Json};

use super::hist::LogHist;
use super::span::{track_name, Recorder};

/// What [`write_chrome_trace`] emitted, for gating and logs.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExportSummary {
    /// Complete (`ph:"X"`) span events written.
    pub spans: u64,
    /// Instant (`ph:"i"`) events written.
    pub instants: u64,
    /// Events lost to ring wraparound before export (also recorded in
    /// the trace's metadata so the file itself is self-describing).
    pub dropped: u64,
}

/// Write the recorder's retained events as Chrome trace-event JSON at
/// `path`. Fails if the recorder is enabled but exported **zero**
/// events — a trace that silently says nothing is a bug, not a result.
pub fn write_chrome_trace(path: &Path, rec: &Recorder) -> Result<ExportSummary> {
    if !rec.is_enabled() {
        bail!("trace export requested but the recorder is disabled");
    }
    let mut events: Vec<Json> = Vec::new();
    let mut summary = ExportSummary { dropped: rec.total_dropped(), ..Default::default() };
    for (track, ring) in rec.rings().iter().enumerate() {
        events.push(obj(vec![
            ("name", s("thread_name")),
            ("ph", s("M")),
            ("pid", num(0.0)),
            ("tid", num(track as f64)),
            ("args", obj(vec![("name", s(&track_name(track)))])),
        ]));
        for ev in ring.snapshot() {
            let mut fields = vec![
                ("name", s(ev.kind.name())),
                ("cat", s("serve")),
                ("pid", num(0.0)),
                ("tid", num(track as f64)),
                ("ts", num(ev.ts_us as f64)),
                ("args", event_args(&ev)),
            ];
            if ev.kind.is_span() {
                summary.spans += 1;
                fields.push(("ph", s("X")));
                fields.push(("dur", num(ev.dur_us as f64)));
            } else if ev.kind == super::span::EventKind::Locality {
                // counter-track sample: Perfetto plots the args as a
                // per-process curve (mean reuse distance, predicted
                // miss, self-community reuse over the run)
                summary.instants += 1;
                fields.push(("ph", s("C")));
            } else {
                summary.instants += 1;
                fields.push(("ph", s("i")));
                fields.push(("s", s("t"))); // thread-scoped instant
            }
            events.push(obj(fields));
        }
    }
    if summary.spans + summary.instants == 0 {
        bail!(
            "trace export at {} produced zero events — tracing was on \
             but nothing was recorded",
            path.display()
        );
    }
    let doc = obj(vec![
        ("traceEvents", arr(events)),
        ("displayTimeUnit", s("ms")),
        (
            "otherData",
            obj(vec![
                ("dropped_events", num(summary.dropped as f64)),
                ("sample_permille", num(rec.sample_permille() as f64)),
            ]),
        ),
    ]);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, doc.to_string_pretty())?;
    Ok(summary)
}

/// Per-kind `args` payload names, mirroring the [`super::span::EventKind`]
/// counter documentation.
fn event_args(ev: &super::span::Event) -> Json {
    use super::span::EventKind as K;
    let n = |x: u32| num(x as f64);
    let mut pairs: Vec<(&str, Json)> = match ev.kind {
        K::Coalesce => vec![
            ("batch", n(ev.a)),
            ("purity_permille", n(ev.b)),
            ("communities", n(ev.c)),
        ],
        K::Sample => vec![
            ("refs", n(ev.a)),
            ("input_nodes", n(ev.b)),
            ("overlap_permille", n(ev.c)),
        ],
        K::Gather => vec![
            ("hits", n(ev.a)),
            ("misses", n(ev.b)),
            ("stale", n(ev.c)),
        ],
        K::Execute => vec![
            ("batch", n(ev.a)),
            ("param_version", n(ev.b)),
        ],
        K::Reply => vec![
            ("deadline_missed", n(ev.a)),
            ("error", n(ev.b)),
        ],
        K::Degrade => vec![("fanout0", n(ev.a))],
        K::Churn => vec![("applied", n(ev.a)), ("moves", n(ev.b))],
        K::Refine => vec![("visited", n(ev.a)), ("moves", n(ev.b))],
        K::Relabel => vec![("num_comms", n(ev.a))],
        K::CkptSwap => vec![("epoch", n(ev.a))],
        K::MetricsFlush => vec![("seq", n(ev.a))],
        K::SloFire | K::SloClear => vec![
            ("slo", n(ev.a)),
            ("burn_fast_x100", n(ev.b)),
            ("burn_slow_x100", n(ev.c)),
        ],
        K::Stall => vec![("thread", n(ev.a)), ("silent_ms", n(ev.b))],
        K::Locality => vec![
            ("mean_reuse_distance", n(ev.a)),
            ("pred_miss_permille", n(ev.b)),
            ("self_reuse_permille", n(ev.c)),
        ],
        K::Enqueue | K::Shed | K::QueueWait => vec![],
    };
    if ev.req_id != 0 {
        pairs.push(("req", num(ev.req_id as f64)));
    }
    obj(pairs)
}

/// Prometheus text-exposition builder. The engine's metrics thread
/// fills one of these every `metrics_ms=` and writes it atomically
/// (tmp + rename), so a scrape never reads a torn snapshot.
#[derive(Default)]
pub struct PromText {
    buf: String,
}

/// Escape one label value per the Prometheus text-exposition rules:
/// backslash, double quote and newline must be escaped, and the
/// backslash **first** (escaping it last would re-escape the
/// backslashes the other two replacements just introduced, producing
/// invalid exposition text — the satellite bug this fixes).
fn escape_label_value(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn fmt_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

impl PromText {
    /// Empty snapshot.
    pub fn new() -> PromText {
        PromText::default()
    }

    /// `# HELP` / `# TYPE` header for a metric family. Emit once per
    /// family, before its samples.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        self.buf.push_str(&format!("# HELP {name} {help}\n"));
        self.buf.push_str(&format!("# TYPE {name} {kind}\n"));
    }

    /// One counter/gauge sample.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.buf
            .push_str(&format!("{name}{} {v}\n", fmt_labels(labels)));
    }

    /// A histogram as a Prometheus *summary*: `{quantile=...}` samples
    /// straight from the shared [`LogHist`] — the very same buckets
    /// the `ServeReport` percentiles come from, so the two can never
    /// disagree.
    pub fn summary(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        h: &LogHist,
    ) {
        for (q, qs) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
            let mut ls: Vec<(&str, &str)> = labels.to_vec();
            ls.push(("quantile", qs));
            self.sample(name, &ls, h.quantile(q) as f64);
        }
        self.buf.push_str(&format!(
            "{name}_sum{} {}\n",
            fmt_labels(labels),
            h.sum()
        ));
        self.buf.push_str(&format!(
            "{name}_count{} {}\n",
            fmt_labels(labels),
            h.count()
        ));
    }

    /// The accumulated exposition text.
    pub fn text(&self) -> &str {
        &self.buf
    }

    /// Write atomically at `path` (tmp file + rename).
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, &self.buf)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::{
        shard_track, EventKind, TRACK_BATCHER, TRACK_CLIENT,
    };
    use std::time::Instant;

    fn tmppath(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("comm_rand_obs_{tag}_{}.json", std::process::id()))
    }

    /// Build a recorder with two requests' worth of realistic events,
    /// export it, re-parse the JSON, and check structure: valid
    /// trace-event fields, thread names present, and every traced
    /// request's spans well-ordered (queue_wait before sample before
    /// gather before execute) with phase durations summing to at most
    /// the request's wall time.
    #[test]
    fn chrome_trace_round_trips_and_spans_nest() {
        let rec = Recorder::new(1, 1024, 1000, Instant::now());
        for (req, base) in [(1u64, 100u64), (2, 200)] {
            rec.instant(TRACK_CLIENT, EventKind::Enqueue, base, req, 0, 0, 0);
            rec.span(
                TRACK_CLIENT, EventKind::QueueWait, base, 50, req, 0, 0, 0,
            );
            let t = shard_track(0);
            rec.span(t, EventKind::Sample, base + 50, 20, req, 8, 64, 300);
            rec.span(t, EventKind::Gather, base + 70, 15, req, 40, 20, 4);
            rec.span(t, EventKind::Execute, base + 85, 10, req, 8, 1, 0);
            rec.instant(TRACK_CLIENT, EventKind::Reply, base + 95, req, 0, 0, 0);
        }
        rec.instant(TRACK_BATCHER, EventKind::Coalesce, 90, 0, 8, 875, 2);
        let path = tmppath("roundtrip");
        let summary = write_chrome_trace(&path, &rec).unwrap();
        assert_eq!(summary.dropped, 0);
        assert!(summary.spans >= 8, "8 spans recorded, got {}", summary.spans);

        let doc = Json::parse_file(&path).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // thread-name metadata for all 5 tracks (4 fixed + 1 shard)
        let names: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "M")
            .map(|e| {
                e.get("args").unwrap().get("name").unwrap().as_str().unwrap()
            })
            .collect();
        assert_eq!(names.len(), 5);
        assert!(names.contains(&"batcher"));
        assert!(names.contains(&"shard0"));

        // per-request span ordering + wall-time bound
        for req in [1.0, 2.0] {
            let mut spans: Vec<(&str, f64, f64)> = events
                .iter()
                .filter(|e| {
                    e.get("ph").unwrap().as_str().unwrap() == "X"
                        && e.get("args")
                            .unwrap()
                            .opt("req")
                            .map(|r| r.as_f64().unwrap() == req)
                            .unwrap_or(false)
                })
                .map(|e| {
                    (
                        e.get("name").unwrap().as_str().unwrap(),
                        e.get("ts").unwrap().as_f64().unwrap(),
                        e.get("dur").unwrap().as_f64().unwrap(),
                    )
                })
                .collect();
            spans.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let order: Vec<&str> = spans.iter().map(|s| s.0).collect();
            assert_eq!(
                order,
                vec!["queue_wait", "sample", "gather", "execute"],
                "span order for req {req}"
            );
            // spans do not overlap backwards and fit the wall time
            for w in spans.windows(2) {
                assert!(
                    w[0].1 + w[0].2 <= w[1].1 + 1e-9,
                    "span {} overlaps {}",
                    w[0].0,
                    w[1].0
                );
            }
            let wall = 95.0; // enqueue -> reply
            let total: f64 = spans.iter().map(|s| s.2).sum();
            assert!(total <= wall, "phases {total} exceed wall {wall}");
        }

        // gather spans carry the cache tags
        let gather = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str().unwrap() == "gather")
            .unwrap();
        let args = gather.get("args").unwrap();
        assert_eq!(args.get("hits").unwrap().as_usize().unwrap(), 40);
        assert_eq!(args.get("misses").unwrap().as_usize().unwrap(), 20);
        assert_eq!(args.get("stale").unwrap().as_usize().unwrap(), 4);
        // coalesce carries the purity counter
        let coalesce = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str().unwrap() == "coalesce")
            .unwrap();
        assert_eq!(
            coalesce
                .get("args")
                .unwrap()
                .get("purity_permille")
                .unwrap()
                .as_usize()
                .unwrap(),
            875
        );
        // dropped count is in the file itself
        assert_eq!(
            doc.get("otherData")
                .unwrap()
                .get("dropped_events")
                .unwrap()
                .as_usize()
                .unwrap(),
            0
        );
        std::fs::remove_file(&path).ok();
    }

    /// Locality windows export as Chrome counter-track samples
    /// (`ph:"C"`) carrying the curve values in `args`.
    #[test]
    fn locality_windows_export_as_counter_samples() {
        let rec = Recorder::new(1, 64, 1000, Instant::now());
        rec.instant(TRACK_CLIENT, EventKind::Locality, 50, 0, 120, 250, 900);
        rec.instant(TRACK_CLIENT, EventKind::Locality, 100, 0, 80, 150, 950);
        let path = tmppath("loccounter");
        let summary = write_chrome_trace(&path, &rec).unwrap();
        assert_eq!(summary.instants, 2);
        let doc = Json::parse_file(&path).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let counters: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "C")
            .collect();
        assert_eq!(counters.len(), 2);
        assert_eq!(
            counters[0].get("name").unwrap().as_str().unwrap(),
            "locality"
        );
        let args = counters[0].get("args").unwrap();
        assert_eq!(
            args.get("mean_reuse_distance").unwrap().as_usize().unwrap(),
            120
        );
        assert_eq!(
            args.get("pred_miss_permille").unwrap().as_usize().unwrap(),
            250
        );
        assert_eq!(
            args.get("self_reuse_permille").unwrap().as_usize().unwrap(),
            900
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_enabled_trace_is_an_error() {
        let rec = Recorder::new(1, 16, 1000, Instant::now());
        let path = tmppath("empty");
        assert!(write_chrome_trace(&path, &rec).is_err());
        assert!(write_chrome_trace(&path, &Recorder::disabled()).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// Satellite regression: label values containing backslashes,
    /// quotes and newlines must come out as valid exposition text.
    /// Before the fix only quotes were escaped, so a value like
    /// `C:\path` or a multi-line alert message produced a snapshot
    /// Prometheus rejects.
    #[test]
    fn label_values_escape_backslash_quote_and_newline() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value(r#"say "hi""#), r#"say \"hi\""#);
        assert_eq!(escape_label_value(r"C:\path"), r"C:\\path");
        assert_eq!(escape_label_value("a\nb"), r"a\nb");
        // order matters: the backslash introduced by quote-escaping
        // must NOT be re-escaped
        assert_eq!(escape_label_value("\\\""), r#"\\\""#);
        let mut p = PromText::new();
        p.sample(
            "m",
            &[("path", "C:\\tmp\n\"x\"")],
            1.0,
        );
        assert_eq!(p.text(), "m{path=\"C:\\\\tmp\\n\\\"x\\\"\"} 1\n");
    }

    #[test]
    fn prom_text_shape() {
        let mut h = LogHist::new();
        for v in [100u64, 200, 300, 400, 5000] {
            h.record(v);
        }
        let mut p = PromText::new();
        p.family("serve_queue_depth", "gauge", "requests waiting");
        p.sample("serve_queue_depth", &[], 7.0);
        p.family("serve_cache_hits_total", "counter", "feature cache hits");
        p.sample("serve_cache_hits_total", &[("shard", "0")], 123.0);
        p.family("serve_latency_us", "summary", "request latency");
        p.summary("serve_latency_us", &[("shard", "0")], &h);
        let t = p.text();
        assert!(t.contains("# TYPE serve_queue_depth gauge"));
        assert!(t.contains("serve_queue_depth 7\n"));
        assert!(t.contains("serve_cache_hits_total{shard=\"0\"} 123\n"));
        assert!(t.contains("serve_latency_us{shard=\"0\",quantile=\"0.5\"}"));
        assert!(t.contains("serve_latency_us_count{shard=\"0\"} 5\n"));
        assert!(t.contains("serve_latency_us_sum{shard=\"0\"} 6000\n"));
        // atomic write lands the file
        let path = tmppath("prom");
        p.write(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), t);
        std::fs::remove_file(&path).ok();
    }
}
