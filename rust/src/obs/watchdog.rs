//! Heartbeat-based liveness for the serving run's long-lived threads.
//!
//! Every long-lived thread of a serving run — per-shard workers, the
//! micro-batcher, the churn maintainer, the checkpoint watcher, the
//! telemetry thread itself — owns one [`Heartbeat`] slot in the run's
//! [`Watchdog`]. A beat is two relaxed atomic stores, cheap enough to
//! stamp on every loop iteration; the health tick then calls
//! [`Watchdog::check`] and declares any thread *stalled* that has been
//! **busy** with no beat for longer than the stall bound.
//!
//! The busy/idle distinction is what keeps this sound for workers that
//! block on a channel `recv()`: a worker marks itself *idle*
//! immediately before blocking and *busy* immediately after a batch
//! arrives, so a worker waiting for work is silent-but-idle (healthy)
//! while a worker wedged mid-batch — stuck in a poisoned lock, an
//! executor that never returns, an unbounded retry — is
//! silent-but-busy (stalled). Loop-style threads (batcher, churn,
//! telemetry, watcher) just beat busy at the top of every bounded-wait
//! iteration, so a wedged loop goes silent and trips the same check.
//!
//! Stalls surface three ways: a [`crate::obs::span::EventKind::Stall`]
//! trace instant, the `health{}` section of the serve report, and —
//! when a flight recorder is configured — a postmortem bundle
//! ([`crate::obs::flight`]).

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

/// Lifecycle states a heartbeat can report (the `u8` stored in the
/// slot).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum HeartbeatState {
    /// Waiting for work (blocking on a queue); silence is healthy.
    Idle = 0,
    /// Processing; prolonged silence means the thread is wedged.
    Busy = 1,
    /// Exited cleanly; never considered stalled.
    Retired = 2,
}

impl HeartbeatState {
    fn from_u8(v: u8) -> HeartbeatState {
        match v {
            1 => HeartbeatState::Busy,
            2 => HeartbeatState::Retired,
            _ => HeartbeatState::Idle,
        }
    }
}

/// One thread's liveness slot: last beat timestamp, a beat counter and
/// the busy/idle/retired state, all relaxed atomics — a beat never
/// takes a lock and never allocates.
#[derive(Debug, Default)]
pub struct Heartbeat {
    last_beat_us: AtomicU64,
    beats: AtomicU64,
    state: AtomicU8,
}

impl Heartbeat {
    /// Fresh slot in the [`HeartbeatState::Idle`] state.
    pub fn new() -> Heartbeat {
        Heartbeat::default()
    }

    /// Mark the thread busy (processing) as of `now_us`.
    #[inline]
    pub fn busy(&self, now_us: u64) {
        self.state.store(HeartbeatState::Busy as u8, Ordering::Relaxed);
        self.last_beat_us.store(now_us, Ordering::Relaxed);
        self.beats.fetch_add(1, Ordering::Relaxed);
    }

    /// Mark the thread idle (about to block waiting for work) as of
    /// `now_us`.
    #[inline]
    pub fn idle(&self, now_us: u64) {
        self.state.store(HeartbeatState::Idle as u8, Ordering::Relaxed);
        self.last_beat_us.store(now_us, Ordering::Relaxed);
        self.beats.fetch_add(1, Ordering::Relaxed);
    }

    /// Mark the thread cleanly exited; it can never be stalled again.
    #[inline]
    pub fn retire(&self) {
        self.state
            .store(HeartbeatState::Retired as u8, Ordering::Relaxed);
    }

    /// Timestamp of the most recent beat (µs, run clock).
    pub fn last_beat_us(&self) -> u64 {
        self.last_beat_us.load(Ordering::Relaxed)
    }

    /// Total beats ever recorded.
    pub fn beats(&self) -> u64 {
        self.beats.load(Ordering::Relaxed)
    }

    /// Current reported state.
    pub fn state(&self) -> HeartbeatState {
        HeartbeatState::from_u8(self.state.load(Ordering::Relaxed))
    }
}

/// One stalled thread found by [`Watchdog::check`].
#[derive(Clone, Debug)]
pub struct Stall {
    /// Registration index of the stalled thread (the `a` payload of
    /// the emitted [`crate::obs::span::EventKind::Stall`] instant).
    pub index: usize,
    /// Registered thread name (`shard0/worker1`, `batcher`, …).
    pub name: String,
    /// µs since the thread's last heartbeat.
    pub silent_us: u64,
}

/// The run-wide registry of heartbeats. Threads are registered (by
/// name) before the serving scope spawns them; each thread then beats
/// its own slot by shared reference, and the telemetry thread sweeps
/// all slots with [`Watchdog::check`].
#[derive(Debug, Default)]
pub struct Watchdog {
    names: Vec<String>,
    slots: Vec<Heartbeat>,
}

impl Watchdog {
    /// Empty registry.
    pub fn new() -> Watchdog {
        Watchdog::default()
    }

    /// Register a named thread; returns its slot index. Call before
    /// spawning (registration needs `&mut`, beating only `&`).
    pub fn register(&mut self, name: &str) -> usize {
        self.names.push(name.to_string());
        self.slots.push(Heartbeat::new());
        self.slots.len() - 1
    }

    /// The heartbeat slot for index `i`.
    pub fn hb(&self, i: usize) -> &Heartbeat {
        &self.slots[i]
    }

    /// Registered thread count.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when no thread is registered.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Registered name for index `i`.
    pub fn name(&self, i: usize) -> &str {
        &self.names[i]
    }

    /// Sweep every slot: a thread is stalled iff it reports
    /// [`HeartbeatState::Busy`] and its last beat is more than
    /// `stall_us` µs before `now_us`. Idle and retired threads are
    /// never stalled, and a busy thread that has never beaten is
    /// impossible by construction (`busy` is itself a beat).
    pub fn check(&self, now_us: u64, stall_us: u64) -> Vec<Stall> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, hb)| hb.state() == HeartbeatState::Busy)
            .filter_map(|(i, hb)| {
                let silent = now_us.saturating_sub(hb.last_beat_us());
                (silent > stall_us).then(|| Stall {
                    index: i,
                    name: self.names[i].clone(),
                    silent_us: silent,
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_and_retired_threads_are_never_stalled() {
        let mut wd = Watchdog::new();
        let idle = wd.register("idle-worker");
        let retired = wd.register("retired-worker");
        wd.hb(idle).idle(100);
        wd.hb(retired).busy(100);
        wd.hb(retired).retire();
        // both silent for far longer than the bound
        assert!(wd.check(10_000_000, 1_000).is_empty());
    }

    /// Satellite test: an injected stalled worker — marked busy, then
    /// silent past the bound — is detected by name, while a healthy
    /// worker beating away is not.
    #[test]
    fn busy_silent_thread_is_detected_as_stalled() {
        let mut wd = Watchdog::new();
        let wedged = wd.register("shard0/worker0");
        let healthy = wd.register("shard0/worker1");
        wd.hb(wedged).busy(1_000);
        wd.hb(healthy).busy(1_000);
        // healthy keeps beating; wedged goes silent mid-batch
        wd.hb(healthy).busy(2_000_000);
        let stalls = wd.check(2_001_000, 500_000);
        assert_eq!(stalls.len(), 1);
        assert_eq!(stalls[0].name, "shard0/worker0");
        assert_eq!(stalls[0].index, wedged);
        assert_eq!(stalls[0].silent_us, 2_000_000);
        // a beat recovers it
        wd.hb(wedged).busy(2_002_000);
        assert!(wd.check(2_010_000, 500_000).is_empty());
        // going idle (back to blocking on the queue) also clears it
        wd.hb(wedged).busy(2_020_000);
        wd.hb(wedged).idle(2_030_000);
        assert!(wd.check(99_000_000, 500_000).is_empty());
    }

    #[test]
    fn beats_count_and_state_report() {
        let hb = Heartbeat::new();
        assert_eq!(hb.state(), HeartbeatState::Idle);
        assert_eq!(hb.beats(), 0);
        hb.busy(5);
        hb.idle(9);
        assert_eq!(hb.beats(), 2);
        assert_eq!(hb.last_beat_us(), 9);
        assert_eq!(hb.state(), HeartbeatState::Idle);
        hb.retire();
        assert_eq!(hb.state(), HeartbeatState::Retired);
    }

    #[test]
    fn boundary_is_strictly_greater_than_stall_bound() {
        let mut wd = Watchdog::new();
        let i = wd.register("b");
        wd.hb(i).busy(0);
        assert!(wd.check(1_000, 1_000).is_empty(), "exactly at bound");
        assert_eq!(wd.check(1_001, 1_000).len(), 1, "one past bound");
    }
}
