//! Mergeable log-bucketed (HDR-style) histograms.
//!
//! The serving stack used to keep every latency sample in a `Vec` and
//! sort it at report time — O(n) memory and O(n log n) time that grows
//! with offered load, and impossible to snapshot mid-run without
//! copying the whole vector. A [`LogHist`] replaces that with a fixed
//! ~2k-bucket layout: values are binned by their power of two with
//! [`SUB_BUCKETS`] linear sub-buckets per octave, so any quantile is
//! reconstructed with relative error at most `1 / SUB_BUCKETS`
//! (≈ 3.1%), independent of how many samples were recorded.
//!
//! Histograms **merge** by bucket-wise addition ([`LogHist::merge`]),
//! which is associative and commutative — per-shard histograms roll up
//! into the global report and into the Prometheus snapshot without
//! ever disagreeing about what p50/p99 mean, because they are all the
//! *same* bucketed data (see `ServeReport` and
//! [`crate::obs::export`]).

/// Linear sub-buckets per power-of-two octave. 32 sub-buckets bound
/// the relative quantile error by 1/32 ≈ 3.1%.
pub const SUB_BUCKETS: u64 = 32;

const SUB_BITS: u32 = 5; // log2(SUB_BUCKETS)
/// Bucket count: values below `SUB_BUCKETS` get exact unit buckets;
/// each of the remaining `64 - SUB_BITS` octaves gets `SUB_BUCKETS`
/// sub-buckets.
const NUM_BUCKETS: usize = ((64 - SUB_BITS as usize) + 1) * SUB_BUCKETS as usize;

/// Index of the bucket holding `v` (values `< SUB_BUCKETS` map to
/// themselves, so small values are exact).
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB_BUCKETS {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros(); // >= SUB_BITS
    let shift = msb - SUB_BITS;
    let sub = ((v >> shift) & (SUB_BUCKETS - 1)) as usize;
    ((shift as usize + 1) << SUB_BITS) + sub
}

/// Inclusive lower bound of bucket `i` (inverse of [`bucket_index`]).
#[inline]
fn bucket_lo(i: usize) -> u64 {
    if i < SUB_BUCKETS as usize {
        return i as u64;
    }
    let shift = (i >> SUB_BITS) as u32 - 1;
    let sub = (i & (SUB_BUCKETS as usize - 1)) as u64;
    (SUB_BUCKETS + sub) << shift
}

/// Exclusive upper bound of bucket `i`.
#[inline]
fn bucket_hi(i: usize) -> u64 {
    if i < SUB_BUCKETS as usize {
        return i as u64 + 1;
    }
    let shift = (i >> SUB_BITS) as u32 - 1;
    let sub = (i & (SUB_BUCKETS as usize - 1)) as u64;
    (SUB_BUCKETS + sub + 1) << shift
}

/// Fixed-memory log-bucketed histogram over `u64` values (µs, bytes,
/// batch sizes — anything non-negative). See the module docs for the
/// error bound.
#[derive(Clone)]
pub struct LogHist {
    counts: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for LogHist {
    fn default() -> LogHist {
        LogHist::new()
    }
}

impl std::fmt::Debug for LogHist {
    /// Summarized (the ~2k bucket array would drown any debug dump).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LogHist")
            .field("count", &self.count)
            .field("min", &self.min())
            .field("max", &self.max)
            .field("mean", &self.mean())
            .finish()
    }
}

impl LogHist {
    /// Empty histogram (~16 KiB of buckets, allocated eagerly so
    /// recording never allocates).
    pub fn new() -> LogHist {
        LogHist {
            counts: vec![0; NUM_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Record one value.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Bucket-wise addition: `self` absorbs `other`'s samples.
    /// Associative and commutative, so any merge order over a set of
    /// histograms yields the same result.
    pub fn merge(&mut self, other: &LogHist) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when no sample has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of all recorded values.
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Exact mean of all recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Smallest recorded value (exact; 0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded value (exact; 0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// The `q`-quantile (`q` in `[0, 1]`), reconstructed as the
    /// midpoint of the bucket holding the `ceil(q * count)`-th sample
    /// and clamped to the observed `[min, max]` — so p0/p100 are exact
    /// and everything between carries the `1 / SUB_BUCKETS` relative
    /// error bound.
    ///
    /// **Empty histograms**: a histogram with no recorded samples has
    /// no quantiles; this returns **0** for every `q` (matching
    /// [`LogHist::min`]/[`LogHist::mean`] on empty), so report paths
    /// can print "0" for idle shards without a sentinel check. Callers
    /// that need to distinguish "no data" from "all-zero data" must
    /// check [`LogHist::is_empty`] first. A non-finite `q` (NaN/±inf)
    /// is a caller bug and trips a debug assertion; release builds
    /// clamp it into `[0, 1]` like any other out-of-range value.
    pub fn quantile(&self, q: f64) -> u64 {
        debug_assert!(
            q.is_finite(),
            "LogHist::quantile called with non-finite q ({q})"
        );
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if seen >= rank {
                let mid = bucket_lo(i) + (bucket_hi(i) - bucket_lo(i)) / 2;
                return mid.clamp(self.min, self.max);
            }
        }
        self.max // unreachable in practice; defensive
    }

    /// Samples recorded in buckets strictly **above** the bucket
    /// holding `v` — i.e. samples known to exceed `v` at bucket
    /// granularity. Samples in `v`'s own bucket are *not* counted
    /// (they may be ≤ `v`), so the result undercounts by at most one
    /// bucket's population — the same `1 / SUB_BUCKETS` relative
    /// resolution as [`LogHist::quantile`]. The SLO burn-rate
    /// evaluator uses this to turn a latency histogram into a
    /// fraction-of-requests-over-target.
    pub fn count_above(&self, v: u64) -> u64 {
        let first = bucket_index(v) + 1;
        self.counts[first.min(NUM_BUCKETS)..].iter().sum()
    }

    /// Bucket-wise difference `self − earlier`: the histogram of
    /// samples recorded *between* the `earlier` snapshot and `self`,
    /// assuming `earlier` is a prefix of `self`'s sample stream (the
    /// cumulative-snapshot discipline of the windowed health series,
    /// [`crate::obs::series`]). Per-bucket counts and the sum subtract
    /// exactly; `min`/`max` of the delta are only known to bucket
    /// resolution, so they are reconstructed from the delta's lowest /
    /// highest non-empty bucket bounds. Subtraction saturates
    /// defensively if `earlier` is not actually a prefix.
    pub fn diff(&self, earlier: &LogHist) -> LogHist {
        let mut out = LogHist::new();
        for (i, (a, b)) in self.counts.iter().zip(&earlier.counts).enumerate() {
            let d = a.saturating_sub(*b);
            if d > 0 {
                out.counts[i] = d;
                out.count += d;
                out.min = out.min.min(bucket_lo(i));
                out.max = out.max.max(bucket_hi(i).saturating_sub(1));
            }
        }
        out.sum = self.sum.saturating_sub(earlier.sum);
        out
    }

    /// Iterate non-empty buckets as `(lo_inclusive, hi_exclusive,
    /// count)` — the exposition format exporters consume.
    pub fn buckets(&self) -> impl Iterator<Item = (u64, u64, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_lo(i), bucket_hi(i), c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_round_trip_covers_the_u64_range() {
        for v in [0u64, 1, 31, 32, 33, 100, 1_000, 123_456, u32::MAX as u64,
            u64::MAX / 2, u64::MAX]
        {
            let i = bucket_index(v);
            assert!(i < NUM_BUCKETS, "index {i} out of range for {v}");
            assert!(
                bucket_lo(i) <= v && (v < bucket_hi(i) || bucket_hi(i) <= bucket_lo(i)),
                "v={v} not in [{}, {}) (bucket {i})",
                bucket_lo(i),
                bucket_hi(i),
            );
        }
        // buckets tile the line: hi(i) == lo(i+1) within an octave run
        for i in 0..2_000.min(NUM_BUCKETS - 1) {
            assert_eq!(bucket_hi(i), bucket_lo(i + 1), "gap after bucket {i}");
        }
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = LogHist::new();
        for v in 0..SUB_BUCKETS {
            h.record(v);
        }
        for (k, v) in (0..SUB_BUCKETS).enumerate() {
            let q = (k as f64 + 1.0) / SUB_BUCKETS as f64;
            assert_eq!(h.quantile(q), v, "quantile {q} of 0..32");
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), SUB_BUCKETS - 1);
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = LogHist::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.buckets().count(), 0);
    }

    /// Quantiles from the histogram stay within the documented
    /// relative error bound of the exact sorted-sample quantiles, on
    /// uniform and heavy-tailed data.
    #[test]
    fn quantile_error_bound_vs_exact_sort() {
        let mut rng = Rng::new(17);
        for (name, gen) in [
            ("uniform", Box::new(|r: &mut Rng| r.below(1_000_000))
                as Box<dyn Fn(&mut Rng) -> u64>),
            ("powerlaw", Box::new(|r: &mut Rng| {
                r.powerlaw(1.0, 1e9, 1.5) as u64
            })),
        ] {
            let xs: Vec<u64> = (0..50_000).map(|_| gen(&mut rng)).collect();
            let mut h = LogHist::new();
            for &x in &xs {
                h.record(x);
            }
            let mut sorted = xs.clone();
            sorted.sort_unstable();
            for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999] {
                let rank = ((q * xs.len() as f64).ceil() as usize)
                    .clamp(1, xs.len());
                let exact = sorted[rank - 1] as f64;
                let approx = h.quantile(q) as f64;
                let rel = (approx - exact).abs() / exact.max(1.0);
                assert!(
                    rel <= 1.0 / SUB_BUCKETS as f64 + 1e-9,
                    "{name} q={q}: approx {approx} vs exact {exact} \
                     (rel err {rel:.4})"
                );
            }
            assert_eq!(h.count(), xs.len() as u64);
            assert_eq!(h.min(), sorted[0]);
            assert_eq!(h.max(), *sorted.last().unwrap());
            let exact_mean =
                xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
            assert!((h.mean() - exact_mean).abs() < 1e-6 * exact_mean.max(1.0));
        }
    }

    /// Merging is associative: (a ∪ b) ∪ c == a ∪ (b ∪ c), and the
    /// merged histogram equals one built from the concatenated stream.
    #[test]
    fn merge_is_associative_and_matches_concat() {
        let mut rng = Rng::new(23);
        let streams: Vec<Vec<u64>> = (0..3)
            .map(|_| (0..5_000).map(|_| rng.below(10_000_000)).collect())
            .collect();
        let hist_of = |vss: &[&[u64]]| {
            let mut h = LogHist::new();
            for vs in vss {
                for &v in *vs {
                    h.record(v);
                }
            }
            h
        };
        let [a, b, c] = [&streams[0], &streams[1], &streams[2]];
        // (a ∪ b) ∪ c
        let mut left = hist_of(&[a]);
        left.merge(&hist_of(&[b]));
        left.merge(&hist_of(&[c]));
        // a ∪ (b ∪ c)
        let mut right_inner = hist_of(&[b]);
        right_inner.merge(&hist_of(&[c]));
        let mut right = hist_of(&[a]);
        right.merge(&right_inner);
        let concat = hist_of(&[a, b, c]);
        for h in [&left, &right] {
            assert_eq!(h.count(), concat.count());
            assert_eq!(h.sum(), concat.sum());
            assert_eq!(h.min(), concat.min());
            assert_eq!(h.max(), concat.max());
            for q in [0.5, 0.9, 0.99] {
                assert_eq!(h.quantile(q), concat.quantile(q), "q={q}");
            }
            assert!(h.buckets().eq(concat.buckets()));
        }
    }

    /// Satellite regression: the empty-histogram quantile contract is
    /// explicit — 0 for every q, including the clamped extremes.
    #[test]
    fn empty_quantile_returns_zero_for_every_q() {
        let h = LogHist::new();
        for q in [0.0, 0.25, 0.5, 0.99, 1.0, -3.0, 7.0] {
            assert_eq!(h.quantile(q), 0, "empty quantile({q})");
        }
    }

    #[test]
    #[should_panic(expected = "non-finite q")]
    #[cfg(debug_assertions)]
    fn non_finite_quantile_trips_debug_assert() {
        let mut h = LogHist::new();
        h.record(1);
        let _ = h.quantile(f64::NAN);
    }

    #[test]
    fn count_above_is_bucket_granular_and_monotone() {
        let mut h = LogHist::new();
        for v in [1u64, 10, 100, 1_000, 10_000, 100_000] {
            h.record(v);
        }
        // small values are exact-bucketed, so thresholds < SUB_BUCKETS
        // count exactly
        assert_eq!(h.count_above(0), 5);
        assert_eq!(h.count_above(10), 4);
        // large thresholds: undercounts by at most the threshold's own
        // bucket, never more
        let above = h.count_above(1_000);
        assert!((1..=2).contains(&above), "count_above(1000) = {above}");
        // monotone non-increasing in the threshold
        let mut prev = h.count_above(0);
        for t in [1u64, 10, 100, 1_000, 10_000, 100_000, 1_000_000] {
            let c = h.count_above(t);
            assert!(c <= prev, "count_above not monotone at {t}");
            prev = c;
        }
        assert_eq!(h.count_above(u64::MAX), 0);
        assert_eq!(LogHist::new().count_above(0), 0);
    }

    /// `diff` of two cumulative snapshots is exactly the histogram of
    /// the samples recorded in between, bucket for bucket.
    #[test]
    fn diff_recovers_the_between_snapshot_samples() {
        let mut rng = Rng::new(41);
        let first: Vec<u64> = (0..2_000).map(|_| rng.below(1_000_000)).collect();
        let second: Vec<u64> = (0..3_000).map(|_| rng.below(1_000_000)).collect();
        let mut early = LogHist::new();
        for &v in &first {
            early.record(v);
        }
        let mut cum = early.clone();
        for &v in &second {
            cum.record(v);
        }
        let mut want = LogHist::new();
        for &v in &second {
            want.record(v);
        }
        let delta = cum.diff(&early);
        assert_eq!(delta.count(), want.count());
        assert_eq!(delta.sum(), want.sum());
        assert!(delta.buckets().eq(want.buckets()));
        // min/max are bucket-resolution bounds around the true extremes
        assert!(delta.min() <= want.min());
        assert!(delta.max() >= want.max());
        // diff against self is empty; diff against empty is identity
        assert!(cum.diff(&cum).is_empty());
        assert!(cum.diff(&LogHist::new()).buckets().eq(cum.buckets()));
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut h = LogHist::new();
        for v in [5u64, 500, 50_000] {
            h.record(v);
        }
        let before: Vec<_> = h.buckets().collect();
        h.merge(&LogHist::new());
        assert!(h.buckets().eq(before.iter().copied()));
        assert_eq!(h.count(), 3);
        assert_eq!(h.min(), 5);
        assert_eq!(h.max(), 50_000);
    }
}
