//! Flight recorder: atomic postmortem bundles.
//!
//! When something goes wrong mid-run — an SLO burn-rate alert fires,
//! the watchdog declares a thread stalled, a fatal error unwinds the
//! engine — the numbers that explain it are exactly the ones about to
//! be lost: the recent span rings, the last N health windows, the
//! alert history, the resolved config. [`dump_postmortem`] captures
//! all of that as one directory of JSON files under
//! `results/postmortem-<reason>-<ts>/`, written **atomically**: every
//! file lands in a `.tmp` staging directory first and a single
//! `rename` publishes the bundle, so a crash mid-dump can never leave
//! a half-readable postmortem at the published path.
//!
//! Bundle layout (all hand-rolled JSON, no serde):
//!
//! ```text
//! postmortem-<reason>-<ts_ms>/
//!   manifest.json   reason, trigger timestamp, file inventory + counts
//!   windows.json    last N sealed health windows (series ring)
//!   spans.json      retained trace events, one entry per track
//!   alerts.json     SLO spec, per-target state, transition log
//!   config.json     resolved ServeConfig (as the engine ran it)
//!   shards.json     per-shard state at dump time
//! ```
//!
//! [`read_postmortem`] re-parses a bundle and cross-checks the
//! manifest's counts against the actual file contents, so the `exp
//! health` gate (and any human) can trust that a bundle that parses is
//! a bundle that is complete.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{arr, num, obj, s, Json};

use super::series::WindowedSeries;
use super::slo::SloRuntime;
use super::span::{track_name, Recorder};

/// A re-parsed postmortem bundle (counts cross-checked against the
/// manifest).
#[derive(Debug)]
pub struct PostmortemBundle {
    /// Why the dump was triggered (`slo-shed_rate`, `stall-batcher`,
    /// `manual`, …).
    pub reason: String,
    /// Trigger timestamp, µs on the run clock.
    pub ts_us: u64,
    /// Health windows captured.
    pub windows: usize,
    /// Trace events captured across all tracks.
    pub span_events: usize,
    /// Alert transitions in the history.
    pub alert_transitions: usize,
    /// The resolved run config, verbatim.
    pub config: Json,
}

/// Keep reasons filesystem- and label-safe: lowercase alphanumerics
/// and dashes only.
fn sanitize_reason(reason: &str) -> String {
    let cleaned: String = reason
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c.to_ascii_lowercase()
            } else {
                '-'
            }
        })
        .collect();
    if cleaned.is_empty() { "unknown".into() } else { cleaned }
}

fn spans_json(rec: &Recorder) -> (Json, usize) {
    let mut tracks = Vec::new();
    let mut total = 0usize;
    for (track, ring) in rec.rings().iter().enumerate() {
        let events: Vec<Json> = ring
            .snapshot()
            .into_iter()
            .map(|ev| {
                obj(vec![
                    ("ts_us", num(ev.ts_us as f64)),
                    ("dur_us", num(ev.dur_us as f64)),
                    ("req", num(ev.req_id as f64)),
                    ("kind", s(ev.kind.name())),
                    ("a", num(ev.a as f64)),
                    ("b", num(ev.b as f64)),
                    ("c", num(ev.c as f64)),
                ])
            })
            .collect();
        total += events.len();
        tracks.push(obj(vec![
            ("track", num(track as f64)),
            ("name", s(&track_name(track))),
            ("dropped", num(ring.dropped() as f64)),
            ("events", arr(events)),
        ]));
    }
    (obj(vec![("tracks", arr(tracks))]), total)
}

fn alerts_json(slo: Option<&SloRuntime>) -> (Json, usize) {
    let Some(rt) = slo else {
        return (
            obj(vec![
                ("enabled", Json::Bool(false)),
                ("states", arr(vec![])),
                ("transitions", arr(vec![])),
            ]),
            0,
        );
    };
    let states: Vec<Json> = rt
        .states()
        .iter()
        .map(|st| {
            obj(vec![
                ("slo", s(st.target.kind.label())),
                ("threshold", num(st.target.threshold)),
                ("firing", Json::Bool(st.firing)),
                ("fired", num(st.fired as f64)),
                ("cleared", num(st.cleared as f64)),
                ("burn_fast", num(st.burn_fast)),
                ("burn_slow", num(st.burn_slow)),
                (
                    "first_breach_us",
                    st.first_breach_us
                        .map(|t| num(t as f64))
                        .unwrap_or(Json::Null),
                ),
                (
                    "first_fire_us",
                    st.first_fire_us
                        .map(|t| num(t as f64))
                        .unwrap_or(Json::Null),
                ),
            ])
        })
        .collect();
    let transitions: Vec<Json> = rt
        .transitions()
        .iter()
        .map(|t| {
            obj(vec![
                ("slo", s(t.slo)),
                ("state", s(if t.fired { "fire" } else { "clear" })),
                ("ts_us", num(t.ts_us as f64)),
                ("burn_fast", num(t.burn_fast)),
                ("burn_slow", num(t.burn_slow)),
            ])
        })
        .collect();
    let n = transitions.len();
    (
        obj(vec![
            ("enabled", Json::Bool(true)),
            ("spec", s(&rt.spec().label())),
            ("states", arr(states)),
            ("transitions", arr(transitions)),
        ]),
        n,
    )
}

/// Dump a postmortem bundle under `base_dir` and return the published
/// bundle directory. `reason` names the trigger; `ts_us` is the run
/// clock at trigger time (also disambiguates the directory name —
/// collisions get a numeric suffix). `config` and `shards` are the
/// engine-resolved run config and per-shard state as JSON. A disabled
/// recorder yields an empty-but-valid `spans.json`.
#[allow(clippy::too_many_arguments)] // a dump site passes the whole run state
pub fn dump_postmortem(
    base_dir: &Path,
    reason: &str,
    ts_us: u64,
    rec: &Recorder,
    series: &WindowedSeries,
    slo: Option<&SloRuntime>,
    config: Json,
    shards: Json,
) -> Result<PathBuf> {
    let reason = sanitize_reason(reason);
    std::fs::create_dir_all(base_dir)
        .with_context(|| format!("creating {}", base_dir.display()))?;
    let mut name = format!("postmortem-{reason}-{}", ts_us / 1_000);
    let mut n = 1;
    while base_dir.join(&name).exists() {
        name = format!("postmortem-{reason}-{}-{n}", ts_us / 1_000);
        n += 1;
    }
    let final_dir = base_dir.join(&name);
    let tmp_dir = base_dir.join(format!("{name}.tmp"));
    if tmp_dir.exists() {
        std::fs::remove_dir_all(&tmp_dir)?;
    }
    std::fs::create_dir_all(&tmp_dir)?;

    let windows: Vec<Json> = series.windows().map(|w| w.to_json()).collect();
    let n_windows = windows.len();
    let windows_doc = obj(vec![
        ("window_us", num(series.config().window_us as f64)),
        ("sealed_total", num(series.sealed() as f64)),
        ("windows", arr(windows)),
    ]);
    let (spans_doc, n_spans) = spans_json(rec);
    let (alerts_doc, n_transitions) = alerts_json(slo);

    let manifest = obj(vec![
        ("reason", s(&reason)),
        ("ts_us", num(ts_us as f64)),
        ("windows", num(n_windows as f64)),
        ("span_events", num(n_spans as f64)),
        ("alert_transitions", num(n_transitions as f64)),
        (
            "files",
            arr(
                [
                    "windows.json",
                    "spans.json",
                    "alerts.json",
                    "config.json",
                    "shards.json",
                ]
                .iter()
                .map(|f| s(f))
                .collect(),
            ),
        ),
    ]);

    for (file, doc) in [
        ("manifest.json", &manifest),
        ("windows.json", &windows_doc),
        ("spans.json", &spans_doc),
        ("alerts.json", &alerts_doc),
        ("config.json", &config),
        ("shards.json", &shards),
    ] {
        std::fs::write(tmp_dir.join(file), doc.to_string_pretty())
            .with_context(|| format!("writing postmortem {file}"))?;
    }
    std::fs::rename(&tmp_dir, &final_dir).with_context(|| {
        format!("publishing postmortem at {}", final_dir.display())
    })?;
    Ok(final_dir)
}

/// Re-parse a bundle directory, cross-checking the manifest's counts
/// against the file contents. Errors on anything missing, unparseable
/// or inconsistent.
pub fn read_postmortem(dir: &Path) -> Result<PostmortemBundle> {
    let manifest = Json::parse_file(&dir.join("manifest.json"))?;
    let reason = manifest.get("reason")?.as_str()?.to_string();
    let ts_us = manifest.get("ts_us")?.as_f64()? as u64;

    let windows_doc = Json::parse_file(&dir.join("windows.json"))?;
    let windows = windows_doc.get("windows")?.as_arr()?.len();
    for w in windows_doc.get("windows")?.as_arr()? {
        w.get("seq")?.as_usize()?;
        w.get("completed")?.as_usize()?;
        w.get("lat_p99_us")?.as_f64()?;
    }

    let spans_doc = Json::parse_file(&dir.join("spans.json"))?;
    let mut span_events = 0usize;
    for t in spans_doc.get("tracks")?.as_arr()? {
        t.get("name")?.as_str()?;
        for ev in t.get("events")?.as_arr()? {
            ev.get("ts_us")?.as_f64()?;
            ev.get("kind")?.as_str()?;
            span_events += 1;
        }
    }

    let alerts_doc = Json::parse_file(&dir.join("alerts.json"))?;
    let alert_transitions = alerts_doc.get("transitions")?.as_arr()?.len();

    let config = Json::parse_file(&dir.join("config.json"))?;
    Json::parse_file(&dir.join("shards.json"))?;

    for (key, got) in [
        ("windows", windows),
        ("span_events", span_events),
        ("alert_transitions", alert_transitions),
    ] {
        let want = manifest.get(key)?.as_usize()?;
        if want != got {
            bail!(
                "postmortem at {}: manifest says {want} {key}, files hold \
                 {got}",
                dir.display()
            );
        }
    }
    Ok(PostmortemBundle {
        reason,
        ts_us,
        windows,
        span_events,
        alert_transitions,
        config,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::series::{HealthSample, SeriesConfig};
    use crate::obs::slo::SloSpec;
    use crate::obs::span::{EventKind, TRACK_CLIENT};
    use crate::obs::LogHist;
    use std::time::Instant;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "comm_rand_flight_{tag}_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn storm_series() -> WindowedSeries {
        let mut series = WindowedSeries::new(
            SeriesConfig { window_us: 1_000, retention: 8 },
            0,
        );
        let mut lat = LogHist::new();
        for t in 1..=12u64 {
            for i in 0..20 {
                lat.record(1_000 + i * t);
            }
            let samp = HealthSample {
                lat: lat.clone(),
                completed: t * 20,
                shed: t * 10,
                ..Default::default()
            };
            series.observe(t * 1_000, samp);
        }
        series
    }

    /// Satellite test: dump → parse → spans and windows present, with
    /// the manifest counts agreeing with the files.
    #[test]
    fn bundle_round_trips() {
        let rec = Recorder::new(1, 64, 1000, Instant::now());
        rec.instant(TRACK_CLIENT, EventKind::Enqueue, 10, 1, 0, 0, 0);
        rec.span(TRACK_CLIENT, EventKind::QueueWait, 10, 5, 1, 0, 0, 0);
        rec.instant(TRACK_CLIENT, EventKind::SloFire, 900, 0, 1, 250, 180);
        let series = storm_series();
        let mut rt = SloRuntime::new(SloSpec::parse("shed=0.05").unwrap());
        for ts in [11_000, 12_000] {
            rt.evaluate(&series, ts);
        }
        assert!(rt.any_firing(), "storm series should fire the shed SLO");

        let base = tmpdir("roundtrip");
        let dir = dump_postmortem(
            &base,
            "slo-shed_rate",
            12_345_678,
            &rec,
            &series,
            Some(&rt),
            obj(vec![("p", num(0.9))]),
            arr(vec![obj(vec![("shard", num(0.0))])]),
        )
        .unwrap();
        assert!(dir.file_name().unwrap().to_str().unwrap()
            .starts_with("postmortem-slo-shed_rate-"));
        // no staging residue
        assert!(!base.join(format!(
            "{}.tmp",
            dir.file_name().unwrap().to_str().unwrap()
        ))
        .exists());

        let bundle = read_postmortem(&dir).unwrap();
        assert_eq!(bundle.reason, "slo-shed_rate");
        assert_eq!(bundle.ts_us, 12_345_678);
        assert_eq!(bundle.windows, 8, "series retention captured");
        assert_eq!(bundle.span_events, 3);
        assert_eq!(bundle.alert_transitions, 1);
        assert_eq!(bundle.config.get("p").unwrap().as_f64().unwrap(), 0.9);

        // a second dump with the same reason+ts gets a fresh directory
        let dir2 = dump_postmortem(
            &base,
            "slo-shed_rate",
            12_345_678,
            &rec,
            &series,
            Some(&rt),
            obj(vec![]),
            arr(vec![]),
        )
        .unwrap();
        assert_ne!(dir, dir2);
        read_postmortem(&dir2).unwrap();
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn disabled_recorder_and_no_slo_still_dump_valid_bundles() {
        let base = tmpdir("minimal");
        let series = storm_series();
        let dir = dump_postmortem(
            &base,
            "Manual Trigger!",
            1_000,
            &Recorder::disabled(),
            &series,
            None,
            obj(vec![]),
            obj(vec![]),
        )
        .unwrap();
        // reason sanitized for the filesystem
        assert!(dir.file_name().unwrap().to_str().unwrap()
            .starts_with("postmortem-manual-trigger-"));
        let bundle = read_postmortem(&dir).unwrap();
        assert_eq!(bundle.span_events, 0);
        assert_eq!(bundle.alert_transitions, 0);
        assert_eq!(bundle.windows, 8);
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn tampered_manifest_counts_fail_the_parse() {
        let base = tmpdir("tamper");
        let series = storm_series();
        let dir = dump_postmortem(
            &base,
            "tamper",
            5_000,
            &Recorder::disabled(),
            &series,
            None,
            obj(vec![]),
            obj(vec![]),
        )
        .unwrap();
        let mpath = dir.join("manifest.json");
        let txt = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, txt.replace("\"windows\": 8", "\"windows\": 3"))
            .unwrap();
        assert!(read_postmortem(&dir).is_err());
        std::fs::remove_dir_all(&base).ok();
    }
}
