//! Miss-ratio curves and the cache right-sizing advisor.
//!
//! Mattson's classic result: an LRU cache of capacity `C` rows hits an
//! access exactly when its stack distance is `< C`. So the reuse-
//! distance histogram a [`super::locality::LocalityShard`] accumulates
//! *is* the miss-ratio curve — one pass over the live stream predicts
//! the hit rate at **every** capacity at once:
//!
//! ```text
//! miss(C) ≈ (cold + #[distance ≥ C]) / sampled_accesses
//! ```
//!
//! `#[distance ≥ C]` comes from [`LogHist::count_above`] at bucket
//! granularity (~3 % relative capacity resolution), and cold
//! first-touches miss at any capacity, which makes the curve
//! non-increasing in `C` by construction ([`miss_ratio_at`]).
//!
//! On top of the curve sit two consumers:
//!
//! * [`curve`] samples `mrc_points=` log-spaced capacities for the
//!   report / Prometheus export;
//! * [`advise`] inverts the curve: the smallest `cache_rows` achieving
//!   a target hit rate, plus the predicted hit rate at the *current*
//!   size — which `exp locality` cross-checks against the serving
//!   cache's real `hits / lookups` (within 5 points), pinning the
//!   model to the live cache.
//!
//! The prediction models a fully-associative LRU over the shard's
//! whole access stream; the real cache is 8-way set-associative and
//! striped by `node % stripes`, so conflict misses make the observed
//! rate sit slightly *under* the prediction — part of the 5-point
//! tolerance budget, documented rather than hidden.

use super::hist::LogHist;
use super::locality::LocalitySample;

/// Default hit-rate target the advisor sizes for.
pub const DEFAULT_TARGET_HIT_RATE: f64 = 0.9;

/// One sampled point of a miss-ratio curve.
#[derive(Clone, Copy, Debug)]
pub struct MrcPoint {
    /// Cache capacity in feature rows.
    pub capacity_rows: u64,
    /// Predicted miss ratio at that capacity, in `[0, 1]`.
    pub miss_ratio: f64,
}

/// Predicted miss ratio of a fully-associative LRU of `rows` capacity
/// over the sampled stream: `(cold + #[distance ≥ rows]) / sampled`.
/// Returns 1.0 when nothing was sampled (an unprofiled stream predicts
/// nothing, and all-miss is the conservative answer). Non-increasing
/// in `rows` because [`LogHist::count_above`] is monotone.
pub fn miss_ratio_at(s: &LocalitySample, rows: u64) -> f64 {
    if s.sampled == 0 {
        return 1.0;
    }
    // distance d hits capacity C iff d < C ⇔ misses iff d ≥ C, i.e.
    // strictly above C−1 (capacity 0 is clamped to 1 row).
    let threshold = rows.max(1) - 1;
    let over = s.cold + s.dist.count_above(threshold);
    (over as f64 / s.sampled as f64).min(1.0)
}

/// Sample the miss-ratio curve at up to `points` log-spaced capacities
/// in `[1, max_rows]` (deduplicated, ascending; always includes both
/// endpoints). Empty when `points == 0`.
pub fn curve(
    s: &LocalitySample,
    points: usize,
    max_rows: u64,
) -> Vec<MrcPoint> {
    if points == 0 {
        return Vec::new();
    }
    let max_rows = max_rows.max(1);
    let mut caps: Vec<u64> = Vec::with_capacity(points);
    if points == 1 {
        caps.push(max_rows);
    } else {
        let span = (max_rows as f64).ln();
        for i in 0..points {
            let c = (span * i as f64 / (points - 1) as f64).exp();
            caps.push((c.round() as u64).clamp(1, max_rows));
        }
    }
    caps.dedup();
    caps.iter()
        .map(|&c| MrcPoint {
            capacity_rows: c,
            miss_ratio: miss_ratio_at(s, c),
        })
        .collect()
}

/// The right-sizing advisor's verdict for one shard's cache.
#[derive(Clone, Copy, Debug)]
pub struct CacheAdvice {
    /// The cache's current capacity in rows.
    pub rows_now: u64,
    /// MRC-predicted hit rate at `rows_now`.
    pub predicted_hit_rate: f64,
    /// The real cache's observed `hits / lookups` over the same run.
    pub observed_hit_rate: f64,
    /// The hit-rate target `rows_for_target` sizes for.
    pub target_hit_rate: f64,
    /// Smallest capacity whose predicted hit rate reaches the target,
    /// or `None` when no capacity can (the cold-miss share alone
    /// exceeds the miss budget).
    pub rows_for_target: Option<u64>,
}

/// Derive right-sizing advice from a sample: predicted hit rate at the
/// current size, and the smallest capacity reaching `target` (searched
/// over the distance histogram's bucket boundaries, so the answer
/// carries the histogram's ~3 % capacity resolution).
pub fn advise(
    s: &LocalitySample,
    rows_now: u64,
    observed_hit_rate: f64,
    target: f64,
) -> CacheAdvice {
    let target = target.clamp(0.0, 1.0);
    let predicted_hit_rate = 1.0 - miss_ratio_at(s, rows_now);
    CacheAdvice {
        rows_now,
        predicted_hit_rate,
        observed_hit_rate,
        target_hit_rate: target,
        rows_for_target: rows_for_target(s, target),
    }
}

/// Smallest capacity (in rows) whose predicted hit rate reaches
/// `target`. Candidates are 1 plus each non-empty distance bucket's
/// exclusive upper bound — capacities at which the curve can actually
/// step.
fn rows_for_target(s: &LocalitySample, target: f64) -> Option<u64> {
    if s.sampled == 0 {
        return None;
    }
    let mut candidates: Vec<u64> = std::iter::once(1)
        .chain(s.dist.buckets().map(|(_, hi, _)| hi))
        .collect();
    candidates.sort_unstable();
    candidates.dedup();
    candidates
        .into_iter()
        .find(|&c| 1.0 - miss_ratio_at(s, c) >= target)
}

/// Convenience: the distance histogram of `s`, exposed so exporters
/// can summarize the curve's raw material without reaching into the
/// sample's fields.
pub fn distance_hist(s: &LocalitySample) -> &LogHist {
    &s.dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::locality::{Access, LocalityConfig, LocalityShard};
    use crate::util::rng::Rng;

    fn sample_of(stream: &[(u32, u32)]) -> LocalitySample {
        let shard = LocalityShard::new(LocalityConfig {
            sample_permille: 1000,
            trace_cap: 0,
        });
        let batch: Vec<Access> = stream
            .iter()
            .map(|&(node, comm)| Access { node, comm, hit: false })
            .collect();
        shard.observe_batch(batch.len() as u64, &batch);
        shard.snapshot()
    }

    /// Satellite property test: the MRC is non-increasing in capacity,
    /// on randomized streams, at every probed capacity and across the
    /// sampled curve.
    #[test]
    fn miss_ratio_is_monotone_non_increasing_in_capacity() {
        let mut rng = Rng::new(99);
        for case in 0..8 {
            let n_nodes = 20 + rng.below(500) as u32;
            let stream: Vec<(u32, u32)> = (0..4_000)
                .map(|_| (rng.below(n_nodes as u64) as u32, 0))
                .collect();
            let s = sample_of(&stream);
            let mut prev = miss_ratio_at(&s, 1);
            assert!(prev <= 1.0 && prev >= 0.0);
            for rows in (1..1_200).step_by(7) {
                let m = miss_ratio_at(&s, rows);
                assert!(
                    m <= prev + 1e-12,
                    "case {case}: miss({rows}) = {m} > {prev}"
                );
                prev = m;
            }
            let c = curve(&s, 16, 1_024);
            for w in c.windows(2) {
                assert!(w[0].capacity_rows < w[1].capacity_rows);
                assert!(w[1].miss_ratio <= w[0].miss_ratio + 1e-12);
            }
            assert_eq!(c.first().unwrap().capacity_rows, 1);
            assert_eq!(c.last().unwrap().capacity_rows, 1_024);
        }
    }

    /// A cyclic scan over N nodes is the textbook MRC step function:
    /// capacity below N misses everything, capacity ≥ N hits
    /// everything but the cold pass.
    #[test]
    fn cyclic_scan_produces_the_textbook_step() {
        let n = 64u32;
        let stream: Vec<(u32, u32)> =
            (0..10 * n).map(|i| (i % n, 0)).collect();
        let s = sample_of(&stream);
        // every reuse has distance exactly n−1
        assert_eq!(s.dist.min(), (n - 1) as u64);
        assert_eq!(s.dist.max(), (n - 1) as u64);
        let below = miss_ratio_at(&s, (n / 2) as u64);
        let at = miss_ratio_at(&s, n as u64 + 2);
        assert!(below > 0.99, "below-capacity miss {below}");
        let cold_share = s.cold as f64 / s.sampled as f64;
        assert!(
            (at - cold_share).abs() < 1e-9,
            "at-capacity miss {at} vs cold share {cold_share}"
        );
    }

    /// The advisor finds the smallest capacity reaching the target and
    /// its prediction at that capacity really does reach it.
    #[test]
    fn advisor_inverts_the_curve() {
        let n = 100u32;
        let stream: Vec<(u32, u32)> =
            (0..50 * n).map(|i| (i % n, 0)).collect();
        let s = sample_of(&stream);
        let a = advise(&s, 16, 0.1, 0.9);
        assert_eq!(a.rows_now, 16);
        // 16 rows over a 100-node scan: essentially all misses
        assert!(a.predicted_hit_rate < 0.05);
        let rows = a.rows_for_target.expect("target reachable");
        assert!(1.0 - miss_ratio_at(&s, rows) >= a.target_hit_rate);
        // the advice sits at the scan's working set (bucket-granular)
        assert!(
            (rows as i64 - n as i64).abs() <= 4,
            "advice {rows} vs working set {n}"
        );
        // clearly below the working set the target is unreachable
        assert!(1.0 - miss_ratio_at(&s, (n / 2) as u64) < a.target_hit_rate);
        // an unreachable target (cold share too high) is None, not 0
        let one_shot: Vec<(u32, u32)> =
            (0..500u32).map(|i| (i, 0)).collect();
        let cold_only = sample_of(&one_shot);
        assert_eq!(advise(&cold_only, 64, 0.0, 0.5).rows_for_target, None);
    }

    #[test]
    fn empty_sample_predicts_all_miss_and_no_advice() {
        let s = LocalitySample::default();
        assert_eq!(miss_ratio_at(&s, 1), 1.0);
        assert_eq!(miss_ratio_at(&s, 1 << 20), 1.0);
        let a = advise(&s, 128, 0.0, 0.9);
        assert_eq!(a.predicted_hit_rate, 0.0);
        assert_eq!(a.rows_for_target, None);
        assert!(curve(&s, 8, 1024).iter().all(|p| p.miss_ratio == 1.0));
        assert!(curve(&s, 0, 1024).is_empty());
        assert!(distance_hist(&s).is_empty());
    }
}
