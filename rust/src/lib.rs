//! COMM-RAND: Community-structure-aware randomized mini-batching for
//! efficient GNN training.
//!
//! Reproduction of Balaji et al., "Efficient GNN Training Through
//! Structure-Aware Randomized Mini-batching" (2025), as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the data-pipeline coordinator: graph
//!   substrate, community detection + reordering, the paper's mini-batch
//!   construction policies (root partitioning + biased neighborhood
//!   sampling), a pipelined dataloader with backpressure, the trainer,
//!   and the cache-model instrumentation used by the evaluation.
//! * **Layer 2 (python/compile/model.py)** — GraphSAGE / GCN / GAT
//!   forward+backward+Adam as a jitted JAX function, AOT-lowered to HLO
//!   text at build time (`make artifacts`).
//! * **Layer 1 (python/compile/kernels/)** — the gather/aggregate compute
//!   hot-spot as Pallas kernels (interpret=True), called from Layer 2 so
//!   they lower into the same HLO module.
//!
//! Python never runs on the training path: the rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and drives
//! every epoch itself.

pub mod batch;
pub mod cachesim;
pub mod community;
pub mod config;
pub mod exp;
pub mod graph;
pub mod runtime;
pub mod sampler;
pub mod train;
pub mod util;

pub mod cli;

pub use cli::cli_main;

/// Build an [`cli::Args`] from raw strings (used by bench targets).
pub fn cli_args(argv: Vec<String>) -> cli::Args {
    cli::Args::parse(argv)
}
