//! COMM-RAND: Community-structure-aware randomized mini-batching for
//! efficient GNN training.
//!
//! Reproduction of Balaji et al., "Efficient GNN Training Through
//! Structure-Aware Randomized Mini-batching" (2025), as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the data-pipeline coordinator: graph
//!   substrate, community detection + reordering, the paper's mini-batch
//!   construction policies (root partitioning + biased neighborhood
//!   sampling), a pipelined dataloader with backpressure, the trainer,
//!   and the cache-model instrumentation used by the evaluation.
//! * **Layer 2 (python/compile/model.py)** — GraphSAGE / GCN / GAT
//!   forward+backward+Adam as a jitted JAX function, AOT-lowered to HLO
//!   text at build time (`make artifacts`).
//! * **Layer 1 (python/compile/kernels/)** — the gather/aggregate compute
//!   hot-spot as Pallas kernels (interpret=True), called from Layer 2 so
//!   they lower into the same HLO module.
//!
//! Python never runs on the training path: the rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and drives
//! every epoch itself.
//!
//! # Online serving ([`serve`])
//!
//! Beyond the offline reproduction, [`serve`] turns the stack into an
//! online inference server: a bounded request queue feeds a dynamic
//! micro-batcher whose **community-bias knob `p ∈ [0, 1]`** interpolates
//! between pure-FIFO coalescing (`p = 0`) and pure community-grouped
//! coalescing (`p = 1`); a worker pool samples each micro-batch's MFG,
//! stages features through a *functional* `Arc`-sharded LRU feature
//! cache (the same set-associative core as the cache simulator, now
//! carrying payload), and drives the PJRT infer executable — or the
//! pure-rust host reference model when AOT artifacts are absent, so
//! logits (and accuracy) are real anywhere. With `shards=N` the
//! engine partitions communities across N logical device shards
//! (consistent assignment from the Louvain labels) and routes each
//! micro-batch to the shard owning its community, with a configurable
//! spill policy (`strict` / `steal` / `broadcast`) for cross-shard
//! batches — each shard runs its own worker pool and feature cache.
//! `comm-rand serve bench` replays a Zipf-skewed trace — closed loop,
//! or **open-loop Poisson** (`arrival=poisson:RATE`) to sweep offered
//! load past saturation — through a **deadline-aware admission gate**
//! (`admission=none|reject|degrade`, per-shard service-time EWMA) and
//! reports throughput plus p50/p95/p99 latency, shed/degrade counts
//! and feature-cache hit rate (per shard and rolled up) as JSON;
//! `comm-rand exp serve` sweeps `p`, the shard count and the offered
//! load into paper-style tables. The request lifecycle and knob
//! reference live in `docs/ARCHITECTURE.md`.
//!
//! # Streaming graph mutation ([`stream`])
//!
//! The [`stream`] subsystem opens the dynamic-graph workload: `serve
//! bench mutate=RATE` drives timestamped edge inserts/deletes and
//! feature-row rewrites alongside the request load. Updates batch
//! into epochs applied through a versioned CSR delta-overlay
//! ([`graph::TopoSnapshot`]) so in-flight samplers read consistent
//! snapshots; an incremental community maintainer re-refines the
//! Louvain labels only around touched vertices and escalates to a
//! stop-the-world full relabel (new shard plan, flushed caches, new
//! checkpoint-fence fingerprint) when modularity drift crosses the
//! threshold; and the serving feature cache is version-tagged, so
//! rewrites turn cached rows *stale* (`stale_hits`, served like
//! misses, `hits + misses + stale_hits == lookups` exactly).
//! `comm-rand exp stream` sweeps throughput/accuracy against churn
//! with incremental vs. naive full-relabel maintenance.
//!
//! # Checkpoints & hot swap ([`ckpt`])
//!
//! The [`ckpt`] subsystem bridges train → serve: the training loop
//! writes versioned, CRC-checked checkpoints (`ckpt_dir=` /
//! `ckpt_every=`, retention keeps best-by-val-acc + latest), each
//! fenced by a fingerprint of the Louvain labeling it was trained
//! against, and `serve bench ckpt=...` loads one — so the bench
//! reports **real top-1 accuracy** next to latency. With `watch_ms=N`
//! the engine polls the checkpoint directory during the run and
//! hot-swaps newer versions in between micro-batches with zero
//! dropped requests (`param_version` / `swaps` per shard in the
//! report). In artifact-less environments the pure-rust host
//! reference model ([`runtime::host`], `train backend=host`) stands
//! in for the PJRT executable end to end.
//!
//! # Observability ([`obs`])
//!
//! Always compiled in, off by default: `serve bench trace=PATH`
//! records every pipeline stage of every (sampled) request — enqueue,
//! admission verdicts, queue wait, coalesce (with community-purity
//! counters), sample (with cross-request neighborhood overlap),
//! feature gather (hit/stale/miss tags), execute, reply — into
//! fixed-capacity lock-free ring buffers and exports a Chrome-trace
//! JSON that Perfetto loads directly, one track per shard plus the
//! batcher/maintainer/watcher/client threads. Latency percentiles
//! everywhere (the serve report, per-shard tables, the `metrics_ms=N`
//! Prometheus text snapshot) come from one mergeable log-bucketed
//! histogram type ([`obs::LogHist`]), so no two surfaces of a run can
//! disagree about p50/p99. `comm-rand exp obs` gates full-rate
//! tracing overhead at ≤ 5 % of untraced throughput.
//!
//! # Live health ([`obs`] again: series / slo / watchdog / flight)
//!
//! Tracing explains a request; the health layer watches the run.
//! `health_ms=N` seals a windowed time-series
//! ([`obs::WindowedSeries`]: per-window latency [`obs::LogHist`] +
//! counter deltas) every N ms; `slo=` evaluates declarative targets
//! with multi-window fast/slow **burn-rate** alerting and hysteresis
//! ([`obs::SloRuntime`]), emitting `slo_fire`/`slo_clear` trace
//! instants and `serve_slo_*` Prometheus gauges; every long-lived
//! engine thread beats a liveness heartbeat swept by a watchdog
//! ([`obs::Watchdog`]); and `flight=DIR` arms a flight recorder that
//! dumps an atomic `postmortem-*/` bundle (windows, raw trace rings,
//! alert history, resolved config, per-shard state —
//! [`obs::dump_postmortem`] / re-parsed by [`obs::read_postmortem`])
//! on the first alert or stall. `comm-rand exp health` gates it: zero
//! steady-state false positives, fire within two slow lookback spans
//! of the first breach past saturation, and ≤ 5 % overhead.
//!
//! # Locality observatory ([`obs`] again: locality / mrc)
//!
//! The health layer watches *time*; the locality observatory watches
//! *memory-access structure* — the quantity the paper's community
//! reordering actually optimizes. `serve bench locality=1` taps every
//! shard's feature-gather loop with a SHARDS-sampled online Mattson
//! profiler ([`obs::LocalityShard`], `locality_sample=PERMILLE`
//! selects nodes by stateless hash so distances stay unbiased):
//! per-window log-bucketed reuse-distance histograms, cold-miss and
//! self- vs cross-community affinity counters, and a bounded access
//! trace replayable through [`cachesim::SetAssocCore`] offline. From
//! one pass [`obs::mrc`] derives the full **miss-ratio curve**
//! (predicted hit rate at every capacity, `mrc_points=` samples) and
//! a cache right-sizing advisor — smallest `cache_rows` meeting a
//! target hit rate, plus predicted-vs-observed hit rate at the
//! current size, cross-checked against the live cache's own counters
//! (`ServeReport.locality{}`, `serve_locality_*` / `serve_mrc_*`
//! Prometheus gauges, a `locality` Chrome-trace counter track).
//! `comm-rand exp locality` gates it: sweeping `p` 0 → 1 must
//! *strictly* shorten mean reuse distance and the MRC-predicted miss
//! rate at equal accuracy, the advisor's predicted hit rate must land
//! within 5 points of the observed one, and profiling costs ≤ 5 %
//! throughput.

#![warn(missing_docs)]
// missing_docs burn-down: the crate root and the serving subsystem
// (`serve/`) are fully documented and the lint is enforced in CI via
// `cargo doc` with RUSTDOCFLAGS="-D warnings". The offline
// reproduction modules below predate the lint and carry a scoped
// allow until their own docs pass lands (tracked in ROADMAP.md);
// remove an `#[allow]` to burn one down.

#[allow(missing_docs)]
pub mod batch;
pub mod cachesim;
pub mod ckpt;
pub mod community;
pub mod config;
#[allow(missing_docs)]
pub mod exp;
#[allow(missing_docs)]
pub mod graph;
pub mod obs;
pub mod runtime;
#[allow(missing_docs)]
pub mod sampler;
pub mod serve;
pub mod stream;
pub mod train;
pub mod util;

#[allow(missing_docs)]
pub mod cli;

pub use cli::cli_main;

/// Build an [`cli::Args`] from raw strings (used by bench targets).
pub fn cli_args(argv: Vec<String>) -> cli::Args {
    cli::Args::parse(argv)
}
