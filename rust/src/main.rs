fn main() -> anyhow::Result<()> {
    comm_rand::cli_main()
}
