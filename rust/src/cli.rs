//! Command-line interface of the `comm-rand` leader binary.
//!
//! Subcommands (run `comm-rand help` for the list):
//! * `gen-data [preset...]` — materialize the synthetic datasets
//! * `smoke`                — end-to-end vertical-slice check (tiny)
//! * `train`                — train one configuration
//! * `serve bench`          — closed-loop online-inference benchmark
//! * `exp <id>`             — regenerate a paper table/figure
//! * `bench-epoch`          — per-epoch timing for one configuration
//! * `inspect <preset>`     — dataset statistics
//!
//! Flag syntax is `key=value` (no external CLI crate offline).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::{preset, preset_names, BatchPolicy, TrainConfig};
use crate::sampler::roots::RootPolicy;

pub struct Args {
    pub cmd: String,
    pub pos: Vec<String>,
    pub kv: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: Vec<String>) -> Args {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut pos = Vec::new();
        let mut kv = BTreeMap::new();
        for a in argv.into_iter().skip(1) {
            if let Some((k, v)) = a.split_once('=') {
                kv.insert(k.to_string(), v.to_string());
            } else {
                pos.push(a);
            }
        }
        Args { cmd, pos, kv }
    }

    pub fn get(&self, k: &str) -> Option<&str> {
        self.kv.get(k).map(|s| s.as_str())
    }

    pub fn get_f64(&self, k: &str, default: f64) -> Result<f64> {
        match self.get(k) {
            Some(v) => v.parse().with_context(|| format!("bad {k}={v}")),
            None => Ok(default),
        }
    }

    pub fn get_usize(&self, k: &str, default: usize) -> Result<usize> {
        match self.get(k) {
            Some(v) => v.parse().with_context(|| format!("bad {k}={v}")),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, k: &str, default: u64) -> Result<u64> {
        match self.get(k) {
            Some(v) => v.parse().with_context(|| format!("bad {k}={v}")),
            None => Ok(default),
        }
    }

    /// Parse a root policy: rand | norand | mix0 | mix12.5 | mix25 | mix50
    pub fn root_policy(&self, default: RootPolicy) -> Result<RootPolicy> {
        match self.get("roots") {
            None => Ok(default),
            Some("rand") => Ok(RootPolicy::Rand),
            Some("norand") => Ok(RootPolicy::NoRand),
            Some(s) if s.starts_with("mix") => {
                let pct: f64 = s[3..].parse().with_context(|| format!("bad roots={s}"))?;
                Ok(RootPolicy::CommRandMix { pct: pct / 100.0 })
            }
            Some(s) => bail!("unknown roots policy {s}"),
        }
    }
}

pub fn cli_main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect());
    match args.cmd.as_str() {
        "gen-data" => cmd_gen_data(&args),
        "smoke" => cmd_smoke(&args),
        "train" => cmd_train(&args),
        "inspect" => cmd_inspect(&args),
        "serve" => cmd_serve(&args),
        "exp" => crate::exp::run(&args),
        "help" | _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "comm-rand — community-structure-aware randomized GNN mini-batching

USAGE: comm-rand <cmd> [pos...] [key=value...]

COMMANDS:
  gen-data [preset...]   materialize datasets (default: all presets)
  smoke                  vertical-slice check on the tiny dataset
  train <preset>         train one configuration
                           roots=rand|norand|mix0|mix12.5|mix25|mix50
                           p=0.5..1.0  epochs=N  batch=N  seed=N  lr=F
                           ckpt_dir=PATH  ckpt_every=N (write CRC-checked
                           checkpoints every N epochs; retention keeps
                           best-by-val-acc + latest)
                           backend=auto|pjrt|host (host = pure-rust
                           SGC reference model; auto falls back to it
                           when AOT artifacts are absent)
  inspect <preset>       print dataset statistics
  serve bench [preset]   online-inference benchmark
                           p=0..1 (community-bias knob)  batch=N
                           sampler=uniform|biased|labor (micro-batch
                           MFG sampler; labor = cooperative shared-
                           variate sampling across co-batched requests,
                           default uniform keeps pre-knob benches
                           bitwise-identical)
                           sample_p=0..1 (intra-community weight for
                           sampler=biased; distinct from p, which
                           shapes batch composition)
                           clients=N  requests=N (per client)
                           delay_ms=F  deadline_ms=F  zipf=F
                           workers=N  cache_rows=N  cache_shards=N
                           shards=N (logical device shards; communities
                           are partitioned across them)
                           spill=strict|steal|broadcast  seed=N
                           arrival=closed|poisson:RATE (open-loop
                           Poisson arrivals at RATE req/s)
                           admission=none|reject|degrade (shed or
                           fanout-degrade unmeetable deadlines)
                           ckpt=PATH (serve trained parameters from a
                           checkpoint file, or the newest in a dir;
                           real top-1 accuracy lands in the report)
                           watch_ms=N (poll the ckpt dir during the
                           run and hot-swap newer checkpoints in)
                           cache_warm=1 (pre-stage hot feature rows
                           before the bench clock starts)
                           mutate=RATE (streaming graph churn at RATE
                           updates/s: edge inserts/deletes + feature
                           rewrites, applied in epochs while serving)
                           mutate_epoch=N (updates per mutation epoch)
                           maint=incr|full (incremental community
                           refinement vs naive full relabel per epoch)
                           drift=F (modularity-drift threshold that
                           triggers a full relabel under maint=incr)
                           trace=PATH (record per-request span events
                           and export a Chrome-trace JSON — load it in
                           Perfetto or chrome://tracing)
                           trace_sample=N (trace N permille of request
                           ids, default 1000 = all)
                           metrics_ms=N (write a Prometheus text
                           snapshot to results/serve_metrics.prom
                           every N ms; 0 = off)
                           health_ms=N (seal a windowed health
                           time-series every N ms; 0 = off; feeds
                           slo= and flight=)
                           slo=SPEC (burn-rate SLO alerting over the
                           health windows; SPEC is comma-separated
                           key=value — p99_ms= shed= err= stale=
                           acc= fast= slow= burn= clear_ratio=
                           clear= — or \"default\")
                           flight=DIR (flight recorder: dump an
                           atomic postmortem bundle into DIR on the
                           first SLO fire or thread stall)
                           locality=0|1 (reuse-distance profiler on
                           the feature-gather path; adds a locality{}
                           report section with a miss-ratio curve and
                           per-shard cache right-sizing advice)
                           locality_sample=N (profile N permille of
                           the node id space by stateless hash,
                           default 1000 = every node)
                           mrc_points=N (capacities sampled on the
                           miss-ratio curve, default 16)
                           kernel=auto|scalar|avx2 (SIMD dispatch for
                           the quantized i16q integer path; auto picks
                           the best the CPU supports, a named variant
                           is forced and errors if unavailable; every
                           variant is bitwise-identical)
                           (uses the PJRT infer artifact when present,
                            the pure-rust host executor otherwise)
  exp <id>               regenerate a paper artifact into results/
                           ids: fig2 fig5 fig6 fig7 fig8 fig9 fig10
                                tab3 tab4 tab5 fullbatch inference
                                preproc ablation autotune serve ckpt
                                stream obs coop quant health
                                locality all
  help                   this message

Presets: {}",
        preset_names().join(", ")
    );
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let names: Vec<String> = if args.pos.is_empty() {
        preset_names().iter().map(|s| s.to_string()).collect()
    } else {
        args.pos.clone()
    };
    for n in names {
        let p = preset(&n).with_context(|| format!("unknown preset {n}"))?;
        let ds = crate::train::dataset::load_or_build(&p, true)?;
        println!(
            "{}: |V|={} |E|={} comms={} train={} val={}",
            n,
            ds.n(),
            ds.csr.num_directed_edges() / 2,
            ds.num_comms,
            ds.train_nodes().len(),
            ds.val_nodes().len()
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let name = args.pos.first().context("inspect <preset>")?;
    let p = preset(name).with_context(|| format!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;
    let deg = crate::graph::stats::degree_stats(&ds.csr);
    let q = crate::graph::stats::modularity(&ds.csr, &ds.community);
    let intra = crate::graph::gen::intra_fraction(&ds.csr, &ds.community);
    println!("dataset {name}");
    println!("  |V| = {}", ds.n());
    println!("  |E| = {} (undirected)", ds.csr.num_directed_edges() / 2);
    println!(
        "  degree: min {} / median {} / mean {:.1} / max {}",
        deg.min, deg.median, deg.mean, deg.max
    );
    println!("  feat dim = {}, classes = {}", ds.feat_dim, ds.num_classes);
    println!(
        "  splits: train {} val {} test {}",
        ds.train_nodes().len(),
        ds.val_nodes().len(),
        ds.test_nodes().len()
    );
    println!("  communities (louvain): {}  Q = {q:.3}  intra-edge {intra:.3}", ds.num_comms);
    Ok(())
}

fn cmd_smoke(_args: &Args) -> Result<()> {
    use crate::runtime::{artifact, Runtime};
    let p = preset("tiny").unwrap();
    let ds = crate::train::dataset::load_or_build(&p, true)?;
    let manifest = artifact::Manifest::load(&artifact::default_dir())?;
    let train_meta = manifest.get("tiny.train")?;
    let infer_meta = manifest.get("tiny.infer")?;
    let rt = Runtime::cpu()?;
    println!(
        "platform = {} ({} devices)",
        rt.client.platform_name(),
        rt.client.device_count()
    );
    let mut st = crate::runtime::TrainState::new(
        &rt,
        train_meta,
        Some(infer_meta),
        Some(&ds),
        1e-3,
        0,
    )?;

    let mut rng = crate::util::rng::Rng::new(7);
    let train_nodes = ds.train_nodes();
    let policy = BatchPolicy::baseline();
    let spec = &train_meta.spec;
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for step in 0..20 {
        let order = crate::sampler::roots::order_roots(
            policy.roots,
            &train_nodes,
            &ds.community,
            &mut rng,
        );
        let roots = &order[..spec.batch_size.min(order.len())];
        let mfg = crate::sampler::build_mfg(
            &ds.csr,
            &ds.community,
            roots,
            &spec.fanouts,
            crate::sampler::NeighborPolicy::Uniform,
            &mut rng,
        );
        let batch = crate::batch::assemble(&mfg, &ds, train_meta, true)?;
        let out = st.step(&batch)?;
        if first_loss.is_none() {
            first_loss = Some(out.loss);
        }
        last_loss = out.loss;
        if step % 5 == 0 {
            println!(
                "step {step:>3}: loss {:.4}  acc {:.3}  (input nodes {})",
                out.loss,
                out.correct / batch.stats.num_labeled.max(1) as f32,
                batch.stats.input_nodes
            );
        }
    }
    let f = first_loss.unwrap();
    println!("loss {f:.4} -> {last_loss:.4}");
    if !(last_loss.is_finite() && last_loss < f) {
        bail!("smoke: loss did not decrease");
    }
    println!("smoke OK");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let sub = args.pos.first().map(String::as_str).unwrap_or("bench");
    match sub {
        "bench" => cmd_serve_bench(args),
        other => bail!("unknown serve subcommand {other:?} (try: serve bench)"),
    }
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    use crate::serve::{
        engine, AdmissionPolicy, Arrival, LoadConfig, ServeConfig, SpillPolicy,
    };
    use crate::stream::MaintenanceMode;

    let name = args.pos.get(1).map(String::as_str).unwrap_or("tiny");
    let p = preset(name).with_context(|| format!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;

    let defaults = ServeConfig::for_dataset(&ds);
    let scfg = ServeConfig {
        batch_size: args.get_usize("batch", defaults.batch_size)?,
        max_delay_us: (args.get_f64("delay_ms", 2.0)? * 1e3) as u64,
        deadline_us: (args.get_f64("deadline_ms", 50.0)? * 1e3) as u64,
        community_bias: args.get_f64("p", defaults.community_bias)?,
        workers: args.get_usize("workers", defaults.workers)?,
        queue_cap: args.get_usize("queue", defaults.queue_cap)?,
        cache_rows: args.get_usize("cache_rows", defaults.cache_rows)?,
        cache_shards: args.get_usize("cache_shards", defaults.cache_shards)?,
        shards: args.get_usize("shards", defaults.shards)?,
        spill: SpillPolicy::parse(args.get("spill").unwrap_or("strict"))?,
        admission: AdmissionPolicy::parse(
            args.get("admission").unwrap_or("none"),
        )?,
        fanouts: defaults.fanouts,
        sampler: {
            let v = args.get("sampler").unwrap_or("uniform");
            crate::sampler::SamplerKind::parse(v).ok_or_else(|| {
                anyhow::anyhow!(
                    "sampler must be uniform|biased|labor, got {v:?}"
                )
            })?
        },
        sample_p: args.get_f64("sample_p", defaults.sample_p)?,
        seed: args.get_u64("seed", 0)?,
        kernel: args.get("kernel").unwrap_or("auto").to_string(),
        ckpt: args.get("ckpt").map(std::path::PathBuf::from),
        ckpt_watch_ms: args.get_u64("watch_ms", 0)?,
        cache_warm: args.get_usize("cache_warm", 0)? != 0,
        mutate_rps: args.get_f64("mutate", 0.0)?,
        mutate_epoch: args.get_usize("mutate_epoch", 64)?,
        drift_threshold: args.get_f64("drift", 0.15)?,
        maintenance: MaintenanceMode::parse(
            args.get("maint").unwrap_or("incr"),
        )?,
        trace: args.get("trace").map(std::path::PathBuf::from),
        trace_sample: args.get_u64("trace_sample", 1000)? as u32,
        metrics_ms: args.get_u64("metrics_ms", 0)?,
        metrics_path: defaults.metrics_path,
        health_ms: args.get_u64("health_ms", 0)?,
        slo: args
            .get("slo")
            .map(crate::obs::SloSpec::parse)
            .transpose()
            .context("slo= knob")?,
        flight: args.get("flight").map(std::path::PathBuf::from),
        locality: args.get_u64("locality", 0)? != 0,
        locality_sample: args.get_u64("locality_sample", 1000)? as u32,
        mrc_points: args.get_usize("mrc_points", 16)?,
    };
    if !(0.0..=1.0).contains(&scfg.community_bias) {
        bail!("p must be in [0, 1], got {}", scfg.community_bias);
    }
    if !(0.0..=1.0).contains(&scfg.sample_p) {
        bail!("sample_p must be in [0, 1], got {}", scfg.sample_p);
    }
    if scfg.shards == 0 {
        bail!("shards must be >= 1");
    }
    // resolve early for a crisp CLI error (build_executor re-resolves)
    crate::runtime::kernels::KernelBackend::resolve(&scfg.kernel)
        .context("kernel= knob")?;
    if !scfg.mutate_rps.is_finite() || scfg.mutate_rps < 0.0 {
        bail!("mutate must be a non-negative rate, got {}", scfg.mutate_rps);
    }
    if !(scfg.drift_threshold.is_finite() && scfg.drift_threshold > 0.0) {
        bail!("drift must be a positive threshold, got {}", scfg.drift_threshold);
    }
    if scfg.trace_sample > 1000 {
        bail!(
            "trace_sample is permille in [0, 1000], got {}",
            scfg.trace_sample
        );
    }
    if scfg.locality_sample == 0 || scfg.locality_sample > 1000 {
        bail!(
            "locality_sample is permille in [1, 1000], got {}",
            scfg.locality_sample
        );
    }
    if scfg.mrc_points == 0 {
        bail!("mrc_points must be >= 1");
    }
    if scfg.slo.is_some() && scfg.health_ms == 0 {
        bail!("slo= needs health_ms=N > 0 (no windows to evaluate against)");
    }
    if scfg.flight.is_some() && scfg.health_ms == 0 {
        bail!("flight= needs health_ms=N > 0 (no health tick to trigger it)");
    }
    let lcfg = LoadConfig {
        clients: args.get_usize("clients", 8)?,
        requests_per_client: args.get_usize("requests", 64)?,
        zipf_s: args.get_f64("zipf", 1.1)?,
        arrival: Arrival::parse(args.get("arrival").unwrap_or("closed"))?,
        seed: scfg.seed ^ 0x10AD,
    };

    let (exec, meta) = engine::build_executor(&p, &ds, &scfg)?;
    let report = engine::run(&ds, &meta, exec.as_ref(), &scfg, &lcfg)?;
    println!("{}", report.summary());
    if report.n_shards > 1 {
        for sh in &report.shards {
            println!(
                "  shard {}: {} comms / {} nodes owned | {} req \
                 ({} foreign, {} shed, {} degraded) in {} batches | \
                 params v{} ({} swaps) | depth max {} | est service \
                 {:.0} us | p50 {:.2} p99 {:.2} ms | cache hit {:.1}% \
                 ({} stale)",
                sh.id,
                sh.owned_comms,
                sh.owned_nodes,
                sh.requests,
                sh.foreign_requests,
                sh.shed,
                sh.degraded,
                sh.batches,
                sh.param_version,
                sh.swaps,
                sh.queue_depth_max,
                sh.est_service_us,
                sh.lat_p50_ms,
                sh.lat_p99_ms,
                sh.cache_hit_rate * 100.0,
                sh.stale_hits,
            );
        }
    }
    let json = report.to_json().to_string_pretty();
    println!("{json}");
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/serve_bench.json", &json)
        .context("writing results/serve_bench.json")?;
    println!("[serve] wrote results/serve_bench.json");
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    use crate::ckpt::{CheckpointWriter, Retention};
    use crate::train::CkptConfig;

    let name = args.pos.first().context("train <preset>")?.clone();
    let p = preset(&name).with_context(|| format!("unknown preset {name}"))?;
    let ds = crate::train::dataset::load_or_build(&p, true)?;
    let ckpt = match args.get("ckpt_dir") {
        Some(dir) => Some(CkptConfig {
            dir: dir.into(),
            every: args.get_usize("ckpt_every", 1)?.max(1),
            retention: Retention::BestAndLatest,
        }),
        None => None,
    };

    // backend selection: the PJRT trainer needs the AOT artifacts; the
    // host backend (pure-rust SGC reference model) runs anywhere and
    // writes the same checkpoint format
    let backend = args.get("backend").unwrap_or("auto");
    let pjrt_available = crate::runtime::artifact::Manifest::load(
        &crate::runtime::artifact::default_dir(),
    )
    .and_then(|m| m.get(&format!("{}.train", p.artifact)).map(|_| ()))
    .is_ok();
    let use_host = match backend {
        "host" => true,
        "pjrt" => false,
        "auto" => {
            if !pjrt_available {
                eprintln!(
                    "[train] AOT artifacts unavailable; falling back to \
                     backend=host (pure-rust SGC reference model)"
                );
            }
            !pjrt_available
        }
        other => bail!("unknown backend {other:?} (try: auto | pjrt | host)"),
    };

    if use_host {
        // the linear host model takes a larger step size than the GNN
        let cfg = TrainConfig {
            batch_size: args.get_usize("batch", 256)?,
            lr: args.get_f64("lr", 0.5)? as f32,
            max_epochs: args.get_usize("epochs", 8)?,
            seed: args.get_u64("seed", 0)?,
            ..Default::default()
        };
        let mut writer = match &ckpt {
            Some(cc) => {
                Some(CheckpointWriter::new(&cc.dir, cc.every, cc.retention)?)
            }
            None => None,
        };
        let (_, report) =
            crate::train::train_host(&ds, &cfg, writer.as_mut(), true)?;
        println!("{}", report.summary());
        if let Some(w) = &writer {
            for e in w.entries() {
                println!(
                    "[ckpt] kept {} (epoch {}, val acc {:.4})",
                    e.path.display(),
                    e.epoch,
                    e.val_acc
                );
            }
        }
        return Ok(());
    }

    let policy = BatchPolicy {
        roots: args.root_policy(RootPolicy::Rand)?,
        p_intra: args.get_f64("p", 0.5)?,
    };
    let cfg = TrainConfig {
        batch_size: args.get_usize("batch", 256)?,
        lr: args.get_f64("lr", 1e-3)? as f32,
        max_epochs: args.get_usize("epochs", 60)?,
        seed: args.get_u64("seed", 0)?,
        ..Default::default()
    };
    let report =
        crate::train::run_training(&ds, p.artifact, &policy, &cfg, true, ckpt)?;
    println!("{}", report.summary());
    Ok(())
}
