//! Cache simulation + device time model.
//!
//! The paper's per-epoch speedups come from on-chip (A100 L2) and
//! software-managed cache reuse during feature fetches — effects a
//! CPU-only testbed cannot measure directly. We therefore replay each
//! batch's feature access stream through:
//!
//! * [`lru`] — a set-associative LRU cache modelling the GPU L2
//!   (Fig. 5/6 per-epoch time model, Fig. 10 capacity sweep), and
//! * [`swcache`] — a feature-granularity LRU modelling DGL's GPU
//!   software cache over UVA transfers (Fig. 9),
//!
//! and convert hit/miss counts into a modelled epoch time with
//! [`timemodel`] (bandwidth-calibrated to the A100's L2:HBM ratio).
//! Wall-clock CPU times are *also* reported by every experiment; the
//! model is what makes the cache-sensitivity studies reproducible.

pub mod lru;
pub mod swcache;
pub mod timemodel;

pub use lru::{SetAssocCache, SetAssocCore};
pub use swcache::SoftwareCache;
pub use timemodel::{DeviceModel, EpochCost};
