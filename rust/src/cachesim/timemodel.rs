//! Modelled device epoch time (the figure-generating experiments
//! report both this and measured CPU wall-clock).
//!
//! GNN mini-batch training on an A100 is memory-bound in the feature
//! gather: per-batch cost ≈ feature traffic at the achieved level of
//! the memory hierarchy + a compute term proportional to the sampled
//! sub-graph's dense work. We model:
//!
//!   t_batch = hits * line / BW_l2 + misses * line / BW_hbm
//!           + dense_flops / F_eff [+ uva_bytes / BW_pcie]
//!
//! with A100 constants: BW_l2 ≈ 4 TB/s, BW_hbm ≈ 2 TB/s (2039 GB/s
//! peak ≈ 0.8 achieved), F_eff ≈ 60 TFLOP/s effective f32 tensor-core
//! rate on small GEMMs, PCIe-gen4 ≈ 25 GB/s. Absolute numbers are not
//! the claim (the paper's testbed differs); what the model preserves is
//! the *relative* cost shift as hit rates move — exactly what Figs
//! 5/6/9/10 measure.

use super::lru::SetAssocCache;

/// Bandwidth/compute constants of the modelled device (see module
/// docs for the calibration rationale).
#[derive(Clone, Debug)]
pub struct DeviceModel {
    /// L2 bandwidth, bytes/s.
    pub l2_bw: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Effective dense-compute rate, flop/s.
    pub flops: f64,
    /// PCIe bandwidth for UVA transfers, bytes/s.
    pub pcie_bw: f64,
    /// Cache-line size, bytes.
    pub line_bytes: f64,
    /// Fixed per-batch launch/driver overhead (s).
    pub batch_overhead: f64,
}

impl Default for DeviceModel {
    fn default() -> Self {
        // Scaled-testbed calibration (DESIGN.md §Cache-Model): the
        // simulated datasets are ~15-100x smaller than the real ones,
        // so bandwidths are scaled down 10x from A100 peaks to keep the
        // *relative* weight of feature traffic vs. dense compute at the
        // level the paper measures (feature gather dominant, Fig. 6).
        // The effective GEMM rate reflects small-batch GEMM efficiency
        // (~15% of tensor-core peak).
        DeviceModel {
            l2_bw: 400.0e9,
            hbm_bw: 160.0e9,
            flops: 9.0e12,
            pcie_bw: 2.5e9,
            line_bytes: 128.0,
            batch_overhead: 4e-6,
        }
    }
}

/// Accumulated modelled cost over an epoch.
#[derive(Clone, Debug, Default)]
pub struct EpochCost {
    /// Line accesses served from L2.
    pub l2_hits: u64,
    /// Line accesses that went to HBM.
    pub l2_misses: u64,
    /// Accumulated dense work, flops.
    pub dense_flops: f64,
    /// Bytes moved over PCIe (UVA fallback path).
    pub uva_bytes: f64,
    /// Mini-batches accumulated (each pays `batch_overhead`).
    pub batches: usize,
}

impl EpochCost {
    /// Fold a cache replay's hit/miss counters into the cost.
    pub fn add_cache(&mut self, c: &SetAssocCache) {
        self.l2_hits += c.hits;
        self.l2_misses += c.misses;
    }

    /// Dense work of one batch: Σ_l rows_l · f_in · f_out · 2 (+
    /// aggregation traffic folded into the cache replay).
    pub fn add_dense(&mut self, level_sizes: &[usize], dims: &[usize]) {
        // dims: [feat, hidden, ..., classes]; level_sizes: input-most
        // first, len = layers+1
        let layers = dims.len() - 1;
        for l in 0..layers {
            let rows = *level_sizes.get(l + 1).unwrap_or(&0) as f64;
            self.dense_flops += 2.0 * rows * dims[l] as f64 * dims[l + 1] as f64;
        }
    }

    /// Total modelled epoch time under device model `m`, in seconds.
    pub fn seconds(&self, m: &DeviceModel) -> f64 {
        self.l2_hits as f64 * m.line_bytes / m.l2_bw
            + self.l2_misses as f64 * m.line_bytes / m.hbm_bw
            + self.dense_flops / m.flops
            + self.uva_bytes / m.pcie_bw
            + self.batches as f64 * m.batch_overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_misses_cost_more() {
        let m = DeviceModel::default();
        let mut a = EpochCost { l2_hits: 1000, l2_misses: 10, ..Default::default() };
        let mut b = EpochCost { l2_hits: 10, l2_misses: 1000, ..Default::default() };
        a.batches = 1;
        b.batches = 1;
        assert!(a.seconds(&m) < b.seconds(&m));
    }

    #[test]
    fn dense_term_accumulates() {
        let mut c = EpochCost::default();
        c.add_dense(&[100, 50, 10], &[32, 16, 4]);
        // layer0: 50*32*16*2, layer1: 10*16*4*2
        assert!((c.dense_flops - (50.0 * 32.0 * 16.0 * 2.0 + 10.0 * 16.0 * 4.0 * 2.0)).abs() < 1.0);
    }

    #[test]
    fn uva_term() {
        let m = DeviceModel::default();
        let c = EpochCost { uva_bytes: m.pcie_bw, ..Default::default() };
        assert!((c.seconds(&m) - 1.0).abs() < 1e-9);
    }
}
