//! Set-associative true-LRU machinery.
//!
//! Two layers:
//!
//! * [`SetAssocCore`] — the reusable tag/stamp core (sets × ways,
//!   true-LRU replacement, no payload). It backs both the
//!   statistics-only L2 model below and the *functional* sharded
//!   feature cache on the serving hot path
//!   ([`crate::serve::cache::ShardedFeatureCache`]), which attaches a
//!   payload slab to the core's slot indices.
//! * [`SetAssocCache`] — the GPU-L2 stand-in used by the evaluation:
//!   addresses are byte addresses, expanded into line accesses by the
//!   caller (a 128-float row = 4 lines of 128B).

/// Geometry of a modelled cache, in bytes and lines.
#[derive(Clone, Debug)]
pub struct CacheConfig {
    /// Total capacity in bytes; rounded down to whole lines.
    pub capacity_bytes: usize,
    /// Cache-line size in bytes (the unit of allocation and lookup).
    pub line_bytes: usize,
    /// Set associativity; clamped to the line count at construction.
    pub ways: usize,
}

impl CacheConfig {
    /// A100 L2 (40 MB), scaled variants via `scale`.
    pub fn a100_l2(scale: f64) -> CacheConfig {
        CacheConfig {
            capacity_bytes: (40.0 * 1024.0 * 1024.0 * scale) as usize,
            line_bytes: 128,
            ways: 16,
        }
    }
}

/// Result of one [`SetAssocCore::probe`].
#[derive(Clone, Copy, Debug)]
pub struct Probe {
    /// Flat slot index (`set * ways + way`) the key now occupies;
    /// payload-carrying callers index their slab with this.
    pub slot: usize,
    /// Whether the key was already resident before this probe.
    pub hit: bool,
    /// Key evicted to make room (miss with a valid victim only).
    pub evicted: Option<u64>,
}

/// Reusable set-associative true-LRU core: tags and LRU stamps only.
///
/// Keys are arbitrary `u64`s except `u64::MAX` (the invalid sentinel);
/// both users key by values far below that (cache-line numbers, node
/// ids). A mixer spreads power-of-two-strided keys over sets.
pub struct SetAssocCore {
    sets: usize,
    ways: usize,
    /// tags[set * ways + way]; u64::MAX = invalid
    tags: Vec<u64>,
    /// LRU stamps, same layout
    stamp: Vec<u64>,
    clock: u64,
}

#[inline]
fn mix(key: u64) -> u64 {
    let mut h = key;
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51afd7ed558ccd);
    h ^= h >> 33;
    h
}

impl SetAssocCore {
    /// Build an empty core with the given geometry (both dimensions
    /// clamped to at least 1).
    pub fn new(sets: usize, ways: usize) -> SetAssocCore {
        let sets = sets.max(1);
        let ways = ways.max(1);
        SetAssocCore {
            sets,
            ways,
            tags: vec![u64::MAX; sets * ways],
            stamp: vec![0; sets * ways],
            clock: 0,
        }
    }

    /// Number of sets.
    pub fn sets(&self) -> usize {
        self.sets
    }

    /// Associativity (slots per set).
    pub fn ways(&self) -> usize {
        self.ways
    }

    /// Total slot count (`sets * ways`).
    pub fn slots(&self) -> usize {
        self.sets * self.ways
    }

    /// Look up `key`, inserting it (with true-LRU victim selection in
    /// its set) on a miss.
    #[inline]
    pub fn probe(&mut self, key: u64) -> Probe {
        debug_assert!(key != u64::MAX, "u64::MAX is the invalid-tag sentinel");
        self.clock += 1;
        let set = (mix(key) % self.sets as u64) as usize;
        let base = set * self.ways;
        let mut victim = base;
        let mut oldest = u64::MAX;
        for i in base..base + self.ways {
            if self.tags[i] == key {
                self.stamp[i] = self.clock;
                return Probe { slot: i, hit: true, evicted: None };
            }
            if self.stamp[i] < oldest {
                oldest = self.stamp[i];
                victim = i;
            }
        }
        let evicted = if self.tags[victim] == u64::MAX {
            None
        } else {
            Some(self.tags[victim])
        };
        self.tags[victim] = key;
        self.stamp[victim] = self.clock;
        Probe { slot: victim, hit: false, evicted }
    }
}

/// Statistics-only set-associative LRU cache model (GPU L2 stand-in).
pub struct SetAssocCache {
    cfg: CacheConfig,
    core: SetAssocCore,
    /// Line accesses that found their line resident.
    pub hits: u64,
    /// Line accesses that allocated (and possibly evicted).
    pub misses: u64,
}

impl SetAssocCache {
    /// Build an empty cache from `cfg`, deriving `sets` from
    /// capacity / line size / ways.
    pub fn new(cfg: CacheConfig) -> SetAssocCache {
        let lines = (cfg.capacity_bytes / cfg.line_bytes).max(1);
        let ways = cfg.ways.min(lines).max(1);
        let sets = (lines / ways).max(1);
        SetAssocCache {
            core: SetAssocCore::new(sets, ways),
            hits: 0,
            misses: 0,
            cfg: CacheConfig { ways, ..cfg },
        }
    }

    /// Touch the line containing `byte_addr`; returns whether it hit.
    #[inline]
    pub fn access(&mut self, byte_addr: u64) -> bool {
        let line = byte_addr / self.cfg.line_bytes as u64;
        let p = self.core.probe(line);
        if p.hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        p.hit
    }

    /// Replay a feature-row access: row `node` of a `[n, feat_dim]` f32
    /// table at base address 0.
    pub fn access_row(&mut self, node: u32, feat_dim: usize) {
        let row_bytes = feat_dim * 4;
        let base = node as u64 * row_bytes as u64;
        let mut off = 0;
        while off < row_bytes {
            self.access(base + off as u64);
            off += self.cfg.line_bytes;
        }
    }

    /// `misses / (hits + misses)`, or 0 before any access.
    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }

    /// Zero the hit/miss counters, keeping cache contents warm.
    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SetAssocCache {
        SetAssocCache::new(CacheConfig {
            capacity_bytes: 4096,
            line_bytes: 64,
            ways: 4,
        })
    }

    #[test]
    fn repeat_access_hits() {
        let mut c = tiny();
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(32)); // same line
        assert!(!c.access(64)); // next line
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn working_set_within_capacity_all_hits() {
        let mut c = tiny();
        // 4KB cache, touch 2KB twice
        for addr in (0..2048u64).step_by(64) {
            c.access(addr);
        }
        c.reset_counters();
        for addr in (0..2048u64).step_by(64) {
            assert!(c.access(addr), "addr {addr} missed");
        }
        assert_eq!(c.misses, 0);
    }

    #[test]
    fn thrashing_when_oversized() {
        let mut c = tiny();
        // stream 64KB >> 4KB cache, twice: second pass still misses
        for _ in 0..2 {
            for addr in (0..65536u64).step_by(64) {
                c.access(addr);
            }
        }
        assert!(c.miss_rate() > 0.9, "miss rate {}", c.miss_rate());
    }

    #[test]
    fn row_access_counts_lines() {
        let mut c = tiny();
        c.access_row(0, 32); // 128 bytes = 2 lines of 64B
        assert_eq!(c.hits + c.misses, 2);
    }

    #[test]
    fn smaller_cache_misses_more() {
        let stream: Vec<u32> = (0..1000u32).map(|i| (i * 37) % 256).collect();
        let mut big = SetAssocCache::new(CacheConfig {
            capacity_bytes: 64 * 1024,
            line_bytes: 64,
            ways: 8,
        });
        let mut small = SetAssocCache::new(CacheConfig {
            capacity_bytes: 2 * 1024,
            line_bytes: 64,
            ways: 8,
        });
        for &n in &stream {
            big.access_row(n, 16);
            small.access_row(n, 16);
        }
        assert!(small.misses >= big.misses);
    }

    #[test]
    fn core_fully_associative_is_exact_lru() {
        // sets=1 => stamps implement exact LRU over all slots
        let mut core = SetAssocCore::new(1, 2);
        assert!(!core.probe(10).hit);
        assert!(!core.probe(20).hit);
        assert!(core.probe(10).hit); // 10 now MRU
        let p = core.probe(30); // evicts 20 (LRU)
        assert!(!p.hit);
        assert_eq!(p.evicted, Some(20));
        assert!(core.probe(10).hit);
        assert!(!core.probe(20).hit); // 20 gone
    }

    #[test]
    fn core_slot_stable_across_hits() {
        let mut core = SetAssocCore::new(4, 4);
        let a = core.probe(123);
        assert!(!a.hit);
        let b = core.probe(123);
        assert!(b.hit);
        assert_eq!(a.slot, b.slot);
        assert!(a.slot < core.slots());
    }

    /// Regression: eviction strictly follows true-LRU order within a
    /// set, including after hits reorder the recency stamps.
    #[test]
    fn core_eviction_order_is_true_lru() {
        let mut core = SetAssocCore::new(1, 3);
        assert_eq!(core.probe(1).evicted, None); // fills empty ways:
        assert_eq!(core.probe(2).evicted, None); // no victim until the
        assert_eq!(core.probe(3).evicted, None); // set is full
        assert!(core.probe(1).hit); // recency now: 2 < 3 < 1
        let p = core.probe(4);
        assert!(!p.hit);
        assert_eq!(p.evicted, Some(2), "2 was least recently used");
        // recency now: 3 < 1 < 4
        let p = core.probe(2);
        assert_eq!(p.evicted, Some(3));
        // recency now: 1 < 4 < 2
        let p = core.probe(5);
        assert_eq!(p.evicted, Some(1));
        // survivors hit, victims miss
        assert!(core.probe(4).hit);
        assert!(core.probe(2).hit);
        assert!(!core.probe(3).hit);
    }

    /// Regression: traffic in one set never evicts another set's lines
    /// (with `ways = 1`, any cross-set interference would be an
    /// immediate miss).
    #[test]
    fn core_sets_are_isolated() {
        // find two keys that land in different sets, and one sharing
        // a's set, by probing fresh cores
        let set_of = |k: u64| {
            let mut c = SetAssocCore::new(2, 1);
            c.probe(k).slot // ways = 1 => slot == set index
        };
        let a = 0u64;
        let b = (1..100u64).find(|&k| set_of(k) != set_of(a)).unwrap();
        let a2 = (1..100u64)
            .find(|&k| set_of(k) == set_of(a) && k != a)
            .unwrap();
        let mut core = SetAssocCore::new(2, 1);
        core.probe(a);
        core.probe(b);
        // hammer b's set: a must survive untouched
        for _ in 0..10 {
            assert!(core.probe(b).hit);
        }
        assert!(core.probe(a).hit, "cross-set eviction");
        // same-set conflict does evict (ways = 1)
        let p = core.probe(a2);
        assert_eq!(p.evicted, Some(a));
        assert!(!core.probe(a).hit);
        assert!(core.probe(b).hit, "victim must come from a's set only");
    }

    /// Regression: hit + miss accounting is exact and deterministic —
    /// the serving feature cache reuses this core, so a silent change
    /// here would skew `serve bench` hit rates too.
    #[test]
    fn core_accounting_is_exact_and_deterministic() {
        let run = || {
            let mut c = SetAssocCache::new(CacheConfig {
                capacity_bytes: 8 * 1024,
                line_bytes: 64,
                ways: 4,
            });
            for i in 0..5_000u32 {
                // 8 hot rows (short reuse distance -> hits) interleaved
                // with a long streaming scan (capacity misses)
                let node =
                    if i % 2 == 0 { (i / 2) % 8 } else { (i * 37) % 512 };
                c.access_row(node, 16);
            }
            (c.hits, c.misses)
        };
        let (h1, m1) = run();
        let (h2, m2) = run();
        assert_eq!((h1, m1), (h2, m2), "replay must be deterministic");
        // 16 floats * 4B = 64B = exactly 1 line per row
        assert_eq!(h1 + m1, 5_000, "every access accounted exactly once");
        assert!(h1 > 0 && m1 > 0, "stream must exercise both paths");
    }
}
