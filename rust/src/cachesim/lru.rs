//! Set-associative LRU cache model (GPU L2 stand-in).
//!
//! Addresses are byte addresses; the simulator tracks tags per set with
//! true-LRU replacement. Feature-row accesses are expanded into line
//! accesses by the caller (a 128-float row = 4 lines of 128B).

#[derive(Clone, Debug)]
pub struct CacheConfig {
    pub capacity_bytes: usize,
    pub line_bytes: usize,
    pub ways: usize,
}

impl CacheConfig {
    /// A100 L2 (40 MB), scaled variants via `scale`.
    pub fn a100_l2(scale: f64) -> CacheConfig {
        CacheConfig {
            capacity_bytes: (40.0 * 1024.0 * 1024.0 * scale) as usize,
            line_bytes: 128,
            ways: 16,
        }
    }
}

pub struct SetAssocCache {
    cfg: CacheConfig,
    sets: usize,
    /// tags[set * ways + way]; u64::MAX = invalid
    tags: Vec<u64>,
    /// LRU stamps, same layout
    stamp: Vec<u64>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl SetAssocCache {
    pub fn new(cfg: CacheConfig) -> SetAssocCache {
        let lines = (cfg.capacity_bytes / cfg.line_bytes).max(1);
        let ways = cfg.ways.min(lines).max(1);
        let sets = (lines / ways).max(1);
        SetAssocCache {
            sets,
            tags: vec![u64::MAX; sets * ways],
            stamp: vec![0; sets * ways],
            clock: 0,
            hits: 0,
            misses: 0,
            cfg: CacheConfig { ways, ..cfg },
        }
    }

    #[inline]
    pub fn access(&mut self, byte_addr: u64) -> bool {
        self.clock += 1;
        let line = byte_addr / self.cfg.line_bytes as u64;
        // mix the line number so power-of-two strides spread over sets
        let mut h = line;
        h ^= h >> 33;
        h = h.wrapping_mul(0xff51afd7ed558ccd);
        h ^= h >> 33;
        let set = (h % self.sets as u64) as usize;
        let base = set * self.cfg.ways;
        let ways = self.cfg.ways;
        let mut victim = base;
        let mut oldest = u64::MAX;
        for i in base..base + ways {
            if self.tags[i] == line {
                self.stamp[i] = self.clock;
                self.hits += 1;
                return true;
            }
            if self.stamp[i] < oldest {
                oldest = self.stamp[i];
                victim = i;
            }
        }
        self.tags[victim] = line;
        self.stamp[victim] = self.clock;
        self.misses += 1;
        false
    }

    /// Replay a feature-row access: row `node` of a `[n, feat_dim]` f32
    /// table at base address 0.
    pub fn access_row(&mut self, node: u32, feat_dim: usize) {
        let row_bytes = feat_dim * 4;
        let base = node as u64 * row_bytes as u64;
        let mut off = 0;
        while off < row_bytes {
            self.access(base + off as u64);
            off += self.cfg.line_bytes;
        }
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SetAssocCache {
        SetAssocCache::new(CacheConfig {
            capacity_bytes: 4096,
            line_bytes: 64,
            ways: 4,
        })
    }

    #[test]
    fn repeat_access_hits() {
        let mut c = tiny();
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(32)); // same line
        assert!(!c.access(64)); // next line
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn working_set_within_capacity_all_hits() {
        let mut c = tiny();
        // 4KB cache, touch 2KB twice
        for addr in (0..2048u64).step_by(64) {
            c.access(addr);
        }
        c.reset_counters();
        for addr in (0..2048u64).step_by(64) {
            assert!(c.access(addr), "addr {addr} missed");
        }
        assert_eq!(c.misses, 0);
    }

    #[test]
    fn thrashing_when_oversized() {
        let mut c = tiny();
        // stream 64KB >> 4KB cache, twice: second pass still misses
        for _ in 0..2 {
            for addr in (0..65536u64).step_by(64) {
                c.access(addr);
            }
        }
        assert!(c.miss_rate() > 0.9, "miss rate {}", c.miss_rate());
    }

    #[test]
    fn row_access_counts_lines() {
        let mut c = tiny();
        c.access_row(0, 32); // 128 bytes = 2 lines of 64B
        assert_eq!(c.hits + c.misses, 2);
    }

    #[test]
    fn smaller_cache_misses_more() {
        let stream: Vec<u32> = (0..1000u32).map(|i| (i * 37) % 256).collect();
        let mut big = SetAssocCache::new(CacheConfig {
            capacity_bytes: 64 * 1024,
            line_bytes: 64,
            ways: 8,
        });
        let mut small = SetAssocCache::new(CacheConfig {
            capacity_bytes: 2 * 1024,
            line_bytes: 64,
            ways: 8,
        });
        for &n in &stream {
            big.access_row(n, 16);
            small.access_row(n, 16);
        }
        assert!(small.misses >= big.misses);
    }
}
