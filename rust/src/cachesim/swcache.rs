//! Software-managed feature cache (Fig. 9): models DGL's GPU-resident
//! embedding cache over UVA. Granularity is a whole feature row; exact
//! LRU via an intrusive doubly-linked list over a dense node-indexed
//! table (O(1) per access, no hashing).

/// Row-granular exact-LRU software cache over a dense node id space.
pub struct SoftwareCache {
    capacity: usize,
    len: usize,
    /// per-node slot state; u32::MAX sentinels
    prev: Vec<u32>,
    next: Vec<u32>,
    resident: Vec<bool>,
    head: u32, // most-recent
    tail: u32, // least-recent
    /// Row accesses that found the row resident.
    pub hits: u64,
    /// Row accesses that faulted the row in (evicting the LRU row
    /// when full).
    pub misses: u64,
}

const NIL: u32 = u32::MAX;

impl SoftwareCache {
    /// `capacity` = number of feature rows the cache can hold;
    /// `n` = total nodes.
    pub fn new(capacity: usize, n: usize) -> SoftwareCache {
        SoftwareCache {
            capacity: capacity.max(1),
            len: 0,
            prev: vec![NIL; n],
            next: vec![NIL; n],
            resident: vec![false; n],
            head: NIL,
            tail: NIL,
            hits: 0,
            misses: 0,
        }
    }

    fn unlink(&mut self, v: u32) {
        let p = self.prev[v as usize];
        let nx = self.next[v as usize];
        if p != NIL {
            self.next[p as usize] = nx;
        } else {
            self.head = nx;
        }
        if nx != NIL {
            self.prev[nx as usize] = p;
        } else {
            self.tail = p;
        }
        self.prev[v as usize] = NIL;
        self.next[v as usize] = NIL;
    }

    fn push_front(&mut self, v: u32) {
        self.prev[v as usize] = NIL;
        self.next[v as usize] = self.head;
        if self.head != NIL {
            self.prev[self.head as usize] = v;
        }
        self.head = v;
        if self.tail == NIL {
            self.tail = v;
        }
    }

    /// Access node `v`'s feature row; returns true on hit.
    pub fn access(&mut self, v: u32) -> bool {
        if self.resident[v as usize] {
            self.hits += 1;
            self.unlink(v);
            self.push_front(v);
            true
        } else {
            self.misses += 1;
            if self.len == self.capacity {
                let evict = self.tail;
                self.unlink(evict);
                self.resident[evict as usize] = false;
                self.len -= 1;
            }
            self.resident[v as usize] = true;
            self.push_front(v);
            self.len += 1;
            false
        }
    }

    /// `misses / (hits + misses)`, or 0 before any access.
    pub fn miss_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.misses as f64 / t as f64
        }
    }

    /// Zero the hit/miss counters, keeping cache contents warm.
    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_eviction_order() {
        let mut c = SoftwareCache::new(2, 10);
        assert!(!c.access(0));
        assert!(!c.access(1));
        assert!(c.access(0)); // 0 now MRU
        assert!(!c.access(2)); // evicts 1
        assert!(c.access(0));
        assert!(!c.access(1)); // 1 was evicted
    }

    #[test]
    fn capacity_respected() {
        let mut c = SoftwareCache::new(5, 100);
        for v in 0..50u32 {
            c.access(v);
        }
        assert_eq!(c.len, 5);
        // last 5 resident
        c.reset_counters();
        for v in 45..50u32 {
            assert!(c.access(v));
        }
        assert_eq!(c.misses, 0);
    }

    #[test]
    fn full_residency_all_hits() {
        let mut c = SoftwareCache::new(10, 10);
        for v in 0..10u32 {
            c.access(v);
        }
        c.reset_counters();
        for _ in 0..3 {
            for v in 0..10u32 {
                assert!(c.access(v));
            }
        }
        assert_eq!(c.miss_rate(), 0.0);
    }
}
