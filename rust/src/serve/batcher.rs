//! Dynamic micro-batcher: coalesces queued requests into batches under
//! a latency budget, with a COMM-RAND-style community-bias knob.
//!
//! Pure, clock-injected logic (no threads, no `Instant`): the engine's
//! batcher thread feeds it wall time, unit tests feed it synthetic
//! time. A batch forms when either
//!
//! * enough requests are pending (`batch_size`), or
//! * some request reaches its *flush point*
//!   `min(arrive + max_delay, deadline)` — so a lone request is flushed
//!   at its deadline, never starved.
//!
//! Batch membership is where the knob `p` acts: overdue requests are
//! always taken (deadlines dominate), then remaining slots are filled
//! by drawing per slot — with probability `p` the next pending request
//! from the *seed community* (the oldest member's community), otherwise
//! the global FIFO head. `p = 0` degenerates to pure FIFO; `p = 1`
//! admits only seed-community requests and sends a short batch rather
//! than mix communities.
//!
//! Admission metadata rides through untouched: a degraded request
//! (`Request::fanout_cap`, set by [`super::admission`]) is coalesced
//! exactly like any other — the *worker* applies the cap when it
//! samples the batch's MFG, so the batcher stays a pure
//! membership/timing policy.

use std::collections::VecDeque;

use crate::util::rng::Rng;

use super::Request;

/// Micro-batcher knobs (a subset of the engine's `ServeConfig`).
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Maximum requests per micro-batch (≤ the artifact's batch cap).
    pub batch_size: usize,
    /// Coalescing budget: a request waits at most this long before its
    /// batch is flushed, deadline permitting.
    pub max_delay_us: u64,
    /// Community-bias knob `p ∈ [0, 1]`.
    pub community_bias: f64,
}

/// Dynamic micro-batcher (see the module docs for the policy).
pub struct MicroBatcher {
    cfg: BatcherConfig,
    /// Arrival (FIFO) order.
    pending: VecDeque<Request>,
    rng: Rng,
}

impl MicroBatcher {
    /// New batcher; `seed` fixes the per-slot bias draws.
    pub fn new(cfg: BatcherConfig, seed: u64) -> MicroBatcher {
        MicroBatcher {
            cfg,
            pending: VecDeque::new(),
            rng: Rng::new(seed ^ 0xBA7C_4E5A),
        }
    }

    /// Add a dequeued request to the pending pool.
    pub fn push(&mut self, r: Request) {
        self.pending.push_back(r);
    }

    /// Requests currently pending.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// Whether nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    fn flush_at(&self, r: &Request) -> u64 {
        (r.arrive_us.saturating_add(self.cfg.max_delay_us)).min(r.deadline_us)
    }

    /// Earliest time at which [`MicroBatcher::poll`] must run again
    /// (None when nothing is pending).
    pub fn next_flush_us(&self) -> Option<u64> {
        self.pending.iter().map(|r| self.flush_at(r)).min()
    }

    /// Form the next micro-batch if one is due at `now_us`; `community`
    /// maps node id → community id.
    pub fn poll(&mut self, now_us: u64, community: &[u32]) -> Option<Vec<Request>> {
        if self.pending.is_empty() {
            return None;
        }
        let overdue = self.pending.iter().any(|r| self.flush_at(r) <= now_us);
        if !overdue && self.pending.len() < self.cfg.batch_size.max(1) {
            return None;
        }
        Some(self.form_batch(now_us, community))
    }

    fn form_batch(&mut self, now_us: u64, community: &[u32]) -> Vec<Request> {
        let cap = self.cfg.batch_size.max(1);
        let mut batch: Vec<Request> = Vec::with_capacity(cap);

        // 1. every overdue request rides, FIFO order, up to capacity —
        //    the community knob never delays a request past its flush
        //    point.
        let mut i = 0;
        while i < self.pending.len() && batch.len() < cap {
            if self.flush_at(&self.pending[i]) <= now_us {
                batch.push(self.pending.remove(i).unwrap());
            } else {
                i += 1;
            }
        }

        // 2. seed community = the oldest member's (or, for a pure
        //    size-triggered flush, the FIFO head's).
        let seed_node = batch
            .first()
            .map(|r| r.node)
            .or_else(|| self.pending.front().map(|r| r.node));
        let seed_comm = match seed_node {
            Some(v) => community[v as usize],
            None => return batch,
        };
        if batch.is_empty() {
            batch.push(self.pending.pop_front().unwrap());
        }

        // 3. fill remaining slots with bias p toward the seed community.
        while batch.len() < cap && !self.pending.is_empty() {
            let prefer_same = self.rng.f64() < self.cfg.community_bias;
            let pick = if prefer_same {
                self.pending
                    .iter()
                    .position(|r| community[r.node as usize] == seed_comm)
            } else {
                Some(0)
            };
            match pick {
                Some(k) => batch.push(self.pending.remove(k).unwrap()),
                // no same-community request pending: at p = 1 keep the
                // batch pure (short batch), otherwise fall back to FIFO
                None if self.cfg.community_bias >= 1.0 => break,
                None => batch.push(self.pending.pop_front().unwrap()),
            }
        }
        batch
    }
}

/// Community purity of a formed batch: `(purity_permille,
/// distinct_communities)`, where purity is the share of members in the
/// batch's dominant community, in permille. This is the per-micro-batch
/// locality counter the trace recorder attaches to every `Coalesce`
/// span — at `p = 1` size-triggered batches read 1000, at `p = 0` the
/// number falls toward `1000 / distinct` on a mixed trace.
pub fn batch_purity(batch: &[Request], community: &[u32]) -> (u32, u32) {
    if batch.is_empty() {
        return (0, 0);
    }
    let mut counts: std::collections::HashMap<u32, u32> =
        std::collections::HashMap::new();
    for r in batch {
        *counts.entry(community[r.node as usize]).or_insert(0) += 1;
    }
    let dominant = counts.values().copied().max().unwrap_or(0);
    let purity = (dominant as u64 * 1000 / batch.len() as u64) as u32;
    (purity, counts.len() as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64, node: u32, arrive_us: u64, deadline_us: u64) -> Request {
        // the batcher never sends on `reply`; a dropped receiver is fine
        let (tx, _rx) = mpsc::channel();
        Request {
            id,
            node,
            label: 0,
            arrive_us,
            deadline_us,
            fanout_cap: None,
            reply: tx,
        }
    }

    fn ids(batch: &[Request]) -> Vec<u64> {
        batch.iter().map(|r| r.id).collect()
    }

    #[test]
    fn lone_request_flushes_at_deadline_not_before() {
        let mut mb = MicroBatcher::new(
            BatcherConfig {
                batch_size: 8,
                max_delay_us: 10_000,
                community_bias: 1.0,
            },
            1,
        );
        let comm = vec![0u32; 4];
        // deadline (5ms) earlier than arrive+max_delay (10ms)
        mb.push(req(1, 0, 0, 5_000));
        assert!(mb.poll(4_999, &comm).is_none(), "flushed early");
        let b = mb.poll(5_000, &comm).expect("must flush at deadline");
        assert_eq!(ids(&b), vec![1]);
        assert!(mb.is_empty());
    }

    #[test]
    fn lone_request_flushes_after_max_delay() {
        let mut mb = MicroBatcher::new(
            BatcherConfig {
                batch_size: 8,
                max_delay_us: 2_000,
                community_bias: 0.5,
            },
            1,
        );
        let comm = vec![0u32; 4];
        mb.push(req(7, 2, 1_000, 1_000_000));
        assert_eq!(mb.next_flush_us(), Some(3_000));
        assert!(mb.poll(2_999, &comm).is_none());
        assert_eq!(ids(&mb.poll(3_000, &comm).unwrap()), vec![7]);
    }

    #[test]
    fn p0_is_pure_fifo() {
        let mut mb = MicroBatcher::new(
            BatcherConfig {
                batch_size: 3,
                max_delay_us: 1_000_000,
                community_bias: 0.0,
            },
            1,
        );
        let comm = vec![0, 1, 0, 1, 0];
        for (id, node) in [(1, 0u32), (2, 1), (3, 2), (4, 3), (5, 4)] {
            mb.push(req(id, node, 0, 1_000_000));
        }
        // size-triggered flush, FIFO membership and order
        let b = mb.poll(1, &comm).unwrap();
        assert_eq!(ids(&b), vec![1, 2, 3]);
        assert_eq!(mb.len(), 2);
    }

    #[test]
    fn p1_groups_by_community_and_stays_pure() {
        let mut mb = MicroBatcher::new(
            BatcherConfig {
                batch_size: 3,
                max_delay_us: 1_000_000,
                community_bias: 1.0,
            },
            1,
        );
        let comm = vec![0, 1, 0, 1, 0];
        // nodes 0,1,2,3 pending: communities 0,1,0,1
        for (id, node) in [(1, 0u32), (2, 1), (3, 2), (4, 3)] {
            mb.push(req(id, node, 0, 1_000_000));
        }
        let b = mb.poll(1, &comm).unwrap();
        // seed = id 1 (comm 0); only id 3 shares the community; the
        // batch stays pure rather than filling with community 1
        assert_eq!(ids(&b), vec![1, 3]);
        assert_eq!(mb.len(), 2);
    }

    #[test]
    fn overdue_requests_ride_regardless_of_community() {
        let mut mb = MicroBatcher::new(
            BatcherConfig {
                batch_size: 4,
                max_delay_us: 1_000,
                community_bias: 1.0,
            },
            1,
        );
        let comm = vec![0, 1, 2, 3];
        mb.push(req(1, 0, 0, 1_000_000)); // flush at 1_000
        mb.push(req(2, 1, 0, 1_000_000)); // flush at 1_000, other comm
        let b = mb.poll(1_000, &comm).unwrap();
        assert_eq!(ids(&b), vec![1, 2], "deadlines dominate the knob");
    }

    /// Flush ordering honors deadlines: with the coalescing budget out
    /// of the picture, the request with the earlier deadline defines
    /// the first flush point even though it arrived second.
    #[test]
    fn flush_ordering_honors_deadlines() {
        let mut mb = MicroBatcher::new(
            BatcherConfig {
                batch_size: 1, // every flush carries exactly one request
                max_delay_us: 1_000_000,
                community_bias: 0.0,
            },
            1,
        );
        let comm = vec![0u32; 4];
        mb.push(req(1, 0, 0, 10_000)); // arrived first, later deadline
        mb.push(req(2, 1, 0, 2_000)); // arrived second, earlier deadline
        // batch_size 1: the size trigger fires immediately and takes
        // the FIFO head only
        let b = mb.poll(0, &comm).unwrap();
        assert_eq!(ids(&b), vec![1]);
        // now the earlier-deadline request defines the flush point
        assert_eq!(mb.next_flush_us(), Some(2_000));
        let b = mb.poll(2_000, &comm).unwrap();
        assert_eq!(ids(&b), vec![2]);
    }

    /// Same check without the size trigger: deadlines alone decide who
    /// flushes first, in deadline (not arrival) order.
    #[test]
    fn deadline_order_beats_arrival_order() {
        let mut mb = MicroBatcher::new(
            BatcherConfig {
                batch_size: 8, // never size-triggered (2 pending)
                max_delay_us: 1_000_000,
                community_bias: 0.0,
            },
            1,
        );
        let comm = vec![0u32, 1, 2, 3];
        mb.push(req(1, 0, 0, 10_000));
        mb.push(req(2, 1, 0, 2_000));
        assert_eq!(mb.next_flush_us(), Some(2_000));
        assert!(mb.poll(1_999, &comm).is_none());
        // at t=2000 only request 2 is overdue; it seeds the batch and
        // (p=0) request 1 rides along FIFO — overdue-first ordering
        let b = mb.poll(2_000, &comm).unwrap();
        assert_eq!(ids(&b)[0], 2, "overdue request must lead the batch");
    }

    /// `next_flush_us` is the exact time `poll` starts producing: one
    /// microsecond earlier yields nothing, the reported instant yields
    /// a batch — over a whole staggered schedule. Every request sits in
    /// its own community at `p = 1`, so flushes stay singletons instead
    /// of coalescing the still-early pending requests.
    #[test]
    fn next_flush_us_agrees_with_actual_flush_times() {
        let mut mb = MicroBatcher::new(
            BatcherConfig {
                batch_size: 100, // flushes are time-triggered only
                max_delay_us: 5_000,
                community_bias: 1.0,
            },
            3,
        );
        let comm: Vec<u32> = (0..16u32).collect();
        // staggered arrivals; two get deadline-capped flush points
        mb.push(req(1, 0, 0, 3_000)); // flush 3_000 (deadline < delay)
        mb.push(req(2, 1, 1_000, 1_000_000)); // flush 6_000
        mb.push(req(3, 2, 4_000, 4_500)); // flush 4_500
        mb.push(req(4, 3, 9_000, 1_000_000)); // flush 14_000
        let mut flushed = Vec::new();
        while let Some(t) = mb.next_flush_us() {
            assert!(
                mb.poll(t - 1, &comm).is_none(),
                "flushed before the advertised time {t}"
            );
            let b = mb.poll(t, &comm).expect("advertised flush must fire");
            flushed.push((t, ids(&b)));
        }
        assert!(mb.is_empty());
        let times: Vec<u64> = flushed.iter().map(|(t, _)| *t).collect();
        assert_eq!(times, vec![3_000, 4_500, 6_000, 14_000]);
        let all: Vec<u64> =
            flushed.iter().flat_map(|(_, ids)| ids.clone()).collect();
        assert_eq!(all, vec![1, 3, 2, 4], "flush order = flush-point order");
    }

    /// `p = 0` stays pure FIFO across *successive* batches, whatever
    /// the community layout.
    #[test]
    fn p0_fifo_across_batches() {
        let mut mb = MicroBatcher::new(
            BatcherConfig {
                batch_size: 4,
                max_delay_us: 1_000_000,
                community_bias: 0.0,
            },
            99,
        );
        let comm: Vec<u32> = (0..12u32).map(|v| v % 3).collect();
        for id in 0..12u64 {
            mb.push(req(id, id as u32, 0, 1_000_000));
        }
        let mut seen = Vec::new();
        while let Some(b) = mb.poll(0, &comm) {
            seen.extend(ids(&b));
        }
        assert_eq!(seen, (0..12).collect::<Vec<u64>>());
    }

    /// `p = 1` groups by community deterministically: same seed, same
    /// batches; every batch is community-pure on a size-triggered
    /// flush.
    #[test]
    fn p1_grouping_is_deterministic_under_fixed_seed() {
        // 3 communities interleaved in arrival order
        let comm: Vec<u32> = (0..12u32).map(|v| v % 3).collect();
        let run = |seed: u64| -> Vec<Vec<u64>> {
            let mut mb = MicroBatcher::new(
                BatcherConfig {
                    batch_size: 4,
                    max_delay_us: 1_000_000,
                    community_bias: 1.0,
                },
                seed,
            );
            for id in 0..12u64 {
                mb.push(req(id, id as u32, 0, 1_000_000));
            }
            let mut out = Vec::new();
            // t=0: nothing overdue, so membership is pure p=1 grouping
            while let Some(b) = mb.poll(0, &comm) {
                out.push(ids(&b));
            }
            out
        };
        let a = run(7);
        assert_eq!(a, run(7), "same seed must reproduce identical batches");
        // every batch single-community; all 12 requests delivered once
        for batch in &a {
            let c0 = comm[batch[0] as usize];
            assert!(
                batch.iter().all(|&id| comm[id as usize] == c0),
                "mixed-community batch under p=1: {batch:?}"
            );
        }
        let mut all: Vec<u64> = a.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..12).collect::<Vec<_>>());
    }

    /// The purity counter: pure batches read 1000, an even two-way mix
    /// reads 500, and a dominant community sets the numerator.
    #[test]
    fn batch_purity_counts_dominant_share() {
        let comm = vec![0u32, 0, 1, 1, 2];
        let mk = |nodes: &[u32]| -> Vec<Request> {
            nodes
                .iter()
                .enumerate()
                .map(|(i, &n)| req(i as u64, n, 0, 1_000))
                .collect()
        };
        assert_eq!(batch_purity(&[], &comm), (0, 0));
        assert_eq!(batch_purity(&mk(&[0, 1]), &comm), (1000, 1));
        assert_eq!(batch_purity(&mk(&[0, 2]), &comm), (500, 2));
        // 3 of 4 in community 0
        assert_eq!(batch_purity(&mk(&[0, 1, 0, 4]), &comm), (750, 2));
    }

    #[test]
    fn deterministic_given_seed() {
        let comm: Vec<u32> = (0..16u32).map(|v| v % 4).collect();
        let run = |seed: u64| -> Vec<Vec<u64>> {
            let mut mb = MicroBatcher::new(
                BatcherConfig {
                    batch_size: 4,
                    max_delay_us: 10,
                    community_bias: 0.5,
                },
                seed,
            );
            for id in 0..16u64 {
                mb.push(req(id, (id as u32 * 5) % 16, 0, 1_000));
            }
            let mut out = Vec::new();
            while let Some(b) = mb.poll(1_000, &comm) {
                out.push(ids(&b));
            }
            out
        };
        assert_eq!(run(9), run(9));
        // all 16 delivered exactly once
        let mut all: Vec<u64> = run(9).into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..16).collect::<Vec<_>>());
    }
}
