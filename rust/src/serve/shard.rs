//! Multi-shard community-affinity layer: partition communities across
//! `n_shards` logical devices and route every micro-batch to the shard
//! that owns its community.
//!
//! COMM-RAND's locality argument is that community structure turns
//! irregular feature access into reuse; on one device the serving
//! cache captures that reuse, and sharding extends it across devices:
//! each shard's feature cache only ever sees its own communities, so
//! per-device working sets shrink by roughly the shard count
//! (the same cross-batch-reuse argument Cooperative Minibatching makes,
//! arXiv 2310.12403). Shards here are *logical* devices — each gets its
//! own worker pool, feature cache and batch channel; binding each shard
//! to a distinct PJRT device is the remaining mechanical step.
//!
//! Three pieces:
//!
//! * [`ShardPlan`] — deterministic community → shard assignment
//!   (largest community first into the lightest shard, node-balanced),
//!   built once from the Louvain labels.
//! * [`route_batch`] — splits or redirects a formed micro-batch
//!   according to the [`SpillPolicy`] when its members span shards.
//! * [`ShardStatsCell`] / [`ShardReport`] — per-shard accounting
//!   (queue depth, affinity violations, latency percentiles, cache hit
//!   rate) rolled up into the engine's `ServeReport`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::ckpt::format::community_fingerprint;
use crate::obs::LogHist;
use crate::util::json::{arr, num, obj, s, Json};

use super::Request;

/// What to do with a micro-batch whose requests span several shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpillPolicy {
    /// Split the batch: every request is processed by the shard owning
    /// its community, always. Maximum cache affinity; cross-shard
    /// batches become several smaller per-shard batches.
    Strict,
    /// Keep the batch whole on the majority owner's shard, but let the
    /// least-loaded shard steal it when the owner's channel is full.
    /// Affinity most of the time, load balance under pressure.
    Steal,
    /// Ignore affinity: the whole batch goes to the least-loaded
    /// shard, so every shard's cache eventually sees every community
    /// (the no-affinity baseline the other two policies are measured
    /// against).
    Broadcast,
}

impl SpillPolicy {
    /// Parse a CLI knob value: `strict | steal | broadcast`.
    pub fn parse(s: &str) -> Result<SpillPolicy> {
        match s {
            "strict" => Ok(SpillPolicy::Strict),
            "steal" => Ok(SpillPolicy::Steal),
            "broadcast" => Ok(SpillPolicy::Broadcast),
            other => bail!(
                "unknown spill policy {other:?} (try: strict | steal | broadcast)"
            ),
        }
    }

    /// The knob spelling this policy parses from.
    pub fn name(&self) -> &'static str {
        match self {
            SpillPolicy::Strict => "strict",
            SpillPolicy::Steal => "steal",
            SpillPolicy::Broadcast => "broadcast",
        }
    }
}

/// Deterministic community → shard assignment.
///
/// Communities are packed largest-first into the lightest shard (by
/// node count, ties broken by lower id on both sides), the same greedy
/// balancing [`crate::community::pack_partitions`] uses for the
/// ClusterGCN baseline — but keyed purely by the label array, so the
/// same Louvain labels always yield the same plan on every run and
/// every process.
///
/// ```
/// use comm_rand::serve::ShardPlan;
///
/// // three communities with sizes 3, 2, 1 packed onto two shards
/// let community = vec![0, 0, 0, 1, 1, 2];
/// let plan = ShardPlan::build(&community, 3, 2);
///
/// // the largest community seeds one shard; greedy largest-first
/// // packing then stacks the two smaller ones on the other, so the
/// // node counts balance 3 / 3
/// assert_eq!(plan.n_shards(), 2);
/// assert_eq!(plan.shard_of_comm(1), plan.shard_of_comm(2));
/// assert_ne!(plan.shard_of_comm(0), plan.shard_of_comm(1));
/// assert_eq!(plan.owned_nodes(0) + plan.owned_nodes(1), 6);
///
/// // routing a request follows its node's community label
/// assert_eq!(plan.shard_of_node(&community, 4), plan.shard_of_comm(1));
/// ```
#[derive(Clone, Debug)]
pub struct ShardPlan {
    n_shards: usize,
    /// community id → owning shard.
    comm_shard: Vec<u32>,
    /// Per shard: number of (non-empty) communities owned.
    owned_comms: Vec<usize>,
    /// Per shard: number of nodes owned.
    owned_nodes: Vec<usize>,
}

impl ShardPlan {
    /// Build the plan from per-node community labels (`community[v]`
    /// in `0..num_comms`) for `n_shards` logical devices.
    pub fn build(community: &[u32], num_comms: usize, n_shards: usize) -> ShardPlan {
        let n_shards = n_shards.max(1);
        let mut size = vec![0usize; num_comms.max(1)];
        for &c in community {
            size[c as usize] += 1;
        }
        let mut order: Vec<usize> = (0..size.len()).collect();
        order.sort_by_key(|&c| (std::cmp::Reverse(size[c]), c));
        let mut comm_shard = vec![0u32; size.len()];
        let mut owned_comms = vec![0usize; n_shards];
        let mut owned_nodes = vec![0usize; n_shards];
        for c in order {
            let lightest = (0..n_shards)
                .min_by_key(|&s| (owned_nodes[s], s))
                .unwrap();
            comm_shard[c] = lightest as u32;
            owned_nodes[lightest] += size[c];
            if size[c] > 0 {
                owned_comms[lightest] += 1;
            }
        }
        ShardPlan { n_shards, comm_shard, owned_comms, owned_nodes }
    }

    /// Number of shards this plan partitions across.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Shard owning community `comm`.
    pub fn shard_of_comm(&self, comm: u32) -> usize {
        self.comm_shard[comm as usize] as usize
    }

    /// Shard owning `node`, via its community label.
    pub fn shard_of_node(&self, community: &[u32], node: u32) -> usize {
        self.shard_of_comm(community[node as usize])
    }

    /// Non-empty communities assigned to `shard`.
    pub fn owned_comms(&self, shard: usize) -> usize {
        self.owned_comms[shard]
    }

    /// Nodes assigned to `shard` (through their communities).
    pub fn owned_nodes(&self, shard: usize) -> usize {
        self.owned_nodes[shard]
    }

    /// Patch the plan in place for one vertex that moved from
    /// community `old_c` to `new_c` (incremental maintenance): the
    /// community → shard mapping is untouched — only the per-shard
    /// node-ownership counters follow the mover. `owned_comms` is left
    /// as-is even if a community empties; a full relabel rebuilds the
    /// plan exactly.
    pub fn apply_move(&mut self, old_c: u32, new_c: u32) {
        let s_old = self.shard_of_comm(old_c);
        let s_new = self.shard_of_comm(new_c);
        if s_old != s_new {
            self.owned_nodes[s_old] = self.owned_nodes[s_old].saturating_sub(1);
            self.owned_nodes[s_new] += 1;
        }
    }
}

/// One immutable, versioned view of the community labeling and the
/// routing state derived from it: the label array, its shard plan,
/// the checkpoint-fence fingerprint of the labeling *generation*, and
/// the warm-cache routing overrides for recent cross-shard movers.
///
/// Static runs build one at startup and never replace it; streaming
/// runs publish a new snapshot per refinement wave (cheap: labels are
/// copied, the plan is patched) and per full relabel (plan rebuilt,
/// fingerprint regenerated — which is what fences stale checkpoints).
/// Readers hold an `Arc` per batch/request, so routing, foreign-
/// request accounting and sampling within one batch all see the same
/// labeling.
pub struct LabelSnapshot {
    /// Monotone snapshot version (0 = the labels the run started with).
    pub version: u64,
    /// Node → community labels.
    pub labels: Vec<u32>,
    /// Size of the community id space.
    pub num_comms: usize,
    /// [`community_fingerprint`] of the labeling *generation*: stable
    /// across incremental refinement waves, regenerated by a full
    /// relabel (checkpoints fenced against it stop validating then).
    pub fingerprint: u64,
    /// Community → shard plan for this labeling.
    pub plan: ShardPlan,
    /// Node → shard routing overrides for cross-shard movers: for one
    /// refinement wave the mover keeps routing to its *old* shard,
    /// whose cache still holds its rows (the strict-spill fallback;
    /// the move shows up as a foreign request there, so the affinity
    /// cost stays observable).
    pub overrides: HashMap<u32, u32>,
}

impl LabelSnapshot {
    /// Version-0 snapshot over a frozen labeling (the non-streaming
    /// path, and the starting point of every streaming run).
    pub fn initial(
        labels: &[u32],
        num_comms: usize,
        n_shards: usize,
    ) -> LabelSnapshot {
        LabelSnapshot {
            version: 0,
            labels: labels.to_vec(),
            num_comms,
            fingerprint: community_fingerprint(labels, num_comms),
            plan: ShardPlan::build(labels, num_comms, n_shards),
            overrides: HashMap::new(),
        }
    }

    /// The shard that *owns* `node` under the plan (plan truth — used
    /// for foreign-request accounting and admission attribution).
    pub fn owner_shard(&self, node: u32) -> usize {
        self.plan.shard_of_comm(self.labels[node as usize])
    }

    /// The shard a request for `node` is *routed* to: the owner,
    /// unless a recent cross-shard move left its rows warm on the old
    /// shard (the override).
    pub fn route_shard(&self, node: u32) -> usize {
        if let Some(&s) = self.overrides.get(&node) {
            return s as usize;
        }
        self.owner_shard(node)
    }
}

/// Shared cell holding the current [`LabelSnapshot`]: readers take
/// cheap `Arc` snapshots; the streaming applier publishes replacements
/// through [`LabelCell::replace_blocking`]. A stop-the-world full
/// relabel runs its (expensive) rebuild *inside* the lock on purpose —
/// that serialization is the cost the naive maintenance baseline pays
/// and `exp stream` measures.
pub struct LabelCell {
    cur: Mutex<Arc<LabelSnapshot>>,
}

impl LabelCell {
    /// Cell starting at `snap`.
    pub fn new(snap: LabelSnapshot) -> LabelCell {
        LabelCell { cur: Mutex::new(Arc::new(snap)) }
    }

    /// The current snapshot (lock + `Arc` clone).
    pub fn snapshot(&self) -> Arc<LabelSnapshot> {
        self.cur.lock().unwrap().clone()
    }

    /// Replace the snapshot with `f(current)`, holding the cell locked
    /// while `f` runs — readers block until the replacement is
    /// published. Incremental waves keep `f` in the microsecond range;
    /// the naive full relabel deliberately runs Louvain inside it.
    pub fn replace_blocking<F>(&self, f: F) -> Arc<LabelSnapshot>
    where
        F: FnOnce(&LabelSnapshot) -> LabelSnapshot,
    {
        let mut g = self.cur.lock().unwrap();
        let next = Arc::new(f(&**g));
        *g = next.clone();
        next
    }
}

/// Route one formed micro-batch to shards under `policy`, against one
/// consistent [`LabelSnapshot`] (routing follows
/// [`LabelSnapshot::route_shard`], i.e. the plan plus the cross-shard
/// mover overrides).
///
/// `depths` is a snapshot of each shard's queued-batch count and
/// `caps` the per-shard channel capacity (used by [`SpillPolicy::Steal`]
/// to detect an overloaded owner). `rr` is a per-batch counter the
/// caller increments: depth ties break round-robin from it, so a fast
/// no-op executor (where depth snapshots are almost always all-zero)
/// still spreads broadcast/steal traffic over every shard instead of
/// collapsing onto shard 0. Returns `(shard, sub-batch)` pairs; every
/// request appears in exactly one sub-batch.
pub fn route_batch(
    snap: &LabelSnapshot,
    policy: SpillPolicy,
    depths: &[usize],
    caps: &[usize],
    rr: usize,
    batch: Vec<Request>,
) -> Vec<(usize, Vec<Request>)> {
    let n = snap.plan.n_shards();
    if n == 1 || batch.is_empty() {
        return vec![(0, batch)];
    }
    match policy {
        SpillPolicy::Strict => {
            let mut per: Vec<Vec<Request>> = (0..n).map(|_| Vec::new()).collect();
            for r in batch {
                per[snap.route_shard(r.node)].push(r);
            }
            per.into_iter()
                .enumerate()
                .filter(|(_, b)| !b.is_empty())
                .collect()
        }
        SpillPolicy::Steal => {
            let owner = majority_owner(snap, &batch);
            let target = if depths[owner] >= caps[owner].max(1) {
                least_loaded(depths, rr)
            } else {
                owner
            };
            vec![(target, batch)]
        }
        SpillPolicy::Broadcast => vec![(least_loaded(depths, rr), batch)],
    }
}

/// Shard owning the plurality of the batch's requests (ties → lower
/// shard id).
fn majority_owner(snap: &LabelSnapshot, batch: &[Request]) -> usize {
    let mut count = vec![0usize; snap.plan.n_shards()];
    for r in batch {
        count[snap.route_shard(r.node)] += 1;
    }
    (0..count.len()).max_by_key(|&s| (count[s], usize::MAX - s)).unwrap_or(0)
}

/// Shallowest queue, scanning from `start` so equal depths rotate
/// instead of always electing shard 0.
fn least_loaded(depths: &[usize], start: usize) -> usize {
    let n = depths.len().max(1);
    (0..n)
        .map(|k| (start + k) % n)
        .min_by_key(|&s| depths.get(s).copied().unwrap_or(0))
        .unwrap_or(0)
}

/// Mutable per-shard accounting, written by that shard's workers.
#[derive(Clone, Debug, Default)]
pub struct ShardStatsCell {
    /// Micro-batches processed.
    pub batches: usize,
    /// Requests processed.
    pub requests: usize,
    /// Requests processed here whose community this shard does NOT
    /// own — always 0 under [`SpillPolicy::Strict`].
    pub foreign_requests: usize,
    /// Unique input-frontier nodes across this shard's batches.
    pub input_nodes: usize,
    /// Input-frontier references *with multiplicity* across this
    /// shard's batches — `frontier_refs / input_nodes` is the shard's
    /// cross-request dedup factor.
    pub frontier_refs: u64,
    /// Max queued batches observed on this shard's channel.
    pub queue_depth_max: usize,
    /// Highest parameter version any batch on this shard was served
    /// with (0 = seed parameters). Monotone by construction.
    pub param_version: u64,
    /// Whether any batch has recorded a version yet.
    pub seen_version: bool,
    /// Hot swaps observed: upward transitions of `param_version`.
    pub swaps: usize,
    /// Batches that completed carrying a version *older* than the
    /// shard's maximum. 0 whenever the shard's batches are serialized
    /// (one worker per shard — the reload tests assert this); with
    /// several workers it can also count benign in-flight overlap at
    /// the swap instant, never a rolled-back report.
    pub version_regressions: usize,
    /// Per-request completion latency histogram, µs (error replies
    /// excluded, so per-shard percentiles share the global report's
    /// definition). Log-bucketed and mergeable: the engine folds every
    /// shard's histogram into the run-wide one, so the global and
    /// per-shard percentiles — and the Prometheus snapshot — all read
    /// the *same* buckets and can never disagree.
    pub lat_us: LogHist,
    /// Executor timing for batches served on the f32 path.
    pub exec_f32: ExecCell,
    /// Executor timing for batches served on the quantized (`i16q`)
    /// integer-kernel path.
    pub exec_i16: ExecCell,
}

/// Per-dtype executor timing, folded by the shard worker after each
/// error-free batch ([`BatchOutcome::execute_us`], the
/// `ctx.exec.infer` window only — batch assembly excluded, so the f32
/// vs `i16q` comparison isolates exactly the work quantization
/// changes).
///
/// [`BatchOutcome::execute_us`]: super::worker::BatchOutcome::execute_us
#[derive(Clone, Debug, Default)]
pub struct ExecCell {
    /// Micro-batches executed at this dtype.
    pub batches: u64,
    /// Requests those batches carried.
    pub requests: u64,
    /// Total executor wall time, µs.
    pub total_us: u64,
    /// Per-batch executor wall-time histogram, µs (log-bucketed and
    /// mergeable like the latency histogram).
    pub us: LogHist,
}

impl ExecCell {
    /// Roll this cell into its report slice (`None` when no batch ran
    /// at this dtype — the report only lists dtypes that executed).
    pub fn report(&self, dtype: &'static str) -> Option<ExecReport> {
        if self.batches == 0 {
            return None;
        }
        Some(ExecReport {
            dtype,
            batches: self.batches,
            requests: self.requests,
            total_us: self.total_us,
            mean_us: self.total_us as f64 / self.batches as f64,
            p50_us: self.us.quantile(0.5),
            p99_us: self.us.quantile(0.99),
        })
    }

    /// Fold another cell into this one (the engine merges every
    /// shard's cells into the run-wide per-dtype breakdown).
    pub fn merge(&mut self, other: &ExecCell) {
        self.batches += other.batches;
        self.requests += other.requests;
        self.total_us += other.total_us;
        self.us.merge(&other.us);
    }
}

/// One dtype's executor-timing slice of the end-of-run report.
#[derive(Clone, Copy, Debug)]
pub struct ExecReport {
    /// Execution dtype (`"f32"` / `"i16q"`).
    pub dtype: &'static str,
    /// Micro-batches executed at this dtype.
    pub batches: u64,
    /// Requests those batches carried.
    pub requests: u64,
    /// Total executor wall time, µs.
    pub total_us: u64,
    /// Mean executor wall time per micro-batch, µs.
    pub mean_us: f64,
    /// Median per-batch executor wall time, µs.
    pub p50_us: u64,
    /// 99th-percentile per-batch executor wall time, µs.
    pub p99_us: u64,
}

impl ExecReport {
    /// Serialize one dtype's executor-timing slice.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("dtype", s(self.dtype)),
            ("batches", num(self.batches as f64)),
            ("requests", num(self.requests as f64)),
            ("total_us", num(self.total_us as f64)),
            ("mean_us", num(self.mean_us)),
            ("p50_us", num(self.p50_us as f64)),
            ("p99_us", num(self.p99_us as f64)),
        ])
    }
}

/// Per-shard slice of the end-of-run report.
#[derive(Clone, Debug)]
pub struct ShardReport {
    /// Shard id (`0..n_shards`).
    pub id: usize,
    /// Non-empty communities this shard owns.
    pub owned_comms: usize,
    /// Nodes this shard owns.
    pub owned_nodes: usize,
    /// Requests processed on this shard.
    pub requests: usize,
    /// Requests processed here whose community this shard does not own
    /// (0 under strict spill).
    pub foreign_requests: usize,
    /// Requests shed toward this shard (admission + open-loop
    /// drop-tail).
    pub shed: usize,
    /// Requests admitted with degraded fanout toward this shard.
    pub degraded: usize,
    /// Micro-batches processed on this shard.
    pub batches: usize,
    /// Input-frontier references (with multiplicity) sampled across
    /// this shard's batches.
    pub frontier_refs: u64,
    /// Cross-request dedup factor on this shard: frontier refs ÷
    /// unique input nodes (1.0 when nothing was shared or no batch
    /// ran). The gather loop pays for unique nodes only.
    pub dedup_factor: f64,
    /// Max queued batches observed on this shard's channel.
    pub queue_depth_max: usize,
    /// Highest parameter version this shard served a batch with
    /// (0 = seed parameters; bumps when a checkpoint hot-swaps in).
    /// Monotone: a pre-swap batch finishing late cannot roll it back.
    pub param_version: u64,
    /// Hot swaps this shard's workers observed (upward version
    /// transitions between micro-batches).
    pub swaps: usize,
    /// Completions carrying a version older than the shard's maximum.
    /// Exactly 0 when the shard runs one worker (batches serialized —
    /// the reload integration test asserts monotonicity through
    /// this); with several workers per shard a nonzero value can also
    /// reflect benign in-flight overlap at the swap instant.
    pub version_regressions: usize,
    /// Final EWMA micro-batch service-time estimate, µs (0 before any
    /// sample).
    pub est_service_us: f64,
    /// Median per-request latency, ms.
    pub lat_p50_ms: f64,
    /// 95th-percentile per-request latency, ms.
    pub lat_p95_ms: f64,
    /// 99th-percentile per-request latency, ms.
    pub lat_p99_ms: f64,
    /// Feature-cache hits on this shard's cache.
    pub cache_hits: u64,
    /// Feature-cache misses on this shard's cache.
    pub cache_misses: u64,
    /// Stale hits (cached at an older feature version; refreshed and
    /// served like misses) on this shard's cache.
    pub stale_hits: u64,
    /// Total fetches on this shard's cache — always equals
    /// `cache_hits + cache_misses + stale_hits`.
    pub cache_lookups: u64,
    /// hits / lookups, 0 when the cache was never touched.
    pub cache_hit_rate: f64,
    /// Executor timing per execution dtype — one entry per dtype that
    /// actually served a batch here, so a run that hot-swapped from an
    /// f32 to a quantized checkpoint shows both.
    pub execute: Vec<ExecReport>,
}

impl ShardReport {
    /// Roll one shard's stats cell, cache counters and admission
    /// counters up into its report slice.
    pub fn from_cell(
        id: usize,
        plan: &ShardPlan,
        cell: &ShardStatsCell,
        cache: super::cache::CacheStats,
        adm: &super::admission::AdmissionController,
    ) -> ShardReport {
        // quantiles straight from the log-bucketed histogram (exact at
        // the observed min/max, ≤ ~3% relative error between)
        let pct = |q: f64| cell.lat_us.quantile(q) as f64 / 1e3;
        ShardReport {
            id,
            owned_comms: plan.owned_comms(id),
            owned_nodes: plan.owned_nodes(id),
            requests: cell.requests,
            foreign_requests: cell.foreign_requests,
            shed: adm.shard_shed(id),
            degraded: adm.shard_degraded(id),
            batches: cell.batches,
            frontier_refs: cell.frontier_refs,
            dedup_factor: if cell.input_nodes == 0 {
                1.0
            } else {
                cell.frontier_refs as f64 / cell.input_nodes as f64
            },
            queue_depth_max: cell.queue_depth_max,
            param_version: cell.param_version,
            swaps: cell.swaps,
            version_regressions: cell.version_regressions,
            est_service_us: adm.est_service_us(id).unwrap_or(0.0),
            lat_p50_ms: pct(0.5),
            lat_p95_ms: pct(0.95),
            lat_p99_ms: pct(0.99),
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            stale_hits: cache.stale_hits,
            cache_lookups: cache.lookups,
            cache_hit_rate: cache.hit_rate(),
            execute: [
                cell.exec_f32.report("f32"),
                cell.exec_i16.report("i16q"),
            ]
            .into_iter()
            .flatten()
            .collect(),
        }
    }

    /// Serialize this shard's slice of the `ServeReport` JSON.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("shard", num(self.id as f64)),
            ("owned_comms", num(self.owned_comms as f64)),
            ("owned_nodes", num(self.owned_nodes as f64)),
            ("requests", num(self.requests as f64)),
            ("foreign_requests", num(self.foreign_requests as f64)),
            ("shed", num(self.shed as f64)),
            ("degraded", num(self.degraded as f64)),
            ("batches", num(self.batches as f64)),
            ("frontier_refs", num(self.frontier_refs as f64)),
            ("dedup_factor", num(self.dedup_factor)),
            ("queue_depth_max", num(self.queue_depth_max as f64)),
            ("param_version", num(self.param_version as f64)),
            ("swaps", num(self.swaps as f64)),
            ("version_regressions", num(self.version_regressions as f64)),
            ("est_service_us", num(self.est_service_us)),
            ("lat_p50_ms", num(self.lat_p50_ms)),
            ("lat_p95_ms", num(self.lat_p95_ms)),
            ("lat_p99_ms", num(self.lat_p99_ms)),
            ("cache_hits", num(self.cache_hits as f64)),
            ("cache_misses", num(self.cache_misses as f64)),
            ("stale_hits", num(self.stale_hits as f64)),
            ("cache_lookups", num(self.cache_lookups as f64)),
            ("cache_hit_rate", num(self.cache_hit_rate)),
            (
                "execute",
                arr(self.execute.iter().map(|e| e.to_json()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64, node: u32) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id,
            node,
            label: 0,
            arrive_us: 0,
            deadline_us: 1_000_000,
            fanout_cap: None,
            reply: tx,
        }
    }

    fn ids(batch: &[Request]) -> Vec<u64> {
        batch.iter().map(|r| r.id).collect()
    }

    #[test]
    fn plan_covers_every_community_and_balances_nodes() {
        // 6 communities with skewed sizes over 2 shards
        let sizes = [40usize, 30, 10, 10, 5, 5];
        let mut community = Vec::new();
        for (c, &s) in sizes.iter().enumerate() {
            let len = community.len();
            community.resize(len + s, c as u32);
        }
        let plan = ShardPlan::build(&community, sizes.len(), 2);
        assert_eq!(plan.n_shards(), 2);
        for c in 0..sizes.len() as u32 {
            assert!(plan.shard_of_comm(c) < 2);
        }
        let total: usize = (0..2).map(|s| plan.owned_nodes(s)).sum();
        assert_eq!(total, community.len());
        let comms: usize = (0..2).map(|s| plan.owned_comms(s)).sum();
        assert_eq!(comms, sizes.len());
        // largest-first greedy keeps the split within the largest block
        let diff = plan.owned_nodes(0).abs_diff(plan.owned_nodes(1));
        assert!(diff <= 40, "unbalanced: {diff}");
    }

    #[test]
    fn plan_is_deterministic() {
        let community: Vec<u32> = (0..997u32).map(|v| v % 13).collect();
        let a = ShardPlan::build(&community, 13, 4);
        let b = ShardPlan::build(&community, 13, 4);
        assert_eq!(a.comm_shard, b.comm_shard);
    }

    #[test]
    fn plan_single_shard_owns_everything() {
        let community: Vec<u32> = (0..100u32).map(|v| v % 5).collect();
        let plan = ShardPlan::build(&community, 5, 1);
        assert_eq!(plan.owned_nodes(0), 100);
        assert_eq!(plan.owned_comms(0), 5);
        for c in 0..5 {
            assert_eq!(plan.shard_of_comm(c), 0);
        }
    }

    #[test]
    fn strict_splits_by_owning_shard() {
        // 2 communities, one per shard
        let community = vec![0u32, 0, 1, 1];
        let snap = LabelSnapshot::initial(&community, 2, 2);
        let batch = vec![req(1, 0), req(2, 2), req(3, 1), req(4, 3)];
        let routed = route_batch(
            &snap,
            SpillPolicy::Strict,
            &[0, 0],
            &[4, 4],
            0,
            batch,
        );
        assert_eq!(routed.len(), 2);
        let total: usize = routed.iter().map(|(_, b)| b.len()).sum();
        assert_eq!(total, 4);
        for (shard, sub) in &routed {
            for r in sub {
                assert_eq!(
                    snap.owner_shard(r.node),
                    *shard,
                    "request {} on foreign shard",
                    r.id
                );
            }
        }
    }

    #[test]
    fn steal_keeps_batch_whole_on_majority_owner() {
        let community = vec![0u32, 0, 1, 1];
        let snap = LabelSnapshot::initial(&community, 2, 2);
        let owner0 = snap.plan.shard_of_comm(0);
        // 2 requests from community 0, 1 from community 1
        let batch = vec![req(1, 0), req(2, 1), req(3, 2)];
        let routed = route_batch(
            &snap,
            SpillPolicy::Steal,
            &[0, 0],
            &[4, 4],
            0,
            batch,
        );
        assert_eq!(routed.len(), 1);
        assert_eq!(routed[0].0, owner0);
        assert_eq!(ids(&routed[0].1), vec![1, 2, 3]);
    }

    #[test]
    fn steal_spills_to_least_loaded_when_owner_full() {
        let community = vec![0u32, 0, 1, 1];
        let snap = LabelSnapshot::initial(&community, 2, 2);
        let owner0 = snap.plan.shard_of_comm(0);
        let other = 1 - owner0;
        let mut depths = [0usize, 0];
        depths[owner0] = 4; // at cap
        let batch = vec![req(1, 0), req(2, 1)];
        let routed = route_batch(
            &snap,
            SpillPolicy::Steal,
            &depths,
            &[4, 4],
            0,
            batch,
        );
        assert_eq!(routed.len(), 1);
        assert_eq!(routed[0].0, other, "full owner must spill");
    }

    #[test]
    fn broadcast_targets_least_loaded_shard() {
        let community = vec![0u32, 0, 1, 1];
        let snap = LabelSnapshot::initial(&community, 2, 2);
        let batch = vec![req(1, 0), req(2, 0)];
        let routed = route_batch(
            &snap,
            SpillPolicy::Broadcast,
            &[3, 1],
            &[4, 4],
            0,
            batch,
        );
        assert_eq!(routed.len(), 1);
        assert_eq!(routed[0].0, 1, "must pick the shallower queue");
    }

    /// With an idle pool (all depths zero) broadcast must still spread
    /// batches across shards via the round-robin tie-break, not funnel
    /// everything into shard 0.
    #[test]
    fn broadcast_rotates_across_idle_shards() {
        let community = vec![0u32, 1, 2, 3];
        let snap = LabelSnapshot::initial(&community, 4, 4);
        let mut hit = [0usize; 4];
        for rr in 0..8 {
            let batch = vec![req(rr as u64, 0)];
            let routed = route_batch(
                &snap,
                SpillPolicy::Broadcast,
                &[0, 0, 0, 0],
                &[2, 2, 2, 2],
                rr,
                batch,
            );
            hit[routed[0].0] += 1;
        }
        assert_eq!(hit, [2, 2, 2, 2], "idle shards must share batches");
    }

    #[test]
    fn single_shard_routes_whole_batch_to_zero() {
        let community = vec![0u32, 1, 2, 3];
        let snap = LabelSnapshot::initial(&community, 4, 1);
        for policy in
            [SpillPolicy::Strict, SpillPolicy::Steal, SpillPolicy::Broadcast]
        {
            let batch = vec![req(1, 0), req(2, 3)];
            let routed =
                route_batch(&snap, policy, &[0], &[2], 0, batch);
            assert_eq!(routed.len(), 1);
            assert_eq!(routed[0].0, 0);
            assert_eq!(routed[0].1.len(), 2);
        }
    }

    /// A cross-shard mover with a routing override keeps landing on
    /// its old (warm-cache) shard under strict spill, while
    /// `owner_shard` reports plan truth — so the batch is still
    /// accounted as foreign there.
    #[test]
    fn mover_override_routes_to_the_warm_shard() {
        let community = vec![0u32, 0, 1, 1];
        let mut snap = LabelSnapshot::initial(&community, 2, 2);
        let s0 = snap.plan.shard_of_comm(0);
        let s1 = snap.plan.shard_of_comm(1);
        assert_ne!(s0, s1);
        // node 1 moves community 0 -> 1 (now owned by s1), but keeps
        // routing to s0 for one wave
        snap.labels[1] = 1;
        snap.plan.apply_move(0, 1);
        snap.overrides.insert(1, s0 as u32);
        assert_eq!(snap.owner_shard(1), s1, "plan truth follows the move");
        assert_eq!(snap.route_shard(1), s0, "override keeps the cache warm");
        let routed = route_batch(
            &snap,
            SpillPolicy::Strict,
            &[0, 0],
            &[4, 4],
            0,
            vec![req(1, 1)],
        );
        assert_eq!(routed.len(), 1);
        assert_eq!(routed[0].0, s0);
        // ownership counters followed the mover
        assert_eq!(snap.plan.owned_nodes(s0), 1);
        assert_eq!(snap.plan.owned_nodes(s1), 3);
    }

    #[test]
    fn initial_snapshot_matches_plan_and_fingerprint() {
        let community: Vec<u32> = (0..100u32).map(|v| v % 5).collect();
        let snap = LabelSnapshot::initial(&community, 5, 2);
        assert_eq!(snap.version, 0);
        assert_eq!(snap.num_comms, 5);
        assert!(snap.overrides.is_empty());
        assert_eq!(
            snap.fingerprint,
            crate::ckpt::format::community_fingerprint(&community, 5)
        );
        for v in 0..100u32 {
            assert_eq!(
                snap.owner_shard(v),
                snap.plan.shard_of_node(&community, v)
            );
            assert_eq!(snap.route_shard(v), snap.owner_shard(v));
        }
    }

    #[test]
    fn label_cell_publishes_replacements_atomically() {
        let community = vec![0u32, 1, 2, 3];
        let cell = LabelCell::new(LabelSnapshot::initial(&community, 4, 2));
        assert_eq!(cell.snapshot().version, 0);
        let published = cell.replace_blocking(|old| LabelSnapshot {
            version: old.version + 1,
            labels: old.labels.clone(),
            num_comms: old.num_comms,
            fingerprint: old.fingerprint,
            plan: old.plan.clone(),
            overrides: HashMap::new(),
        });
        assert_eq!(published.version, 1);
        assert_eq!(cell.snapshot().version, 1);
    }

    #[test]
    fn spill_policy_parses_and_round_trips() {
        for (s, p) in [
            ("strict", SpillPolicy::Strict),
            ("steal", SpillPolicy::Steal),
            ("broadcast", SpillPolicy::Broadcast),
        ] {
            let parsed = SpillPolicy::parse(s).unwrap();
            assert_eq!(parsed, p);
            assert_eq!(parsed.name(), s);
        }
        assert!(SpillPolicy::parse("bogus").is_err());
    }
}
