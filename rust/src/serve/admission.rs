//! Deadline-aware admission control / load shedding.
//!
//! Under open-loop load (see [`super::loadgen::Arrival::Poisson`]) an
//! overloaded server cannot slow its clients down; without admission
//! control every queued request is processed *late*, so past the
//! saturation point tail latency diverges with queue depth while
//! goodput (replies inside their deadline) collapses to zero. This
//! module sheds that work at enqueue time instead: each arriving
//! request gets a **feasibility check** — "given the current backlog
//! and the observed per-micro-batch service time, can this deadline
//! still be met?" — and an [`AdmissionPolicy`] decides what to do when
//! the answer is no.
//!
//! The service-time estimate is a rolling per-shard EWMA
//! ([`ServiceEwma`]) fed by the shard workers after every micro-batch,
//! so the controller adapts to the executor actually in use (PJRT vs
//! no-op) and to per-shard load imbalance. Because batch-construction
//! policy changes per-request work (the Cooperative Minibatching
//! observation, arXiv 2310.12403), the `degrade` policy does not just
//! gate on the queue: it shrinks the *sampling fanout* of the admitted
//! request until the estimated MFG work fits the remaining deadline
//! budget ([`degraded_fanouts`]).
//!
//! The three policies:
//!
//! * `none` — admit everything (the latency-cliff baseline);
//! * `reject` — shed requests whose deadline is already unmeetable,
//!   counted as `shed` in the `ServeReport`;
//! * `degrade` — admit, but cap the request's per-layer fanouts so its
//!   micro-batch fits the remaining budget (counted as `degraded`).
//!
//! Under request tracing (`trace=`) every decision on a trace-sampled
//! request also lands on the client track as an `Enqueue`, `Degrade`
//! (carrying the layer-0 fanout cap) or `Shed` instant, so a Perfetto
//! view of an overloaded run shows exactly *when* the gate started
//! firing relative to the queue-wait spans (see [`crate::obs`]). The
//! emission lives in [`super::loadgen`], next to the enqueue itself —
//! this module stays trace-agnostic.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use anyhow::{bail, Result};

/// What to do with a request whose deadline is already unmeetable at
/// enqueue time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit everything; requests past saturation are processed late.
    None,
    /// Shed the request (no reply is ever produced; the load generator
    /// records it as shed).
    Reject,
    /// Admit the request but shrink its sampling fanout so the MFG
    /// fits the remaining deadline budget (see [`degraded_fanouts`]).
    Degrade,
}

impl AdmissionPolicy {
    /// Parse a CLI knob value: `none | reject | degrade`.
    pub fn parse(s: &str) -> Result<AdmissionPolicy> {
        match s {
            "none" => Ok(AdmissionPolicy::None),
            "reject" => Ok(AdmissionPolicy::Reject),
            "degrade" => Ok(AdmissionPolicy::Degrade),
            other => bail!(
                "unknown admission policy {other:?} (try: none | reject | degrade)"
            ),
        }
    }

    /// The knob spelling this policy parses from.
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::None => "none",
            AdmissionPolicy::Reject => "reject",
            AdmissionPolicy::Degrade => "degrade",
        }
    }
}

/// Lock-free rolling EWMA of micro-batch service time in microseconds.
///
/// The value lives as `f64` bits in an `AtomicU64` (zero bits encode
/// "no sample yet"), updated with a CAS loop so many workers can feed
/// it and many clients read it without a mutex on the admission path.
pub struct ServiceEwma {
    bits: AtomicU64,
    alpha: f64,
}

impl ServiceEwma {
    /// New empty estimator; `alpha` is the EWMA smoothing factor in
    /// `(0, 1]` (higher = reacts faster, noisier).
    pub fn new(alpha: f64) -> ServiceEwma {
        ServiceEwma { bits: AtomicU64::new(0), alpha: alpha.clamp(1e-3, 1.0) }
    }

    /// Fold one observed per-batch service time (µs) into the average.
    pub fn record(&self, service_us: f64) {
        if !service_us.is_finite() || service_us < 0.0 {
            return;
        }
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = if cur == 0 {
                service_us
            } else {
                let prev = f64::from_bits(cur);
                prev + self.alpha * (service_us - prev)
            };
            // never store the 0 bit pattern for a real sample: 0 means
            // "empty", and a literal 0.0 µs sample becomes ~5e-324
            let nb = next.to_bits().max(1);
            match self.bits.compare_exchange_weak(
                cur,
                nb,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(v) => cur = v,
            }
        }
    }

    /// Current estimate (µs), or `None` before the first sample.
    pub fn get(&self) -> Option<f64> {
        let bits = self.bits.load(Ordering::Relaxed);
        if bits == 0 {
            None
        } else {
            Some(f64::from_bits(bits))
        }
    }
}

/// Estimated completion time (µs) for a request enqueued at `now_us`
/// behind `batches_ahead` *sequential* micro-batches (or parallel
/// drain waves — see [`AdmissionController::decide`]), each taking
/// `service_us` on the EWMA estimate — the request itself rides the
/// `+ 1`-th.
///
/// ```
/// use comm_rand::serve::admission::est_finish_us;
///
/// // 3 batches ahead at ~1 ms each: a 2 ms deadline is unmeetable,
/// // a 5 ms deadline is fine
/// let est = est_finish_us(0, 3, 1_000.0);
/// assert!(est > 2_000);
/// assert!(est <= 5_000);
/// ```
pub fn est_finish_us(now_us: u64, batches_ahead: usize, service_us: f64) -> u64 {
    let work = (batches_ahead as f64 + 1.0) * service_us.max(0.0);
    now_us.saturating_add(work as u64)
}

/// Per-layer fanouts shrunk so an estimated `est_full_us` of MFG work
/// fits into `budget_us`: every fanout is scaled by
/// `clamp(budget / est_full, 0, 1)` and floored at 1 neighbor, so the
/// degraded request still produces a (cheap) answer instead of an
/// error. Monotone: a smaller budget never yields a larger fanout.
///
/// ```
/// use comm_rand::serve::admission::degraded_fanouts;
///
/// // half the budget -> half the fanout
/// assert_eq!(degraded_fanouts(&[10, 10], 500.0, 1_000.0), vec![5, 5]);
/// // no budget left at all -> minimum fanout, never zero
/// assert_eq!(degraded_fanouts(&[10, 10], 0.0, 1_000.0), vec![1, 1]);
/// // budget covers the full estimate -> untouched
/// assert_eq!(degraded_fanouts(&[10, 10], 2_000.0, 1_000.0), vec![10, 10]);
/// ```
pub fn degraded_fanouts(
    base: &[usize],
    budget_us: f64,
    est_full_us: f64,
) -> Vec<usize> {
    let scale = if est_full_us > 0.0 {
        (budget_us / est_full_us).clamp(0.0, 1.0)
    } else {
        1.0
    };
    base.iter()
        .map(|&f| (((f as f64) * scale).floor() as usize).max(1))
        .collect()
}

/// Outcome of one admission decision.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmitDecision {
    /// Enqueue the request unchanged.
    Admit,
    /// Enqueue the request with these per-layer fanout caps attached
    /// (`Request::fanout_cap`).
    Degrade(Vec<usize>),
    /// Drop the request; its deadline is already unmeetable.
    Shed,
}

/// Per-shard admission state: the service-time estimator plus the
/// shed/degrade counters reported per shard.
struct ShardAdm {
    ewma: ServiceEwma,
    shed: AtomicUsize,
    degraded: AtomicUsize,
}

/// Deadline-feasibility gate shared by the load generators (decide at
/// enqueue) and the shard workers (EWMA feedback after every batch).
///
/// Everything is atomics, so one controller is shared by reference
/// across every client and worker thread of a run.
pub struct AdmissionController {
    policy: AdmissionPolicy,
    shards: Vec<ShardAdm>,
    /// Worker threads per shard: queued batches drain in parallel
    /// waves of this size, so the backlog wait divides by it.
    shard_workers: Vec<usize>,
    batch_size: usize,
    /// Micro-batcher coalescing budget (µs): a known, configured wait
    /// every admitted request pays before its batch even forms, so
    /// feasibility accounts for it on top of the backlog estimate.
    coalesce_us: u64,
    base_fanouts: Vec<usize>,
}

impl AdmissionController {
    /// `batch_size` is the micro-batch cap (used to convert queued
    /// requests into queued batches), `coalesce_us` the micro-batcher's
    /// per-request coalescing budget (added to every feasibility
    /// estimate), `shard_workers` the per-shard worker-pool sizes (one
    /// entry per shard — defines the shard count, and how many queued
    /// batches drain concurrently), and `base_fanouts` the per-layer
    /// sampling fanouts a non-degraded request uses; `alpha` is the
    /// EWMA smoothing factor.
    pub fn new(
        policy: AdmissionPolicy,
        batch_size: usize,
        coalesce_us: u64,
        shard_workers: Vec<usize>,
        base_fanouts: Vec<usize>,
        alpha: f64,
    ) -> AdmissionController {
        let shard_workers =
            if shard_workers.is_empty() { vec![1] } else { shard_workers };
        let n_shards = shard_workers.len();
        let shards = (0..n_shards)
            .map(|_| ShardAdm {
                ewma: ServiceEwma::new(alpha),
                shed: AtomicUsize::new(0),
                degraded: AtomicUsize::new(0),
            })
            .collect();
        AdmissionController {
            policy,
            shards,
            shard_workers,
            batch_size: batch_size.max(1),
            coalesce_us,
            base_fanouts,
        }
    }

    /// The configured policy.
    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }

    /// Worker feedback: one micro-batch on `shard` took `service_us`.
    pub fn record_service(&self, shard: usize, service_us: f64) {
        self.shards[shard].ewma.record(service_us);
    }

    /// Current EWMA service-time estimate for `shard` (µs).
    pub fn est_service_us(&self, shard: usize) -> Option<f64> {
        self.shards[shard].ewma.get()
    }

    /// Count a shed that happened outside [`AdmissionController::decide`]
    /// (the open-loop generator's queue-full drop-tail).
    pub fn note_shed(&self, shard: usize) {
        self.shards[shard].shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests shed on `shard` so far (admission + drop-tail).
    pub fn shard_shed(&self, shard: usize) -> usize {
        self.shards[shard].shed.load(Ordering::Relaxed)
    }

    /// Requests admitted degraded on `shard` so far.
    pub fn shard_degraded(&self, shard: usize) -> usize {
        self.shards[shard].degraded.load(Ordering::Relaxed)
    }

    /// Total sheds across shards.
    pub fn total_shed(&self) -> usize {
        self.shards.iter().map(|s| s.shed.load(Ordering::Relaxed)).sum()
    }

    /// Total degraded admissions across shards.
    pub fn total_degraded(&self) -> usize {
        self.shards.iter().map(|s| s.degraded.load(Ordering::Relaxed)).sum()
    }

    /// Decide admission for a request arriving at `now_us` with
    /// absolute deadline `deadline_us`, destined for `shard`.
    ///
    /// `queue_len` is the global request-queue depth and `shard_depth`
    /// the number of micro-batches already routed to (and queued on)
    /// the shard's channel. The wait model: this shard's share of the
    /// global queue (`queue_len / n_shards` — the batcher has not
    /// routed those requests yet) plus its routed batches, drained in
    /// parallel *waves* of the shard's worker-pool size; each wave
    /// takes one EWMA service time, and every request additionally
    /// pays the micro-batcher's coalescing budget (`coalesce_us`)
    /// before its batch forms. Before the first service-time sample
    /// (cold start) everything is admitted. `Shed` / `Degrade`
    /// outcomes bump the shard's counters.
    pub fn decide(
        &self,
        now_us: u64,
        deadline_us: u64,
        shard: usize,
        queue_len: usize,
        shard_depth: usize,
    ) -> AdmitDecision {
        if self.policy == AdmissionPolicy::None {
            return AdmitDecision::Admit;
        }
        let Some(service) = self.shards[shard].ewma.get() else {
            return AdmitDecision::Admit; // cold start: no estimate yet
        };
        let own_queue = queue_len / self.shards.len().max(1);
        let batches_ahead = own_queue.div_ceil(self.batch_size) + shard_depth;
        let waves_ahead =
            batches_ahead.div_ceil(self.shard_workers[shard].max(1));
        let start_us = now_us.saturating_add(self.coalesce_us);
        if est_finish_us(start_us, waves_ahead, service) <= deadline_us {
            return AdmitDecision::Admit;
        }
        match self.policy {
            AdmissionPolicy::Reject => {
                self.shards[shard].shed.fetch_add(1, Ordering::Relaxed);
                AdmitDecision::Shed
            }
            AdmissionPolicy::Degrade => {
                // neither the wait behind queued batches nor the
                // coalescing delay can be degraded away; only this
                // request's own service slice can
                let wait = waves_ahead as f64 * service;
                let budget =
                    deadline_us as f64 - start_us as f64 - wait;
                self.shards[shard].degraded.fetch_add(1, Ordering::Relaxed);
                AdmitDecision::Degrade(degraded_fanouts(
                    &self.base_fanouts,
                    budget,
                    service,
                ))
            }
            AdmissionPolicy::None => unreachable!("handled above"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // coalesce_us = 0 and 1 worker per shard keep the backlog
    // arithmetic in these tests exact (waves == batches); the
    // coalescing and parallelism terms have their own tests below
    fn ctrl(policy: AdmissionPolicy) -> AdmissionController {
        AdmissionController::new(policy, 8, 0, vec![1, 1], vec![10, 10], 0.3)
    }

    #[test]
    fn policy_parses_and_round_trips() {
        for (s, p) in [
            ("none", AdmissionPolicy::None),
            ("reject", AdmissionPolicy::Reject),
            ("degrade", AdmissionPolicy::Degrade),
        ] {
            let parsed = AdmissionPolicy::parse(s).unwrap();
            assert_eq!(parsed, p);
            assert_eq!(parsed.name(), s);
        }
        assert!(AdmissionPolicy::parse("bogus").is_err());
    }

    #[test]
    fn ewma_converges_to_constant_stream() {
        let e = ServiceEwma::new(0.3);
        assert_eq!(e.get(), None);
        for _ in 0..50 {
            e.record(1_000.0);
        }
        let v = e.get().unwrap();
        assert!((v - 1_000.0).abs() < 1e-6, "ewma {v} != 1000");
    }

    #[test]
    fn ewma_tracks_a_level_shift() {
        let e = ServiceEwma::new(0.5);
        for _ in 0..20 {
            e.record(100.0);
        }
        for _ in 0..20 {
            e.record(900.0);
        }
        let v = e.get().unwrap();
        assert!(v > 800.0, "ewma {v} stuck at the old level");
    }

    #[test]
    fn cold_start_admits_everything() {
        let c = ctrl(AdmissionPolicy::Reject);
        // no service samples yet: even an absurd deadline is admitted
        assert_eq!(c.decide(1_000, 1_001, 0, 10_000, 50), AdmitDecision::Admit);
        assert_eq!(c.total_shed(), 0);
    }

    /// `none` is a no-op: unmeetable deadlines are admitted unchanged
    /// and nothing is ever counted.
    #[test]
    fn none_policy_is_a_noop() {
        let c = ctrl(AdmissionPolicy::None);
        c.record_service(0, 10_000.0);
        let d = c.decide(0, 1, 0, 1_000, 100);
        assert_eq!(d, AdmitDecision::Admit);
        assert_eq!(c.total_shed(), 0);
        assert_eq!(c.total_degraded(), 0);
    }

    /// `reject` sheds a request whose deadline is already unmeetable
    /// and admits one with slack, counting sheds per shard.
    #[test]
    fn reject_sheds_unmeetable_deadline() {
        let c = ctrl(AdmissionPolicy::Reject);
        c.record_service(0, 10_000.0); // 10 ms per batch
        // empty queue: our own batch alone takes 10 ms > 5 ms deadline
        assert_eq!(c.decide(0, 5_000, 0, 0, 0), AdmitDecision::Shed);
        // generous deadline is admitted
        assert_eq!(c.decide(0, 1_000_000, 0, 0, 0), AdmitDecision::Admit);
        // backlog makes the same deadline unmeetable again: 32 global
        // requests / 2 shards / batch 8 = 2 batches ahead -> est 30 ms
        assert_eq!(c.decide(0, 25_000, 0, 32, 0), AdmitDecision::Shed);
        assert_eq!(c.shard_shed(0), 2);
        assert_eq!(c.shard_shed(1), 0);
        assert_eq!(c.total_shed(), 2);
    }

    /// A bigger worker pool drains the same backlog in parallel waves,
    /// turning a shed into an admit at the same deadline.
    #[test]
    fn worker_parallelism_divides_the_backlog_wait() {
        let serial = ctrl(AdmissionPolicy::Reject); // 1 worker/shard
        serial.record_service(0, 10_000.0);
        // 4 routed batches ahead at 10 ms each -> est 50 ms > 30 ms
        assert_eq!(serial.decide(0, 30_000, 0, 0, 4), AdmitDecision::Shed);
        // 4 workers on the shard: the 4 batches drain in one wave ->
        // est (1+1)*10 ms = 20 ms <= 30 ms
        let pooled = AdmissionController::new(
            AdmissionPolicy::Reject,
            8,
            0,
            vec![4],
            vec![10, 10],
            0.3,
        );
        pooled.record_service(0, 10_000.0);
        assert_eq!(pooled.decide(0, 30_000, 0, 0, 4), AdmitDecision::Admit);
    }

    /// `degrade` admits everything, but fanouts shrink monotonically as
    /// the remaining deadline budget shrinks — and never reach zero.
    #[test]
    fn degrade_shrinks_fanout_monotonically() {
        let c = ctrl(AdmissionPolicy::Degrade);
        c.record_service(0, 10_000.0);
        let mut last = vec![usize::MAX; 2];
        // deadlines from almost-feasible down to hopeless
        for deadline in [9_000u64, 7_000, 5_000, 3_000, 1_000, 0] {
            match c.decide(0, deadline, 0, 0, 0) {
                AdmitDecision::Degrade(f) => {
                    assert_eq!(f.len(), 2);
                    for (a, (&b, &base)) in
                        f.iter().zip(last.iter().zip([10usize, 10].iter()))
                    {
                        assert!(*a <= b, "fanout grew as budget shrank");
                        assert!(*a >= 1, "fanout reached zero");
                        assert!(*a <= base);
                    }
                    last = f;
                }
                other => panic!("degrade policy never sheds, got {other:?}"),
            }
        }
        // the hopeless deadline bottoms out at the minimum fanout
        assert_eq!(last, vec![1, 1]);
        assert_eq!(c.total_degraded(), 6);
        assert_eq!(c.total_shed(), 0);
    }

    /// The coalescing budget counts against feasibility: a deadline
    /// the backlog alone would meet becomes unmeetable once the
    /// batcher's coalescing delay is added.
    #[test]
    fn coalescing_budget_counts_against_the_deadline() {
        // service alone (10 ms) fits an 11 ms deadline...
        let zero = ctrl(AdmissionPolicy::Reject);
        zero.record_service(0, 10_000.0);
        assert_eq!(zero.decide(0, 11_000, 0, 0, 0), AdmitDecision::Admit);
        // ...but not once a 2 ms coalescing budget starts the clock
        let with_delay = AdmissionController::new(
            AdmissionPolicy::Reject,
            8,
            2_000,
            vec![1],
            vec![10, 10],
            0.3,
        );
        with_delay.record_service(0, 10_000.0);
        assert_eq!(with_delay.decide(0, 11_000, 0, 0, 0), AdmitDecision::Shed);
        assert_eq!(with_delay.decide(0, 13_000, 0, 0, 0), AdmitDecision::Admit);
    }

    #[test]
    fn degraded_fanouts_pure_function_bounds() {
        // budget >= estimate leaves fanouts untouched
        assert_eq!(degraded_fanouts(&[5, 7], 100.0, 100.0), vec![5, 7]);
        // negative budget clamps to the floor
        assert_eq!(degraded_fanouts(&[5, 7], -50.0, 100.0), vec![1, 1]);
        // zero estimate (degenerate) is treated as "no information"
        assert_eq!(degraded_fanouts(&[5, 7], 10.0, 0.0), vec![5, 7]);
    }

    #[test]
    fn est_finish_accounts_for_backlog() {
        assert_eq!(est_finish_us(100, 0, 1_000.0), 1_100);
        assert_eq!(est_finish_us(100, 3, 1_000.0), 4_100);
        // saturating at u64::MAX rather than wrapping
        assert_eq!(est_finish_us(u64::MAX, 1, 1e12), u64::MAX);
    }

    /// Concurrent recorders never corrupt the estimate (CAS loop).
    #[test]
    fn ewma_concurrent_records_stay_finite() {
        let e = ServiceEwma::new(0.2);
        std::thread::scope(|s| {
            for t in 0..4 {
                let e = &e;
                s.spawn(move || {
                    for i in 0..5_000 {
                        e.record(100.0 + ((t * i) % 100) as f64);
                    }
                });
            }
        });
        let v = e.get().unwrap();
        assert!(v.is_finite() && (100.0..=200.0).contains(&v), "ewma {v}");
    }
}
