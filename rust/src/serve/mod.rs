//! Online GNN inference serving with community-aware request batching.
//!
//! The offline stack trains by *constructing* locality (COMM-RAND root
//! partitioning + biased sampling). This subsystem applies the same
//! insight to an online workload: per-node inference requests arrive on
//! a bounded queue, and the dynamic micro-batcher coalesces them into
//! padded batches under a latency budget with a community-bias knob
//! `p ∈ [0, 1]` — pure-FIFO coalescing at `p = 0`, pure
//! community-grouped at `p = 1`. Grouping same-community requests makes
//! their sampled L-hop frontiers overlap, which the *functional*
//! sharded feature cache ([`cache::ShardedFeatureCache`]) converts into
//! skipped feature gathers — the serving-side analogue of the paper's
//! on-chip reuse (and of Cooperative Minibatching's cross-batch
//! overlap).
//!
//! Pipeline: [`admission`] gate → [`queue::RequestQueue`] →
//! [`batcher::MicroBatcher`] → [`shard`] router (communities
//! partitioned across `n_shards` logical devices; strict/steal/
//! broadcast spill for cross-shard batches) → per-shard [`worker`]
//! pools (sampling + cache-fed assembly + the PJRT infer executable,
//! or the pure-rust host reference executor when AOT artifacts are
//! absent) → per-request replies. Each shard owns its own feature
//! cache, so under strict spill a shard's cache only ever sees its
//! own communities. Trained parameters arrive via the checkpoint
//! subsystem ([`crate::ckpt`]): `ckpt=` installs a validated
//! checkpoint before the clock starts (real top-1 accuracy in the
//! report), and `watch_ms=` hot-swaps newer checkpoints in mid-run
//! between micro-batches — zero dropped requests, per-shard
//! `param_version`/`swaps` counters.
//!
//! [`loadgen`] drives the load two ways: a **closed loop** (each Zipf
//! client blocks on its reply, so offered load adapts to capacity) and
//! an **open loop** (Poisson arrivals at a fixed offered rate, so the
//! latency cliff past saturation is measurable). [`admission`] protects
//! that cliff: per-request deadline feasibility from a rolling
//! per-shard EWMA of micro-batch service time, with `reject` (shed) and
//! `degrade` (shrink sampling fanout to fit the remaining budget)
//! policies. [`engine::run`] ties it all together and produces the
//! throughput / tail-latency / shed-rate report with a per-shard
//! breakdown (`comm-rand serve bench`, `comm-rand exp serve`).
//!
//! With `mutate=RATE` the graph itself churns while it is served
//! ([`crate::stream`]): edge inserts/deletes and feature rewrites land
//! in epochs through versioned snapshots — workers sample the current
//! [`crate::graph::TopoSnapshot`], route against the current
//! [`LabelSnapshot`] ([`shard::LabelCell`]), and stage features
//! through the version-tagged cache, where a rewritten row's cached
//! copies turn *stale* (counted, served like misses). Incremental
//! community maintenance keeps the shard plan aligned with the live
//! topology; full relabels re-fingerprint the checkpoint fence.
//!
//! Each coalesced micro-batch is served from **one merged MFG** over
//! its deduplicated roots, so co-batched requests share sampling and
//! feature-gather work; per-request replies are root views into that
//! shared batch. The `sampler=` knob picks how the merged MFG is
//! built — `uniform` (default, independent sampling), `biased`
//! (community-weighted by `sample_p=`), or `labor` (cooperative
//! shared-variate sampling, which shrinks the union frontier as
//! co-batched neighborhoods overlap). The saved work is reported as
//! `dedup_factor` in [`ServeReport`]/[`shard::ShardReport`].
//!
//! See `docs/ARCHITECTURE.md` for the request lifecycle diagram, the
//! knob reference, and the update lifecycle (mutation → relabel →
//! invalidation).

pub mod admission;
pub mod batcher;
pub mod cache;
pub mod engine;
pub mod loadgen;
pub mod queue;
pub mod shard;
pub mod worker;

pub use admission::{AdmissionController, AdmissionPolicy, AdmitDecision};
pub use batcher::{batch_purity, BatcherConfig, MicroBatcher};
pub use cache::{CacheStats, FeatureCacheConfig, Fetched, ShardedFeatureCache};
pub use crate::sampler::SamplerKind;
pub use engine::{
    run, LocalityReport, ServeConfig, ServeReport, ShardAdvice,
};
pub use loadgen::{Arrival, LoadConfig};
pub use queue::RequestQueue;
pub use shard::{
    ExecCell, ExecReport, LabelCell, LabelSnapshot, ShardPlan, ShardReport,
    SpillPolicy,
};
pub use worker::{
    HostExecutor, InferExecutor, InferOut, NullExecutor, PjrtExecutor,
};

use std::time::Instant;

/// One inference request: classify `node` before `deadline_us`.
pub struct Request {
    /// Client-assigned id, unique within a run.
    pub id: u64,
    /// Global node id to classify.
    pub node: u32,
    /// Ground-truth label of `node`, carried through to the reply so
    /// the load generator can score top-1 accuracy on real labels
    /// without a side lookup.
    pub label: u16,
    /// [`ServeClock`] microseconds at enqueue time.
    pub arrive_us: u64,
    /// Absolute completion deadline, same clock.
    pub deadline_us: u64,
    /// Degraded-fanout metadata set by [`admission`]: per-layer caps on
    /// the sampling fanout (`None` = the artifact's full fanouts). The
    /// micro-batcher carries this through untouched; the worker takes
    /// the elementwise minimum across a batch's members.
    pub fanout_cap: Option<Vec<usize>>,
    /// Completion channel back to the issuing client.
    pub reply: std::sync::mpsc::Sender<Reply>,
}

/// Completion record delivered to the client.
pub struct Reply {
    /// The request's id.
    pub id: u64,
    /// The node that was classified.
    pub node: u32,
    /// Ground-truth label (copied from the request) — compare against
    /// the logits' argmax for top-1 accuracy.
    pub label: u16,
    /// Logits row for `node` (empty under the no-op executor).
    pub logits: Vec<f32>,
    /// [`ServeClock`] microseconds the request was enqueued (copied
    /// from the request, so open-loop collectors can compute latency
    /// without a side table).
    pub arrive_us: u64,
    /// [`ServeClock`] microseconds at completion.
    pub finish_us: u64,
    /// Size of the micro-batch this request rode in.
    pub batch_size: usize,
    /// The worker hit an execution error; `logits` is empty.
    pub error: bool,
}

/// Monotonic microsecond clock shared by every serving component, so
/// deadlines and latencies live on one timeline.
pub struct ServeClock {
    start: Instant,
}

impl ServeClock {
    /// Start the timeline at 0 µs.
    pub fn start() -> ServeClock {
        ServeClock { start: Instant::now() }
    }

    /// Microseconds elapsed since [`ServeClock::start`].
    pub fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    /// The instant this clock's timeline starts from. The trace
    /// recorder ([`crate::obs::Recorder`]) is constructed with this so
    /// event timestamps and request deadlines share one timeline.
    pub fn origin(&self) -> Instant {
        self.start
    }
}
