//! Online GNN inference serving with community-aware request batching.
//!
//! The offline stack trains by *constructing* locality (COMM-RAND root
//! partitioning + biased sampling). This subsystem applies the same
//! insight to an online workload: per-node inference requests arrive on
//! a bounded queue, and the dynamic micro-batcher coalesces them into
//! padded batches under a latency budget with a community-bias knob
//! `p ∈ [0, 1]` — pure-FIFO coalescing at `p = 0`, pure
//! community-grouped at `p = 1`. Grouping same-community requests makes
//! their sampled L-hop frontiers overlap, which the *functional*
//! sharded feature cache ([`cache::ShardedFeatureCache`]) converts into
//! skipped feature gathers — the serving-side analogue of the paper's
//! on-chip reuse (and of Cooperative Minibatching's cross-batch
//! overlap).
//!
//! Pipeline: [`queue::RequestQueue`] → [`batcher::MicroBatcher`] →
//! [`shard`] router (communities partitioned across `n_shards` logical
//! devices; strict/steal/broadcast spill for cross-shard batches) →
//! per-shard [`worker`] pools (sampling + cache-fed assembly + the
//! PJRT infer executable, or a no-op executor when AOT artifacts are
//! absent) → per-request replies. Each shard owns its own feature
//! cache, so under strict spill a shard's cache only ever sees its own
//! communities. [`loadgen`] drives the closed loop with a Zipf-skewed
//! trace and [`engine::run`] ties it all together and produces the
//! throughput / tail-latency report with a per-shard breakdown
//! (`comm-rand serve bench`, `comm-rand exp serve`).

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod loadgen;
pub mod queue;
pub mod shard;
pub mod worker;

pub use batcher::{BatcherConfig, MicroBatcher};
pub use cache::{CacheStats, FeatureCacheConfig, ShardedFeatureCache};
pub use engine::{run, ServeConfig, ServeReport};
pub use loadgen::LoadConfig;
pub use queue::RequestQueue;
pub use shard::{ShardPlan, ShardReport, SpillPolicy};
pub use worker::{InferExecutor, NullExecutor, PjrtExecutor};

use std::time::Instant;

/// One inference request: classify `node` before `deadline_us`.
pub struct Request {
    pub id: u64,
    pub node: u32,
    /// [`ServeClock`] microseconds at enqueue time.
    pub arrive_us: u64,
    /// Absolute completion deadline, same clock.
    pub deadline_us: u64,
    /// Completion channel back to the issuing client.
    pub reply: std::sync::mpsc::Sender<Reply>,
}

/// Completion record delivered to the client.
pub struct Reply {
    pub id: u64,
    pub node: u32,
    /// Logits row for `node` (empty under the no-op executor).
    pub logits: Vec<f32>,
    /// [`ServeClock`] microseconds at completion.
    pub finish_us: u64,
    /// Size of the micro-batch this request rode in.
    pub batch_size: usize,
    /// The worker hit an execution error; `logits` is empty.
    pub error: bool,
}

/// Monotonic microsecond clock shared by every serving component, so
/// deadlines and latencies live on one timeline.
pub struct ServeClock {
    start: Instant,
}

impl ServeClock {
    pub fn start() -> ServeClock {
        ServeClock { start: Instant::now() }
    }

    pub fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }
}
