//! Serving workers: take a coalesced micro-batch, sample its L-hop
//! MFG, stage features through the sharded cache, assemble the padded
//! batch and drive the inference executable, then fan per-request
//! replies back out.
//!
//! The executable is abstracted behind [`InferExecutor`] so the whole
//! pipeline (queue → coalesce → cache → assemble) runs end-to-end even
//! when no AOT artifacts exist: [`NullExecutor`] skips the PJRT call
//! and returns empty logits, [`PjrtExecutor`] wraps a compiled
//! [`InferState`].
//!
//! Two admission-control hooks live here: the per-batch service time
//! each worker measures feeds the [`AdmissionController`]'s per-shard
//! EWMA, and a batch containing degraded requests
//! (`Request::fanout_cap`) is sampled with the elementwise-minimum
//! fanout — the padded artifact shape is unchanged, only the sampled
//! neighbor count shrinks.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Mutex;

use anyhow::Result;

use crate::batch::assemble;
use crate::graph::Dataset;
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::InferState;
use crate::sampler::{build_mfg, NeighborPolicy};
use crate::util::rng::Rng;

use super::admission::AdmissionController;
use super::cache::ShardedFeatureCache;
use super::shard::{ShardPlan, ShardStatsCell};
use super::{Reply, Request, ServeClock};

/// Inference backend driven by the worker pool.
pub trait InferExecutor: Send + Sync {
    /// Short name for reports (`pjrt` / `null`).
    fn name(&self) -> &str;

    /// Logit columns per root row.
    fn num_classes(&self) -> usize;

    /// Returns logits `[batch_cap * num_classes]`, or an empty vector
    /// for a no-op backend.
    fn infer(&self, batch: &crate::batch::PaddedBatch) -> Result<Vec<f32>>;
}

/// No-op backend for artifact-less environments: exercises everything
/// up to (and including) batch assembly, returns empty logits.
pub struct NullExecutor {
    /// Logit columns the (absent) model would produce.
    pub num_classes: usize,
}

impl InferExecutor for NullExecutor {
    fn name(&self) -> &str {
        "null"
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn infer(&self, _batch: &crate::batch::PaddedBatch) -> Result<Vec<f32>> {
        Ok(Vec::new())
    }
}

/// PJRT-backed executor over a compiled `<name>.infer` artifact. The
/// state is mutex-guarded: PJRT CPU execution is serialized across
/// workers (sampling/assembly still overlap it).
pub struct PjrtExecutor {
    state: Mutex<InferState>,
    num_classes: usize,
}

impl PjrtExecutor {
    /// Wrap a compiled infer state producing `num_classes` logits.
    pub fn new(state: InferState, num_classes: usize) -> PjrtExecutor {
        PjrtExecutor { state: Mutex::new(state), num_classes }
    }
}

impl InferExecutor for PjrtExecutor {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn infer(&self, batch: &crate::batch::PaddedBatch) -> Result<Vec<f32>> {
        self.state.lock().unwrap().infer(batch)
    }
}

/// Shared read-only context one worker needs.
pub struct WorkerCtx<'a> {
    /// The dataset being served (graph + features + communities).
    pub ds: &'a Dataset,
    /// Artifact spec the padded batches are assembled against.
    pub meta: &'a ArtifactMeta,
    /// This shard's feature cache.
    pub cache: &'a ShardedFeatureCache,
    /// Inference backend (PJRT or no-op).
    pub exec: &'a dyn InferExecutor,
    /// The run's shared monotonic clock.
    pub clock: &'a ServeClock,
}

/// Per-batch accounting merged into the engine's totals (cache
/// hit/miss counters live in the shared [`ShardedFeatureCache`];
/// executor failures travel per request via [`Reply::error`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchOutcome {
    /// Requests carried by the batch (before root dedup).
    pub requests: usize,
    /// Unique input-frontier nodes sampled for the batch.
    pub input_nodes: usize,
    /// Requests answered with an error reply (executor failure is
    /// all-or-nothing per batch: 0 or `requests`).
    pub errors: usize,
}

/// One shard worker: drain the shard's batch channel until it closes,
/// processing each sub-batch against the shard's own feature cache and
/// folding the outcome into the shard's stats cell.
///
/// `depth` is the shard's queued-batch counter (incremented by the
/// router at send time); the observed value at receive time feeds the
/// per-shard `queue_depth_max` stat. `foreign_requests` counts the
/// requests whose community this shard does not own — the affinity
/// violation metric that is zero by construction under strict spill.
/// Each processed batch's wall service time is folded into `adm`'s
/// per-shard EWMA — the estimate admission decisions run on.
#[allow(clippy::too_many_arguments)]
pub fn shard_worker_loop(
    ctx: &WorkerCtx<'_>,
    shard_id: usize,
    plan: &ShardPlan,
    rx: &Mutex<Receiver<Vec<Request>>>,
    depth: &AtomicUsize,
    cell: &Mutex<ShardStatsCell>,
    adm: &AdmissionController,
    rng: &mut Rng,
) {
    loop {
        let next = rx.lock().unwrap().recv();
        let Ok(reqs) = next else { return };
        // depth at receive time (pre-decrement) still includes this batch
        let d = depth.fetch_sub(1, Ordering::Relaxed);
        let community = &ctx.ds.community;
        let foreign = reqs
            .iter()
            .filter(|r| plan.shard_of_node(community, r.node) != shard_id)
            .count();
        let arrives: Vec<u64> = reqs.iter().map(|r| r.arrive_us).collect();
        let t0 = ctx.clock.now_us();
        let out = process_batch(ctx, reqs, rng);
        let now = ctx.clock.now_us();
        adm.record_service(shard_id, now.saturating_sub(t0) as f64);
        let mut g = cell.lock().unwrap();
        g.batches += 1;
        g.requests += out.requests;
        g.foreign_requests += foreign;
        g.input_nodes += out.input_nodes;
        g.queue_depth_max = g.queue_depth_max.max(d);
        // error replies stay out of the latency samples, matching the
        // engine's global percentile definition
        if out.errors == 0 {
            g.lat_us
                .extend(arrives.iter().map(|&a| now.saturating_sub(a)));
        }
    }
}

/// Process one coalesced micro-batch end to end. Every request is
/// always replied to — executor failures produce `error` replies, so a
/// closed-loop client can never hang on a lost request.
///
/// Degraded requests (`Request::fanout_cap`) cap the batch's sampling
/// fanout at the elementwise minimum across members — one degraded
/// rider shrinks the whole batch's MFG, which is the point: the batch
/// must fit the tightest remaining deadline budget in it.
pub fn process_batch(
    ctx: &WorkerCtx<'_>,
    reqs: Vec<Request>,
    rng: &mut Rng,
) -> BatchOutcome {
    let ds = ctx.ds;
    let spec = &ctx.meta.spec;

    // duplicate nodes collapse into one root; replies fan back out
    let mut roots: Vec<u32> = reqs.iter().map(|r| r.node).collect();
    roots.sort_unstable();
    roots.dedup();

    // effective fanouts: the artifact's, capped by any degraded rider
    let mut fanouts = spec.fanouts.clone();
    for r in &reqs {
        if let Some(cap) = &r.fanout_cap {
            for (f, &c) in fanouts.iter_mut().zip(cap.iter()) {
                *f = (*f).min(c.max(1));
            }
        }
    }

    let mfg = build_mfg(
        &ds.csr,
        &ds.community,
        &roots,
        &fanouts,
        NeighborPolicy::Uniform,
        rng,
    );

    // stage the input frontier through the serving feature cache; this
    // is the gather the community-biased coalescing exists to shrink.
    // In resident-feature mode this staging buffer is what a real
    // deployment would DMA to the device alongside the index arrays;
    // in staged mode it becomes the batch's x0 payload below.
    let f = ds.feat_dim;
    let input = mfg.input_nodes();
    let mut staged = vec![0f32; input.len() * f];
    for (i, &v) in input.iter().enumerate() {
        ctx.cache.fetch(v, ds.feature_row(v), &mut staged[i * f..(i + 1) * f]);
    }

    let result: Result<Vec<f32>> =
        assemble(&mfg, ds, ctx.meta, false).and_then(|mut batch| {
            if let Some(x0) = batch.x0.as_mut() {
                // staged-mode artifact: serve the executable from the
                // cache-staged rows, not assemble's own table gather
                x0[..staged.len()].copy_from_slice(&staged);
            }
            ctx.exec.infer(&batch)
        });

    let mut outcome = BatchOutcome {
        requests: reqs.len(),
        input_nodes: input.len(),
        errors: 0,
    };
    let now = ctx.clock.now_us();
    let bsz = reqs.len();
    match result {
        Ok(logits) => {
            let nc = ctx.exec.num_classes().max(1);
            for r in reqs {
                let row = if logits.is_empty() {
                    Vec::new()
                } else {
                    // roots is sorted, so the row index is its rank
                    let i = roots.binary_search(&r.node).unwrap();
                    logits[i * nc..(i + 1) * nc].to_vec()
                };
                let _ = r.reply.send(Reply {
                    id: r.id,
                    node: r.node,
                    logits: row,
                    arrive_us: r.arrive_us,
                    finish_us: now,
                    batch_size: bsz,
                    error: false,
                });
            }
            outcome
        }
        Err(_) => {
            for r in reqs {
                let _ = r.reply.send(Reply {
                    id: r.id,
                    node: r.node,
                    logits: Vec::new(),
                    arrive_us: r.arrive_us,
                    finish_us: now,
                    batch_size: bsz,
                    error: true,
                });
            }
            outcome.errors = bsz;
            outcome
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::serve::cache::FeatureCacheConfig;
    use crate::serve::engine::synthetic_infer_meta;
    use std::sync::mpsc;

    #[test]
    fn process_batch_replies_to_every_request() {
        let ds = crate::train::dataset::build(&preset("tiny").unwrap(), true);
        let meta = synthetic_infer_meta(&ds, 8, &[5, 5]);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig::for_dataset(
            ds.n(),
            ds.feat_dim,
        ));
        let exec = NullExecutor { num_classes: ds.num_classes };
        let clock = ServeClock::start();
        let ctx = WorkerCtx {
            ds: &ds,
            meta: &meta,
            cache: &cache,
            exec: &exec,
            clock: &clock,
        };
        let (tx, rx) = mpsc::channel();
        // includes a duplicate node: both requests must be answered
        let reqs: Vec<Request> = [(1u64, 3u32), (2, 7), (3, 3)]
            .iter()
            .map(|&(id, node)| Request {
                id,
                node,
                arrive_us: 0,
                deadline_us: 1_000_000,
                fanout_cap: None,
                reply: tx.clone(),
            })
            .collect();
        let mut rng = Rng::new(5);
        let out = process_batch(&ctx, reqs, &mut rng);
        assert_eq!(out.requests, 3);
        assert_eq!(out.errors, 0);
        assert!(out.input_nodes >= 2);
        drop(tx);
        let replies: Vec<Reply> = rx.iter().collect();
        assert_eq!(replies.len(), 3);
        let mut ids: Vec<u64> = replies.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2, 3]);
        assert!(replies.iter().all(|r| !r.error && r.batch_size == 3));
    }

    /// A degraded rider caps the whole batch's sampling fanout: the
    /// input frontier shrinks versus the same batch at full fanout,
    /// and every request is still answered without error.
    #[test]
    fn degraded_fanout_cap_shrinks_the_frontier() {
        let ds = crate::train::dataset::build(&preset("tiny").unwrap(), true);
        let meta = synthetic_infer_meta(&ds, 8, &[8, 8]);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig::for_dataset(
            ds.n(),
            ds.feat_dim,
        ));
        let exec = NullExecutor { num_classes: ds.num_classes };
        let clock = ServeClock::start();
        let ctx = WorkerCtx {
            ds: &ds,
            meta: &meta,
            cache: &cache,
            exec: &exec,
            clock: &clock,
        };
        let nodes: [u32; 4] = [11, 23, 42, 57];
        let run = |caps: Option<Vec<usize>>| -> BatchOutcome {
            let (tx, rx) = mpsc::channel();
            let reqs: Vec<Request> = nodes
                .iter()
                .enumerate()
                .map(|(i, &node)| Request {
                    id: i as u64,
                    node,
                    arrive_us: 0,
                    deadline_us: 1_000_000,
                    // one degraded rider is enough to cap the batch
                    fanout_cap: if i == 0 { caps.clone() } else { None },
                    reply: tx.clone(),
                })
                .collect();
            let mut rng = Rng::new(9);
            let out = process_batch(&ctx, reqs, &mut rng);
            drop(tx);
            let replies: Vec<Reply> = rx.iter().collect();
            assert_eq!(replies.len(), 4);
            assert!(replies.iter().all(|r| !r.error));
            out
        };
        let full = run(None);
        let degraded = run(Some(vec![1, 1]));
        assert_eq!(full.requests, 4);
        assert_eq!(degraded.requests, 4);
        assert!(
            degraded.input_nodes < full.input_nodes,
            "fanout cap [1,1] must shrink the frontier: {} !< {}",
            degraded.input_nodes,
            full.input_nodes
        );
    }
}
