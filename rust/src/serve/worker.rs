//! Serving workers: take a coalesced micro-batch, sample its L-hop
//! MFG, stage features through the sharded cache, assemble the padded
//! batch and drive the inference executable, then fan per-request
//! replies back out.
//!
//! The executable is abstracted behind [`InferExecutor`] so the whole
//! pipeline (queue → coalesce → cache → assemble) runs end-to-end even
//! when no AOT artifacts exist: [`NullExecutor`] skips the model call
//! and returns empty logits, [`HostExecutor`] runs the pure-rust
//! SGC reference model ([`crate::runtime::host`]) so accuracy is real
//! without PJRT, and [`PjrtExecutor`] wraps a compiled [`InferState`].
//!
//! **Hot swap** happens at this layer's seams: the engine (startup
//! load or the checkpoint watcher) pushes a validated
//! [`ParamVersion`] through [`InferExecutor::try_install`]; executors
//! stash it behind a mutex and every [`InferExecutor::infer`] call —
//! i.e. every micro-batch — picks up whatever version is installed at
//! that moment. Workers never pause: a batch runs either entirely on
//! the old version or entirely on the new one, and each reply's batch
//! reports the version it was computed with ([`InferOut`]), which
//! feeds the per-shard `param_version` / `swaps` counters.
//!
//! Two admission-control hooks live here: the per-batch service time
//! each worker measures feeds the [`AdmissionController`]'s per-shard
//! EWMA, and a batch containing degraded requests
//! (`Request::fanout_cap`) is sampled with the elementwise-minimum
//! fanout — the padded artifact shape is unchanged, only the sampled
//! neighbor count shrinks.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::batch::assemble;
use crate::ckpt::quant::{pick_exp, rounded_div, FEAT_LIMIT, FEAT_MAX_EXP};
use crate::ckpt::ParamVersion;
use crate::graph::{Dataset, Topology};
use crate::obs::{
    Access, EventKind, Heartbeat, LocalityShard, Recorder, TRACK_CLIENT,
};
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::host;
use crate::runtime::kernels::{
    accumulate_rows_i8, matvec_i16_i32, pad_to_lanes, KernelBackend,
};
use crate::runtime::InferState;
use crate::sampler::{build_mfg, build_mfg_labor, NeighborPolicy, SamplerKind};
use crate::stream::StreamState;
use crate::util::rng::Rng;

use super::admission::AdmissionController;
use super::cache::{Fetched, ShardedFeatureCache};
use super::shard::{LabelCell, LabelSnapshot, ShardStatsCell};
use super::{Reply, Request, ServeClock};

/// Result of one executor call: the logits plus the parameter version
/// they were computed with (0 = seed/initial parameters, >0 = the
/// store version of an installed checkpoint).
pub struct InferOut {
    /// Logits, `num_classes` per root row (empty under [`NullExecutor`]).
    pub logits: Vec<f32>,
    /// Parameter version used for this batch.
    pub param_version: u64,
    /// Execution dtype of the installed parameters: `"f32"` for the
    /// float path, `"i16q"` when the quantized integer kernels ran.
    /// Feeds the per-dtype execute breakdown in the serve report.
    pub dtype: &'static str,
}

/// Inference backend driven by the worker pool.
pub trait InferExecutor: Send + Sync {
    /// Short name for reports (`pjrt` / `host` / `null`).
    fn name(&self) -> &str;

    /// Logit columns per root row.
    fn num_classes(&self) -> usize;

    /// Run one micro-batch; returns logits plus the parameter version
    /// they were computed with.
    fn infer(&self, batch: &crate::batch::PaddedBatch) -> Result<InferOut>;

    /// Atomically install a published parameter version; subsequent
    /// [`InferExecutor::infer`] calls (micro-batch boundaries) use it.
    /// The default refuses — a backend with no parameters (the no-op
    /// executor) cannot serve a checkpoint, and the engine surfaces
    /// that at startup rather than silently reporting seed accuracy.
    fn try_install(&self, version: &Arc<ParamVersion>) -> Result<()> {
        let _ = version;
        bail!(
            "executor {:?} cannot install checkpoint parameters",
            self.name()
        )
    }
}

/// No-op backend for pipeline-only benchmarks: exercises everything up
/// to (and including) batch assembly, returns empty logits.
pub struct NullExecutor {
    /// Logit columns the (absent) model would produce.
    pub num_classes: usize,
}

impl InferExecutor for NullExecutor {
    fn name(&self) -> &str {
        "null"
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn infer(&self, _batch: &crate::batch::PaddedBatch) -> Result<InferOut> {
        Ok(InferOut { logits: Vec::new(), param_version: 0, dtype: "f32" })
    }
}

/// Pure-rust reference backend: the SGC-style host model over 1-hop
/// smoothed features ([`crate::runtime::host`]). Real logits — and
/// therefore real top-1 accuracy — with no artifacts and no PJRT, and
/// the default artifact-less executor since the checkpoint subsystem
/// landed. Parameters hot-swap via [`InferExecutor::try_install`].
///
/// Two execution engines live behind the install seam:
///
/// * **f32** — the scalar [`host::logits_into`] reference path, used
///   for seed parameters and plain f32 checkpoints.
/// * **i16q** — when an `i16q` checkpoint installs, the weights run
///   through the integer SIMD kernels ([`crate::runtime::kernels`])
///   against a pre-quantized activation table: raw features quantized
///   to i8 at one table-wide power-of-two scale, aggregated over
///   `{v} ∪ N(v)` with [`accumulate_rows_i8`] and rounded-divided by
///   the closed-neighborhood size — the integer mirror of
///   [`host::aggregate_table`]. Install proves the per-class i32
///   accumulator bound (`max|x| · Σ|w| + |bias| ≤ i32::MAX`) and
///   fails the swap loudly if the checkpoint could overflow.
///
/// A mixed-dtype hot swap (f32 → i16q or back) is just an engine
/// replacement at a micro-batch boundary: in-flight batches finish on
/// the engine they snapshotted.
pub struct HostExecutor {
    /// 1-hop aggregated feature table (`n * feat_dim`), built once.
    agg: Vec<f32>,
    /// Integer activation table for the quantized engine: the same
    /// closed-neighborhood mean, quantized at scale `2^qagg_exp`,
    /// zero-padded rows of `feat_pad` i16.
    qagg: Vec<i16>,
    /// Power-of-two scale exponent of `qagg`.
    qagg_exp: u32,
    /// `feat_dim` rounded up to the kernel lane width.
    feat_pad: usize,
    /// Kernel variant every quantized batch dispatches to (resolved
    /// once, at construction).
    backend: KernelBackend,
    feat_dim: usize,
    num_classes: usize,
    /// Installed engine + its parameter version (0 = seed init).
    cur: Mutex<(HostEngine, u64)>,
}

/// The parameter representation a host batch executes against.
enum HostEngine {
    /// Scalar f32 path over the raw checkpoint tensors.
    F32(Arc<Vec<Vec<f32>>>),
    /// Quantized integer path (prepared by `HostExecutor::quant_model`).
    Quant(Arc<QuantHostModel>),
}

/// An installed quantized parameter set, laid out for the kernels.
struct QuantHostModel {
    /// Class-major transposed weights: `num_classes` rows of
    /// `feat_pad` i16 (zero-padded), so one [`matvec_i16_i32`] row
    /// sweep is one logit.
    wt: Vec<i16>,
    /// Bias at the combined weight×activation scale.
    bias: Vec<i32>,
    /// `1 / 2^(w_exp + qagg_exp)` — multiplying an i32 accumulator by
    /// this dequantizes it to an f32 logit exactly.
    out_scale: f32,
}

impl HostExecutor {
    /// [`HostExecutor::with_backend`] with the `kernel=auto` dispatch
    /// rule (honors the `COMM_RAND_KERNEL` env override).
    pub fn new(ds: &Dataset, seed: u64) -> Result<HostExecutor> {
        HostExecutor::with_backend(ds, seed, KernelBackend::resolve("auto")?)
    }

    /// Build both engines' tables and seed-initialize parameters
    /// (version 0) — `seed` matches the host trainer's init stream, so
    /// an untrained serving run reports true "seed parameter"
    /// accuracy. Errors if the dataset's features cannot be quantized
    /// (non-finite, or magnitude beyond the i8 range at scale 1).
    pub fn with_backend(
        ds: &Dataset,
        seed: u64,
        backend: KernelBackend,
    ) -> Result<HostExecutor> {
        let n = ds.n();
        let f = ds.feat_dim;
        let feat_pad = pad_to_lanes(f);

        // one table-wide activation scale: every row must share it for
        // the aggregation (and the matvec) to be a plain integer sum
        let mut max_abs = 0f32;
        for v in 0..n as u32 {
            for &x in ds.feature_row(v) {
                if !x.is_finite() {
                    bail!("feature table has a non-finite value at node {v}");
                }
                max_abs = max_abs.max(x.abs());
            }
        }
        let qagg_exp = pick_exp(max_abs, FEAT_LIMIT, FEAT_MAX_EXP)?;
        let scale = (1u64 << qagg_exp) as f32;
        let mut qfeat = vec![0i8; n * feat_pad];
        for v in 0..n {
            let row = ds.feature_row(v as u32);
            let dst = &mut qfeat[v * feat_pad..v * feat_pad + f];
            for (d, &x) in dst.iter_mut().zip(row) {
                *d = (x * scale).round() as i8;
            }
        }

        // integer closed-neighborhood mean via the aggregation kernel
        // (the same kernel the equivalence suite pins across variants)
        let mut qagg = vec![0i16; n * feat_pad];
        let mut acc = vec![0i32; feat_pad];
        for v in 0..n as u32 {
            acc.fill(0);
            let nbrs = ds.csr.neighbors(v);
            accumulate_rows_i8(backend, &qfeat, feat_pad, &[v], &mut acc);
            accumulate_rows_i8(backend, &qfeat, feat_pad, nbrs, &mut acc);
            let d = (nbrs.len() + 1) as i32;
            let dst = &mut qagg[v as usize * feat_pad..][..feat_pad];
            for (o, &a) in dst.iter_mut().zip(&acc) {
                // mean of i8 values stays in the i8 range, so the i16
                // store is lossless
                *o = rounded_div(a, d) as i16;
            }
        }

        Ok(HostExecutor {
            agg: host::aggregate_table(ds),
            qagg,
            qagg_exp,
            feat_pad,
            backend,
            feat_dim: f,
            num_classes: ds.num_classes,
            cur: Mutex::new((
                HostEngine::F32(Arc::new(host::init_params(
                    f,
                    ds.num_classes,
                    seed,
                ))),
                0,
            )),
        })
    }

    /// The installed parameter version (0 until a checkpoint lands).
    pub fn param_version(&self) -> u64 {
        self.cur.lock().unwrap().1
    }

    /// The kernel variant quantized batches run on.
    pub fn backend(&self) -> KernelBackend {
        self.backend
    }

    /// Execution dtype of the installed engine (`"f32"` / `"i16q"`).
    pub fn dtype(&self) -> &'static str {
        match self.cur.lock().unwrap().0 {
            HostEngine::F32(_) => "f32",
            HostEngine::Quant(_) => "i16q",
        }
    }

    /// Lay a quantized checkpoint out for the kernels: transpose the
    /// feature-major `W` into class-major zero-padded i16 rows,
    /// re-quantize the (exactly dequantized) bias at the combined
    /// weight×activation scale, and prove the i32 accumulator bound
    /// for every class — a checkpoint that could overflow is refused
    /// here, at install time, not discovered as wrapped logits later.
    fn quant_model(
        &self,
        version: &ParamVersion,
    ) -> Result<QuantHostModel> {
        let Some(qts) = version.quant.as_ref() else {
            bail!("quant_model on a non-quantized parameter version");
        };
        let (f, c, fp) = (self.feat_dim, self.num_classes, self.feat_pad);
        let w = &qts[0];
        let mut wt = vec![0i16; c * fp];
        for k in 0..f {
            for (cls, row) in wt.chunks_exact_mut(fp).enumerate() {
                row[k] = w.q[k * c + cls];
            }
        }
        let comb_exp = w.exp + self.qagg_exp;
        let comb = (1u64 << comb_exp) as f64;
        let mut bias = Vec::with_capacity(c);
        for &b in &version.params[1] {
            let r = (b as f64 * comb).round();
            if r.abs() > i32::MAX as f64 {
                bail!(
                    "quantized bias {b} overflows i32 at combined scale \
                     2^{comb_exp}"
                );
            }
            bias.push(r as i32);
        }
        let x_max =
            self.qagg.iter().map(|&x| (x as i64).abs()).max().unwrap_or(0);
        for (cls, row) in wt.chunks_exact(fp).enumerate() {
            let wsum: i64 = row.iter().map(|&x| (x as i64).abs()).sum();
            let bound = x_max * wsum + (bias[cls] as i64).abs();
            if bound > i32::MAX as i64 {
                bail!(
                    "quantized accumulator for class {cls} could reach \
                     {bound} (> i32::MAX): checkpoint is out of the \
                     integer envelope, refusing to install it"
                );
            }
        }
        Ok(QuantHostModel { wt, bias, out_scale: (1.0 / comb) as f32 })
    }
}

impl InferExecutor for HostExecutor {
    fn name(&self) -> &str {
        "host"
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn infer(&self, batch: &crate::batch::PaddedBatch) -> Result<InferOut> {
        // snapshot the installed engine: the whole batch runs on it
        let (engine, version) = {
            let g = self.cur.lock().unwrap();
            let e = match &g.0 {
                HostEngine::F32(p) => HostEngine::F32(p.clone()),
                HostEngine::Quant(m) => HostEngine::Quant(m.clone()),
            };
            (e, g.1)
        };
        let c = self.num_classes;
        let f = self.feat_dim;
        let mut logits = vec![0f32; batch.roots.len() * c];
        let dtype = match engine {
            HostEngine::F32(params) => {
                for (i, &v) in batch.roots.iter().enumerate() {
                    let feat =
                        &self.agg[v as usize * f..(v as usize + 1) * f];
                    host::logits_into(
                        &params,
                        feat,
                        &mut logits[i * c..(i + 1) * c],
                    );
                }
                "f32"
            }
            HostEngine::Quant(m) => {
                let fp = self.feat_pad;
                let mut acc = vec![0i32; c];
                for (i, &v) in batch.roots.iter().enumerate() {
                    let x = &self.qagg[v as usize * fp..][..fp];
                    matvec_i16_i32(
                        self.backend,
                        &m.wt,
                        x,
                        &m.bias,
                        fp,
                        &mut acc,
                    );
                    for (o, &a) in
                        logits[i * c..(i + 1) * c].iter_mut().zip(&acc)
                    {
                        // exact: the accumulator is within the proven
                        // envelope and out_scale is a power of two
                        *o = a as f32 * m.out_scale;
                    }
                }
                "i16q"
            }
        };
        Ok(InferOut { logits, param_version: version, dtype })
    }

    fn try_install(&self, version: &Arc<ParamVersion>) -> Result<()> {
        host::check_params(&version.params, self.feat_dim, self.num_classes)?;
        let engine = if version.quant.is_some() {
            HostEngine::Quant(Arc::new(self.quant_model(version)?))
        } else {
            HostEngine::F32(Arc::new(version.params.clone()))
        };
        let mut g = self.cur.lock().unwrap();
        *g = (engine, version.version);
        Ok(())
    }
}

/// PJRT-backed executor over a compiled `<name>.infer` artifact. The
/// state is mutex-guarded: PJRT CPU execution is serialized across
/// workers (sampling/assembly still overlap it). Checkpoints install
/// through [`InferState::set_params`], which validates tensor count
/// and shapes against the artifact's param specs.
pub struct PjrtExecutor {
    state: Mutex<InferState>,
    num_classes: usize,
    /// Version of the installed parameters (0 = seed init).
    installed: std::sync::atomic::AtomicU64,
}

impl PjrtExecutor {
    /// Wrap a compiled infer state producing `num_classes` logits.
    pub fn new(state: InferState, num_classes: usize) -> PjrtExecutor {
        PjrtExecutor {
            state: Mutex::new(state),
            num_classes,
            installed: std::sync::atomic::AtomicU64::new(0),
        }
    }
}

impl InferExecutor for PjrtExecutor {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn infer(&self, batch: &crate::batch::PaddedBatch) -> Result<InferOut> {
        // the state lock spans the whole call, so the version read
        // under it is exactly the one the executable ran with
        let g = self.state.lock().unwrap();
        let logits = g.infer(batch)?;
        let param_version = self.installed.load(Ordering::Acquire);
        // PJRT always executes the exact dequantized f32 view, even
        // for an i16q checkpoint (set_params takes version.params)
        Ok(InferOut { logits, param_version, dtype: "f32" })
    }

    fn try_install(&self, version: &Arc<ParamVersion>) -> Result<()> {
        let mut g = self.state.lock().unwrap();
        g.set_params(version.params.clone())?;
        self.installed.store(version.version, Ordering::Release);
        Ok(())
    }
}

/// Shared read-only context one worker needs.
pub struct WorkerCtx<'a> {
    /// The dataset being served (graph + features + communities).
    pub ds: &'a Dataset,
    /// Artifact spec the padded batches are assembled against.
    pub meta: &'a ArtifactMeta,
    /// This shard's feature cache.
    pub cache: &'a ShardedFeatureCache,
    /// Inference backend (PJRT, host reference, or no-op).
    pub exec: &'a dyn InferExecutor,
    /// The run's shared monotonic clock.
    pub clock: &'a ServeClock,
    /// Streaming-mutation state (`serve bench mutate=`): when present,
    /// each batch samples against the current topology snapshot and
    /// stages features at their live versions (stale cached copies
    /// refresh and count as `stale_hits`). `None` = frozen graph.
    pub stream: Option<&'a StreamState>,
    /// Trace recorder — pass [`Recorder::disabled`] when tracing is
    /// off; every emit is then a single branch.
    pub rec: &'a Recorder,
    /// The trace track this worker's spans land on
    /// ([`crate::obs::shard_track`] of the shard id).
    pub track: usize,
    /// Which sampler builds the merged per-batch MFG (`sampler=` knob).
    /// `Uniform` keeps the pre-knob RNG draw sequence bit for bit.
    pub sampler: SamplerKind,
    /// Intra-community weight for [`SamplerKind::Biased`] (`sample_p=`
    /// knob); ignored by the other samplers.
    pub sample_p: f64,
    /// This worker's liveness slot in the engine's
    /// [`Watchdog`][crate::obs::Watchdog]: marked idle right before
    /// blocking on the batch channel and busy right after a batch
    /// arrives, so silence-while-waiting is healthy and
    /// silence-mid-batch is a detectable stall. `None` (tests,
    /// embedders) skips the beats entirely.
    pub hb: Option<&'a Heartbeat>,
    /// This shard's reuse-distance profiler (`locality=1`): the
    /// feature-gather loop feeds it one sampled-access batch per
    /// micro-batch. `None` = locality observatory off, zero cost.
    pub locality: Option<&'a LocalityShard>,
}

/// Per-batch accounting merged into the engine's totals (cache
/// hit/miss counters live in the shared [`ShardedFeatureCache`];
/// executor failures travel per request via [`Reply::error`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchOutcome {
    /// Requests carried by the batch (before root dedup).
    pub requests: usize,
    /// Unique input-frontier nodes sampled for the batch.
    pub input_nodes: usize,
    /// Input-frontier references *with multiplicity*
    /// ([`Mfg::frontier_refs`][crate::sampler::Mfg::frontier_refs]):
    /// feature rows the batch would gather without cross-request dedup.
    /// `frontier_refs / input_nodes` is the batch's dedup factor.
    pub frontier_refs: u64,
    /// Requests answered with an error reply (executor failure is
    /// all-or-nothing per batch: 0 or `requests`).
    pub errors: usize,
    /// Parameter version the batch was served with (meaningful only
    /// when `errors == 0`).
    pub param_version: u64,
    /// Wall time of the executor call alone (assemble excluded):
    /// `ctx.exec.infer` entry to return, in µs.
    pub execute_us: u64,
    /// Execution dtype the batch ran at (`"f32"` / `"i16q"`; empty
    /// when the batch errored before executing).
    pub dtype: &'static str,
}

/// One shard worker: drain the shard's batch channel until it closes,
/// processing each sub-batch against the shard's own feature cache and
/// folding the outcome into the shard's stats cell.
///
/// `depth` is the shard's queued-batch counter (incremented by the
/// router at send time); the observed value at receive time feeds the
/// per-shard `queue_depth_max` stat. `foreign_requests` counts the
/// requests whose community this shard does not own — the affinity
/// violation metric that is zero by construction under strict spill.
/// Each processed batch's wall service time is folded into `adm`'s
/// per-shard EWMA — the estimate admission decisions run on. The
/// batch's parameter version feeds the shard's hot-swap counters:
/// `param_version` (latest observed), `swaps` (version changes seen)
/// and `version_regressions` (observed version going backwards —
/// always 0 unless the swap path is broken).
#[allow(clippy::too_many_arguments)]
pub fn shard_worker_loop(
    ctx: &WorkerCtx<'_>,
    shard_id: usize,
    labels: &LabelCell,
    rx: &Mutex<Receiver<Vec<Request>>>,
    depth: &AtomicUsize,
    cell: &Mutex<ShardStatsCell>,
    adm: &AdmissionController,
    rng: &mut Rng,
) {
    loop {
        // idle before blocking: a worker waiting for work is silent
        // but healthy; busy again the moment a batch arrives
        if let Some(hb) = ctx.hb {
            hb.idle(ctx.clock.now_us());
        }
        let next = rx.lock().unwrap().recv();
        let Ok(reqs) = next else {
            if let Some(hb) = ctx.hb {
                hb.retire();
            }
            return;
        };
        if let Some(hb) = ctx.hb {
            hb.busy(ctx.clock.now_us());
        }
        // depth at receive time (pre-decrement) still includes this batch
        let d = depth.fetch_sub(1, Ordering::Relaxed);
        // one label snapshot per batch: foreign accounting, sampling
        // bias and (for movers) the warm-cache routing all agree
        let snap = labels.snapshot();
        let foreign = reqs
            .iter()
            .filter(|r| snap.owner_shard(r.node) != shard_id)
            .count();
        let arrives: Vec<u64> = reqs.iter().map(|r| r.arrive_us).collect();
        let t0 = ctx.clock.now_us();
        let out = process_batch(ctx, &snap, reqs, rng);
        let now = ctx.clock.now_us();
        adm.record_service(shard_id, now.saturating_sub(t0) as f64);
        let mut g = cell.lock().unwrap();
        g.batches += 1;
        g.requests += out.requests;
        g.foreign_requests += foreign;
        g.input_nodes += out.input_nodes;
        g.frontier_refs += out.frontier_refs;
        g.queue_depth_max = g.queue_depth_max.max(d);
        if out.errors == 0 {
            // error replies stay out of the latency samples, matching
            // the engine's global percentile definition
            for &a in &arrives {
                g.lat_us.record(now.saturating_sub(a));
            }
            // per-dtype executor timing (batches that errored never
            // reached — or never finished — the executor)
            let exec = match out.dtype {
                "i16q" => Some(&mut g.exec_i16),
                "f32" => Some(&mut g.exec_f32),
                _ => None,
            };
            if let Some(e) = exec {
                e.batches += 1;
                e.requests += out.requests as u64;
                e.total_us += out.execute_us;
                e.us.record(out.execute_us);
            }
            // hot-swap accounting. `param_version` tracks the highest
            // version served (monotone by construction, so a batch
            // that started pre-swap and finished late can never roll
            // the reported version back), `swaps` counts upward
            // transitions of that maximum, and a completion carrying
            // an *older* version than the maximum counts as a
            // regression — guaranteed 0 when the shard's batches are
            // serialized (one worker); with several workers per shard
            // it can also capture benign in-flight overlap at the
            // exact swap instant (see ShardReport docs).
            if !g.seen_version {
                g.param_version = out.param_version;
                g.seen_version = true;
            } else if out.param_version > g.param_version {
                g.swaps += 1;
                g.param_version = out.param_version;
            } else if out.param_version < g.param_version {
                g.version_regressions += 1;
            }
        }
    }
}

/// Process one coalesced micro-batch end to end. Every request is
/// always replied to — executor failures produce `error` replies, so a
/// closed-loop client can never hang on a lost request.
///
/// Degraded requests (`Request::fanout_cap`) cap the batch's sampling
/// fanout at the elementwise minimum across members — one degraded
/// rider shrinks the whole batch's MFG, which is the point: the batch
/// must fit the tightest remaining deadline budget in it.
///
/// `snap` is the label snapshot the batch was routed under; sampling
/// reads its labels, so a batch is consistent with its own routing
/// even while refinement publishes newer snapshots. Under streaming
/// (`ctx.stream`) the MFG samples the current topology snapshot and
/// feature staging goes through the versioned cache path.
pub fn process_batch(
    ctx: &WorkerCtx<'_>,
    snap: &LabelSnapshot,
    reqs: Vec<Request>,
    rng: &mut Rng,
) -> BatchOutcome {
    let ds = ctx.ds;
    let spec = &ctx.meta.spec;

    // trace bookkeeping: which riders are sampled, captured up front
    // (one hash per request; everything below is branch-on-disabled)
    let enabled = ctx.rec.is_enabled();
    let traced: Vec<(u64, u64)> = if enabled {
        reqs.iter()
            .filter(|r| ctx.rec.traced(r.id))
            .map(|r| (r.id, r.arrive_us))
            .collect()
    } else {
        Vec::new()
    };
    // batch-level spans carry one representative traced rider id
    let span_req = traced.first().map(|&(id, _)| id).unwrap_or(0);
    if enabled {
        let t0 = ctx.rec.now_us();
        for &(id, arrive) in &traced {
            // queue wait = enqueue → the batch starting to process,
            // drawn on the client track so it nests under nothing
            ctx.rec.span(
                TRACK_CLIENT,
                EventKind::QueueWait,
                arrive,
                t0.saturating_sub(arrive),
                id,
                0,
                0,
                0,
            );
        }
    }

    // duplicate nodes collapse into one root; replies fan back out
    let mut roots: Vec<u32> = reqs.iter().map(|r| r.node).collect();
    roots.sort_unstable();
    roots.dedup();

    // effective fanouts: the artifact's, capped by any degraded rider
    let mut fanouts = spec.fanouts.clone();
    for r in &reqs {
        if let Some(cap) = &r.fanout_cap {
            for (f, &c) in fanouts.iter_mut().zip(cap.iter()) {
                *f = (*f).min(c.max(1));
            }
        }
    }

    // topology: the frozen CSR, or — streaming — the snapshot current
    // at batch start (held for the whole batch, so the MFG is
    // internally consistent no matter what epochs land meanwhile)
    let topo_snap = ctx.stream.map(|st| st.topo());
    let topo: &dyn Topology = match &topo_snap {
        Some(t) => &**t,
        None => &ds.csr,
    };
    let t_sample = if enabled { ctx.rec.now_us() } else { 0 };
    let mfg = match ctx.sampler {
        SamplerKind::Uniform => build_mfg(
            topo,
            &snap.labels,
            &roots,
            &fanouts,
            NeighborPolicy::Uniform,
            rng,
        ),
        SamplerKind::Biased => build_mfg(
            topo,
            &snap.labels,
            &roots,
            &fanouts,
            NeighborPolicy::Biased { p: ctx.sample_p },
            rng,
        ),
        // cooperative path: one merged MFG whose per-source variates
        // are shared across every request in the batch
        SamplerKind::Labor => build_mfg_labor(topo, &roots, &fanouts, rng),
    };
    // cross-request neighborhood overlap: how many sampled input
    // references deduplicated away. refs counts every slot into the
    // input frontier with multiplicity (each layer-1 dst plus its
    // sampled neighbors); unique is the frontier the gather pays for.
    let refs = mfg.frontier_refs();
    let unique = mfg.input_nodes().len() as u64;
    if enabled {
        let end = ctx.rec.now_us();
        let overlap_permille = if refs == 0 {
            0
        } else {
            (1000 * refs.saturating_sub(unique) / refs) as u32
        };
        ctx.rec.span(
            ctx.track,
            EventKind::Sample,
            t_sample,
            end.saturating_sub(t_sample),
            span_req,
            refs as u32,
            unique as u32,
            overlap_permille,
        );
    }

    // stage the input frontier through the serving feature cache; this
    // is the gather the community-biased coalescing exists to shrink.
    // In resident-feature mode this staging buffer is what a real
    // deployment would DMA to the device alongside the index arrays;
    // in staged mode it becomes the batch's x0 payload below.
    let f = ds.feat_dim;
    let input = mfg.input_nodes();
    let mut staged = vec![0f32; input.len() * f];
    let t_gather = if enabled { ctx.rec.now_us() } else { 0 };
    // locality tap: lock-free pre-filter per access, one profiler
    // lock per batch. While an offline-replay trace is open every
    // access is forwarded (the trace must be a true prefix of the
    // cache's access order); otherwise only SHARDS-sampled nodes are.
    let loc_trace =
        ctx.locality.map(|l| l.wants_trace()).unwrap_or(false);
    let mut loc_acc: Vec<Access> = Vec::new();
    let (mut hits, mut misses, mut stale) = (0u32, 0u32, 0u32);
    for (i, &v) in input.iter().enumerate() {
        let dst = &mut staged[i * f..(i + 1) * f];
        let hit_now = match ctx.stream {
            Some(st) => {
                // versioned path: a rewritten row carries its overlay
                // version; cached copies at older versions refresh and
                // count as stale hits
                let (ver, row) = st.feat().version_and_row(v);
                let src: &[f32] = match &row {
                    Some(r) => r.as_slice(),
                    None => ds.feature_row(v),
                };
                match ctx.cache.fetch_versioned(v, ver, src, dst) {
                    Fetched::Hit => {
                        hits += 1;
                        true
                    }
                    Fetched::Stale => {
                        stale += 1;
                        false
                    }
                    Fetched::Miss => {
                        misses += 1;
                        false
                    }
                }
            }
            None => {
                let hit = ctx.cache.fetch(v, ds.feature_row(v), dst);
                if hit {
                    hits += 1;
                } else {
                    misses += 1;
                }
                hit
            }
        };
        if let Some(loc) = ctx.locality {
            if loc_trace || loc.is_sampled(v) {
                loc_acc.push(Access {
                    node: v,
                    comm: *snap.labels.get(v as usize).unwrap_or(&0),
                    hit: hit_now,
                });
            }
        }
    }
    if let Some(loc) = ctx.locality {
        loc.observe_batch(input.len() as u64, &loc_acc);
    }
    if enabled {
        let end = ctx.rec.now_us();
        ctx.rec.span(
            ctx.track,
            EventKind::Gather,
            t_gather,
            end.saturating_sub(t_gather),
            span_req,
            hits,
            misses,
            stale,
        );
    }

    let t_exec = if enabled { ctx.rec.now_us() } else { 0 };
    // executor-only wall time: the window the per-dtype execute stats
    // aggregate (assemble stays outside — it is the same work for
    // every dtype and would dilute the f32-vs-i16q comparison)
    let mut exec_us = 0u64;
    let result: Result<InferOut> =
        assemble(&mfg, ds, ctx.meta, false).and_then(|mut batch| {
            if let Some(x0) = batch.x0.as_mut() {
                // staged-mode artifact: serve the executable from the
                // cache-staged rows, not assemble's own table gather
                x0[..staged.len()].copy_from_slice(&staged);
            }
            let t0 = ctx.clock.now_us();
            let out = ctx.exec.infer(&batch);
            exec_us = ctx.clock.now_us().saturating_sub(t0);
            out
        });
    if enabled {
        let end = ctx.rec.now_us();
        let pv = result.as_ref().map(|o| o.param_version).unwrap_or(0);
        ctx.rec.span(
            ctx.track,
            EventKind::Execute,
            t_exec,
            end.saturating_sub(t_exec),
            span_req,
            reqs.len() as u32,
            pv as u32,
            0,
        );
    }

    let mut outcome = BatchOutcome {
        requests: reqs.len(),
        input_nodes: input.len(),
        frontier_refs: refs,
        errors: 0,
        param_version: 0,
        execute_us: exec_us,
        dtype: "",
    };
    let now = ctx.clock.now_us();
    let bsz = reqs.len();
    match result {
        Ok(out) => {
            outcome.param_version = out.param_version;
            outcome.dtype = out.dtype;
            let logits = out.logits;
            let nc = ctx.exec.num_classes().max(1);
            for r in reqs {
                let row = if logits.is_empty() {
                    Vec::new()
                } else {
                    // roots is sorted, so the row index is its rank
                    let i = roots.binary_search(&r.node).unwrap();
                    logits[i * nc..(i + 1) * nc].to_vec()
                };
                if enabled && ctx.rec.traced(r.id) {
                    ctx.rec.instant(
                        TRACK_CLIENT,
                        EventKind::Reply,
                        now,
                        r.id,
                        (now > r.deadline_us) as u32,
                        0,
                        0,
                    );
                }
                let _ = r.reply.send(Reply {
                    id: r.id,
                    node: r.node,
                    label: r.label,
                    logits: row,
                    arrive_us: r.arrive_us,
                    finish_us: now,
                    batch_size: bsz,
                    error: false,
                });
            }
            outcome
        }
        Err(_) => {
            for r in reqs {
                if enabled && ctx.rec.traced(r.id) {
                    ctx.rec.instant(
                        TRACK_CLIENT,
                        EventKind::Reply,
                        now,
                        r.id,
                        (now > r.deadline_us) as u32,
                        1,
                        0,
                    );
                }
                let _ = r.reply.send(Reply {
                    id: r.id,
                    node: r.node,
                    label: r.label,
                    logits: Vec::new(),
                    arrive_us: r.arrive_us,
                    finish_us: now,
                    batch_size: bsz,
                    error: true,
                });
            }
            outcome.errors = bsz;
            outcome
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::{Checkpoint, CkptMeta, ParamStore, ParamVersion};
    use crate::config::preset;
    use crate::serve::cache::FeatureCacheConfig;
    use crate::serve::engine::synthetic_infer_meta;
    use std::sync::mpsc;

    fn tiny() -> Dataset {
        crate::train::dataset::build(&preset("tiny").unwrap(), true)
    }

    fn mk_req(
        id: u64,
        node: u32,
        label: u16,
        tx: &mpsc::Sender<Reply>,
    ) -> Request {
        Request {
            id,
            node,
            label,
            arrive_us: 0,
            deadline_us: 1_000_000,
            fanout_cap: None,
            reply: tx.clone(),
        }
    }

    #[test]
    fn process_batch_replies_to_every_request() {
        let ds = tiny();
        let meta = synthetic_infer_meta(&ds, 8, &[5, 5]);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig::for_dataset(
            ds.n(),
            ds.feat_dim,
        ));
        let exec = NullExecutor { num_classes: ds.num_classes };
        let clock = ServeClock::start();
        let rec = Recorder::disabled();
        let ctx = WorkerCtx {
            ds: &ds,
            meta: &meta,
            cache: &cache,
            exec: &exec,
            clock: &clock,
            stream: None,
            rec: &rec,
            track: 0,
            sampler: SamplerKind::Uniform,
            sample_p: 0.9,
            hb: None,
            locality: None,
        };
        let (tx, rx) = mpsc::channel();
        // includes a duplicate node: both requests must be answered
        let reqs: Vec<Request> = [(1u64, 3u32), (2, 7), (3, 3)]
            .iter()
            .map(|&(id, node)| mk_req(id, node, ds.labels[node as usize], &tx))
            .collect();
        let snap = LabelSnapshot::initial(&ds.community, ds.num_comms, 1);
        let mut rng = Rng::new(5);
        let out = process_batch(&ctx, &snap, reqs, &mut rng);
        assert_eq!(out.requests, 3);
        assert_eq!(out.errors, 0);
        assert!(out.input_nodes >= 2);
        drop(tx);
        let replies: Vec<Reply> = rx.iter().collect();
        assert_eq!(replies.len(), 3);
        let mut ids: Vec<u64> = replies.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2, 3]);
        assert!(replies.iter().all(|r| !r.error && r.batch_size == 3));
        // ground-truth labels ride the reply for accuracy accounting
        for r in &replies {
            assert_eq!(r.label, ds.labels[r.node as usize]);
        }
    }

    /// A degraded rider caps the whole batch's sampling fanout: the
    /// input frontier shrinks versus the same batch at full fanout,
    /// and every request is still answered without error.
    #[test]
    fn degraded_fanout_cap_shrinks_the_frontier() {
        let ds = tiny();
        let meta = synthetic_infer_meta(&ds, 8, &[8, 8]);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig::for_dataset(
            ds.n(),
            ds.feat_dim,
        ));
        let exec = NullExecutor { num_classes: ds.num_classes };
        let clock = ServeClock::start();
        let rec = Recorder::disabled();
        let ctx = WorkerCtx {
            ds: &ds,
            meta: &meta,
            cache: &cache,
            exec: &exec,
            clock: &clock,
            stream: None,
            rec: &rec,
            track: 0,
            sampler: SamplerKind::Uniform,
            sample_p: 0.9,
            hb: None,
            locality: None,
        };
        let nodes: [u32; 4] = [11, 23, 42, 57];
        let run = |caps: Option<Vec<usize>>| -> BatchOutcome {
            let (tx, rx) = mpsc::channel();
            let reqs: Vec<Request> = nodes
                .iter()
                .enumerate()
                .map(|(i, &node)| {
                    let mut r = mk_req(i as u64, node, 0, &tx);
                    // one degraded rider is enough to cap the batch
                    if i == 0 {
                        r.fanout_cap = caps.clone();
                    }
                    r
                })
                .collect();
            let snap =
                LabelSnapshot::initial(&ds.community, ds.num_comms, 1);
            let mut rng = Rng::new(9);
            let out = process_batch(&ctx, &snap, reqs, &mut rng);
            drop(tx);
            let replies: Vec<Reply> = rx.iter().collect();
            assert_eq!(replies.len(), 4);
            assert!(replies.iter().all(|r| !r.error));
            out
        };
        let full = run(None);
        let degraded = run(Some(vec![1, 1]));
        assert_eq!(full.requests, 4);
        assert_eq!(degraded.requests, 4);
        assert!(
            degraded.input_nodes < full.input_nodes,
            "fanout cap [1,1] must shrink the frontier: {} !< {}",
            degraded.input_nodes,
            full.input_nodes
        );
    }

    /// Cooperative (labor) sampling through `process_batch`: every
    /// request is answered and the dedup accounting is consistent —
    /// refs ≥ unique inputs, so the implied dedup factor is ≥ 1.
    #[test]
    fn labor_sampler_processes_batch_with_consistent_dedup() {
        let ds = tiny();
        let meta = synthetic_infer_meta(&ds, 16, &[8, 8]);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig::for_dataset(
            ds.n(),
            ds.feat_dim,
        ));
        let exec = NullExecutor { num_classes: ds.num_classes };
        let clock = ServeClock::start();
        let rec = Recorder::disabled();
        let ctx = WorkerCtx {
            ds: &ds,
            meta: &meta,
            cache: &cache,
            exec: &exec,
            clock: &clock,
            stream: None,
            rec: &rec,
            track: 0,
            sampler: SamplerKind::Labor,
            sample_p: 0.9,
            hb: None,
            locality: None,
        };
        let (tx, rx) = mpsc::channel();
        let reqs: Vec<Request> = (0..12u32)
            .map(|i| mk_req(i as u64, i * 3, ds.labels[(i * 3) as usize], &tx))
            .collect();
        let snap = LabelSnapshot::initial(&ds.community, ds.num_comms, 1);
        let mut rng = Rng::new(7);
        let out = process_batch(&ctx, &snap, reqs, &mut rng);
        assert_eq!(out.requests, 12);
        assert_eq!(out.errors, 0);
        assert!(
            out.frontier_refs >= out.input_nodes as u64,
            "refs {} < unique {}",
            out.frontier_refs,
            out.input_nodes
        );
        drop(tx);
        let replies: Vec<Reply> = rx.iter().collect();
        assert_eq!(replies.len(), 12);
        assert!(replies.iter().all(|r| !r.error));
    }

    /// Host executor: real logits for every root, param version 0
    /// before any install, bumped after a checkpoint installs, and
    /// shape-mismatched checkpoints are refused.
    #[test]
    fn host_executor_serves_and_hot_swaps() {
        let ds = tiny();
        let meta = synthetic_infer_meta(&ds, 8, &[5, 5]);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig::for_dataset(
            ds.n(),
            ds.feat_dim,
        ));
        let exec = HostExecutor::new(&ds, 0).unwrap();
        assert_eq!(exec.param_version(), 0);
        let clock = ServeClock::start();
        let rec = Recorder::disabled();
        let ctx = WorkerCtx {
            ds: &ds,
            meta: &meta,
            cache: &cache,
            exec: &exec,
            clock: &clock,
            stream: None,
            rec: &rec,
            track: 0,
            sampler: SamplerKind::Uniform,
            sample_p: 0.9,
            hb: None,
            locality: None,
        };
        let snap = LabelSnapshot::initial(&ds.community, ds.num_comms, 1);
        let (tx, rx) = mpsc::channel();
        let reqs =
            vec![mk_req(1, 10, ds.labels[10], &tx), mk_req(2, 20, ds.labels[20], &tx)];
        let mut rng = Rng::new(1);
        let out = process_batch(&ctx, &snap, reqs, &mut rng);
        assert_eq!(out.errors, 0);
        assert_eq!(out.param_version, 0);
        drop(tx);
        let replies: Vec<Reply> = rx.iter().collect();
        assert_eq!(replies.len(), 2);
        for r in &replies {
            assert_eq!(r.logits.len(), ds.num_classes, "real logits expected");
        }

        // install a trained-shape checkpoint → version bumps
        let store = ParamStore::new();
        let meta_ck = CkptMeta::for_run(
            &ds,
            "host-sgc",
            "t",
            0,
            crate::runtime::host::param_shapes(ds.feat_dim, ds.num_classes),
        );
        let params = crate::runtime::host::init_params(
            ds.feat_dim,
            ds.num_classes,
            99,
        );
        let ck = Checkpoint::new(meta_ck.clone(), params).unwrap();
        let v = store.publish(ck, "mem".into());
        exec.try_install(&v).unwrap();
        assert_eq!(exec.param_version(), 1);
        let (tx2, rx2) = mpsc::channel();
        let out2 = process_batch(
            &ctx,
            &snap,
            vec![mk_req(3, 10, ds.labels[10], &tx2)],
            &mut rng,
        );
        assert_eq!(out2.param_version, 1);
        drop(tx2);
        assert_eq!(rx2.iter().count(), 1);

        // wrong shapes are refused and leave the installed version alone
        let mut bad_meta = meta_ck;
        bad_meta.shapes = vec![vec![3, 3]];
        let bad =
            Checkpoint::new(bad_meta, vec![vec![0.0; 9]]).unwrap();
        let vbad = store.publish(bad, "mem".into());
        assert!(exec.try_install(&vbad).is_err());
        assert_eq!(exec.param_version(), 1);
    }

    /// A quantized checkpoint hot-swaps the host executor onto the
    /// integer engine: dtype flips to `i16q`, the served logits match
    /// a naive integer reference bit for bit, a later f32 checkpoint
    /// swaps back, and an out-of-envelope quantized version is refused
    /// without disturbing the installed engine.
    #[test]
    fn host_executor_installs_quantized_checkpoints() {
        let ds = tiny();
        let meta = synthetic_infer_meta(&ds, 8, &[5, 5]);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig::for_dataset(
            ds.n(),
            ds.feat_dim,
        ));
        let exec = HostExecutor::new(&ds, 0).unwrap();
        assert_eq!(exec.dtype(), "f32");
        let store = ParamStore::new();
        let shapes = crate::runtime::host::param_shapes(
            ds.feat_dim,
            ds.num_classes,
        );
        let meta_ck = CkptMeta::for_run(&ds, "host-sgc", "t", 0, shapes);
        let params = crate::runtime::host::init_params(
            ds.feat_dim,
            ds.num_classes,
            99,
        );
        let ck = Checkpoint::new(meta_ck.clone(), params.clone()).unwrap();
        let qck = crate::ckpt::quantize_checkpoint(&ck).unwrap();
        let qts = qck.quant.clone().unwrap();
        let v = store.publish(qck, "mem".into());
        exec.try_install(&v).unwrap();
        assert_eq!(exec.dtype(), "i16q");
        assert_eq!(exec.param_version(), 1);

        let clock = ServeClock::start();
        let rec = Recorder::disabled();
        let ctx = WorkerCtx {
            ds: &ds,
            meta: &meta,
            cache: &cache,
            exec: &exec,
            clock: &clock,
            stream: None,
            rec: &rec,
            track: 0,
            sampler: SamplerKind::Uniform,
            sample_p: 0.9,
            hb: None,
            locality: None,
        };
        let snap = LabelSnapshot::initial(&ds.community, ds.num_comms, 1);
        let (tx, rx) = mpsc::channel();
        let nodes = [4u32, 9, 31];
        let reqs: Vec<Request> = nodes
            .iter()
            .enumerate()
            .map(|(i, &n)| mk_req(i as u64, n, ds.labels[n as usize], &tx))
            .collect();
        let mut rng = Rng::new(3);
        let out = process_batch(&ctx, &snap, reqs, &mut rng);
        assert_eq!(out.errors, 0);
        assert_eq!(out.dtype, "i16q");
        drop(tx);
        let replies: Vec<Reply> = rx.iter().collect();
        assert_eq!(replies.len(), 3);

        // naive integer reference computed straight from the quantized
        // tensors and the executor's activation table — the served
        // logits must match it bit for bit
        let (c, fp) = (ds.num_classes, exec.feat_pad);
        let comb = (1u64 << (qts[0].exp + exec.qagg_exp)) as f64;
        let out_scale = (1.0 / comb) as f32;
        for r in &replies {
            let x = &exec.qagg[r.node as usize * fp..][..fp];
            for (cls, &got) in r.logits.iter().enumerate() {
                let mut acc = (v.params[1][cls] as f64 * comb).round() as i32;
                for (k, &xv) in x.iter().enumerate().take(ds.feat_dim) {
                    let w = qts[0].q[k * c + cls] as i32;
                    acc = acc.wrapping_add(w.wrapping_mul(xv as i32));
                }
                let want = acc as f32 * out_scale;
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "node {} class {cls}: {got} != {want}",
                    r.node
                );
            }
        }

        // a plain f32 checkpoint swaps the engine back
        let ck2 = Checkpoint::new(meta_ck.clone(), params).unwrap();
        let v2 = store.publish(ck2, "mem".into());
        exec.try_install(&v2).unwrap();
        assert_eq!(exec.dtype(), "f32");
        assert_eq!(exec.param_version(), 2);

        // an out-of-envelope quantized version (bias beyond i32 at the
        // combined scale) is refused and leaves the engine alone
        let mut bad = ParamVersion {
            version: 3,
            params: v.params.clone(),
            quant: v.quant.clone(),
            meta: v.meta.clone(),
            source: "mem".into(),
        };
        bad.params[1][0] = 1.0e9;
        let err = exec.try_install(&Arc::new(bad)).unwrap_err();
        assert!(format!("{err:#}").contains("overflows i32"), "{err:#}");
        assert_eq!(exec.dtype(), "f32");
        assert_eq!(exec.param_version(), 2);
    }

    /// Cross-check satellite: a live-captured access trace replayed
    /// through fresh [`crate::cachesim::SetAssocCore`]s (built from
    /// [`ShardedFeatureCache::geometry`]) must agree with the serving
    /// cache access for access *and* in totals — the simulator and the
    /// serving cache are the same replacement policy over the same
    /// geometry, so any divergence is a bug in one of them.
    #[test]
    fn offline_replay_matches_live_cache_accounting() {
        use crate::cachesim::SetAssocCore;
        use crate::obs::LocalityConfig;

        let ds = tiny();
        let meta = synthetic_infer_meta(&ds, 8, &[5, 5]);
        // small cache so the trace exercises hits, misses and evictions
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 256,
            shards: 4,
            ways: 4,
            feat_dim: ds.feat_dim,
        });
        let loc = LocalityShard::new(LocalityConfig {
            sample_permille: 1000,
            trace_cap: 100_000,
        });
        let exec = NullExecutor { num_classes: ds.num_classes };
        let clock = ServeClock::start();
        let rec = Recorder::disabled();
        let ctx = WorkerCtx {
            ds: &ds,
            meta: &meta,
            cache: &cache,
            exec: &exec,
            clock: &clock,
            stream: None,
            rec: &rec,
            track: 0,
            sampler: SamplerKind::Uniform,
            sample_p: 0.9,
            hb: None,
            locality: Some(&loc),
        };
        let snap = LabelSnapshot::initial(&ds.community, ds.num_comms, 1);
        let mut rng = Rng::new(17);
        // single-threaded batches => the trace is the cache's exact
        // access order. Adjacent batch pairs share roots, so root rows
        // re-hit while the wider frontier churns the sets.
        for b in 0..20u32 {
            let (tx, _rx) = mpsc::channel();
            let reqs: Vec<Request> = (0..6u32)
                .map(|i| {
                    let node =
                        (((b / 2) * 31 + i * 7) as usize % ds.n()) as u32;
                    mk_req((b * 6 + i) as u64, node, 0, &tx)
                })
                .collect();
            let out = process_batch(&ctx, &snap, reqs, &mut rng);
            assert_eq!(out.errors, 0);
        }

        let stats = cache.stats();
        let trace = loc.trace();
        assert_eq!(
            trace.len() as u64,
            stats.lookups,
            "trace must cover every access (cap not reached)"
        );
        assert_eq!(stats.hits + stats.misses, stats.lookups);

        // offline replay through the simulator core at the live
        // cache's exact geometry and routing
        let (stripes, sets, ways) = cache.geometry();
        let mut cores: Vec<SetAssocCore> =
            (0..stripes).map(|_| SetAssocCore::new(sets, ways)).collect();
        let (mut sim_hits, mut sim_misses) = (0u64, 0u64);
        for (i, &(node, live_hit)) in trace.iter().enumerate() {
            let p = cores[node as usize % stripes].probe(node as u64);
            assert_eq!(
                p.hit, live_hit,
                "access {i} (node {node}): simulator {} vs live {}",
                p.hit, live_hit
            );
            if p.hit {
                sim_hits += 1;
            } else {
                sim_misses += 1;
            }
        }
        assert_eq!(sim_hits, stats.hits, "hit totals must agree");
        assert_eq!(sim_misses, stats.misses, "miss totals must agree");
        assert!(sim_hits > 0 && sim_misses > 0, "trace must exercise both");
    }

    /// The no-op executor cannot serve a checkpoint: the default
    /// `try_install` refuses, which the engine turns into a startup
    /// error instead of silently reporting seed accuracy.
    #[test]
    fn null_executor_refuses_checkpoints() {
        let ds = tiny();
        let exec = NullExecutor { num_classes: ds.num_classes };
        let store = ParamStore::new();
        let meta_ck = CkptMeta::for_run(&ds, "host-sgc", "t", 0, vec![vec![1]]);
        let ck = Checkpoint::new(meta_ck, vec![vec![0.5]]).unwrap();
        let v = store.publish(ck, "mem".into());
        assert!(exec.try_install(&v).is_err());
    }
}
