//! Bounded MPSC request queue: many client threads push, the batcher
//! thread pops. `push` blocks while the queue is full (closed-loop
//! backpressure — an overloaded server slows its clients instead of
//! buffering unboundedly), and `close` wakes everyone for shutdown.
//!
//! Two non-blocking entry points serve the load-shedding paths:
//! [`RequestQueue::try_push`] (plain capacity rejection) and
//! [`RequestQueue::push_gated`], the **admission hook** — it runs a
//! caller-supplied gate under the queue lock, handing it the exact
//! queue depth, so an admission decision and the enqueue it authorizes
//! are atomic with respect to other producers.
//!
//! Generic over the item so tests can drive it with plain values; the
//! engine instantiates it with [`super::Request`].

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Bounded multi-producer/single-consumer FIFO with blocking,
/// non-blocking and gated push paths (see the module docs).
pub struct RequestQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// Outcome of a timed pop.
pub enum Pop<T> {
    /// An item was dequeued.
    Item(T),
    /// Nothing arrived within the timeout; the queue is still open.
    TimedOut,
    /// Queue closed and drained.
    Closed,
}

/// Why [`RequestQueue::push_gated`] refused an item; each variant hands
/// the item back so the caller can account for it.
pub enum PushRejected<T> {
    /// The queue was closed (shutdown).
    Closed(T),
    /// The queue is at capacity (open-loop drop-tail shed).
    Full(T),
    /// The gate declined the item (admission shed).
    Denied(T),
}

impl<T> RequestQueue<T> {
    /// New queue holding at most `cap` items (floored at 1).
    pub fn new(cap: usize) -> RequestQueue<T> {
        RequestQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Blocking push; returns the item back if the queue was closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(item);
            }
            if g.q.len() < self.cap {
                g.q.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Non-blocking push; returns the item back when the queue is full
    /// (capacity rejection) or closed, so callers that would rather
    /// shed load than block — admission control, spill paths — never
    /// lose the request.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.q.len() >= self.cap {
            return Err(item);
        }
        g.q.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking **admission-gated** push: `gate` runs under the
    /// queue lock with the current queue depth and a mutable reference
    /// to the item (so an admission controller can both decide and
    /// attach degraded-fanout metadata in one step). The item is
    /// enqueued only if the gate returns `true`; otherwise it comes
    /// back as [`PushRejected::Denied`]. Closed/full checks happen
    /// first, so a full queue never consults the gate.
    pub fn push_gated(
        &self,
        mut item: T,
        gate: impl FnOnce(usize, &mut T) -> bool,
    ) -> Result<(), PushRejected<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushRejected::Closed(item));
        }
        if g.q.len() >= self.cap {
            return Err(PushRejected::Full(item));
        }
        if !gate(g.q.len(), &mut item) {
            return Err(PushRejected::Denied(item));
        }
        g.q.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let item = g.q.pop_front();
        if item.is_some() {
            drop(g);
            self.not_full.notify_one();
        }
        item
    }

    /// Pop, waiting up to `timeout` for an item. Items still queued
    /// after `close` are drained before [`Pop::Closed`] is reported.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Pop::Item(item);
            }
            if g.closed {
                return Pop::Closed;
            }
            let (g2, to) = self.not_empty.wait_timeout(g, timeout).unwrap();
            g = g2;
            if to.timed_out() {
                if let Some(item) = g.q.pop_front() {
                    drop(g);
                    self.not_full.notify_one();
                    return Pop::Item(item);
                }
                return if g.closed { Pop::Closed } else { Pop::TimedOut };
            }
        }
    }

    /// Close the queue: pushes fail from now on; queued items remain
    /// poppable.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Current queue depth (a snapshot; racy by nature).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    /// Whether the queue is currently empty (snapshot).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Configured capacity bound (the metrics exporter reports depth
    /// against it).
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn pop_timeout_times_out_when_empty() {
        let q: RequestQueue<u32> = RequestQueue::new(4);
        match q.pop_timeout(Duration::from_millis(5)) {
            Pop::TimedOut => {}
            _ => panic!("expected timeout"),
        }
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q = RequestQueue::new(4);
        q.push(1u32).unwrap();
        q.close();
        assert!(q.push(2).is_err());
        match q.pop_timeout(Duration::from_millis(1)) {
            Pop::Item(1) => {}
            _ => panic!("expected queued item"),
        }
        match q.pop_timeout(Duration::from_millis(1)) {
            Pop::Closed => {}
            _ => panic!("expected closed"),
        }
    }

    #[test]
    fn full_queue_blocks_until_pop() {
        let q = RequestQueue::new(2);
        q.push(1u32).unwrap();
        q.push(2).unwrap();
        std::thread::scope(|s| {
            let h = s.spawn(|| q.push(3)); // blocks: queue full
            std::thread::sleep(Duration::from_millis(10));
            assert_eq!(q.try_pop(), Some(1));
            h.join().unwrap().unwrap();
        });
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
    }

    #[test]
    fn close_unblocks_pusher() {
        let q = RequestQueue::new(1);
        q.push(1u32).unwrap();
        std::thread::scope(|s| {
            let h = s.spawn(|| q.push(2));
            std::thread::sleep(Duration::from_millis(10));
            q.close();
            assert!(h.join().unwrap().is_err());
        });
    }

    /// Multi-producer stress: every pushed item is popped exactly once
    /// and each producer's items keep their relative (FIFO) order.
    #[test]
    fn multi_producer_delivers_everything_once_in_order() {
        const PRODUCERS: u32 = 4;
        const PER: u32 = 100;
        let q: RequestQueue<u32> = RequestQueue::new(8); // small: forces blocking
        let mut got = Vec::new();
        std::thread::scope(|s| {
            for t in 0..PRODUCERS {
                let q = &q;
                s.spawn(move || {
                    for k in 0..PER {
                        q.push(t * 1_000 + k).unwrap();
                    }
                });
            }
            while got.len() < (PRODUCERS * PER) as usize {
                match q.pop_timeout(Duration::from_secs(5)) {
                    Pop::Item(v) => got.push(v),
                    Pop::TimedOut => panic!("starved with producers alive"),
                    Pop::Closed => panic!("nobody closed the queue"),
                }
            }
        });
        assert_eq!(got.len(), (PRODUCERS * PER) as usize);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), got.len(), "duplicate delivery");
        // per-producer FIFO: each producer's subsequence is increasing
        for t in 0..PRODUCERS {
            let seq: Vec<u32> =
                got.iter().copied().filter(|v| v / 1_000 == t).collect();
            assert_eq!(seq.len(), PER as usize, "producer {t} lost items");
            assert!(
                seq.windows(2).all(|w| w[0] < w[1]),
                "producer {t} reordered: {seq:?}"
            );
        }
    }

    #[test]
    fn try_push_full_queue_rejects_and_returns_item() {
        let q = RequestQueue::new(2);
        assert!(q.try_push(1u32).is_ok());
        assert!(q.try_push(2).is_ok());
        // full: the rejected item comes back to the caller intact
        assert_eq!(q.try_push(3).unwrap_err(), 3);
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
    }

    #[test]
    fn try_push_after_close_returns_item() {
        let q = RequestQueue::new(4);
        q.close();
        assert_eq!(q.try_push(9u32).unwrap_err(), 9);
    }

    /// The gate observes the exact depth under the lock, can mutate the
    /// item before it lands, and a `false` verdict hands it back.
    #[test]
    fn push_gated_sees_depth_and_can_mutate() {
        let q = RequestQueue::new(4);
        q.push(10u32).unwrap();
        q.push(20).unwrap();
        // gate admits and rewrites the item based on observed depth
        q.push_gated(0u32, |len, item| {
            assert_eq!(len, 2);
            *item = 99;
            true
        })
        .unwrap();
        // gate declines: item comes back via Denied
        match q.push_gated(7u32, |len, _| {
            assert_eq!(len, 3);
            false
        }) {
            Err(PushRejected::Denied(7)) => {}
            _ => panic!("expected Denied(7)"),
        }
        assert_eq!(q.try_pop(), Some(10));
        assert_eq!(q.try_pop(), Some(20));
        assert_eq!(q.try_pop(), Some(99));
        assert_eq!(q.try_pop(), None);
    }

    /// Full and closed queues reject *before* the gate runs.
    #[test]
    fn push_gated_full_and_closed_skip_the_gate() {
        let q = RequestQueue::new(1);
        q.push(1u32).unwrap();
        match q.push_gated(2u32, |_, _| panic!("gate ran on a full queue")) {
            Err(PushRejected::Full(2)) => {}
            _ => panic!("expected Full(2)"),
        }
        q.close();
        match q.push_gated(3u32, |_, _| panic!("gate ran on a closed queue")) {
            Err(PushRejected::Closed(3)) => {}
            _ => panic!("expected Closed(3)"),
        }
    }

    /// A pop already blocked on an empty queue is woken by `close` and
    /// reports `Closed` (not a timeout) once nothing is left to drain.
    #[test]
    fn close_wakes_pending_pop_with_closed() {
        let q: RequestQueue<u32> = RequestQueue::new(4);
        std::thread::scope(|s| {
            let h = s.spawn(|| q.pop_timeout(Duration::from_secs(10)));
            std::thread::sleep(Duration::from_millis(20));
            q.close();
            match h.join().unwrap() {
                Pop::Closed => {}
                Pop::Item(v) => panic!("phantom item {v}"),
                Pop::TimedOut => panic!("blocked pop timed out, not woken"),
            }
        });
    }

    /// Items queued before `close` drain in FIFO order before `Closed`
    /// is reported; `push` fails throughout.
    #[test]
    fn close_semantics_drain_then_closed() {
        let q = RequestQueue::new(8);
        for i in 0..3u32 {
            q.push(i).unwrap();
        }
        q.close();
        assert!(q.push(99).is_err(), "push after close must fail");
        for i in 0..3u32 {
            match q.pop_timeout(Duration::from_millis(5)) {
                Pop::Item(v) => assert_eq!(v, i),
                _ => panic!("expected queued item {i}"),
            }
        }
        for _ in 0..2 {
            match q.pop_timeout(Duration::from_millis(5)) {
                Pop::Closed => {}
                _ => panic!("drained queue must report Closed"),
            }
        }
    }
}
