//! Serving engine: wires admission gate → queue → micro-batcher →
//! shard router → per-shard worker pools → replies, drives the load
//! generator against it (closed loop or open-loop Poisson), and
//! reports throughput + latency percentiles + shed/degrade counts +
//! feature-cache hit rate, per shard and rolled up.
//!
//! Thread layout (all scoped, nothing outlives a run):
//!
//! * N client threads ([`super::loadgen`]) push Zipf-skewed requests —
//!   closed loop blocks each client on its reply; open loop issues at
//!   Poisson times and a single collector thread drains the replies;
//! * every arriving request passes the [`super::admission`] gate
//!   (deadline feasibility from the per-shard service-time EWMA the
//!   workers feed back);
//! * 1 batcher thread drains the queue into a [`MicroBatcher`],
//!   sleeping only until the earliest pending flush point, and routes
//!   each formed micro-batch to the shard owning its community
//!   ([`super::shard::route_batch`], spill policy configurable);
//! * per shard, a worker pool consumes routed batches from that
//!   shard's bounded channel and runs sampling → cache staging →
//!   assembly → executor against the shard's own feature cache;
//! * with `mutate > 0`, one churn thread ([`crate::stream`]) generates
//!   and applies graph-update epochs — topology delta-overlay swaps,
//!   incremental label maintenance, feature-version bumps — while
//!   everything above reads immutable snapshots;
//! * with `metrics_ms > 0` or `health_ms > 0`, one telemetry thread
//!   writes periodic Prometheus text snapshots and/or seals windowed
//!   health samples (rolling time-series → SLO burn-rate alerts →
//!   watchdog liveness sweeps → flight-recorder postmortems, see
//!   [`crate::obs`]), and with `trace=PATH` every stage above records
//!   span events that export as a Chrome-trace JSON on shutdown.
//!
//! The single-device path is simply `shards = 1`: one plan owning every
//! community, one channel, one cache — not a separate code path.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::ckpt::{self, ParamStore};
use crate::config::DatasetPreset;
use crate::graph::Dataset;
use crate::obs::{
    dump_postmortem, mrc, shard_track, write_chrome_trace, CacheAdvice,
    EventKind, HealthSample, LocalityConfig, LocalitySample, LocalityShard,
    LogHist, MrcPoint,
    PromText, Recorder, SeriesConfig, SloRuntime, SloSpec, Watchdog,
    WindowedSeries, TRACK_BATCHER, TRACK_CLIENT, TRACK_WATCHER,
};
use crate::runtime::artifact::{default_dir, ArtifactMeta, Manifest, SpecMeta};
use crate::runtime::kernels::KernelBackend;
use crate::sampler::SamplerKind;
use crate::runtime::{InferState, Runtime};
use crate::stream::{
    churn_loop_observed, MaintenanceMode, StreamConfig, StreamReport,
    StreamState,
};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::Rng;

use super::admission::{AdmissionController, AdmissionPolicy};
use super::batcher::{batch_purity, BatcherConfig, MicroBatcher};
use super::cache::{CacheStats, FeatureCacheConfig, ShardedFeatureCache};
use super::loadgen::{self, Arrival, ClientCtx, LoadConfig, ReqRecord};
use super::queue::{Pop, RequestQueue};
use super::shard::{
    route_batch, ExecCell, ExecReport, LabelCell, LabelSnapshot, ShardReport,
    ShardStatsCell, SpillPolicy,
};
use super::worker::{
    shard_worker_loop, HostExecutor, InferExecutor, PjrtExecutor, WorkerCtx,
};
use super::{Reply, Request, ServeClock};

/// Engine-side configuration of one serving run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Max requests coalesced per micro-batch.
    pub batch_size: usize,
    /// Coalescing budget per request (µs).
    pub max_delay_us: u64,
    /// Per-request completion deadline (µs, from arrival).
    pub deadline_us: u64,
    /// Community-bias knob `p ∈ [0, 1]`.
    pub community_bias: f64,
    /// Worker threads running sampling + assembly + the executable,
    /// distributed round-robin across shards (≥ 1 per shard).
    pub workers: usize,
    /// Bounded request-queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Feature cache: total rows across all device shards.
    pub cache_rows: usize,
    /// Mutex-striping count *within* each shard's cache.
    pub cache_shards: usize,
    /// Logical device shards; communities are partitioned across them
    /// and each runs its own worker pool + feature cache.
    pub shards: usize,
    /// What to do with micro-batches that span shards.
    pub spill: SpillPolicy,
    /// Admission policy for requests whose deadline is unmeetable at
    /// enqueue time (`none` / `reject` / `degrade`).
    pub admission: AdmissionPolicy,
    /// Neighbor fanouts used when no artifact dictates them.
    pub fanouts: Vec<usize>,
    /// Which sampler builds each micro-batch's merged MFG
    /// (`sampler=uniform|biased|labor`). The default, `Uniform`, keeps
    /// every pre-knob bench bitwise-identical (same RNG draw
    /// sequence); `Labor` turns on cooperative cross-request sampling.
    pub sampler: SamplerKind,
    /// Intra-community sampling weight for `sampler=biased`
    /// (`sample_p=`, ∈ [0, 1]; 0.5 ≡ uniform). Ignored by the other
    /// samplers — distinct from `community_bias`, which shapes batch
    /// *composition* rather than neighbor selection.
    pub sample_p: f64,
    /// Engine seed (batcher bias draws, per-worker RNG streams).
    pub seed: u64,
    /// Kernel dispatch for the host executor's quantized integer path
    /// (`kernel=auto|scalar|avx2`, plus `avx512` when compiled in):
    /// `auto` picks the best variant the CPU supports (overridable via
    /// the `COMM_RAND_KERNEL` env var); naming a variant forces it and
    /// errors at startup if unavailable — it never silently degrades.
    /// Every variant returns bitwise-identical accumulators.
    pub kernel: String,
    /// Checkpoint to serve (`ckpt=`): a file, or a directory whose
    /// newest checkpoint is loaded. Validated (CRC + community
    /// fingerprint) and installed into the executor before the clock
    /// starts; `None` serves seed-initialized parameters.
    pub ckpt: Option<PathBuf>,
    /// Hot-swap watcher poll interval in ms (`watch_ms=`): when > 0
    /// and `ckpt` is a directory, a watcher thread polls it during the
    /// run and installs newer checkpoints between micro-batches. 0
    /// disables watching.
    pub ckpt_watch_ms: u64,
    /// Pre-populate each shard's feature cache before the bench clock
    /// starts (`cache_warm=1`): rows come from the checkpoint's
    /// hot-node list when one is loaded, else the Zipf-hot prefix of
    /// the popularity ranking.
    pub cache_warm: bool,
    /// Streaming churn rate in graph updates per second
    /// (`mutate=RATE`); 0 disables the mutation subsystem entirely
    /// (the frozen-graph fast path).
    pub mutate_rps: f64,
    /// Updates batched per mutation epoch (`mutate_epoch=`).
    pub mutate_epoch: usize,
    /// Modularity-drift threshold triggering a full relabel under
    /// incremental maintenance (`drift=`).
    pub drift_threshold: f64,
    /// Community maintenance mode under churn (`maint=incr|full`):
    /// incremental local refinement, or the naive stop-the-world full
    /// relabel every epoch.
    pub maintenance: MaintenanceMode,
    /// Request-level tracing (`trace=PATH`): when set, every pipeline
    /// stage records span events into per-track ring buffers and the
    /// run exports a Chrome-trace JSON (Perfetto-loadable) to this
    /// path on shutdown. `None` keeps the hot path at a single
    /// relaxed-load branch per emit site.
    pub trace: Option<PathBuf>,
    /// Trace sampling rate in permille of request ids
    /// (`trace_sample=`, 0–1000). 1000 traces every request; lower
    /// rates keep per-request spans for a deterministic id subset
    /// while pipeline-level spans (coalesce, churn, swaps) are always
    /// recorded.
    pub trace_sample: u32,
    /// Live metrics snapshot period in ms (`metrics_ms=`): when > 0 a
    /// metrics thread writes a Prometheus text-format snapshot (queue
    /// depth, shed/degrade totals, per-shard cache + latency
    /// summaries) to `metrics_path` every period. 0 disables it.
    pub metrics_ms: u64,
    /// Where the metrics thread writes its snapshot (atomic
    /// tmp+rename, so scrapers never see a torn file).
    pub metrics_path: PathBuf,
    /// Health-window period in ms (`health_ms=`): when > 0 the
    /// telemetry thread seals one [`WindowedSeries`] window per period
    /// (latency histogram delta + counter deltas), evaluates the SLO
    /// runtime against it and sweeps the thread watchdog — the
    /// temporal health layer. 0 disables all of it.
    pub health_ms: u64,
    /// Declarative SLO targets (`slo=`, see [`SloSpec::parse`]),
    /// evaluated with fast/slow burn-rate alerting every health tick.
    /// `None` with `health_ms > 0` still records windows and runs the
    /// watchdog, it just never alerts.
    pub slo: Option<SloSpec>,
    /// Flight-recorder directory (`flight=DIR`): the first alert fire
    /// or detected thread stall dumps one postmortem bundle
    /// (`postmortem-*/` with windows, span rings, alert history,
    /// resolved config, per-shard state) under this directory.
    /// Requires `health_ms > 0` to ever trigger.
    pub flight: Option<PathBuf>,
    /// Locality observatory (`locality=1`): tap every shard's
    /// feature-gather loop with a SHARDS-sampled online Mattson
    /// reuse-distance profiler, derive per-window miss-ratio curves
    /// and a cache right-sizing advisor, and attach
    /// [`ServeReport::locality`]. Off by default — the tap then costs
    /// one `None` check per gather loop.
    pub locality: bool,
    /// Locality spatial-sampling rate in permille of the node id
    /// space (`locality_sample=`, 1–1000). 1000 profiles every
    /// access; lower rates profile a stateless hash-selected node
    /// subset with distances rescaled, SHARDS-style, so estimates
    /// stay unbiased.
    pub locality_sample: u32,
    /// Miss-ratio-curve resolution (`mrc_points=`): log-spaced
    /// capacity points per derived curve.
    pub mrc_points: usize,
}

impl ServeConfig {
    /// Serving defaults sized to a dataset (cache ≈ 1/8 of the table).
    pub fn for_dataset(ds: &Dataset) -> ServeConfig {
        ServeConfig {
            batch_size: 32,
            max_delay_us: 2_000,
            deadline_us: 50_000,
            community_bias: 0.5,
            workers: crate::train::default_workers(),
            queue_cap: 1024,
            cache_rows: (ds.n() / 8).max(64),
            cache_shards: 8,
            shards: 1,
            spill: SpillPolicy::Strict,
            admission: AdmissionPolicy::None,
            fanouts: vec![10, 10],
            sampler: SamplerKind::Uniform,
            sample_p: 0.9,
            seed: 0,
            kernel: "auto".to_string(),
            ckpt: None,
            ckpt_watch_ms: 0,
            cache_warm: false,
            mutate_rps: 0.0,
            mutate_epoch: 64,
            drift_threshold: 0.15,
            maintenance: MaintenanceMode::Incremental,
            trace: None,
            trace_sample: 1000,
            metrics_ms: 0,
            metrics_path: PathBuf::from("results/serve_metrics.prom"),
            health_ms: 0,
            slo: None,
            flight: None,
            locality: false,
            locality_sample: 1000,
            mrc_points: 16,
        }
    }
}

/// One SLO target's end-of-run alert accounting (inside
/// [`ServeReport::health`]).
#[derive(Clone, Debug)]
pub struct HealthAlert {
    /// Target label (`p99_latency`, `shed_rate`, …).
    pub slo: String,
    /// Configured threshold (µs for latency, fraction for rates).
    pub threshold: f64,
    /// Whether the alert was still firing when the run ended.
    pub firing: bool,
    /// Fire transitions over the run.
    pub fired: u64,
    /// Clear transitions over the run.
    pub cleared: u64,
    /// Run clock (µs) when the fast burn first crossed the threshold.
    pub first_breach_us: Option<u64>,
    /// Run clock (µs) of the first fire transition. The `exp health`
    /// gate asserts `first_fire_us - first_breach_us` stays within two
    /// slow windows.
    pub first_fire_us: Option<u64>,
    /// Final fast-window burn rate.
    pub burn_fast: f64,
    /// Final slow-window burn rate.
    pub burn_slow: f64,
}

impl HealthAlert {
    /// JSON object for the report artifact.
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<u64>| match v {
            Some(x) => num(x as f64),
            None => Json::Null,
        };
        obj(vec![
            ("slo", s(&self.slo)),
            ("threshold", num(self.threshold)),
            ("firing", Json::Bool(self.firing)),
            ("fired", num(self.fired as f64)),
            ("cleared", num(self.cleared as f64)),
            ("first_breach_us", opt(self.first_breach_us)),
            ("first_fire_us", opt(self.first_fire_us)),
            ("burn_fast", num(self.burn_fast)),
            ("burn_slow", num(self.burn_slow)),
        ])
    }
}

/// End-of-run summary of the temporal health layer (`health_ms > 0`
/// runs only).
#[derive(Clone, Debug)]
pub struct HealthReport {
    /// Health-window period the run used (ms).
    pub window_ms: u64,
    /// Windows sealed over the run.
    pub windows_sealed: u64,
    /// Per-SLO-target alert accounting (empty without `slo=`).
    pub alerts: Vec<HealthAlert>,
    /// Total alert state transitions (fires + clears).
    pub transitions: usize,
    /// Threads the watchdog ever declared stalled, by registered name.
    pub stalled_threads: Vec<String>,
    /// Postmortem bundle directories the flight recorder published.
    pub postmortems: Vec<PathBuf>,
}

impl HealthReport {
    /// JSON object for the report artifact.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("window_ms", num(self.window_ms as f64)),
            ("windows_sealed", num(self.windows_sealed as f64)),
            (
                "alerts",
                arr(self.alerts.iter().map(|a| a.to_json()).collect()),
            ),
            ("transitions", num(self.transitions as f64)),
            (
                "stalled_threads",
                arr(self.stalled_threads.iter().map(|n| s(n)).collect()),
            ),
            (
                "postmortems",
                arr(self
                    .postmortems
                    .iter()
                    .map(|p| s(&p.display().to_string()))
                    .collect()),
            ),
        ])
    }
}

/// One shard's cache right-sizing advice inside
/// [`LocalityReport`]: the MRC inverted at the shard's own profile.
#[derive(Clone, Debug)]
pub struct ShardAdvice {
    /// Device shard index.
    pub shard: usize,
    /// The advisor's verdict for this shard's cache
    /// ([`crate::obs::mrc::advise`]): predicted vs observed hit rate
    /// at the current size, and the smallest capacity meeting the
    /// target rate (when the workload can reach it at all).
    pub advice: CacheAdvice,
}

impl ShardAdvice {
    /// JSON object for the report artifact.
    pub fn to_json(&self) -> Json {
        let rows_target = match self.advice.rows_for_target {
            Some(r) => num(r as f64),
            None => Json::Null,
        };
        obj(vec![
            ("shard", num(self.shard as f64)),
            ("rows_now", num(self.advice.rows_now as f64)),
            ("predicted_hit_rate", num(self.advice.predicted_hit_rate)),
            ("observed_hit_rate", num(self.advice.observed_hit_rate)),
            ("target_hit_rate", num(self.advice.target_hit_rate)),
            ("rows_for_target", rows_target),
        ])
    }
}

/// End-of-run summary of the locality observatory (`locality=1` runs
/// only): the merged reuse-distance profile, the miss-ratio curve
/// derived from it, and per-shard right-sizing advice cross-checked
/// against the live caches' own hit counters.
#[derive(Clone, Debug)]
pub struct LocalityReport {
    /// Spatial sampling rate the profilers ran at (permille of the
    /// node id space; 1000 = every access profiled).
    pub sample_permille: u32,
    /// Gather accesses observed (sampled or not), summed over shards.
    pub accesses: u64,
    /// Accesses to SHARDS-sampled nodes (the profiled subset).
    pub sampled: u64,
    /// Sampled accesses with a finite reuse distance.
    pub reuses: u64,
    /// Sampled first-touches (infinite distance: compulsory misses).
    pub cold: u64,
    /// Mean estimated reuse distance over all reuses, in cache rows
    /// (rescaled for sampling; the quantity community bias shrinks).
    pub mean_reuse_distance: f64,
    /// 95th-percentile estimated reuse distance, rows.
    pub p95_reuse_distance: u64,
    /// Of sampled reuses, the fraction whose previous sampled access
    /// was in the same community — the access-affinity signal.
    pub self_reuse_frac: f64,
    /// Miss-ratio curve from the merged profile: predicted miss ratio
    /// at `mrc_points` log-spaced capacities.
    pub mrc: Vec<MrcPoint>,
    /// Per-shard right-sizing advice.
    pub advice: Vec<ShardAdvice>,
    /// MRC-predicted hit rate at the current per-shard capacity,
    /// lookup-weighted over shards.
    pub predicted_hit_rate: f64,
    /// The live caches' observed fresh-hit rate over the same run —
    /// `exp locality` gates `|predicted - observed|`.
    pub observed_hit_rate: f64,
}

impl LocalityReport {
    /// JSON object for the report artifact.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("sample_permille", num(self.sample_permille as f64)),
            ("accesses", num(self.accesses as f64)),
            ("sampled", num(self.sampled as f64)),
            ("reuses", num(self.reuses as f64)),
            ("cold", num(self.cold as f64)),
            ("mean_reuse_distance", num(self.mean_reuse_distance)),
            ("p95_reuse_distance", num(self.p95_reuse_distance as f64)),
            ("self_reuse_frac", num(self.self_reuse_frac)),
            (
                "mrc",
                arr(self
                    .mrc
                    .iter()
                    .map(|pt| {
                        obj(vec![
                            ("capacity_rows", num(pt.capacity_rows as f64)),
                            ("miss_ratio", num(pt.miss_ratio)),
                        ])
                    })
                    .collect()),
            ),
            (
                "advice",
                arr(self.advice.iter().map(|a| a.to_json()).collect()),
            ),
            ("predicted_hit_rate", num(self.predicted_hit_rate)),
            ("observed_hit_rate", num(self.observed_hit_rate)),
        ])
    }
}

/// End-of-run serving report (`serve bench` prints this as JSON).
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Dataset served.
    pub dataset: String,
    /// Executor used (`pjrt` / `host` / `null`).
    pub executor: String,
    /// Sampler label (`uniform` / `biased` / `labor`).
    pub sampler: String,
    /// Community-bias knob value.
    pub community_bias: f64,
    /// Arrival discipline label (`closed` / `poisson:RATE`).
    pub arrival: String,
    /// Admission policy label (`none` / `reject` / `degrade`).
    pub admission: String,
    /// Offered load in req/s (0 for the closed loop, which has no
    /// fixed offered rate).
    pub offered_rps: f64,
    /// Requests completed (replied to).
    pub requests: usize,
    /// Completed requests whose reply carried an executor error.
    pub errors: usize,
    /// Requests shed (admission rejects + open-loop drop-tail).
    pub shed: usize,
    /// shed / (completed + shed).
    pub shed_rate: f64,
    /// Requests admitted with degraded (capped) fanout.
    pub degraded: usize,
    /// Completed, non-error replies that carried logits — the accuracy
    /// denominator (0 under the no-op executor).
    pub evaluated: usize,
    /// Top-1 accuracy over `evaluated` replies, scored against the
    /// ground-truth labels the requests carried (0 when nothing was
    /// evaluated).
    pub accuracy: f64,
    /// Highest parameter version any shard served a batch with
    /// (0 = seed parameters throughout).
    pub param_version: u64,
    /// Hot swaps observed, summed over shards.
    pub swaps: usize,
    /// Serving wall time, seconds.
    pub wall_s: f64,
    /// Completed requests per second of wall time.
    pub throughput_rps: f64,
    /// Mean completion latency, ms.
    pub lat_mean_ms: f64,
    /// Median completion latency, ms.
    pub lat_p50_ms: f64,
    /// 95th-percentile completion latency, ms.
    pub lat_p95_ms: f64,
    /// 99th-percentile completion latency, ms.
    pub lat_p99_ms: f64,
    /// Worst completion latency, ms.
    pub lat_max_ms: f64,
    /// Fraction of completed requests that finished past their
    /// deadline (shed requests are counted in `shed_rate`, not here).
    pub deadline_miss_frac: f64,
    /// Micro-batches processed.
    pub batches: usize,
    /// Mean requests per micro-batch.
    pub mean_batch_size: f64,
    /// Mean unique input-frontier nodes per micro-batch.
    pub mean_input_nodes: f64,
    /// Input-frontier references with multiplicity, summed over all
    /// micro-batches: the feature rows the run would have gathered
    /// without cross-request dedup.
    pub frontier_refs: u64,
    /// Cross-request dedup factor: `frontier_refs ÷ Σ unique input
    /// nodes` (1.0 when nothing was shared or no batch ran). The
    /// cooperative sampler exists to push this up at high `p`.
    pub dedup_factor: f64,
    /// Feature bytes actually moved by the gather stage:
    /// `Σ unique input nodes × feat_dim × 4`. Cooperative sampling
    /// wins show up here as strictly fewer bytes at equal accuracy.
    pub gather_bytes: u64,
    /// Feature-cache hits, summed over shards.
    pub cache_hits: u64,
    /// Feature-cache misses, summed over shards.
    pub cache_misses: u64,
    /// Stale feature-cache hits (row cached at an older feature
    /// version; refreshed, served like a miss), summed over shards.
    /// Always 0 on frozen-graph runs.
    pub stale_hits: u64,
    /// Total feature-cache fetches, summed over shards — the
    /// accounting invariant `hits + misses + stale_hits == lookups`
    /// holds exactly.
    pub cache_lookups: u64,
    /// hits / lookups over all shards.
    pub cache_hit_rate: f64,
    /// Effective cache capacity in rows, summed over shards (geometry
    /// rounds the `cache_rows` knob up to whole sets).
    pub cache_rows: usize,
    /// Logical device shards in the run.
    pub n_shards: usize,
    /// Spill policy label.
    pub spill: String,
    /// Executor timing per execution dtype, merged over shards — one
    /// entry per dtype that served at least one batch (`"f32"`,
    /// `"i16q"`). A run that hot-swapped a quantized checkpoint in
    /// mid-flight shows both, and the per-dtype mean is the number the
    /// `exp quant` throughput gate reads.
    pub execute: Vec<ExecReport>,
    /// Per-shard breakdown (one entry even when `n_shards == 1`).
    pub shards: Vec<ShardReport>,
    /// Streaming-mutation telemetry (`mutate=RATE` runs only): churn
    /// volume, relabel waves, full relabels, drift, label/topology/
    /// feature versions.
    pub stream: Option<StreamReport>,
    /// Temporal-health telemetry (`health_ms > 0` runs only): windows
    /// sealed, per-SLO alert accounting, stalls, postmortems.
    pub health: Option<HealthReport>,
    /// Locality-observatory telemetry (`locality=1` runs only):
    /// reuse-distance profile, miss-ratio curve, right-sizing advice.
    pub locality: Option<LocalityReport>,
    /// Auxiliary threads that failed to exit within the bounded join
    /// timeout at shutdown (the engine still blocks on them afterwards,
    /// so a non-empty list means shutdown was slow, not leaky).
    pub unjoined_threads: Vec<String>,
}

impl ServeReport {
    /// Serialize the full report (the `serve bench` JSON artifact).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("dataset", s(&self.dataset)),
            ("executor", s(&self.executor)),
            ("sampler", s(&self.sampler)),
            ("p", num(self.community_bias)),
            ("arrival", s(&self.arrival)),
            ("admission", s(&self.admission)),
            ("offered_rps", num(self.offered_rps)),
            ("requests", num(self.requests as f64)),
            ("errors", num(self.errors as f64)),
            ("shed", num(self.shed as f64)),
            ("shed_rate", num(self.shed_rate)),
            ("degraded", num(self.degraded as f64)),
            ("evaluated", num(self.evaluated as f64)),
            ("accuracy", num(self.accuracy)),
            ("param_version", num(self.param_version as f64)),
            ("swaps", num(self.swaps as f64)),
            ("wall_s", num(self.wall_s)),
            ("throughput_rps", num(self.throughput_rps)),
            ("lat_mean_ms", num(self.lat_mean_ms)),
            ("lat_p50_ms", num(self.lat_p50_ms)),
            ("lat_p95_ms", num(self.lat_p95_ms)),
            ("lat_p99_ms", num(self.lat_p99_ms)),
            ("lat_max_ms", num(self.lat_max_ms)),
            ("deadline_miss_frac", num(self.deadline_miss_frac)),
            ("batches", num(self.batches as f64)),
            ("mean_batch_size", num(self.mean_batch_size)),
            ("mean_input_nodes", num(self.mean_input_nodes)),
            ("frontier_refs", num(self.frontier_refs as f64)),
            ("dedup_factor", num(self.dedup_factor)),
            ("gather_bytes", num(self.gather_bytes as f64)),
            ("cache_hits", num(self.cache_hits as f64)),
            ("cache_misses", num(self.cache_misses as f64)),
            ("stale_hits", num(self.stale_hits as f64)),
            ("cache_lookups", num(self.cache_lookups as f64)),
            ("cache_hit_rate", num(self.cache_hit_rate)),
            ("cache_rows_effective", num(self.cache_rows as f64)),
            ("n_shards", num(self.n_shards as f64)),
            ("spill", s(&self.spill)),
            (
                "execute",
                arr(self.execute.iter().map(|e| e.to_json()).collect()),
            ),
            (
                "shards",
                arr(self.shards.iter().map(|sh| sh.to_json()).collect()),
            ),
            (
                "stream",
                match &self.stream {
                    Some(st) => st.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "health",
                match &self.health {
                    Some(h) => h.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "locality",
                match &self.locality {
                    Some(l) => l.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "unjoined_threads",
                arr(self.unjoined_threads.iter().map(|n| s(n)).collect()),
            ),
        ])
    }

    /// Requests processed off their owning shard, summed over shards
    /// (0 under strict spill).
    pub fn foreign_requests(&self) -> usize {
        self.shards.iter().map(|sh| sh.foreign_requests).sum()
    }

    /// One-line human summary printed by `serve bench` and `exp serve`
    /// (streaming runs append a churn/relabel/drift tail).
    pub fn summary(&self) -> String {
        let acc = if self.evaluated > 0 {
            format!("{:.1}% ({})", self.accuracy * 100.0, self.evaluated)
        } else {
            "n/a".to_string()
        };
        let exec_tail: String = self
            .execute
            .iter()
            .map(|e| format!(" | exec {} {:.0}µs/batch", e.dtype, e.mean_us))
            .collect();
        let stream_tail = match &self.stream {
            Some(st) => format!(
                " | churn {:.0}/s ({}) epochs {} waves {} moved {} \
                 full-relabels {} stale {} drift {:.3}",
                st.mutate_ups,
                st.maintenance,
                st.epochs,
                st.relabel_waves,
                st.moved_vertices,
                st.full_relabels,
                self.stale_hits,
                st.drift,
            ),
            None => String::new(),
        };
        let health_tail = match &self.health {
            Some(h) => {
                let fired: u64 = h.alerts.iter().map(|a| a.fired).sum();
                format!(
                    " | health {}w fired {} stalls {} postmortems {}",
                    h.windows_sealed,
                    fired,
                    h.stalled_threads.len(),
                    h.postmortems.len(),
                )
            }
            None => String::new(),
        };
        let locality_tail = match &self.locality {
            Some(l) => format!(
                " | locality dist {:.0} self {:.0}% pred-hit {:.1}% \
                 obs-hit {:.1}%",
                l.mean_reuse_distance,
                l.self_reuse_frac * 100.0,
                l.predicted_hit_rate * 100.0,
                l.observed_hit_rate * 100.0,
            ),
            None => String::new(),
        };
        let join_tail = if self.unjoined_threads.is_empty() {
            String::new()
        } else {
            format!(" | SLOW-JOIN {}", self.unjoined_threads.join(","))
        };
        format!(
            "[serve] {} exec={} sampler={} p={:.2} shards={} spill={} \
             arrival={} \
             admission={}: {} req in {:.2}s = {:.0} req/s | acc {} | \
             params v{} swaps {} | lat ms p50 {:.2} p95 {:.2} p99 {:.2} \
             | miss-deadline {:.1}% | shed {} ({:.1}%) degraded {} | \
             cache hit {:.1}% | {:.1} req/batch | dedup x{:.2} | \
             foreign {}{}{}",
            self.dataset,
            self.executor,
            self.sampler,
            self.community_bias,
            self.n_shards,
            self.spill,
            self.arrival,
            self.admission,
            self.requests,
            self.wall_s,
            self.throughput_rps,
            acc,
            self.param_version,
            self.swaps,
            self.lat_p50_ms,
            self.lat_p95_ms,
            self.lat_p99_ms,
            self.deadline_miss_frac * 100.0,
            self.shed,
            self.shed_rate * 100.0,
            self.degraded,
            self.cache_hit_rate * 100.0,
            self.mean_batch_size,
            self.dedup_factor,
            self.foreign_requests(),
            exec_tail,
            stream_tail,
        ) + &health_tail
            + &locality_tail
            + &join_tail
    }
}

/// Synthetic infer spec for artifact-less serving: resident-feature
/// SAGE shapes sized so assembly can never overflow its caps.
pub fn synthetic_infer_meta(
    ds: &Dataset,
    batch_size: usize,
    fanouts: &[usize],
) -> ArtifactMeta {
    let layers = fanouts.len();
    let mut caps = vec![0usize; layers + 1];
    caps[layers] = batch_size;
    let mut bound = batch_size;
    for l in (0..layers).rev() {
        // level l-1 holds level l's dsts plus ≤ fanout neighbors each
        bound = bound.saturating_mul(fanouts[l] + 1).min(ds.n());
        caps[l] = bound;
    }
    ArtifactMeta {
        name: "serve.synthetic".to_string(),
        file: "/dev/null".into(),
        kind: "infer".to_string(),
        spec: SpecMeta {
            model: "sage".to_string(),
            layers,
            fanouts: fanouts.to_vec(),
            idx_widths: fanouts.to_vec(),
            batch_size,
            num_nodes: ds.n(),
            feat_dim: ds.feat_dim,
            num_classes: ds.num_classes,
            heads: 1,
            feat_mode: "resident".to_string(),
            node_caps: caps,
            padded_edges: 0,
            edge_chunk: 0,
        },
        inputs: Vec::new(),
        outputs: Vec::new(),
    }
}

/// Build the best available executor for a preset: the compiled
/// `<artifact>.infer` PJRT executable when artifacts (and a real PJRT)
/// exist, otherwise the pure-rust host reference executor with a
/// synthetic spec — which still produces real logits, so `serve bench`
/// reports true top-1 accuracy (and can load host-model checkpoints)
/// in artifact-less environments. Returns the executor plus the batch
/// spec the workers should assemble against.
pub fn build_executor(
    preset: &DatasetPreset,
    ds: &Dataset,
    cfg: &ServeConfig,
) -> Result<(Box<dyn InferExecutor>, ArtifactMeta)> {
    // the kernel knob resolves before any executor is built: a forced
    // but unavailable variant is a startup error on every path, never
    // a silent degrade
    let backend = KernelBackend::resolve(&cfg.kernel)?;
    match try_pjrt_executor(preset, ds, cfg.seed) {
        Ok((exec, meta)) => {
            println!("[serve] executor: pjrt ({}.infer)", preset.artifact);
            Ok((Box::new(exec), meta))
        }
        Err(e) => {
            eprintln!(
                "[serve] PJRT unavailable ({e:#}); using the host \
                 reference executor (real logits, pure rust, \
                 kernel={})",
                backend.name(),
            );
            Ok((
                Box::new(HostExecutor::with_backend(ds, cfg.seed, backend)?),
                synthetic_infer_meta(ds, cfg.batch_size, &cfg.fanouts),
            ))
        }
    }
}

fn try_pjrt_executor(
    preset: &DatasetPreset,
    ds: &Dataset,
    seed: u64,
) -> Result<(PjrtExecutor, ArtifactMeta)> {
    let manifest = Manifest::load(&default_dir())?;
    let meta = manifest
        .get(&format!("{}.infer", preset.artifact))
        .context("infer artifact missing")?
        .clone();
    let rt = Runtime::cpu()?;
    let state = InferState::new(&rt, &meta, Some(ds), seed)?;
    let classes = meta.spec.num_classes;
    Ok((PjrtExecutor::new(state, classes), meta))
}

/// Run one serving benchmark to completion (closed or open loop,
/// depending on `lcfg.arrival`).
pub fn run(
    ds: &Dataset,
    meta: &ArtifactMeta,
    exec: &dyn InferExecutor,
    scfg: &ServeConfig,
    lcfg: &LoadConfig,
) -> Result<ServeReport> {
    // never coalesce past the artifact's root capacity
    let root_cap = meta.spec.node_caps.last().copied().unwrap_or(scfg.batch_size);
    let batch_size = scfg.batch_size.clamp(1, root_cap.max(1));
    let n_shards = scfg.shards.max(1);
    let queue: RequestQueue<Request> = RequestQueue::new(scfg.queue_cap);

    // snapshot-versioned community labels + shard plan: version 0 is
    // the dataset's Louvain labeling; under churn (`mutate=`) the
    // maintenance thread publishes newer snapshots through this cell
    // and every reader (clients, batcher, workers) picks up whichever
    // snapshot is current when it looks
    let labels = LabelCell::new(LabelSnapshot::initial(
        &ds.community,
        ds.num_comms,
        n_shards,
    ));

    // streaming-mutation state (churn generator + delta overlay +
    // incremental maintainer); None = frozen graph, zero overhead
    let stream: Option<StreamState> = if scfg.mutate_rps > 0.0 {
        Some(StreamState::new(
            ds,
            StreamConfig {
                rate_ups: scfg.mutate_rps,
                epoch_updates: scfg.mutate_epoch.max(1),
                drift_threshold: scfg.drift_threshold,
                mode: scfg.maintenance,
                seed: scfg.seed,
                louvain_cap: 512,
            },
        ))
    } else {
        None
    };

    // the cache_rows budget is split across device shards: each shard
    // only ever caches its own communities (under strict spill), so
    // per-shard capacity covers a proportionally smaller working set
    let rows_per_shard = scfg.cache_rows.div_ceil(n_shards).max(1);
    let caches: Vec<ShardedFeatureCache> = (0..n_shards)
        .map(|_| {
            ShardedFeatureCache::new(&FeatureCacheConfig {
                rows: rows_per_shard,
                shards: scfg.cache_shards,
                ways: 8,
                feat_dim: ds.feat_dim,
            })
        })
        .collect();

    // ---- locality observatory (locality=1) ----
    // one reuse-distance profiler per device shard, fed by that
    // shard's gather loop; the trace prefix backs offline cachesim
    // cross-checks (`LocalityShard::trace`)
    let loc_profilers: Option<Vec<LocalityShard>> = if scfg.locality {
        let permille = scfg.locality_sample.clamp(1, 1000);
        Some(
            (0..n_shards)
                .map(|_| {
                    LocalityShard::new(LocalityConfig {
                        sample_permille: permille,
                        trace_cap: 65_536,
                    })
                })
                .collect(),
        )
    } else {
        None
    };

    let records: Mutex<Vec<ReqRecord>> = Mutex::new(Vec::new());
    let shard_cells: Vec<Mutex<ShardStatsCell>> =
        (0..n_shards).map(|_| Mutex::new(ShardStatsCell::default())).collect();

    // workers round-robin across shards, at least one each
    let total_workers = scfg.workers.max(1).max(n_shards);
    let mut shard_workers = vec![0usize; n_shards];
    for w in 0..total_workers {
        shard_workers[w % n_shards] += 1;
    }

    // admission gate: per-shard service EWMA fed by the workers,
    // consulted by every load generator at enqueue time. The batcher's
    // coalescing budget counts against every feasibility estimate, and
    // each shard's backlog drains in waves of its worker-pool size.
    let adm = AdmissionController::new(
        scfg.admission,
        batch_size,
        scfg.max_delay_us,
        shard_workers.clone(),
        meta.spec.fanouts.clone(),
        0.3,
    );

    // ---- trained parameters (ckpt=) ----
    // Load + fence-validate the checkpoint and install it into the
    // executor before any request is served; the watcher (below) keeps
    // installing newer versions during the run. The store assigns the
    // monotone version numbers the per-shard swap counters observe.
    let store = ParamStore::new();
    if let Some(ckpt_path) = &scfg.ckpt {
        let (file, ck) = ckpt::resolve_checkpoint(ckpt_path)?;
        ck.validate_against(&ds.community, ds.num_comms)?;
        if ck.meta.dataset != ds.name {
            eprintln!(
                "[serve] warning: checkpoint was trained on {:?}, serving \
                 {:?} (fingerprint matches, proceeding)",
                ck.meta.dataset, ds.name
            );
        }
        let info = (ck.meta.epoch, ck.meta.val_acc);
        let v = store.publish(ck, file.clone());
        exec.try_install(&v).with_context(|| {
            format!("installing checkpoint {}", file.display())
        })?;
        println!(
            "[serve] installed checkpoint {} (epoch {}, val acc {:.4}) \
             as param version {}",
            file.display(),
            info.0,
            info.1,
            v.version
        );
    }
    let watch_dir = match &scfg.ckpt {
        Some(p) if scfg.ckpt_watch_ms > 0 && p.is_dir() => Some(p.clone()),
        _ => None,
    };
    let watch_stop = AtomicBool::new(false);

    // popularity ranking: rank -> node, via a seeded shuffle so hot
    // nodes scatter across communities
    let perm = loadgen::popularity_perm(ds.n(), lcfg.seed);
    let zipf = loadgen::ZipfSampler::new(ds.n(), lcfg.zipf_s);

    // ---- cache warmup (cache_warm=1) ----
    // Fill each shard's feature cache with its share of the hot set —
    // the checkpoint's hot-node list when one is loaded, else the
    // Zipf-hot prefix of the popularity ranking — then zero the
    // counters so warmup traffic never pollutes the reported hit rate.
    if scfg.cache_warm {
        let warm_snap = labels.snapshot();
        let hot: Vec<u32> = match store.current() {
            Some(v) if !v.meta.hot_nodes.is_empty() => {
                v.meta.hot_nodes.clone()
            }
            _ => perm.clone(),
        };
        let mut filled = vec![0usize; n_shards];
        let mut buf = vec![0f32; ds.feat_dim];
        let mut warmed = 0usize;
        for &v in &hot {
            if (v as usize) >= ds.n() {
                continue; // stale hot list from another geometry
            }
            let sid = warm_snap.owner_shard(v);
            if filled[sid] >= caches[sid].rows() {
                continue;
            }
            caches[sid].fetch(v, ds.feature_row(v), &mut buf);
            filled[sid] += 1;
            warmed += 1;
            if filled.iter().zip(&caches).all(|(f, c)| *f >= c.rows()) {
                break;
            }
        }
        for c in &caches {
            c.reset_counters();
        }
        println!("[serve] cache warm: staged {warmed} hot rows");
    }

    // one bounded batch channel per shard; its capacity doubles as the
    // steal policy's overload threshold
    let mut txs = Vec::with_capacity(n_shards);
    let mut rxs: Vec<Mutex<Receiver<Vec<Request>>>> =
        Vec::with_capacity(n_shards);
    let mut caps = Vec::with_capacity(n_shards);
    for &nw in &shard_workers {
        let cap = nw * 2;
        let (tx, rx) = sync_channel::<Vec<Request>>(cap);
        txs.push(tx);
        rxs.push(Mutex::new(rx));
        caps.push(cap);
    }
    let depths: Vec<AtomicUsize> =
        (0..n_shards).map(|_| AtomicUsize::new(0)).collect();

    // start the clock only once setup (popularity shuffle, Zipf CDF,
    // cache slabs, shard plan) is done, so wall_s measures serving,
    // not O(n) prep
    let clock = ServeClock::start();

    // trace recorder, sharing the serve clock's origin so span
    // timestamps and request deadlines live on one timeline. Disabled
    // (the common case) every emit site costs one relaxed load.
    let rec = if scfg.trace.is_some() {
        Recorder::new(n_shards, 1 << 16, scfg.trace_sample, clock.origin())
    } else {
        Recorder::disabled()
    };

    // everything a load-generator thread reads, shared by reference
    let cctx = ClientCtx {
        queue: &queue,
        clock: &clock,
        lcfg,
        deadline_us: scfg.deadline_us,
        perm: &perm,
        labels: &ds.labels,
        zipf: &zipf,
        records: &records,
        adm: &adm,
        label_cell: &labels,
        depths: &depths,
        rec: &rec,
    };

    let churn_stop = AtomicBool::new(false);
    let metrics_stop = AtomicBool::new(false);

    // ---- temporal health layer (health_ms=) ----
    let health_on = scfg.health_ms > 0;
    // batch-purity accumulators fed by the batcher (permille sum over
    // routed batches); the health tick reads the deltas per window
    let purity_sum = AtomicU64::new(0);
    let purity_batches = AtomicU64::new(0);

    // heartbeat registry: every long-lived thread gets a named slot
    // registered before the scope spawns anything; beats are two
    // relaxed stores, stamped regardless of health_ms so enabling the
    // layer changes only who *reads* them
    let mut wd = Watchdog::new();
    let hb_batcher = wd.register("batcher");
    let hb_telemetry = wd.register("telemetry");
    let hb_churn = stream.as_ref().map(|_| wd.register("churn"));
    let hb_watcher = watch_dir.as_ref().map(|_| wd.register("ckpt-watcher"));
    let mut hb_workers = Vec::new();
    for (sidx, &nw) in shard_workers.iter().enumerate() {
        for k in 0..nw {
            hb_workers.push(wd.register(&format!("shard{sidx}/worker{k}")));
        }
    }
    let wd = wd;
    // busy + silent past this bound = stalled; generous so bursty but
    // healthy stages (full relabels, cold executors) never false-fire
    let stall_us = scfg.health_ms.saturating_mul(8).max(2_000) * 1_000;

    // resolved run config, frozen now for flight-recorder bundles
    let resolved_cfg = obj(vec![
        ("dataset", s(&ds.name)),
        ("batch_size", num(batch_size as f64)),
        ("max_delay_us", num(scfg.max_delay_us as f64)),
        ("deadline_us", num(scfg.deadline_us as f64)),
        ("community_bias", num(scfg.community_bias)),
        ("workers", num(total_workers as f64)),
        ("queue_cap", num(scfg.queue_cap as f64)),
        ("shards", num(n_shards as f64)),
        ("spill", s(scfg.spill.name())),
        ("admission", s(scfg.admission.name())),
        ("sampler", s(scfg.sampler.name())),
        ("arrival", s(&lcfg.arrival.label())),
        ("offered_rps", num(lcfg.arrival.offered_rps().unwrap_or(0.0))),
        ("mutate_rps", num(scfg.mutate_rps)),
        ("health_ms", num(scfg.health_ms as f64)),
        (
            "slo",
            match &scfg.slo {
                Some(sp) => s(&sp.label()),
                None => Json::Null,
            },
        ),
        ("seed", num(scfg.seed as f64)),
    ]);

    // the telemetry thread moves its accumulated health state here on
    // exit so the end-of-run report can read it after the scope joins
    type HealthState =
        (WindowedSeries, Option<SloRuntime>, Vec<String>, Vec<PathBuf>);
    let health_out: Mutex<Option<HealthState>> = Mutex::new(None);

    let unjoined = std::thread::scope(|scope| {
        // churn thread (mutate=RATE): the single writer — generate
        // updates at the configured rate, seal epochs, apply them
        // (topology swap, label maintenance, feature versions)
        let churn_handle = stream.as_ref().map(|st| {
            let labels = &labels;
            let caches = &caches[..];
            let clock = &clock;
            let stop = &churn_stop;
            let rec = &rec;
            let hb = hb_churn.map(|i| wd.hb(i));
            scope.spawn(move || {
                churn_loop_observed(
                    st, labels, ds, caches, clock, stop, rec, hb,
                );
            })
        });

        // checkpoint-dir watcher: validate + stage new versions in the
        // background; workers pick them up between micro-batches. The
        // validator fences against the current snapshot's *generation*
        // fingerprint — stable across incremental refinement waves
        // (checkpoints keep hot-swapping under churn), regenerated by
        // a full relabel (pre-relabel checkpoints stop validating).
        let watcher_handle = watch_dir.as_ref().map(|dir| {
            let loaded = store.current().map(|v| v.meta.epoch);
            let watcher = ckpt::DirWatcher::new(dir, loaded);
            let store = &store;
            let labels = &labels;
            let poll_ms = scfg.ckpt_watch_ms;
            let stop = &watch_stop;
            let rec = &rec;
            let hb = hb_watcher.map(|i| wd.hb(i));
            let clock = &clock;
            scope.spawn(move || {
                ckpt::watch_loop_observed(
                    watcher,
                    poll_ms,
                    stop,
                    &|ck| {
                        let snap = labels.snapshot();
                        if ck.meta.comm_fp != snap.fingerprint {
                            anyhow::bail!(
                                "community fingerprint mismatch: checkpoint \
                                 {:#018x} vs serving generation {:#018x} \
                                 (label snapshot v{}) — retrain against the \
                                 current labeling",
                                ck.meta.comm_fp,
                                snap.fingerprint,
                                snap.version
                            );
                        }
                        Ok(())
                    },
                    &|path, ck| {
                        let epoch = ck.meta.epoch;
                        let v = store.publish(ck, path);
                        exec.try_install(&v)?;
                        rec.instant(
                            TRACK_WATCHER,
                            EventKind::CkptSwap,
                            rec.now_us(),
                            0,
                            epoch as u32,
                            0,
                            0,
                        );
                        Ok(())
                    },
                    &move || {
                        if let Some(hb) = hb {
                            hb.busy(clock.now_us());
                        }
                    },
                );
                if let Some(hb) = hb {
                    hb.retire();
                }
            })
        });

        // telemetry thread (metrics_ms=N and/or health_ms=N): the
        // periodic Prometheus snapshot and the temporal health layer
        // share one thread with independent due-times.
        //
        // The *metrics tick* writes queue depth vs. capacity,
        // shed/degrade totals, per-shard cache outcomes and latency
        // summaries quoted from the same log-bucket histograms the
        // end-of-run report uses, so the snapshot and the report can
        // never disagree about p50/p99 (plus SLO burn gauges when
        // `slo=` is set). Writes are atomic (tmp+rename).
        //
        // The *health tick* folds new completion records and live
        // counters into one cumulative [`HealthSample`], seals it into
        // the windowed series, evaluates SLO burn rates (transitions
        // become SloFire/SloClear instants), sweeps the watchdog for
        // stalled threads, and — on the run's first fire or stall with
        // `flight=` set — dumps a postmortem bundle. Both ticks flush
        // one final time on shutdown.
        let telemetry_handle = (scfg.metrics_ms > 0 || health_on).then(|| {
            let queue = &queue;
            let adm = &adm;
            let caches = &caches[..];
            let shard_cells = &shard_cells[..];
            let stream = stream.as_ref();
            let rec = &rec;
            let stop = &metrics_stop;
            let clock = &clock;
            let records = &records;
            let wd = &wd;
            let purity_sum = &purity_sum;
            let purity_batches = &purity_batches;
            let health_out = &health_out;
            let loc_profilers = loc_profilers.as_deref();
            let resolved_cfg = resolved_cfg.clone();
            let flight_dir = scfg.flight.clone();
            let slo_spec = scfg.slo.clone();
            let path = scfg.metrics_path.clone();
            let mut metrics_on = scfg.metrics_ms > 0;
            let metrics_period_us = scfg.metrics_ms.max(1) * 1_000;
            let health_period_us = scfg.health_ms.max(1) * 1_000;
            scope.spawn(move || {
                let hb = wd.hb(hb_telemetry);
                let t0 = clock.now_us();
                let mut series = health_on.then(|| {
                    // retain enough windows to cover the slow burn
                    // window several times over, for postmortem context
                    let retention = slo_spec
                        .as_ref()
                        .map(|sp| sp.slow_windows * 4)
                        .unwrap_or(0)
                        .clamp(32, 512);
                    WindowedSeries::new(
                        SeriesConfig { window_us: health_period_us, retention },
                        t0,
                    )
                });
                let mut slo_rt = if health_on {
                    slo_spec.map(SloRuntime::new)
                } else {
                    None
                };
                // incremental scan cursor over the completion records:
                // each health tick folds only the records that arrived
                // since the previous tick into the cumulative sample
                let mut cursor = 0usize;
                let mut cum = HealthSample::default();
                let mut stalled_names: Vec<String> = Vec::new();
                let mut stalled_mask = vec![false; wd.len()];
                let mut postmortems: Vec<PathBuf> = Vec::new();
                let mut dumped = false;
                let mut seq = 0u32;
                let mut next_metrics = t0 + metrics_period_us;
                let mut next_health = t0 + health_period_us;
                loop {
                    let stopping = stop.load(Ordering::Relaxed);
                    let now = clock.now_us();
                    hb.busy(now);
                    if let Some(series) = series
                        .as_mut()
                        .filter(|_| now >= next_health || stopping)
                    {
                        // ---- health tick ----
                        {
                            let g = records.lock().unwrap();
                            for r in &g[cursor..] {
                                cum.completed += 1;
                                if r.error {
                                    cum.errors += 1;
                                } else {
                                    // errors stay out of the latency
                                    // histogram, matching the report
                                    cum.lat.record(r.latency_us);
                                }
                                if r.deadline_missed {
                                    cum.deadline_missed += 1;
                                }
                                if r.evaluated {
                                    cum.evaluated += 1;
                                }
                                if r.correct {
                                    cum.correct += 1;
                                }
                            }
                            cursor = g.len();
                        }
                        cum.shed = adm.total_shed() as u64;
                        cum.degraded = adm.total_degraded() as u64;
                        let mut cs = CacheStats::default();
                        for c in caches {
                            let st = c.stats();
                            cs.hits += st.hits;
                            cs.misses += st.misses;
                            cs.stale_hits += st.stale_hits;
                        }
                        cum.cache_hits = cs.hits;
                        cum.cache_misses = cs.misses;
                        cum.stale_hits = cs.stale_hits;
                        let (mut refs, mut inputs) = (0u64, 0u64);
                        for cell in shard_cells {
                            let g = cell.lock().unwrap();
                            refs += g.frontier_refs;
                            inputs += g.input_nodes as u64;
                        }
                        cum.frontier_refs = refs;
                        cum.input_nodes = inputs;
                        cum.purity_permille_sum =
                            purity_sum.load(Ordering::Relaxed);
                        cum.batches = purity_batches.load(Ordering::Relaxed);
                        cum.queue_depth = queue.len() as u64;
                        if let Some(profs) = loc_profilers {
                            // fold the per-shard reuse-distance
                            // profiles into the cumulative sample; the
                            // series diffs them into per-window deltas
                            let mut ls = LocalitySample::default();
                            for pr in profs {
                                ls.merge(&pr.snapshot());
                            }
                            cum.reuse_dist = ls.dist;
                            cum.loc_sampled = ls.sampled;
                            cum.loc_cold = ls.cold;
                            cum.loc_self = ls.self_reuses;
                            cum.loc_cross = ls.cross_reuses;
                        }
                        let w = series.observe(now, cum.clone());
                        if loc_profilers.is_some() {
                            // locality counter sample: one point per
                            // sealed window, plotted as a curve by
                            // Perfetto (ph:"C" in the export)
                            let ws = LocalitySample {
                                dist: w.reuse_dist.clone(),
                                accesses: 0,
                                sampled: w.loc_sampled,
                                cold: w.loc_cold,
                                self_reuses: w.loc_self,
                                cross_reuses: w.loc_cross,
                            };
                            let pred_miss = mrc::miss_ratio_at(
                                &ws,
                                rows_per_shard as u64,
                            );
                            rec.instant(
                                TRACK_CLIENT,
                                EventKind::Locality,
                                now,
                                0,
                                w.mean_reuse_distance().min(u32::MAX as f64)
                                    as u32,
                                (pred_miss * 1000.0) as u32,
                                (w.self_reuse_frac() * 1000.0) as u32,
                            );
                        }
                        if let Some(rt) = slo_rt.as_mut() {
                            for t in rt.evaluate(series, now) {
                                let kind = if t.fired {
                                    EventKind::SloFire
                                } else {
                                    EventKind::SloClear
                                };
                                let x100 = |b: f64| {
                                    (b * 100.0).clamp(0.0, u32::MAX as f64)
                                        as u32
                                };
                                rec.instant(
                                    TRACK_CLIENT,
                                    kind,
                                    now,
                                    0,
                                    t.index as u32,
                                    x100(t.burn_fast),
                                    x100(t.burn_slow),
                                );
                                println!(
                                    "[serve] slo {} {} (burn fast {:.2} \
                                     slow {:.2})",
                                    t.slo,
                                    if t.fired { "FIRING" } else { "clear" },
                                    t.burn_fast,
                                    t.burn_slow,
                                );
                            }
                        }
                        // liveness sweep: a newly-stalled thread emits
                        // one Stall instant; re-detections stay quiet
                        for stall in wd.check(now, stall_us) {
                            if stalled_mask[stall.index] {
                                continue;
                            }
                            stalled_mask[stall.index] = true;
                            rec.instant(
                                TRACK_CLIENT,
                                EventKind::Stall,
                                now,
                                0,
                                stall.index as u32,
                                (stall.silent_us / 1_000).min(u32::MAX as u64)
                                    as u32,
                                0,
                            );
                            eprintln!(
                                "[serve] watchdog: {} stalled ({} ms silent)",
                                stall.name,
                                stall.silent_us / 1_000,
                            );
                            stalled_names.push(stall.name);
                        }
                        // flight recorder: the run's FIRST alert fire
                        // or stall dumps one postmortem bundle
                        let firing =
                            slo_rt.as_ref().is_some_and(|rt| rt.any_firing());
                        if !dumped
                            && flight_dir.is_some()
                            && (firing || !stalled_names.is_empty())
                        {
                            dumped = true;
                            let reason = if firing {
                                "slo-fire".to_string()
                            } else {
                                format!("stall-{}", stalled_names[0])
                            };
                            let shards_doc = arr(
                                (0..shard_cells.len())
                                    .map(|sx| {
                                        let g =
                                            shard_cells[sx].lock().unwrap();
                                        let st = caches[sx].stats();
                                        obj(vec![
                                            ("shard", num(sx as f64)),
                                            (
                                                "requests",
                                                num(g.requests as f64),
                                            ),
                                            ("batches", num(g.batches as f64)),
                                            (
                                                "foreign_requests",
                                                num(g.foreign_requests as f64),
                                            ),
                                            (
                                                "queue_depth_max",
                                                num(g.queue_depth_max as f64),
                                            ),
                                            (
                                                "param_version",
                                                num(g.param_version as f64),
                                            ),
                                            ("cache_hits", num(st.hits as f64)),
                                            (
                                                "cache_misses",
                                                num(st.misses as f64),
                                            ),
                                            (
                                                "stale_hits",
                                                num(st.stale_hits as f64),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            );
                            match dump_postmortem(
                                flight_dir.as_ref().unwrap(),
                                &reason,
                                now,
                                rec,
                                series,
                                slo_rt.as_ref(),
                                resolved_cfg.clone(),
                                shards_doc,
                            ) {
                                Ok(p) => {
                                    println!(
                                        "[serve] flight recorder: postmortem \
                                         at {}",
                                        p.display()
                                    );
                                    postmortems.push(p);
                                }
                                Err(e) => eprintln!(
                                    "[serve] flight recorder failed: {e:#}"
                                ),
                            }
                        }
                        next_health = now + health_period_us;
                    }
                    if metrics_on && (now >= next_metrics || stopping) {
                        // ---- metrics tick ----
                        // lock each shard cell once; keep every
                        // family's samples contiguous in the exposition
                        let snaps: Vec<(CacheStats, usize, LogHist)> =
                            (0..shard_cells.len())
                                .map(|sx| {
                                    let g = shard_cells[sx].lock().unwrap();
                                    (
                                        caches[sx].stats(),
                                        g.requests,
                                        g.lat_us.clone(),
                                    )
                                })
                                .collect();
                        let mut p = PromText::new();
                        p.family(
                            "serve_queue_depth",
                            "gauge",
                            "requests waiting in the bounded queue",
                        );
                        p.sample("serve_queue_depth", &[], queue.len() as f64);
                        p.family(
                            "serve_queue_capacity",
                            "gauge",
                            "configured request-queue bound",
                        );
                        p.sample(
                            "serve_queue_capacity",
                            &[],
                            queue.capacity() as f64,
                        );
                        p.family(
                            "serve_shed_total",
                            "counter",
                            "requests shed (admission rejects + drop-tail)",
                        );
                        p.sample(
                            "serve_shed_total",
                            &[],
                            adm.total_shed() as f64,
                        );
                        p.family(
                            "serve_degraded_total",
                            "counter",
                            "requests admitted with degraded fanout",
                        );
                        p.sample(
                            "serve_degraded_total",
                            &[],
                            adm.total_degraded() as f64,
                        );
                        p.family(
                            "serve_requests_total",
                            "counter",
                            "requests completed, per shard",
                        );
                        for (sx, (_, reqs, _)) in snaps.iter().enumerate() {
                            let sl = sx.to_string();
                            p.sample(
                                "serve_requests_total",
                                &[("shard", &sl)],
                                *reqs as f64,
                            );
                        }
                        p.family(
                            "serve_cache_fetches_total",
                            "counter",
                            "feature-cache fetches by outcome, per shard",
                        );
                        for (sx, (cs, _, _)) in snaps.iter().enumerate() {
                            let sl = sx.to_string();
                            for (outcome, v) in [
                                ("hit", cs.hits),
                                ("miss", cs.misses),
                                ("stale", cs.stale_hits),
                            ] {
                                p.sample(
                                    "serve_cache_fetches_total",
                                    &[("shard", &sl), ("outcome", outcome)],
                                    v as f64,
                                );
                            }
                        }
                        p.family(
                            "serve_latency_us",
                            "summary",
                            "completion latency per shard (µs)",
                        );
                        for (sx, (_, _, hist)) in snaps.iter().enumerate() {
                            let sl = sx.to_string();
                            p.summary(
                                "serve_latency_us",
                                &[("shard", &sl)],
                                hist,
                            );
                        }
                        if let Some(profs) = loc_profilers {
                            let mut ls = LocalitySample::default();
                            for pr in profs {
                                ls.merge(&pr.snapshot());
                            }
                            p.family(
                                "serve_locality_accesses_total",
                                "counter",
                                "feature-gather accesses observed by the \
                                 locality profiler",
                            );
                            p.sample(
                                "serve_locality_accesses_total",
                                &[],
                                ls.accesses as f64,
                            );
                            p.family(
                                "serve_locality_sampled_total",
                                "counter",
                                "accesses to SHARDS-sampled nodes",
                            );
                            p.sample(
                                "serve_locality_sampled_total",
                                &[],
                                ls.sampled as f64,
                            );
                            p.family(
                                "serve_locality_mean_reuse_distance",
                                "gauge",
                                "mean estimated reuse distance (cache rows)",
                            );
                            p.sample(
                                "serve_locality_mean_reuse_distance",
                                &[],
                                ls.mean_distance(),
                            );
                            p.family(
                                "serve_locality_self_reuse_frac",
                                "gauge",
                                "fraction of sampled reuses staying in the \
                                 same community",
                            );
                            p.sample(
                                "serve_locality_self_reuse_frac",
                                &[],
                                ls.self_reuse_frac(),
                            );
                            p.family(
                                "serve_locality_reuse_distance",
                                "summary",
                                "estimated reuse-distance distribution \
                                 (rows)",
                            );
                            p.summary(
                                "serve_locality_reuse_distance",
                                &[],
                                &ls.dist,
                            );
                            // keep each family's samples contiguous:
                            // compute the per-shard advice first
                            let advice: Vec<CacheAdvice> = profs
                                .iter()
                                .enumerate()
                                .map(|(sx, pr)| {
                                    mrc::advise(
                                        &pr.snapshot(),
                                        caches[sx].rows() as u64,
                                        caches[sx].stats().hit_rate(),
                                        mrc::DEFAULT_TARGET_HIT_RATE,
                                    )
                                })
                                .collect();
                            p.family(
                                "serve_mrc_predicted_hit_rate",
                                "gauge",
                                "MRC-predicted hit rate at the shard's \
                                 current cache capacity",
                            );
                            for (sx, a) in advice.iter().enumerate() {
                                let sl = sx.to_string();
                                p.sample(
                                    "serve_mrc_predicted_hit_rate",
                                    &[("shard", &sl)],
                                    a.predicted_hit_rate,
                                );
                            }
                            p.family(
                                "serve_mrc_rows_for_target",
                                "gauge",
                                "smallest cache_rows meeting the target \
                                 hit rate (absent when unreachable)",
                            );
                            for (sx, a) in advice.iter().enumerate() {
                                let sl = sx.to_string();
                                if let Some(r) = a.rows_for_target {
                                    p.sample(
                                        "serve_mrc_rows_for_target",
                                        &[("shard", &sl)],
                                        r as f64,
                                    );
                                }
                            }
                        }
                        if let Some(st) = stream {
                            let c = &st.counters;
                            let applied = c.edge_inserts.load(Ordering::Relaxed)
                                + c.edge_deletes.load(Ordering::Relaxed)
                                + c.feature_rewrites.load(Ordering::Relaxed)
                                + c.noop_updates.load(Ordering::Relaxed);
                            p.family(
                                "stream_updates_applied_total",
                                "counter",
                                "graph updates applied (incl. no-ops)",
                            );
                            p.sample(
                                "stream_updates_applied_total",
                                &[],
                                applied as f64,
                            );
                            p.family(
                                "stream_epochs_applied_total",
                                "counter",
                                "mutation epochs applied",
                            );
                            p.sample(
                                "stream_epochs_applied_total",
                                &[],
                                c.epochs_applied.load(Ordering::Relaxed) as f64,
                            );
                            p.family(
                                "stream_full_relabels_total",
                                "counter",
                                "stop-the-world full relabels",
                            );
                            p.sample(
                                "stream_full_relabels_total",
                                &[],
                                c.full_relabels.load(Ordering::Relaxed) as f64,
                            );
                        }
                        if rec.is_enabled() {
                            p.family(
                                "trace_events_dropped_total",
                                "counter",
                                "trace events lost to ring wraparound",
                            );
                            p.sample(
                                "trace_events_dropped_total",
                                &[],
                                rec.total_dropped() as f64,
                            );
                        }
                        if let Some(rt) = slo_rt.as_ref() {
                            rt.export_prom(&mut p);
                        }
                        if let Err(e) = p.write(&path) {
                            // stop snapshotting, but keep the health
                            // layer alive — its state is in-memory
                            eprintln!("[serve] metrics write failed: {e:#}");
                            metrics_on = false;
                        } else {
                            seq += 1;
                            rec.instant(
                                TRACK_CLIENT,
                                EventKind::MetricsFlush,
                                rec.now_us(),
                                0,
                                seq,
                                0,
                                0,
                            );
                            next_metrics = now + metrics_period_us;
                        }
                    }
                    if stopping {
                        break;
                    }
                    // sleep to the earliest due tick in ≤ 20 ms slices
                    // so shutdown flushes promptly
                    let due = match (metrics_on, series.is_some()) {
                        (true, true) => next_metrics.min(next_health),
                        (true, false) => next_metrics,
                        (false, true) => next_health,
                        (false, false) => break,
                    };
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let nowp = clock.now_us();
                        if nowp >= due {
                            break;
                        }
                        std::thread::sleep(Duration::from_micros(
                            (due - nowp).min(20_000),
                        ));
                    }
                }
                hb.retire();
                // hand the health state to the report assembly
                if let Some(series) = series {
                    *health_out.lock().unwrap() =
                        Some((series, slo_rt, stalled_names, postmortems));
                }
            })
        });

        // batcher thread owns every shard sender; workers see their
        // channel close when it exits
        let batcher_handle = {
            let queue = &queue;
            let clock = &clock;
            let labels = &labels;
            let depths = &depths;
            let caps = &caps;
            let rec = &rec;
            let wd = &wd;
            let purity_sum = &purity_sum;
            let purity_batches = &purity_batches;
            scope.spawn(move || {
                let hb = wd.hb(hb_batcher);
                let mut mb = MicroBatcher::new(
                    BatcherConfig {
                        batch_size,
                        max_delay_us: scfg.max_delay_us,
                        community_bias: scfg.community_bias,
                    },
                    scfg.seed,
                );
                // route one formed batch to its shard(s); false once
                // any shard channel has closed. `rr` rotates depth-tie
                // breaks across shards batch by batch. Each batch is
                // grouped AND routed under one label snapshot.
                let mut rr = 0usize;
                let mut send_routed =
                    |b: Vec<Request>, snap: &LabelSnapshot| -> bool {
                        // purity is computed once per routed batch and
                        // feeds both the coalesce span and the health
                        // layer's windowed purity accumulators
                        if (rec.is_enabled() || health_on) && !b.is_empty() {
                            let (purity, comms) =
                                batch_purity(&b, &snap.labels);
                            purity_sum
                                .fetch_add(purity as u64, Ordering::Relaxed);
                            purity_batches.fetch_add(1, Ordering::Relaxed);
                            // coalesce span: the batch's life from its
                            // earliest arrival to routing, tagged with
                            // the community-purity counters the
                            // paper's p-knob trades against
                            if rec.is_enabled() {
                                let ts = b
                                    .iter()
                                    .map(|r| r.arrive_us)
                                    .min()
                                    .unwrap_or(0);
                                let req = b
                                    .iter()
                                    .find(|r| rec.traced(r.id))
                                    .map(|r| r.id)
                                    .unwrap_or(0);
                                rec.span(
                                    TRACK_BATCHER,
                                    EventKind::Coalesce,
                                    ts,
                                    clock.now_us().saturating_sub(ts),
                                    req,
                                    b.len() as u32,
                                    purity,
                                    comms,
                                );
                            }
                        }
                        let snapshot: Vec<usize> = depths
                            .iter()
                            .map(|d| d.load(Ordering::Relaxed))
                            .collect();
                        let routed = route_batch(
                            snap, scfg.spill, &snapshot, caps, rr, b,
                        );
                        rr = rr.wrapping_add(1);
                        for (sid, sub) in routed {
                            depths[sid].fetch_add(1, Ordering::Relaxed);
                            if txs[sid].send(sub).is_err() {
                                return false;
                            }
                        }
                        true
                    };
                'run: loop {
                    hb.busy(clock.now_us());
                    let snap = labels.snapshot();
                    if let Some(b) = mb.poll(clock.now_us(), &snap.labels) {
                        if !send_routed(b, &snap) {
                            break 'run;
                        }
                        continue;
                    }
                    let wait_us = match mb.next_flush_us() {
                        Some(t) => t.saturating_sub(clock.now_us()).clamp(50, 20_000),
                        None => 20_000,
                    };
                    match queue.pop_timeout(Duration::from_micros(wait_us)) {
                        Pop::Item(r) => {
                            mb.push(r);
                            // opportunistically drain whatever is ready
                            while mb.len() < batch_size {
                                match queue.try_pop() {
                                    Some(r2) => mb.push(r2),
                                    None => break,
                                }
                            }
                        }
                        Pop::TimedOut => {}
                        Pop::Closed => {
                            // drain: everything is overdue at t = ∞
                            let snap = labels.snapshot();
                            while let Some(b) = mb.poll(u64::MAX, &snap.labels)
                            {
                                if !send_routed(b, &snap) {
                                    break 'run;
                                }
                            }
                            break 'run;
                        }
                    }
                }
                hb.retire();
            })
        };

        // per-shard worker pools, each against its shard's cache
        let mut worker_handles = Vec::new();
        let mut widx = 0u64;
        for sidx in 0..n_shards {
            for _ in 0..shard_workers[sidx] {
                let ctx = WorkerCtx {
                    ds,
                    meta,
                    cache: &caches[sidx],
                    exec,
                    clock: &clock,
                    stream: stream.as_ref(),
                    rec: &rec,
                    track: shard_track(sidx),
                    sampler: scfg.sampler,
                    sample_p: scfg.sample_p,
                    hb: Some(wd.hb(hb_workers[widx as usize])),
                    locality: loc_profilers.as_ref().map(|v| &v[sidx]),
                };
                let rx = &rxs[sidx];
                let cell = &shard_cells[sidx];
                let depth = &depths[sidx];
                let labels = &labels;
                let adm = &adm;
                let seed = scfg.seed ^ widx.wrapping_mul(0x9E37_79B9_7F4A_7C15);
                widx += 1;
                worker_handles.push(scope.spawn(move || {
                    let mut rng = Rng::new(seed ^ 0x5EBF_11);
                    shard_worker_loop(
                        &ctx, sidx, labels, rx, depth, cell, adm, &mut rng,
                    );
                }));
            }
        }

        // load generators: closed-loop clients block on their replies;
        // open-loop clients share one reply channel drained by a
        // collector thread
        let mut client_handles = Vec::new();
        let mut collector_handle = None;
        let cctx = &cctx;
        match lcfg.arrival {
            Arrival::Closed => {
                for c in 0..lcfg.clients.max(1) {
                    client_handles.push(scope.spawn(move || {
                        loadgen::client_loop(c as u64, cctx);
                    }));
                }
            }
            Arrival::Poisson { rate_rps } => {
                let (rtx, rrx) = std::sync::mpsc::channel::<Reply>();
                let records = &records;
                let deadline_us = scfg.deadline_us;
                collector_handle = Some(scope.spawn(move || {
                    loadgen::collector_loop(rrx, deadline_us, records);
                }));
                let clients = lcfg.clients.max(1);
                let per_client = rate_rps / clients as f64;
                for c in 0..clients {
                    let rtx = rtx.clone();
                    client_handles.push(scope.spawn(move || {
                        loadgen::open_loop_client(
                            c as u64, cctx, per_client, rtx,
                        );
                    }));
                }
                // the collector exits once every clone (clients +
                // in-flight requests) is gone
                drop(rtx);
            }
        }

        for h in client_handles {
            let _ = h.join();
        }
        // open loop: wait until every in-flight request has replied
        if let Some(h) = collector_handle {
            let _ = h.join();
        }
        // bounded-timeout joins for everything downstream of the load:
        // a thread that overruns the bound is *reported* (by name, in
        // `ServeReport::unjoined_threads`) and then joined blocking —
        // scoped threads must join, so the bound detects a wedged
        // shutdown rather than leaking it silently.
        let join_bound = Duration::from_secs(5);
        let mut unjoined: Vec<String> = Vec::new();
        let mut join_bounded =
            |name: &str, h: std::thread::ScopedJoinHandle<'_, ()>| {
                let t0 = Instant::now();
                while !h.is_finished() && t0.elapsed() < join_bound {
                    std::thread::sleep(Duration::from_millis(2));
                }
                if !h.is_finished() {
                    eprintln!(
                        "[serve] warning: {name} thread still running \
                         {join_bound:?} after shutdown; waiting"
                    );
                    unjoined.push(name.to_string());
                }
                let _ = h.join();
            };
        // the load is answered: stop mutating, then shut down
        churn_stop.store(true, Ordering::Relaxed);
        if let Some(h) = churn_handle {
            join_bounded("churn", h);
        }
        queue.close();
        join_bounded("batcher", batcher_handle);
        for (i, h) in worker_handles.into_iter().enumerate() {
            join_bounded(wd.name(hb_workers[i]), h);
        }
        watch_stop.store(true, Ordering::Relaxed);
        if let Some(h) = watcher_handle {
            join_bounded("ckpt-watcher", h);
        }
        // final metrics snapshot + health window cover the drained run
        metrics_stop.store(true, Ordering::Relaxed);
        if let Some(h) = telemetry_handle {
            join_bounded("telemetry", h);
        }
        unjoined
    });

    let wall_s = clock.now_us() as f64 / 1e6;
    let records = records.into_inner().unwrap();

    // the telemetry thread left its windowed series + alert state in
    // the hand-off cell; fold it into the report's health section
    let health = health_out.into_inner().unwrap().map(
        |(series, slo_rt, stalled, postmortems)| {
            let alerts = slo_rt
                .as_ref()
                .map(|rt| {
                    rt.states()
                        .iter()
                        .map(|st| HealthAlert {
                            slo: st.target.kind.label().to_string(),
                            threshold: st.target.threshold,
                            firing: st.firing,
                            fired: st.fired,
                            cleared: st.cleared,
                            first_breach_us: st.first_breach_us,
                            first_fire_us: st.first_fire_us,
                            burn_fast: st.burn_fast,
                            burn_slow: st.burn_slow,
                        })
                        .collect()
                })
                .unwrap_or_default();
            HealthReport {
                window_ms: scfg.health_ms,
                windows_sealed: series.sealed(),
                alerts,
                transitions: slo_rt
                    .as_ref()
                    .map_or(0, |rt| rt.transitions().len()),
                stalled_threads: stalled,
                postmortems,
            }
        },
    );

    // Chrome-trace export (trace=PATH): one JSON the `chrome://tracing`
    // or Perfetto UI loads directly, one track per shard plus the
    // batcher / churn-maintainer / ckpt-watcher / client tracks
    if let Some(path) = &scfg.trace {
        let sum = write_chrome_trace(path, &rec).with_context(|| {
            format!("exporting chrome trace to {}", path.display())
        })?;
        println!(
            "[serve] trace: {} spans + {} instants -> {} \
             ({} events dropped to ring wraparound)",
            sum.spans,
            sum.instants,
            path.display(),
            sum.dropped,
        );
    }

    // roll per-shard cells + caches + admission counters up into shard
    // reports and totals; ownership columns reflect the FINAL label
    // snapshot (relabels move them during streaming runs)
    let final_snap = labels.snapshot();
    let stream_report = stream.as_ref().map(|st| st.report(&labels));
    let mut shard_reports = Vec::with_capacity(n_shards);
    let mut cache_stats = CacheStats::default();
    let mut stats_batches = 0usize;
    let mut stats_requests = 0usize;
    let mut stats_input_nodes = 0usize;
    let mut stats_frontier_refs = 0u64;
    let mut exec_f32 = ExecCell::default();
    let mut exec_i16 = ExecCell::default();
    for (sidx, cell) in shard_cells.into_iter().enumerate() {
        let cell = cell.into_inner().unwrap();
        exec_f32.merge(&cell.exec_f32);
        exec_i16.merge(&cell.exec_i16);
        let cstats = caches[sidx].stats();
        cache_stats.hits += cstats.hits;
        cache_stats.misses += cstats.misses;
        cache_stats.stale_hits += cstats.stale_hits;
        cache_stats.lookups += cstats.lookups;
        stats_batches += cell.batches;
        stats_requests += cell.requests;
        stats_input_nodes += cell.input_nodes;
        stats_frontier_refs += cell.frontier_refs;
        shard_reports.push(ShardReport::from_cell(
            sidx,
            &final_snap.plan,
            &cell,
            cstats,
            &adm,
        ));
    }

    // locality observatory: merge the per-shard profiles, derive the
    // run-level MRC and per-shard right-sizing advice, and cross-check
    // the prediction against the live caches' own counters
    let locality = loc_profilers.as_ref().map(|profs| {
        let mut merged = LocalitySample::default();
        for pr in profs {
            merged.merge(&pr.snapshot());
        }
        let mut advice = Vec::with_capacity(profs.len());
        let (mut pred_w, mut lookups_w) = (0.0f64, 0u64);
        for (sidx, pr) in profs.iter().enumerate() {
            let st = caches[sidx].stats();
            let a = mrc::advise(
                &pr.snapshot(),
                caches[sidx].rows() as u64,
                st.hit_rate(),
                mrc::DEFAULT_TARGET_HIT_RATE,
            );
            pred_w += a.predicted_hit_rate * st.lookups as f64;
            lookups_w += st.lookups;
            advice.push(ShardAdvice { shard: sidx, advice: a });
        }
        // curve span: past the current capacity and past the longest
        // observed distance, so the knee is always on the plot
        let max_rows = (4 * rows_per_shard as u64)
            .max(merged.dist.max().saturating_add(1));
        LocalityReport {
            sample_permille: profs
                .first()
                .map(|p| p.sample_permille())
                .unwrap_or(1000),
            accesses: merged.accesses,
            sampled: merged.sampled,
            reuses: merged.reuses(),
            cold: merged.cold,
            mean_reuse_distance: merged.mean_distance(),
            p95_reuse_distance: merged.dist.quantile(0.95),
            self_reuse_frac: merged.self_reuse_frac(),
            mrc: mrc::curve(&merged, scfg.mrc_points, max_rows),
            advice,
            predicted_hit_rate: if lookups_w == 0 {
                0.0
            } else {
                pred_w / lookups_w as f64
            },
            observed_hit_rate: cache_stats.hit_rate(),
        }
    });

    // errored requests count toward errors/deadlines, not latency
    // percentiles (their latency reflects the failure, not serving).
    // Quantiles come from the same log-bucket histogram family the
    // per-shard reports and the metrics snapshot use, so no two
    // surfaces of the same run can disagree about p50/p99.
    let mut lat_hist = LogHist::new();
    for r in records.iter().filter(|r| !r.error) {
        lat_hist.record(r.latency_us);
    }
    let misses = records.iter().filter(|r| r.deadline_missed).count();
    let errors = records.iter().filter(|r| r.error).count();
    let evaluated = records.iter().filter(|r| r.evaluated).count();
    let correct = records.iter().filter(|r| r.correct).count();
    let n = records.len();
    let shed = adm.total_shed();
    let nb = stats_batches.max(1);
    let param_version =
        shard_reports.iter().map(|sh| sh.param_version).max().unwrap_or(0);
    let swaps: usize = shard_reports.iter().map(|sh| sh.swaps).sum();
    // LogHist quantiles are 0 on empty input, so empty runs still
    // produce a finite, parseable report
    let pct = |q: f64| lat_hist.quantile(q) as f64 / 1e3;
    Ok(ServeReport {
        dataset: ds.name.clone(),
        executor: exec.name().to_string(),
        sampler: scfg.sampler.name().to_string(),
        community_bias: scfg.community_bias,
        arrival: lcfg.arrival.label(),
        admission: scfg.admission.name().to_string(),
        offered_rps: lcfg.arrival.offered_rps().unwrap_or(0.0),
        requests: n,
        errors,
        shed,
        shed_rate: shed as f64 / (n + shed).max(1) as f64,
        degraded: adm.total_degraded(),
        evaluated,
        accuracy: correct as f64 / evaluated.max(1) as f64,
        param_version,
        swaps,
        wall_s,
        throughput_rps: n as f64 / wall_s.max(1e-9),
        lat_mean_ms: lat_hist.mean() / 1e3,
        lat_p50_ms: pct(0.5),
        lat_p95_ms: pct(0.95),
        lat_p99_ms: pct(0.99),
        lat_max_ms: lat_hist.max() as f64 / 1e3,
        deadline_miss_frac: misses as f64 / n.max(1) as f64,
        batches: stats_batches,
        mean_batch_size: stats_requests as f64 / nb as f64,
        mean_input_nodes: stats_input_nodes as f64 / nb as f64,
        frontier_refs: stats_frontier_refs,
        dedup_factor: if stats_input_nodes == 0 {
            1.0
        } else {
            stats_frontier_refs as f64 / stats_input_nodes as f64
        },
        gather_bytes: stats_input_nodes as u64 * ds.feat_dim as u64 * 4,
        cache_hits: cache_stats.hits,
        cache_misses: cache_stats.misses,
        stale_hits: cache_stats.stale_hits,
        cache_lookups: cache_stats.lookups,
        cache_hit_rate: cache_stats.hit_rate(),
        cache_rows: caches.iter().map(|c| c.rows()).sum(),
        n_shards,
        spill: scfg.spill.name().to_string(),
        execute: [exec_f32.report("f32"), exec_i16.report("i16q")]
            .into_iter()
            .flatten()
            .collect(),
        shards: shard_reports,
        stream: stream_report,
        health,
        locality,
        unjoined_threads: unjoined,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::serve::worker::NullExecutor;

    fn tiny() -> Dataset {
        crate::train::dataset::build(&preset("tiny").unwrap(), true)
    }

    fn closed(clients: usize, per: usize, seed: u64) -> LoadConfig {
        LoadConfig {
            clients,
            requests_per_client: per,
            zipf_s: 1.1,
            arrival: Arrival::Closed,
            seed,
        }
    }

    #[test]
    fn serve_bench_end_to_end_without_artifacts() {
        let ds = tiny();
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 16;
        scfg.max_delay_us = 1_000;
        scfg.deadline_us = 200_000;
        scfg.community_bias = 1.0;
        scfg.workers = 2;
        scfg.fanouts = vec![5, 5];
        scfg.seed = 7;
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = closed(4, 25, 3);
        let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        assert_eq!(rep.requests, 100, "closed loop must answer every request");
        assert_eq!(rep.errors, 0);
        // admission=none: nothing shed, nothing degraded
        assert_eq!(rep.shed, 0);
        assert_eq!(rep.degraded, 0);
        assert_eq!(rep.arrival, "closed");
        assert_eq!(rep.admission, "none");
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.lat_p50_ms <= rep.lat_p99_ms);
        assert!(rep.lat_p99_ms.is_finite());
        assert!(rep.batches >= 1);
        assert!(rep.cache_hits + rep.cache_misses > 0, "cache not exercised");
        assert!((0.0..=1.0).contains(&rep.cache_hit_rate));
        // single-device = one shard owning everything, nothing foreign
        assert_eq!(rep.n_shards, 1);
        assert_eq!(rep.shards.len(), 1);
        assert_eq!(rep.shards[0].owned_nodes, ds.n());
        assert_eq!(rep.foreign_requests(), 0);
        // workers fed the admission EWMA even under admission=none
        assert!(rep.shards[0].est_service_us > 0.0);
        // dedup accounting: refs ≥ unique always, so the factor is ≥ 1
        assert!(rep.frontier_refs >= 1);
        assert!(rep.dedup_factor >= 1.0);
        assert_eq!(rep.sampler, "uniform");
        // gather bytes = unique inputs × feat_dim × 4, so whole rows
        assert!(rep.gather_bytes > 0);
        assert_eq!(rep.gather_bytes % (ds.feat_dim as u64 * 4), 0);
        // report serializes
        let j = rep.to_json().to_string_pretty();
        assert!(j.contains("throughput_rps"));
        assert!(j.contains("n_shards"));
        assert!(j.contains("foreign_requests"));
        assert!(j.contains("shed_rate"));
        assert!(j.contains("arrival"));
        assert!(j.contains("dedup_factor"));
        assert!(j.contains("gather_bytes"));
        assert!(j.contains("\"sampler\""));
    }

    /// The sampler knob sweeps cleanly end to end: every mode answers
    /// every request and keeps the dedup accounting consistent. (The
    /// labor-vs-uniform gather-byte comparison is deterministic only at
    /// the sampler layer — see labor.rs — and is gated end-to-end by
    /// `exp coop`, which averages over trials.)
    #[test]
    fn sampler_knob_sweeps_cleanly() {
        let ds = tiny();
        let meta = synthetic_infer_meta(&ds, 16, &[8, 8]);
        let exec = NullExecutor { num_classes: ds.num_classes };
        for sampler in
            [SamplerKind::Uniform, SamplerKind::Biased, SamplerKind::Labor]
        {
            let mut scfg = ServeConfig::for_dataset(&ds);
            scfg.batch_size = 16;
            scfg.max_delay_us = 2_000;
            scfg.community_bias = 0.9;
            scfg.workers = 1;
            scfg.fanouts = vec![8, 8];
            scfg.sampler = sampler;
            scfg.sample_p = 0.9;
            scfg.seed = 13;
            let lcfg = closed(8, 30, 5);
            let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
            assert_eq!(rep.requests, 240, "sampler={}", sampler.name());
            assert_eq!(rep.errors, 0, "sampler={}", sampler.name());
            assert_eq!(rep.sampler, sampler.name());
            assert!(rep.dedup_factor >= 1.0);
            assert!(rep.frontier_refs > 0);
        }
    }

    // NOTE: the strict-spill affinity acceptance check (2/4 shards,
    // zero foreign requests, per-shard accounting sums) lives in
    // rust/tests/serve_shard.rs, and the admission/open-loop
    // saturation checks in rust/tests/serve_admission.rs — not
    // duplicated here.

    #[test]
    fn spill_policies_run_end_to_end() {
        let ds = tiny();
        let meta = synthetic_infer_meta(&ds, 8, &[5, 5]);
        let exec = NullExecutor { num_classes: ds.num_classes };
        for spill in
            [SpillPolicy::Strict, SpillPolicy::Steal, SpillPolicy::Broadcast]
        {
            let mut scfg = ServeConfig::for_dataset(&ds);
            scfg.batch_size = 8;
            scfg.community_bias = 0.5;
            scfg.workers = 2;
            scfg.shards = 2;
            scfg.spill = spill;
            scfg.fanouts = vec![5, 5];
            let lcfg = closed(2, 20, 11);
            let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
            assert_eq!(rep.requests, 40, "spill={}", spill.name());
            assert_eq!(rep.errors, 0, "spill={}", spill.name());
            assert_eq!(rep.spill, spill.name());
            if spill == SpillPolicy::Strict {
                assert_eq!(rep.foreign_requests(), 0);
            }
        }
    }

    #[test]
    fn community_knob_sweeps_cleanly() {
        let ds = tiny();
        let meta = synthetic_infer_meta(&ds, 8, &[5, 5]);
        let exec = NullExecutor { num_classes: ds.num_classes };
        for p in [0.0, 0.5, 1.0] {
            let mut scfg = ServeConfig::for_dataset(&ds);
            scfg.batch_size = 8;
            scfg.community_bias = p;
            scfg.workers = 1;
            scfg.fanouts = vec![5, 5];
            let lcfg = closed(2, 20, 11);
            let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
            assert_eq!(rep.requests, 40, "p={p}");
            assert_eq!(rep.errors, 0, "p={p}");
        }
    }

    /// Open-loop Poisson run at an easily-sustainable rate: every
    /// issued request is either completed or shed (none lost), and the
    /// report labels the arrival discipline and offered rate.
    #[test]
    fn open_loop_poisson_accounts_for_every_request() {
        let ds = tiny();
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 16;
        scfg.max_delay_us = 500;
        scfg.deadline_us = 500_000;
        scfg.workers = 2;
        scfg.fanouts = vec![5, 5];
        scfg.admission = AdmissionPolicy::Reject;
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = LoadConfig {
            clients: 4,
            requests_per_client: 30,
            zipf_s: 1.1,
            arrival: Arrival::Poisson { rate_rps: 4_000.0 },
            seed: 5,
        };
        let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        assert_eq!(
            rep.requests + rep.shed,
            120,
            "open loop must account for every issued request"
        );
        assert_eq!(rep.errors, 0);
        assert_eq!(rep.arrival, "poisson:4000");
        assert_eq!(rep.admission, "reject");
        assert!((rep.offered_rps - 4_000.0).abs() < 1e-9);
        assert!((0.0..=1.0).contains(&rep.shed_rate));
        if rep.requests > 0 {
            assert!(rep.lat_p99_ms.is_finite());
        }
    }

    /// `degrade` admission in a closed loop still answers everything:
    /// degraded requests produce (cheaper) replies, never errors.
    #[test]
    fn degrade_admission_answers_every_request() {
        let ds = tiny();
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 8;
        scfg.workers = 1;
        // deadline so tight that, once the EWMA warms up, requests
        // get degraded rather than processed at full fanout
        scfg.deadline_us = 300;
        scfg.max_delay_us = 100;
        scfg.fanouts = vec![5, 5];
        scfg.admission = AdmissionPolicy::Degrade;
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = closed(2, 30, 13);
        let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        // degrade never sheds: every request is answered
        assert_eq!(rep.requests, 60);
        assert_eq!(rep.shed, 0);
        assert_eq!(rep.errors, 0);
        assert_eq!(rep.admission, "degrade");
    }

    /// The host reference executor end to end: every completed request
    /// carries real logits (evaluated == requests), accuracy is a
    /// well-formed fraction, and with no checkpoint loaded the served
    /// parameter version stays 0.
    #[test]
    fn host_executor_reports_real_accuracy() {
        let ds = tiny();
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 16;
        scfg.workers = 2;
        scfg.fanouts = vec![5, 5];
        scfg.cache_warm = true;
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = super::super::worker::HostExecutor::new(&ds, 0).unwrap();
        let lcfg = closed(4, 25, 3);
        let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        assert_eq!(rep.requests, 100);
        assert_eq!(rep.errors, 0);
        assert_eq!(rep.executor, "host");
        // seed parameters are f32: the execute breakdown must show
        // exactly one dtype covering every batch
        assert_eq!(rep.execute.len(), 1);
        assert_eq!(rep.execute[0].dtype, "f32");
        assert_eq!(rep.execute[0].batches as usize, rep.batches);
        assert_eq!(
            rep.evaluated, 100,
            "host executor must produce logits for every reply"
        );
        assert!((0.0..=1.0).contains(&rep.accuracy));
        assert_eq!(rep.param_version, 0, "no checkpoint loaded");
        assert_eq!(rep.swaps, 0);
        let j = rep.to_json().to_string_pretty();
        assert!(j.contains("accuracy"));
        assert!(j.contains("param_version"));
    }

    /// `ckpt=` pointing at the no-op executor is a startup error, not a
    /// silent seed-accuracy run.
    #[test]
    fn null_executor_with_ckpt_errors_at_startup() {
        use crate::ckpt::{Checkpoint, CkptMeta};
        let ds = tiny();
        let dir = std::env::temp_dir()
            .join(format!("comm_rand_engine_ck_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let meta_ck = CkptMeta::for_run(
            &ds,
            "host-sgc",
            "t",
            0,
            crate::runtime::host::param_shapes(ds.feat_dim, ds.num_classes),
        );
        let params = crate::runtime::host::init_params(
            ds.feat_dim,
            ds.num_classes,
            1,
        );
        let file = dir.join("ckpt-e00000.bin");
        Checkpoint::new(meta_ck, params).unwrap().write_atomic(&file).unwrap();

        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.fanouts = vec![5, 5];
        scfg.ckpt = Some(file);
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = closed(1, 5, 3);
        let err = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap_err();
        assert!(
            format!("{err:#}").contains("install"),
            "expected install failure, got: {err:#}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Streaming churn (`mutate=`) end to end on the no-op executor:
    /// every request answered, no errors, the stream section reports
    /// applied epochs, and the stale-hit accounting invariant holds.
    #[test]
    fn streaming_churn_serves_without_errors() {
        let ds = tiny();
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 16;
        scfg.max_delay_us = 1_000;
        scfg.deadline_us = 500_000;
        scfg.workers = 2;
        scfg.fanouts = vec![5, 5];
        scfg.seed = 11;
        scfg.mutate_rps = 20_000.0;
        scfg.mutate_epoch = 32;
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = closed(4, 50, 3);
        let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        assert_eq!(rep.requests, 200, "closed loop answers everything");
        assert_eq!(rep.errors, 0);
        let st = rep.stream.as_ref().expect("mutate>0 must report stream");
        assert!(st.updates_ingested > 0, "churn generator never ran");
        assert!(st.epochs >= 1, "no update epoch applied");
        assert_eq!(
            st.edge_inserts
                + st.edge_deletes
                + st.feature_rewrites
                + st.noop_updates,
            st.updates_ingested as usize,
            "every ingested update must be accounted for"
        );
        // the stale-hit accounting invariant, rollup and per shard
        assert_eq!(
            rep.cache_lookups,
            rep.cache_hits + rep.cache_misses + rep.stale_hits
        );
        for sh in &rep.shards {
            assert_eq!(
                sh.cache_lookups,
                sh.cache_hits + sh.cache_misses + sh.stale_hits,
                "shard {}",
                sh.id
            );
        }
        let j = rep.to_json().to_string_pretty();
        assert!(j.contains("mutate_ups"));
        assert!(j.contains("stale_hits"));
    }

    /// A frozen-graph run reports no stream section and can never see
    /// a stale hit.
    #[test]
    fn frozen_run_has_no_stream_section() {
        let ds = tiny();
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 8;
        scfg.workers = 1;
        scfg.fanouts = vec![5, 5];
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = closed(2, 10, 7);
        let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        assert!(rep.stream.is_none());
        assert_eq!(rep.stale_hits, 0);
        assert_eq!(
            rep.cache_lookups,
            rep.cache_hits + rep.cache_misses
        );
        let j = rep.to_json().to_string_pretty();
        assert!(j.contains("\"stream\": null"));
    }

    /// Full-rate tracing + live metrics end to end: the exported
    /// Chrome trace parses, carries every pipeline stage by name, and
    /// the metrics snapshot exposes the shared latency summary.
    #[test]
    fn tracing_run_exports_chrome_trace_and_metrics() {
        let ds = tiny();
        let dir = std::env::temp_dir()
            .join(format!("comm_rand_engine_trace_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let trace_path = dir.join("serve_trace.json");
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 8;
        scfg.workers = 2;
        scfg.shards = 2;
        scfg.fanouts = vec![5, 5];
        scfg.trace = Some(trace_path.clone());
        scfg.trace_sample = 1000;
        scfg.metrics_ms = 5;
        scfg.metrics_path = dir.join("serve_metrics.prom");
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = closed(2, 20, 3);
        let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        assert_eq!(rep.requests, 40);
        assert_eq!(rep.errors, 0);

        let j = Json::parse_file(&trace_path).unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
        assert!(!evs.is_empty(), "trace exported no events");
        let has = |name: &str| {
            evs.iter().any(|e| {
                e.get("name").ok().and_then(|n| n.as_str().ok()) == Some(name)
            })
        };
        // every traced request walks the full pipeline at permille 1000
        for name in
            ["enqueue", "queue_wait", "coalesce", "sample", "gather",
             "execute", "reply", "metrics_flush"]
        {
            assert!(has(name), "trace is missing {name:?} events");
        }

        let prom =
            std::fs::read_to_string(dir.join("serve_metrics.prom")).unwrap();
        assert!(prom.contains("serve_latency_us"), "missing latency summary");
        assert!(prom.contains("serve_queue_depth"));
        assert!(prom.contains("serve_cache_fetches_total"));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The health layer end to end on a healthy closed-loop run: the
    /// report carries a health section with sealed windows, generous
    /// SLO targets never fire (zero steady-state false positives), no
    /// thread stalls, and every auxiliary thread joins within the
    /// bound.
    #[test]
    fn health_layer_reports_clean_run() {
        let ds = tiny();
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 16;
        scfg.workers = 2;
        scfg.fanouts = vec![5, 5];
        scfg.deadline_us = 500_000;
        scfg.health_ms = 5;
        scfg.slo =
            Some(SloSpec::parse("p99_ms=5000,shed=0.5,fast=1,slow=3").unwrap());
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = closed(4, 50, 3);
        let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        assert_eq!(rep.requests, 200);
        assert_eq!(rep.errors, 0);
        let h = rep.health.as_ref().expect("health_ms>0 must report health");
        assert!(h.windows_sealed >= 1, "final tick must seal a window");
        assert_eq!(h.alerts.len(), 2, "one alert state per SLO target");
        assert!(
            h.alerts.iter().all(|a| !a.firing && a.fired == 0),
            "healthy run must not alert: {:?}",
            h.alerts
        );
        assert_eq!(h.transitions, 0);
        assert!(h.stalled_threads.is_empty());
        assert!(h.postmortems.is_empty());
        assert!(rep.unjoined_threads.is_empty());
        let j = rep.to_json().to_string_pretty();
        assert!(j.contains("\"health\""));
        assert!(j.contains("windows_sealed"));
        assert!(j.contains("first_breach_us"));
    }

    /// `health_ms=0` keeps the report's health section null and the
    /// run identical to the pre-health engine.
    #[test]
    fn health_disabled_reports_null_section() {
        let ds = tiny();
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 8;
        scfg.workers = 1;
        scfg.fanouts = vec![5, 5];
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = closed(2, 10, 7);
        let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        assert!(rep.health.is_none());
        assert!(rep.unjoined_threads.is_empty());
        let j = rep.to_json().to_string_pretty();
        assert!(j.contains("\"health\": null"));
    }

    /// `locality=1` end to end: the report carries a locality section
    /// whose accounting is internally consistent — accesses cover
    /// every gather lookup, the MRC is monotone non-increasing in
    /// capacity, one advice entry per shard, and the advisor's
    /// predicted hit rate is a real probability next to the observed
    /// one.
    #[test]
    fn locality_observatory_reports_consistent_profile() {
        let ds = tiny();
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 16;
        scfg.workers = 2;
        scfg.fanouts = vec![5, 5];
        scfg.deadline_us = 500_000;
        scfg.community_bias = 1.0;
        scfg.locality = true;
        scfg.mrc_points = 12;
        scfg.seed = 11;
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = closed(4, 40, 9);
        let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        assert_eq!(rep.requests, 160);
        assert_eq!(rep.errors, 0);
        let loc = rep.locality.as_ref().expect("locality=1 must report");
        assert_eq!(loc.sample_permille, 1000);
        // permille=1000 profiles every gather lookup, so the profiler's
        // access count must equal the cache's lookup count exactly.
        assert_eq!(loc.accesses, rep.cache_hits + rep.cache_misses);
        assert_eq!(loc.sampled, loc.accesses);
        assert_eq!(loc.reuses + loc.cold, loc.sampled);
        assert!(loc.reuses > 0, "closed-loop reuse must be observed");
        assert!(loc.mean_reuse_distance > 0.0);
        assert!(loc.p95_reuse_distance > 0);
        assert!((0.0..=1.0).contains(&loc.self_reuse_frac));
        // MRC: non-empty, capacities increasing, miss ratio monotone
        // non-increasing (more cache never predicts more misses).
        assert!(!loc.mrc.is_empty());
        for w in loc.mrc.windows(2) {
            assert!(w[0].capacity_rows < w[1].capacity_rows);
            assert!(
                w[1].miss_ratio <= w[0].miss_ratio + 1e-12,
                "MRC must be monotone: {:?} -> {:?}",
                w[0],
                w[1]
            );
        }
        assert_eq!(loc.advice.len(), rep.n_shards);
        for a in &loc.advice {
            assert!((0.0..=1.0).contains(&a.advice.predicted_hit_rate));
            assert!((0.0..=1.0).contains(&a.advice.observed_hit_rate));
            assert!(a.advice.rows_now > 0);
        }
        assert!((0.0..=1.0).contains(&loc.predicted_hit_rate));
        assert!((loc.observed_hit_rate - rep.cache_hit_rate).abs() < 1e-9);
        let j = rep.to_json().to_string_pretty();
        assert!(j.contains("\"locality\""));
        assert!(j.contains("mean_reuse_distance"));
        assert!(j.contains("rows_for_target"));
        let s = rep.summary();
        assert!(s.contains("locality dist"), "summary tail missing: {s}");
    }

    /// The default run keeps the locality section null: the profiler
    /// is never constructed and the report serializes `"locality":
    /// null`, matching the health layer's off-by-default contract.
    #[test]
    fn locality_disabled_reports_null_section() {
        let ds = tiny();
        let mut scfg = ServeConfig::for_dataset(&ds);
        scfg.batch_size = 8;
        scfg.workers = 1;
        scfg.fanouts = vec![5, 5];
        let meta = synthetic_infer_meta(&ds, scfg.batch_size, &scfg.fanouts);
        let exec = NullExecutor { num_classes: ds.num_classes };
        let lcfg = closed(2, 10, 7);
        let rep = run(&ds, &meta, &exec, &scfg, &lcfg).unwrap();
        assert!(rep.locality.is_none());
        let j = rep.to_json().to_string_pretty();
        assert!(j.contains("\"locality\": null"));
        assert!(!rep.summary().contains("locality dist"));
    }

    #[test]
    fn synthetic_meta_caps_bound_mfg_levels() {
        let ds = tiny();
        let meta = synthetic_infer_meta(&ds, 32, &[10, 10]);
        let caps = &meta.spec.node_caps;
        assert_eq!(caps.len(), 3);
        assert_eq!(caps[2], 32);
        assert!(caps[1] >= 32 && caps[0] >= caps[1].min(ds.n()));
        // worst case: batch * (fanout+1) per hop, clamped to |V|
        assert_eq!(caps[1], (32 * 11).min(ds.n()));
    }
}
