//! Functional sharded feature cache for the serving hot path.
//!
//! Unlike the statistics-only cache models in [`crate::cachesim`], this
//! cache really stores feature rows: a hit copies the row out of the
//! cache slab instead of reading the (large, cold) feature table. The
//! set-associative true-LRU bookkeeping is the same
//! [`SetAssocCore`](crate::cachesim::SetAssocCore) that backs the L2
//! model — promoted here from simulator to data structure by attaching
//! a payload slab indexed by the core's slot ids.
//!
//! Sharding: node id → shard (round-robin by id, so community-ordered
//! ids spread evenly), one mutex per shard, `Arc`-shareable across the
//! worker pool. Hit/miss counters live with each shard and aggregate
//! into [`CacheStats`].
//!
//! **Versioned rows** (streaming mutation support): every cached slot
//! remembers the *feature version* it was staged at. A probe that
//! finds the node but at an older version is a **stale hit** — counted
//! separately (`stale_hits`) and served like a miss (the fresh row is
//! installed and copied through), so a feature rewrite invalidates
//! every cached copy without touching the cache. The accounting
//! invariant `hits + misses + stale_hits == lookups` holds per shard
//! and in aggregate (`lookups` is counted independently at fetch
//! entry, so the invariant is a real cross-check, not a tautology).
//! Frozen-table callers use version 0 everywhere and can never see a
//! stale hit.
//!
//! The per-fetch outcome ([`Fetched`], or [`ShardedFeatureCache::fetch`]'s
//! bool on the frozen path) is what the worker's trace instrumentation
//! tallies into the `Gather` span's hit/miss/stale args — per
//! micro-batch, on the same definitions as the aggregate [`CacheStats`],
//! so a Perfetto trace and the end-of-run report can be cross-checked
//! span by span (see [`crate::obs`]).
//!
//! **Cross-request dedup happens upstream.** The worker fetches the
//! merged MFG's *unique* input frontier — one lookup per distinct node
//! per micro-batch, no matter how many co-batched requests reference
//! it — so `lookups` counts deduplicated fetches. The references that
//! never reached the cache are reported as the run's `dedup_factor`
//! (frontier refs ÷ unique inputs) in `ServeReport`/`ShardReport`; the
//! cooperative sampler (`sampler=labor`) exists to raise it by making
//! co-batched requests sample the *same* sources.

use std::sync::Mutex;

use crate::cachesim::SetAssocCore;

/// Geometry of one [`ShardedFeatureCache`].
#[derive(Clone, Debug)]
pub struct FeatureCacheConfig {
    /// Total feature rows cached across all shards.
    pub rows: usize,
    /// Mutex-striped shards within the cache (concurrency, not device
    /// shards).
    pub shards: usize,
    /// Associativity within a shard (clamped to the shard's rows; a
    /// shard with `ways == rows` is fully associative = exact LRU).
    pub ways: usize,
    /// Floats per cached feature row.
    pub feat_dim: usize,
}

impl FeatureCacheConfig {
    /// Serving default: cache ~1/8 of the table in 8 shards, 8-way.
    pub fn for_dataset(n: usize, feat_dim: usize) -> FeatureCacheConfig {
        FeatureCacheConfig {
            rows: (n / 8).max(64),
            shards: 8,
            ways: 8,
            feat_dim,
        }
    }
}

struct Shard {
    core: SetAssocCore,
    /// `slots * feat_dim` payload, indexed by the core's slot ids.
    slab: Vec<f32>,
    /// Feature version each slot was staged at, same indexing.
    ver: Vec<u64>,
    hits: u64,
    misses: u64,
    stale_hits: u64,
    /// Independent fetch counter (the accounting-invariant witness).
    lookups: u64,
}

/// Outcome of one versioned fetch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fetched {
    /// Served from the cache slab at the requested version.
    Hit,
    /// The node was cached at an older feature version: refreshed from
    /// `src`, counted as `stale_hits`.
    Stale,
    /// Not cached: installed from `src`.
    Miss,
}

/// Aggregated fetch counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Fetches served from the cache slab at the current version.
    pub hits: u64,
    /// Fetches that fell through to the feature table.
    pub misses: u64,
    /// Fetches that found the node cached at an older feature version
    /// (treated as misses: refreshed in place).
    pub stale_hits: u64,
    /// Total fetches, counted independently — must always equal
    /// `hits + misses + stale_hits`.
    pub lookups: u64,
}

impl CacheStats {
    /// hits / lookups; 0 when nothing was fetched.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }
}

/// Mutex-striped set-associative feature-row cache (see module docs).
pub struct ShardedFeatureCache {
    shards: Vec<Mutex<Shard>>,
    feat_dim: usize,
}

impl ShardedFeatureCache {
    /// Geometry is rounded *up* to whole sets, so the effective
    /// capacity is ≥ `cfg.rows` (never silently below the knob);
    /// [`ShardedFeatureCache::rows`] reports the exact figure.
    pub fn new(cfg: &FeatureCacheConfig) -> ShardedFeatureCache {
        let n_shards = cfg.shards.max(1);
        let rows_per_shard = cfg.rows.div_ceil(n_shards).max(1);
        let ways = cfg.ways.clamp(1, rows_per_shard);
        let sets = rows_per_shard.div_ceil(ways);
        let shards = (0..n_shards)
            .map(|_| {
                let core = SetAssocCore::new(sets, ways);
                let slab = vec![0f32; core.slots() * cfg.feat_dim];
                let ver = vec![0u64; core.slots()];
                Mutex::new(Shard {
                    core,
                    slab,
                    ver,
                    hits: 0,
                    misses: 0,
                    stale_hits: 0,
                    lookups: 0,
                })
            })
            .collect();
        ShardedFeatureCache { shards, feat_dim: cfg.feat_dim }
    }

    /// Floats per cached row.
    pub fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    /// Effective total capacity in feature rows (all shards).
    pub fn rows(&self) -> usize {
        self.shards.len() * self.shards[0].lock().unwrap().core.slots()
    }

    /// Mutex-striped shard count.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Exact per-stripe core geometry `(stripes, sets, ways)` — what an
    /// offline replay needs to rebuild this cache's behavior with fresh
    /// [`SetAssocCore`]s (node → stripe is `node % stripes`, the same
    /// routing as [`ShardedFeatureCache::fetch`]). The locality
    /// observatory's cross-check leans on this (see
    /// [`crate::obs::locality`]).
    pub fn geometry(&self) -> (usize, usize, usize) {
        let g = self.shards[0].lock().unwrap();
        (self.shards.len(), g.core.sets(), g.core.ways())
    }

    #[inline]
    fn shard_of(&self, node: u32) -> usize {
        node as usize % self.shards.len()
    }

    /// Fetch `node`'s feature row into `dst` (frozen-table path:
    /// version 0 everywhere, never stale). Returns whether it hit.
    pub fn fetch(&self, node: u32, src: &[f32], dst: &mut [f32]) -> bool {
        self.fetch_versioned(node, 0, src, dst) == Fetched::Hit
    }

    /// Versioned fetch: serve `node`'s row from the slab only if it
    /// was staged at `version`; a cached row at an *older* version is
    /// a stale hit — refreshed from `src` (the authoritative row for
    /// `version`) and counted separately. On a miss `src` is installed
    /// tagged with `version`.
    ///
    /// A reader that raced a rewrite can arrive with an *older*
    /// version than the slot holds; it is served its own (consistent)
    /// `src` and counted stale, but the newer cached row is **not**
    /// downgraded — slot versions only move forward.
    pub fn fetch_versioned(
        &self,
        node: u32,
        version: u64,
        src: &[f32],
        dst: &mut [f32],
    ) -> Fetched {
        let f = self.feat_dim;
        debug_assert_eq!(src.len(), f);
        debug_assert_eq!(dst.len(), f);
        let mut sh = self.shards[self.shard_of(node)].lock().unwrap();
        sh.lookups += 1;
        let p = sh.core.probe(node as u64);
        let off = p.slot * f;
        if p.hit && sh.ver[p.slot] == version {
            sh.hits += 1;
            dst.copy_from_slice(&sh.slab[off..off + f]);
            return Fetched::Hit;
        }
        let outcome = if p.hit {
            sh.stale_hits += 1;
            Fetched::Stale
        } else {
            sh.misses += 1;
            Fetched::Miss
        };
        if !p.hit || sh.ver[p.slot] < version {
            sh.ver[p.slot] = version;
            sh.slab[off..off + f].copy_from_slice(src);
        }
        dst.copy_from_slice(src);
        outcome
    }

    /// Aggregate fetch counters over all shards.
    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for sh in &self.shards {
            let g = sh.lock().unwrap();
            s.hits += g.hits;
            s.misses += g.misses;
            s.stale_hits += g.stale_hits;
            s.lookups += g.lookups;
        }
        s
    }

    /// Zero the fetch counters (contents stay cached).
    pub fn reset_counters(&self) {
        for sh in &self.shards {
            let mut g = sh.lock().unwrap();
            g.hits = 0;
            g.misses = 0;
            g.stale_hits = 0;
            g.lookups = 0;
        }
    }

    /// Drop every cached row (counters are kept): subsequent fetches
    /// miss and restage. Used when a full community relabel rebuilds
    /// the shard plan — per-shard ownership changes wholesale, so the
    /// resident rows no longer match the communities the shard serves.
    pub fn invalidate_all(&self) {
        for sh in &self.shards {
            let mut g = sh.lock().unwrap();
            let (sets, ways) = (g.core.sets(), g.core.ways());
            g.core = SetAssocCore::new(sets, ways);
            for v in g.ver.iter_mut() {
                *v = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::SoftwareCache;
    use crate::util::rng::Rng;

    fn table(n: usize, f: usize) -> Vec<f32> {
        (0..n * f).map(|i| i as f32).collect()
    }

    fn row(t: &[f32], v: u32, f: usize) -> &[f32] {
        &t[v as usize * f..(v as usize + 1) * f]
    }

    #[test]
    fn hit_returns_cached_row_contents() {
        let f = 8;
        let t = table(100, f);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 32,
            shards: 4,
            ways: 8,
            feat_dim: f,
        });
        let mut dst = vec![0f32; f];
        assert!(!cache.fetch(5, row(&t, 5, f), &mut dst));
        assert_eq!(dst, row(&t, 5, f));
        let mut dst2 = vec![0f32; f];
        assert!(cache.fetch(5, row(&t, 5, f), &mut dst2));
        assert_eq!(dst2, row(&t, 5, f));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    /// Acceptance check: with fully-associative shards, hit/miss
    /// accounting matches a reference single-shard exact-LRU
    /// ([`SoftwareCache`]) replayed per shard, request by request.
    #[test]
    fn sharded_accounting_matches_reference_lru() {
        let f = 4;
        let n = 500usize;
        let shards = 4usize;
        let rows_per_shard = 16usize;
        let t = table(n, f);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: shards * rows_per_shard,
            shards,
            ways: rows_per_shard, // fully associative per shard
            feat_dim: f,
        });
        let mut reference: Vec<SoftwareCache> = (0..shards)
            .map(|_| SoftwareCache::new(rows_per_shard, n))
            .collect();
        let mut rng = Rng::new(42);
        let mut dst = vec![0f32; f];
        for step in 0..20_000 {
            // skewed stream with locality bursts
            let v = if step % 3 == 0 {
                rng.usize_below(32) as u32
            } else {
                rng.usize_below(n) as u32
            };
            let want = reference[v as usize % shards].access(v);
            let got = cache.fetch(v, row(&t, v, f), &mut dst);
            assert_eq!(got, want, "step {step} node {v}");
            assert_eq!(dst, row(&t, v, f), "payload corrupt at node {v}");
        }
        let s = cache.stats();
        let ref_hits: u64 = reference.iter().map(|c| c.hits).sum();
        let ref_misses: u64 = reference.iter().map(|c| c.misses).sum();
        assert_eq!((s.hits, s.misses), (ref_hits, ref_misses));
        assert!(s.hits > 0 && s.misses > 0);
    }

    #[test]
    fn concurrent_fetches_are_consistent() {
        let f = 8;
        let n = 256usize;
        let t = table(n, f);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 64,
            shards: 8,
            ways: 8,
            feat_dim: f,
        });
        std::thread::scope(|s| {
            for tid in 0..4u64 {
                let cache = &cache;
                let t = &t;
                s.spawn(move || {
                    let mut rng = Rng::new(tid);
                    let mut dst = vec![0f32; f];
                    for _ in 0..5_000 {
                        let v = rng.usize_below(n) as u32;
                        cache.fetch(v, row(t, v, f), &mut dst);
                        assert_eq!(dst, row(t, v, f));
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, 20_000);
    }

    #[test]
    fn capacity_rounds_up_not_down() {
        // 100 rows over 8 shards doesn't divide evenly; geometry must
        // never deliver less capacity than the knob requested
        let c = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 100,
            shards: 8,
            ways: 8,
            feat_dim: 2,
        });
        assert!(c.rows() >= 100, "effective {} < requested 100", c.rows());
    }

    /// A feature-version bump turns the cached row stale: the next
    /// fetch refreshes it (counted as `stale_hits`), after which the
    /// new version hits normally — and the accounting invariant
    /// `hits + misses + stale_hits == lookups` holds throughout.
    #[test]
    fn version_bump_invalidates_cached_row() {
        let f = 4;
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 16,
            shards: 2,
            ways: 8,
            feat_dim: f,
        });
        let old_row = vec![1.0f32; f];
        let new_row = vec![2.0f32; f];
        let mut dst = vec![0f32; f];
        assert_eq!(cache.fetch_versioned(7, 0, &old_row, &mut dst), Fetched::Miss);
        assert_eq!(cache.fetch_versioned(7, 0, &old_row, &mut dst), Fetched::Hit);
        assert_eq!(dst, old_row);
        // rewrite lands: version 3 — cached copy must not be served
        assert_eq!(
            cache.fetch_versioned(7, 3, &new_row, &mut dst),
            Fetched::Stale
        );
        assert_eq!(dst, new_row, "stale fetch must serve the fresh row");
        assert_eq!(cache.fetch_versioned(7, 3, &new_row, &mut dst), Fetched::Hit);
        assert_eq!(dst, new_row);
        // a racing reader with an OLD version is served its own row
        // but must not downgrade the newer cached copy
        assert_eq!(
            cache.fetch_versioned(7, 0, &old_row, &mut dst),
            Fetched::Stale
        );
        assert_eq!(dst, old_row, "old-version reader sees its own view");
        assert_eq!(
            cache.fetch_versioned(7, 3, &new_row, &mut dst),
            Fetched::Hit,
            "slot version must not move backwards"
        );
        assert_eq!(dst, new_row);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.stale_hits), (3, 1, 2));
        assert_eq!(s.lookups, s.hits + s.misses + s.stale_hits);
    }

    #[test]
    fn invalidate_all_drops_rows_but_keeps_counters() {
        let f = 2;
        let t = table(10, f);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 8,
            shards: 2,
            ways: 4,
            feat_dim: f,
        });
        let mut dst = vec![0f32; f];
        cache.fetch(1, row(&t, 1, f), &mut dst);
        assert!(cache.fetch(1, row(&t, 1, f), &mut dst), "warm hit");
        cache.invalidate_all();
        assert!(
            !cache.fetch(1, row(&t, 1, f), &mut dst),
            "flushed row must miss"
        );
        assert_eq!(dst, row(&t, 1, f));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 2), "counters survive the flush");
        assert_eq!(s.lookups, 3);
    }

    /// Concurrent versioned fetches keep the invariant exact.
    #[test]
    fn concurrent_versioned_accounting_invariant() {
        let f = 4;
        let n = 128usize;
        let t = table(n, f);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 32,
            shards: 4,
            ways: 8,
            feat_dim: f,
        });
        std::thread::scope(|s| {
            for tid in 0..4u64 {
                let cache = &cache;
                let t = &t;
                s.spawn(move || {
                    let mut rng = Rng::new(tid ^ 0xF00D);
                    let mut dst = vec![0f32; f];
                    for _ in 0..2_500 {
                        let v = rng.usize_below(n) as u32;
                        let ver = rng.below(3); // churn the version tag
                        cache.fetch_versioned(v, ver, row(t, v, f), &mut dst);
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.lookups, 10_000);
        assert_eq!(s.lookups, s.hits + s.misses + s.stale_hits);
        assert!(s.stale_hits > 0, "version churn must produce stale hits");
    }

    #[test]
    fn reset_counters_clears_stats() {
        let f = 2;
        let t = table(10, f);
        let cache = ShardedFeatureCache::new(&FeatureCacheConfig {
            rows: 8,
            shards: 2,
            ways: 4,
            feat_dim: f,
        });
        let mut dst = vec![0f32; f];
        cache.fetch(1, row(&t, 1, f), &mut dst);
        cache.reset_counters();
        assert_eq!(cache.stats(), CacheStats::default());
    }
}
